"""The analyzer's own tests: each rule fires exactly once on a seeded
fixture violation, the real tree is clean, and the PR-6 bug class
(unlocked delete loop) is re-introduced by mutation and caught."""

import json
import textwrap

import pytest

from repro.analysis import (Finding, load_baseline, new_findings,
                            run_analysis, save_baseline)
from repro.analysis import apicheck, backendcheck, kernelcheck, locksafety


def rules_of(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# lock-discipline pass
# ---------------------------------------------------------------------------

LOCK_FIXTURE = textwrap.dedent("""
    import threading

    class Writer:
        def __init__(self):
            self._lock = threading.RLock()
            self._segments = ()   # guarded-by: _lock
            self._buffered = 0    # guarded-by: _lock

        def ok(self):
            with self._lock:
                self._buffered += 1
                return self._segments

        def bad_write(self):
            self._buffered += 1
""")


def test_lock_unguarded_write_fires_once():
    findings = locksafety.check_source("fix.py", LOCK_FIXTURE)
    assert rules_of(findings) == ["lock/unguarded-write"]
    (f,) = findings
    assert "_buffered" in f.message and f.path == "fix.py"


def test_lock_unguarded_read_fires_once():
    src = LOCK_FIXTURE + textwrap.dedent("""
        def peek(self):
            return self._segments
    """).replace("\n", "\n    ")  # indent into the class body
    findings = locksafety.check_source("fix.py", src)
    assert rules_of(findings) == ["lock/unguarded-write",
                                  "lock/unguarded-read"]


def test_lock_suppression_and_holds_lock():
    src = textwrap.dedent("""
        class W:
            def __init__(self):
                self._lock = object()
                self._state = {}  # guarded-by: _lock

            def racy_but_ok(self):
                return self._state  # analysis-ok: lock/unguarded-read snapshot

            def helper(self):  # holds-lock: _lock
                self._state["k"] = 1
    """)
    assert locksafety.check_source("fix.py", src) == []


def test_lock_nested_function_loses_lock():
    src = textwrap.dedent("""
        class W:
            def __init__(self):
                self._lock = object()
                self._state = {}  # guarded-by: _lock

            def spawn(self):
                with self._lock:
                    def worker():
                        return self._state
                    return worker
    """)
    findings = locksafety.check_source("fix.py", src)
    assert rules_of(findings) == ["lock/unguarded-read"]


def test_module_level_guard():
    src = textwrap.dedent("""
        import threading
        _pending = []  # guarded-by: _pending_lock
        _pending_lock = threading.Lock()

        def good():
            with _pending_lock:
                _pending.append(1)

        def bad():
            _pending.append(1)
    """)
    findings = locksafety.check_source("fix.py", src)
    assert rules_of(findings) == ["lock/unguarded-read"]
    assert "_pending" in findings[0].message


def test_pr6_style_unlocked_delete_loop_is_flagged():
    """Re-introduce the PR-6 bug class: strip every `with self._lock:`
    from the real lifecycle module and the lock pass must flag the
    delete loop's `_segments` traversal (among others)."""
    with open("src/repro/core/lifecycle.py") as fh:
        src = fh.read()
    assert "with self._lock:" in src
    mutated = src.replace("with self._lock:", "if True:  # lock removed")
    findings = locksafety.check_source("lifecycle.py", mutated)
    assert any(f.rule.startswith("lock/") and "_segments" in f.message
               for f in findings)
    assert any(f.rule == "lock/unguarded-write" for f in findings)
    # ... and the unmutated module is clean
    assert locksafety.check_source("lifecycle.py", src) == []


# ---------------------------------------------------------------------------
# backend-exhaustiveness pass
# ---------------------------------------------------------------------------

BACKEND_FIXTURE = textwrap.dedent("""
    PLAN_NODE_KINDS = ("leaf", "not", "fold")

    def build(i):
        return ("fold", ("and",), (("leaf", i), ("not", ("leaf", i))))

    def register_backend(name):
        def deco(cls):
            return cls
        return deco

    @register_backend("good")
    class GoodBackend:
        def run(self, node):
            if node[0] == "leaf":
                return 1
            if node[0] == "not":
                return 2
            if node[0] != "fold":
                raise ValueError(node[0])
            return 3

    @register_backend("partial")
    class MissingFold:
        def run(self, node):
            if node[0] in ("leaf", "not"):
                return 0
            raise ValueError(node[0])
""")


def test_backend_missing_dispatch_arm_fires_once():
    findings = backendcheck.check_sources({"fix.py": BACKEND_FIXTURE})
    assert rules_of(findings) == ["backend/missing-kind"]
    (f,) = findings
    assert f.detail == "MissingFold:fold"


def test_backend_undeclared_kind():
    src = BACKEND_FIXTURE.replace(
        'PLAN_NODE_KINDS = ("leaf", "not", "fold")',
        'PLAN_NODE_KINDS = ("leaf", "not", "fold", "xor")')
    src += textwrap.dedent("""
        def sneak(c):
            return ("shiny", (c,))
    """)
    findings = backendcheck.check_sources({"fix.py": src})
    by_rule = {f.rule: f for f in findings}
    assert by_rule["backend/undeclared-kind"].detail == "shiny"
    # "xor" declared but dispatched nowhere -> both backends flagged
    missing = [f.detail for f in findings
               if f.rule == "backend/missing-kind"]
    assert set(missing) == {"GoodBackend:xor", "MissingFold:xor",
                            "MissingFold:fold"}


def test_backend_missing_declaration():
    findings = backendcheck.check_sources({"fix.py": "x = 1\n"})
    assert rules_of(findings) == ["backend/missing-declaration"]


def test_backend_real_tree_exhaustive():
    findings = backendcheck.check_files(
        ["src/repro/core/query.py", "src/repro/core/encodings.py"])
    assert findings == []


# ---------------------------------------------------------------------------
# kernel pass
# ---------------------------------------------------------------------------

KERNEL_FIXTURE = textwrap.dedent("""
    import jax.numpy as jnp

    def good_kernel(x_ref, o_ref):
        v = x_ref[...]
        o_ref[...] = jnp.where(v > 0, v, 0)

    def bad_kernel(x_ref, o_ref):
        v = x_ref[0, 0]
        if v > 0:
            o_ref[...] = v
""")


def test_kernel_traced_branch_fires_once():
    findings = kernelcheck.check_source("fix.py", KERNEL_FIXTURE)
    assert rules_of(findings) == ["kernel/traced-branch"]
    (f,) = findings
    assert "bad_kernel" in f.detail and "v" in f.detail


def test_kernel_host_callback():
    src = textwrap.dedent("""
        def chatty_kernel(x_ref, o_ref):
            print("step")
            o_ref[...] = x_ref[...]
    """)
    findings = kernelcheck.check_source("fix.py", src)
    assert rules_of(findings) == ["kernel/host-callback"]


def test_kernel_nonstatic_grid():
    src = textwrap.dedent("""
        import jax.numpy as jnp
        import jax.experimental.pallas as pl

        def launch(x):
            grid = (jnp.ceil(x.shape[0] / 8),)
            return pl.pallas_call(lambda r, o: None, grid=grid)(x)
    """)
    findings = kernelcheck.check_source("fix.py", src)
    assert rules_of(findings) == ["kernel/nonstatic-grid"]


def test_kernel_ceil_div_nested_flagged_two_step_clean():
    nested = "rows_p = -(-(-(-n // lanes)) // RT) * RT\n"
    findings = kernelcheck.check_source("fix.py", nested)
    assert rules_of(findings) == ["kernel/ceil-div"]
    two_step = "rows = -(-n // lanes)\nrows_p = -(-rows // RT) * RT\n"
    assert kernelcheck.check_source("fix.py", two_step) == []


def test_kernel_static_kwonly_param_not_tainted():
    src = textwrap.dedent("""
        def k(x_ref, o_ref, *, flip):
            if flip:
                o_ref[...] = ~x_ref[...]
            else:
                o_ref[...] = x_ref[...]
    """)
    assert kernelcheck.check_source("fix.py", src) == []


def test_kernel_static_tape_interpreter_clean():
    """The planfuse megakernel pattern: branching on a keyword-only
    static instruction tape inside a loop is compile-time unrolling, not
    a traced branch — the pass must stay quiet."""
    src = textwrap.dedent("""
        import jax.numpy as jnp

        def tape_kernel(x_ref, o_ref, *, tape):
            stack = []
            for opcode, arg in tape:
                if opcode == 0:
                    stack.append(x_ref[arg])
                elif opcode == 1:
                    stack.append(stack.pop() ^ jnp.uint32(0xFFFFFFFF))
                else:
                    b = stack.pop()
                    stack.append(stack.pop() & b)
            o_ref[...] = stack.pop()
    """)
    assert kernelcheck.check_source("fix.py", src) == []


def test_kernel_traced_tape_still_flagged():
    """Counter-fixture: the same interpreter shape but with the opcode
    READ FROM A REF (a traced value) must keep firing."""
    src = textwrap.dedent("""
        def tape_kernel(x_ref, t_ref, o_ref):
            opcode = t_ref[0]
            if opcode == 0:
                o_ref[...] = x_ref[...]
    """)
    findings = kernelcheck.check_source("fix.py", src)
    assert "kernel/traced-branch" in rules_of(findings)


# ---------------------------------------------------------------------------
# api pass
# ---------------------------------------------------------------------------

def test_api_deprecated_shim_fires_once():
    src = textwrap.dedent("""
        import warnings

        def search(*args, **kwargs):
            warnings.warn("legacy", DeprecationWarning, stacklevel=2)
    """)
    findings = apicheck.check_deprecated_shims("fix.py", src)
    assert rules_of(findings) == ["api/deprecated-shim"]
    # a comment mentioning the class is NOT a resurrection
    assert apicheck.check_deprecated_shims(
        "fix.py", "# DeprecationWarning was removed here\n") == []


def test_api_unseeded_random_fires_in_string_literals():
    src = 'SCRIPT = r"""\nx = np.random.randint(0, 10, 4)\n"""\n'  # analysis-ok: api/unseeded-random fixture input
    findings = apicheck.check_unseeded_random("fix.py", src)
    assert rules_of(findings) == ["api/unseeded-random"]
    seeded = "rng = np.random.default_rng(0)\nx = rng.integers(0, 10)\n"
    assert apicheck.check_unseeded_random("fix.py", seeded) == []


# ---------------------------------------------------------------------------
# whole-tree run + baseline protocol
# ---------------------------------------------------------------------------

def test_clean_tree_zero_findings():
    assert run_analysis(".") == []


def test_baseline_roundtrip_and_new_finding_detection(tmp_path):
    old = [Finding("lock/unguarded-read", "a.py", 10, "m", "W:_x:read"),
           Finding("lock/unguarded-read", "a.py", 44, "m", "W:_x:read")]
    path = tmp_path / "baseline.json"
    save_baseline(path, old)
    baseline = load_baseline(path)
    assert sum(baseline.values()) == 2
    # same findings at shifted lines stay suppressed; a third is new
    drifted = [Finding("lock/unguarded-read", "a.py", 12, "m", "W:_x:read"),
               Finding("lock/unguarded-read", "a.py", 46, "m", "W:_x:read")]
    assert new_findings(drifted, baseline) == []
    extra = drifted + [Finding("lock/unguarded-write", "a.py", 50, "m",
                               "W:_y:write")]
    fresh = new_findings(extra, baseline)
    assert [f.rule for f in fresh] == ["lock/unguarded-write"]
    assert json.loads(path.read_text())  # file is real JSON


def test_cli_clean_and_list_rules(capsys):
    from repro.analysis.__main__ import main

    assert main(["--root", "."]) == 0
    assert "clean" in capsys.readouterr().out
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    assert "lock/unguarded-write" in out and "kernel/traced-branch" in out


def test_cli_flags_new_finding(tmp_path, capsys):
    bad = tmp_path / "src" / "repro" / "kernels"
    bad.mkdir(parents=True)
    (tmp_path / "src" / "repro" / "core").mkdir()
    (bad / "k.py").write_text(
        "def k(x_ref, o_ref):\n"
        "    v = x_ref[0]\n"
        "    if v:\n"
        "        o_ref[0] = v\n")
    (tmp_path / "src" / "repro" / "core" / "query.py").write_text(
        'PLAN_NODE_KINDS = ()\n')
    from repro.analysis.__main__ import main

    assert main(["--root", str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "kernel/traced-branch" in out
    # baselining the violation makes the run clean again
    base = tmp_path / "b.json"
    assert main(["--root", str(tmp_path), "--baseline", str(base),
                 "--update-baseline"]) == 0
    assert main(["--root", str(tmp_path), "--baseline", str(base)]) == 0
