"""Streaming compressed-domain AND-popcount: correctness + complexity."""

import jax.numpy as jnp
import numpy as np
import pytest

from helpers import random_words
from repro.core import ewah
from repro.core.ewah_stream import and_popcount


def run_case(a_words, b_words):
    ca, cb = ewah.compress(a_words), ewah.compress(b_words)
    count, iters = and_popcount(
        jnp.asarray(ca), len(ca), jnp.asarray(cb), len(cb))
    expect = int(np.bitwise_count(a_words & b_words).sum())
    return int(count), int(iters), expect, len(ca), len(cb)


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("n", [10, 100, 1000])
def test_matches_oracle(seed, n):
    a = random_words(n, seed=seed)
    b = random_words(n, seed=seed + 77)
    count, iters, expect, la, lb = run_case(a, b)
    assert count == expect
    assert iters <= la + lb + 4  # the paper's O(|A| + |B|) claim


def test_sparse_streams_iterate_compressed_not_raw():
    """Two sparse bitmaps over 100k words: iterations ~ compressed sizes
    (tens), nowhere near the 100k uncompressed words."""
    n = 100_000
    a = np.zeros(n, dtype=np.uint32)
    b = np.zeros(n, dtype=np.uint32)
    a[5000:5010] = 0xDEADBEEF
    b[5005:5020] = 0xFFFFFFFF
    count, iters, expect, la, lb = run_case(a, b)
    assert count == expect
    assert iters <= la + lb + 4 < 100  # compressed-domain skip
    assert iters < n // 1000


def test_all_ones_overlap():
    n = 320
    a = np.full(n, 0xFFFFFFFF, dtype=np.uint32)
    b = np.full(n, 0xFFFFFFFF, dtype=np.uint32)
    count, iters, expect, *_ = run_case(a, b)
    assert count == expect == n * 32
    assert iters <= 4


def test_disjoint_is_zero():
    a = ewah.positions_to_words(np.arange(0, 1000, 2), 1000)
    b = ewah.positions_to_words(np.arange(1, 1000, 2), 1000)
    count, _, expect, *_ = run_case(a, b)
    assert count == expect == 0
