"""The public stream engine: cursor/appender edge cases, EwahStream, and
the in-graph AND-popcount (correctness + complexity)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from helpers import random_words
from repro.core import ewah
from repro.core.ewah_stream import (Appender, Cursor, EwahStream,
                                    and_popcount, concat_streams)


def run_case(a_words, b_words):
    ca, cb = ewah.compress(a_words), ewah.compress(b_words)
    count, iters = and_popcount(
        jnp.asarray(ca), len(ca), jnp.asarray(cb), len(cb))
    expect = int(np.bitwise_count(a_words & b_words).sum())
    return int(count), int(iters), expect, len(ca), len(cb)


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("n", [10, 100, 1000])
def test_matches_oracle(seed, n):
    a = random_words(n, seed=seed)
    b = random_words(n, seed=seed + 77)
    count, iters, expect, la, lb = run_case(a, b)
    assert count == expect
    assert iters <= la + lb + 4  # the paper's O(|A| + |B|) claim


def test_sparse_streams_iterate_compressed_not_raw():
    """Two sparse bitmaps over 100k words: iterations ~ compressed sizes
    (tens), nowhere near the 100k uncompressed words."""
    n = 100_000
    a = np.zeros(n, dtype=np.uint32)
    b = np.zeros(n, dtype=np.uint32)
    a[5000:5010] = 0xDEADBEEF
    b[5005:5020] = 0xFFFFFFFF
    count, iters, expect, la, lb = run_case(a, b)
    assert count == expect
    assert iters <= la + lb + 4 < 100  # compressed-domain skip
    assert iters < n // 1000


def test_all_ones_overlap():
    n = 320
    a = np.full(n, 0xFFFFFFFF, dtype=np.uint32)
    b = np.full(n, 0xFFFFFFFF, dtype=np.uint32)
    count, iters, expect, *_ = run_case(a, b)
    assert count == expect == n * 32
    assert iters <= 4


def test_disjoint_is_zero():
    a = ewah.positions_to_words(np.arange(0, 1000, 2), 1000)
    b = ewah.positions_to_words(np.arange(1, 1000, 2), 1000)
    count, _, expect, *_ = run_case(a, b)
    assert count == expect == 0


# ---------------------------------------------------------------------------
# Public cursor / appender API
# ---------------------------------------------------------------------------


def cursor_decompress(stream):
    """Expand a stream by walking the public cursor (no ewah.decompress)."""
    out = []
    cur = Cursor(stream)
    while not cur.exhausted():
        if cur.clean_rem:
            n = cur.clean_rem
            out.extend([0xFFFFFFFF if cur.ctype else 0] * n)
            cur.take_clean(n)
        else:
            out.append(cur.take_dirty())
    return np.asarray(out, dtype=np.uint32)


def test_empty_stream_cursor_and_appender():
    empty = ewah.compress(np.zeros(0, dtype=np.uint32))
    assert Cursor(empty).exhausted()
    assert len(cursor_decompress(empty)) == 0
    # an appender fed nothing still emits a decodable (empty) stream
    finished = Appender().finish()
    assert len(ewah.decompress(finished)) == 0
    assert Cursor(finished).exhausted()


@pytest.mark.parametrize("n_clean", [ewah.MAX_CLEAN - 1, ewah.MAX_CLEAN,
                                     ewah.MAX_CLEAN + 1, 2 * ewah.MAX_CLEAN + 3])
@pytest.mark.parametrize("ctype", [0, 1])
def test_clean_run_at_marker_capacity(n_clean, ctype):
    """Clean runs at exactly the 2^16-1 per-marker capacity (and straddling
    it) survive appender emit + cursor walk."""
    app = Appender()
    app.add_clean(ctype, n_clean)
    app.add_word(0xDEADBEEF)
    stream = app.finish()
    cur = Cursor(stream)
    seen = 0
    while cur.clean_rem:
        assert cur.ctype == ctype
        n = cur.clean_rem
        seen += n
        cur.take_clean(n)
    assert seen == n_clean
    assert cur.take_dirty() == 0xDEADBEEF
    assert cur.exhausted()


@pytest.mark.parametrize("n_dirty", [ewah.MAX_DIRTY - 1, ewah.MAX_DIRTY,
                                     ewah.MAX_DIRTY + 1])
def test_dirty_run_at_marker_capacity(n_dirty):
    """Dirty runs at exactly the 2^15-1 per-marker capacity split across
    continuation markers and read back intact."""
    words = (np.arange(n_dirty, dtype=np.uint32) % 0xFFFFFFFE) + 1
    stream = ewah.compress(words)
    np.testing.assert_array_equal(cursor_decompress(stream), words)
    # appender round-trip through the cursor reproduces the same stream
    app = Appender()
    app.add_cursor(Cursor(stream))
    np.testing.assert_array_equal(app.finish(), stream)


def test_appender_coalesces_adjacent_clean_runs():
    app = Appender()
    app.add_clean(1, 10)
    app.add_clean(1, 5)          # same type: one run
    app.add_word(0xFFFFFFFF)     # clean-typed word joins the run too
    stream = app.finish()
    assert len(stream) == 1      # a single marker encodes all 16 words
    _, n_clean, n_dirty = ewah.unpack_marker(stream[0])
    assert (n_clean, n_dirty) == (16, 0)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 500), st.integers(0, 60))
def test_roundtrip_through_cursor_api(n, seed):
    """compress . decompress round-trips through the public cursor API:
    walking the compressed runs reproduces the words, and re-appending
    them reproduces the stream."""
    words = random_words(n, seed=seed)
    stream = ewah.compress(words)
    np.testing.assert_array_equal(cursor_decompress(stream), words)
    app = Appender()
    app.add_cursor(Cursor(stream))
    rebuilt = app.finish()
    np.testing.assert_array_equal(rebuilt, stream)
    assert app.n_words == n


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 300), st.integers(0, 40), st.integers(1, 5))
def test_concat_streams_equals_whole(n, seed, parts):
    """Compressing word-aligned pieces and concatenating with clean-run
    coalescing equals compressing the whole (the shard merge protocol)."""
    words = random_words(n, seed=seed)
    cuts = sorted({0, n, *(int(x) for x in
                           np.linspace(0, n, parts + 1)[1:-1])})
    pieces = [ewah.compress(words[a:b]) for a, b in zip(cuts, cuts[1:])]
    merged = concat_streams(pieces)
    np.testing.assert_array_equal(merged, ewah.compress(words))


def test_ewah_stream_value_object():
    bits = np.zeros(100, dtype=bool)
    bits[[0, 31, 32, 64, 99]] = True
    stream = EwahStream(ewah.compress(ewah.pack_bits(bits)), n_rows=100)
    assert stream.n_words == 4
    np.testing.assert_array_equal(stream.to_rows(), [0, 31, 32, 64, 99])
    assert stream.count() == 5
    np.testing.assert_array_equal(stream.to_bits(), bits)


def test_ewah_stream_equality_and_hash_by_content():
    words = random_words(40, seed=9)
    a = EwahStream(ewah.compress(words), n_rows=1280, words_scanned=3)
    b = EwahStream(ewah.compress(words.copy()), n_rows=1280, words_scanned=7)
    c = EwahStream(ewah.compress(np.zeros(40, np.uint32)), n_rows=1280)
    assert a == b                       # words_scanned is not identity
    assert hash(a) == hash(b)
    assert a != c and a != "not a stream"
    assert len({a, b, c}) == 2          # usable as dict/set keys


# ---------------------------------------------------------------------------
# Wire codec: versioned header + CRC (what the serve plane ships)
# ---------------------------------------------------------------------------


from repro.core.ewah_stream import EwahValidationError  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 400), st.integers(0, 50))
def test_wire_roundtrip(n, seed):
    words = random_words(n, seed=seed)
    stream = EwahStream(ewah.compress(words), n_rows=n * 32,
                        words_scanned=7)
    back = EwahStream.from_bytes(stream.to_bytes())
    assert back == stream            # content equality (data + n_rows)
    assert back.data.dtype == np.uint32


def test_wire_zero_row_stream():
    empty = EwahStream(ewah.compress(np.zeros(0, dtype=np.uint32)), 0)
    back = EwahStream.from_bytes(empty.to_bytes())
    assert back.n_rows == 0 and back.n_words == 0
    assert back.count() == 0


def test_wire_rejects_corruption():
    stream = EwahStream(ewah.compress(random_words(20, seed=1)), 640)
    blob = bytearray(stream.to_bytes())
    blob[-3] ^= 0xFF                 # flip a payload byte
    with pytest.raises(EwahValidationError, match="CRC"):
        EwahStream.from_bytes(bytes(blob))


def test_wire_rejects_truncation_and_bad_header():
    stream = EwahStream(ewah.compress(random_words(8, seed=2)), 256)
    blob = stream.to_bytes()
    with pytest.raises(EwahValidationError, match="claims"):
        EwahStream.from_bytes(blob[:-2])      # payload shorter than header says
    with pytest.raises(EwahValidationError, match="truncated"):
        EwahStream.from_bytes(blob[:10])      # cut inside the header itself
    with pytest.raises(EwahValidationError, match="magic"):
        EwahStream.from_bytes(b"XXXX" + blob[4:])
    bad_version = bytearray(blob)
    bad_version[4] = 0xEE            # version field, little-endian u16
    with pytest.raises(EwahValidationError, match="version"):
        EwahStream.from_bytes(bytes(bad_version))


def test_wire_sanitize_validates_stream_structure(monkeypatch):
    """Under REPRO_SANITIZE a structurally-broken (but CRC-consistent)
    stream is rejected at deserialization, not at first use."""
    import repro.core.ewah_stream as es

    monkeypatch.setenv("REPRO_SANITIZE", "1")
    # n_rows far smaller than the words the stream encodes
    stream = EwahStream(ewah.compress(random_words(10, seed=3)), 320)
    blob = stream.to_bytes()
    hacked = bytearray(blob)
    hacked[8:16] = (5).to_bytes(8, "little")  # claim n_rows=5
    with pytest.raises(EwahValidationError):
        es.EwahStream.from_bytes(bytes(hacked))
