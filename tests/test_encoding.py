"""k-of-N encodings, Proposition 1, Gray comparators."""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import encoding


@pytest.mark.parametrize("k", [1, 2, 3, 4])
@pytest.mark.parametrize("n_values", [1, 2, 5, 100, 2000, 100_000])
def test_choose_N_minimal(n_values, k):
    N = encoding.choose_N(n_values, k)
    assert math.comb(N, k) >= n_values
    if N > k:
        assert math.comb(N - 1, k) < n_values


def test_choose_N_paper_example():
    # "with only 2,000 bitmaps, we can represent an attribute with 2 million
    # distinct values" via pairs: C(2000, 2) = 1 999 000 ~= 2M
    assert math.comb(2000, 2) == 1_999_000
    assert encoding.choose_N(1_999_000, 2) == 2000


@pytest.mark.parametrize("N,k", [(4, 2), (5, 2), (5, 3), (6, 3), (7, 2), (8, 4), (10, 3)])
def test_prop1_gray_enumeration(N, k):
    """All C(N,k) codes enumerated, successive Hamming distance exactly 2."""
    codes = encoding.gray_kofn_codes(N, k)
    assert codes.shape == (math.comb(N, k), k)
    # all distinct, all valid k-subsets
    as_sets = {tuple(sorted(c)) for c in codes.tolist()}
    assert len(as_sets) == math.comb(N, k)
    h = encoding.hamming_between_successive(codes, N)
    assert (h == 2).all(), h


def test_gray_2of4_matches_paper():
    """Paper §4.2: GC order for 2-of-4 is 1001, 1010, 1100, 0101, 0110, 0011."""
    codes = encoding.gray_kofn_codes(4, 2)
    bits = encoding.codes_to_bits(codes, 4)
    strings = ["".join("1" if b else "0" for b in row) for row in bits]
    assert strings == ["1001", "1010", "1100", "0101", "0110", "0011"]


def test_lex_2of4_matches_paper():
    """Paper §4.2: lex order is 1100, 1010, 1001, 0110, ..."""
    codes = encoding.lex_kofn_codes(4, 2)
    bits = encoding.codes_to_bits(codes, 4)
    strings = ["".join("1" if b else "0" for b in row) for row in bits]
    assert strings == ["1100", "1010", "1001", "0110", "0101", "0011"]


def test_lex_not_hamming_optimal():
    """Paper: 0110 follows 1001 among lex 2-of-4 codes — distance 4."""
    codes = encoding.lex_kofn_codes(4, 2)
    h = encoding.hamming_between_successive(codes, 4)
    assert h.max() == 4


def test_clamp_k():
    assert encoding.clamp_k(4, 4) == 1
    assert encoding.clamp_k(20, 4) == 2
    assert encoding.clamp_k(84, 4) == 3
    assert encoding.clamp_k(85, 4) == 4
    assert encoding.clamp_k(1000, 2) == 2


def test_binary_gray_roundtrip():
    x = np.arange(4096, dtype=np.uint64)
    g = encoding.to_gray(x)
    np.testing.assert_array_equal(encoding.from_gray(g), x)
    # successive Gray codes differ in exactly one bit
    diff = g[1:] ^ g[:-1]
    assert (np.bitwise_count(diff) == 1).all()


def brute_gray_rank(bits):
    """Rank of a bit vector in GC order = from_gray(int of bits)."""
    v = 0
    for b in bits:
        v = (v << 1) | int(b)
    return int(encoding.from_gray(np.uint64(v)))


@settings(max_examples=200, deadline=None)
@given(st.integers(0, 255), st.integers(0, 255))
def test_gray_less_matches_rank(a, b):
    """Algorithm 2 comparator agrees with Gray-code rank comparison."""
    abits = [(a >> (7 - i)) & 1 for i in range(8)]
    bbits = [(b >> (7 - i)) & 1 for i in range(8)]
    apos = [i for i, bit in enumerate(abits) if bit]
    bpos = [i for i, bit in enumerate(bbits) if bit]
    expected = brute_gray_rank(abits) < brute_gray_rank(bbits)
    assert encoding.gray_less(apos, bpos) == expected
