"""Tests for the segmented index lifecycle (repro.core.lifecycle/segment).

Covers the writer state machine (append / seal / close, word-alignment
tail carrying), compaction (explicit spans, the size-tiered policy,
contiguity validation), the segmented query surface (sealed segments +
open buffer, original-row-space ids, both backends), the cache-invalidation
contract (generation scopes, compaction evicts only retired segments'
entries), and a hypothesis property test driving random
append/seal/compact schedules against a monolithic rebuild.
"""

import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analysis.runtime import sanitized
from repro.core import (And, BackgroundCompactor, BitmapIndex, Eq, In,
                        IndexSpec, IndexWriter, Not, Or, Range, Segment,
                        SegmentedIndex, compact, evaluate_mask,
                        size_tiered_pick)
from repro.core.query import (ResultCache, compile_plan, count_merges,
                              get_backend, invalidate_scope, with_live_mask)


def make_table(n, cards, seed):
    r = np.random.default_rng(seed)
    return [r.integers(0, c, size=n) for c in cards]


PREDICATES = [
    Eq(0, 3),
    In(1, [1, 5, 9]),
    Range(1, 2, 8),
    Range(0, 50, 40),                    # empty
    And(Eq(0, 2), Eq(1, 4)),
    Or(Eq(0, 1), Eq(0, 2), Eq(1, 0)),
    Not(Eq(0, 0)),
    And(In(0, [0, 1, 2]), Range(1, 0, 6), Not(Eq(1, 5))),
]


def expected_rows(pred, cols):
    return np.flatnonzero(evaluate_mask(pred, cols))


# -- writer state machine ----------------------------------------------------


def test_seal_carries_unaligned_tail():
    cols = make_table(100, [5, 7], seed=0)
    w = IndexWriter(IndexSpec(k=1, row_order="lex"))
    w.append(cols)
    assert w.buffered_rows == 100
    seg = w.seal()
    assert seg.n_rows == 96 and seg.row_start == 0       # 100 -> 96 + 4
    assert w.buffered_rows == 4 and w.sealed_rows == 96
    assert w.seal() is None                              # < 32 rows buffered
    w.append([c[:60] for c in make_table(60, [5, 7], seed=1)])
    seg2 = w.seal()
    assert seg2.n_rows == 64 and seg2.row_start == 96    # 4 + 60 -> 64 + 0
    final = w.close()
    assert final is None and w.closed                    # buffer was empty


def test_close_seals_everything_and_locks():
    cols = make_table(45, [4], seed=2)
    w = IndexWriter()
    w.append(cols)
    seg = w.close()
    assert seg.n_rows == 45                              # final may be ragged
    assert w.closed
    with pytest.raises(ValueError, match="closed"):
        w.append(cols)
    with pytest.raises(ValueError, match="closed"):
        w.seal()
    with pytest.raises(ValueError, match="closed"):
        w.close()


def test_append_validation():
    w = IndexWriter()
    with pytest.raises(ValueError, match="equal length"):
        w.append([np.arange(5), np.arange(6)])
    w.append([np.arange(5), np.arange(5)])
    with pytest.raises(ValueError, match="columns"):
        w.append([np.arange(5)])                         # column count fixed
    with pytest.raises(ValueError, match="names"):
        w.append({"a": np.arange(5)})                    # dict needs names
    wn = IndexWriter(names=("a", "b"))
    wn.append({"a": np.arange(5), "b": np.arange(5)})
    with pytest.raises(ValueError, match="missing"):
        wn.append({"a": np.arange(5)})


def test_auto_seal_threshold():
    cols = make_table(300, [4, 6], seed=3)
    w = IndexWriter(IndexSpec(), seal_rows=100)
    for i in range(0, 300, 50):
        w.append([c[i : i + 50] for c in cols])
    assert len(w.segments) >= 2
    assert all(s.n_rows % 32 == 0 for s in w.segments)
    assert w.n_rows == 300


def test_generations_are_monotonic():
    cols = make_table(128, [4], seed=4)
    w = IndexWriter()
    w.append(cols)
    a = w.seal()
    w.append(cols)
    b = w.seal()
    assert b.generation > a.generation
    assert w.index.generations() == (a.generation, b.generation)


# -- open buffer -------------------------------------------------------------


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_open_buffer_rows_are_queryable(backend):
    cols = make_table(150, [5, 11], seed=5)
    w = IndexWriter(IndexSpec(k=1, row_order="lex"))
    w.append([c[:100] for c in cols])
    w.seal()                                             # 96 sealed, 4 carried
    w.append([c[100:] for c in cols])                    # 54 in open buffer
    si = w.index
    assert si.n_rows == 150 and si.n_sealed_rows == 96
    for pred in PREDICATES:
        rows, _ = si.query(pred, backend=backend)
        np.testing.assert_array_equal(rows, expected_rows(pred, cols))
        _, merged = si.execute_compressed(pred, backend=backend)
        assert merged.n_rows == 150
        assert merged.count() == len(rows)


def test_empty_writer_queries():
    si = IndexWriter().index
    rows, scanned = si.query(Eq(0, 1))
    assert len(rows) == 0 and scanned == 0
    assert si.n_rows == 0 and si.size_words() == 0


def test_buffer_columns_empty_after_aligned_seal():
    """Regression: an aligned seal leaves zero chunks; buffer_columns must
    return [] (not crash on np.concatenate over nothing)."""
    w = IndexWriter()
    w.append([np.arange(64) % 5])
    w.seal()
    assert w.buffered_rows == 0
    assert w.buffer_columns() == []
    rows, _ = w.index.query(Eq(0, 1))             # buffer-free query works
    np.testing.assert_array_equal(rows, np.flatnonzero(np.arange(64) % 5 == 1))


def test_segments_without_row_store_cannot_compact():
    """keep_columns=False drops the raw-column row store (the fan-out
    shard mode); such segments still query but refuse to compact."""
    cols = make_table(64, [4], seed=13)
    a = Segment.seal([c[:32] for c in cols], row_start=0, keep_columns=False)
    b = Segment.seal([c[32:] for c in cols], row_start=32, keep_columns=False)
    assert a.columns is None
    rows, _ = SegmentedIndex([a, b]).query(Eq(0, 1))
    np.testing.assert_array_equal(rows, expected_rows(Eq(0, 1), cols))
    with pytest.raises(ValueError, match="keep_columns"):
        compact([a, b])


# -- compaction --------------------------------------------------------------


def test_compact_requires_adjacent_segments():
    cols = make_table(64, [4], seed=6)
    a = Segment.seal([c[:32] for c in cols], row_start=0)
    b = Segment.seal([c[32:] for c in cols], row_start=64)  # gap: 32..64
    with pytest.raises(ValueError, match="adjacent"):
        compact([a, b])
    with pytest.raises(ValueError, match="at least 2"):
        compact([a])


def test_compact_merges_and_resorts():
    cols = make_table(512, [4, 9], seed=7)
    spec = IndexSpec(k=1, row_order="lex")
    w = IndexWriter(spec)
    for i in range(0, 512, 128):
        w.append([c[i : i + 128] for c in cols])
        w.seal()
    assert len(w.segments) == 4
    merged = w.compact(span=(1, 3))
    assert [s.row_start for s in w.segments] == [0, 128, 384]
    assert merged.n_rows == 256 and merged.row_start == 128
    for pred in PREDICATES:
        rows, _ = w.index.query(pred)
        np.testing.assert_array_equal(rows, expected_rows(pred, cols))
    # full compaction reaches the monolithic sort exactly
    w.compact(span=(0, 3))
    mono = BitmapIndex.build(cols, spec)
    assert w.size_words() == mono.size_words()


def test_size_tiered_pick():
    class Fake:
        def __init__(self, words):
            self._w = words

        def size_words(self):
            return self._w

    segs = [Fake(100), Fake(10), Fake(12), Fake(11), Fake(13), Fake(500)]
    assert size_tiered_pick(segs, fanout=4, ratio=4.0) == (1, 5)
    assert size_tiered_pick(segs[:3], fanout=4) is None  # too few
    assert size_tiered_pick([Fake(1), Fake(100), Fake(1), Fake(100)],
                            fanout=2, ratio=2.0) is None
    with pytest.raises(ValueError, match="fanout"):
        size_tiered_pick(segs, fanout=1)


def test_writer_compact_policy_end_to_end():
    cols = make_table(640, [4, 6], seed=8)
    w = IndexWriter(IndexSpec(k=1, row_order="lex"), seal_rows=128)
    for i in range(0, 640, 64):
        w.append([c[i : i + 64] for c in cols])
    n_before = len(w.segments)
    assert n_before >= 4
    merged = w.compact(fanout=4, ratio=8.0)
    assert merged is not None and len(w.segments) < n_before
    rows, _ = w.index.query(Eq(0, 1))
    np.testing.assert_array_equal(rows, expected_rows(Eq(0, 1), cols))


# -- cache invalidation ------------------------------------------------------


def test_result_cache_scopes():
    rc = ResultCache(maxsize=4)
    rc.put("k1", "v1", scope="a")
    rc.put("k2", "v2", scope="a")
    rc.put("k3", "v3", scope="b")
    rc.put("k4", "v4")                                   # unscoped
    assert rc.get("k1") == "v1"
    assert rc.invalidate("a") == 2
    assert rc.get("k1") is None and rc.get("k2") is None
    assert rc.get("k3") == "v3" and rc.get("k4") == "v4"
    assert rc.invalidate("a") == 0                       # idempotent
    assert rc.stats()["invalidated"] == 2
    # LRU eviction cleans the scope maps too
    rc.clear()
    for i in range(6):
        rc.put(f"k{i}", i, scope=("s", i))
    assert len(rc) == 4
    assert ("s", 0) not in rc.scopes() and ("s", 5) in rc.scopes()
    # re-putting a key under a new scope detaches the old one
    rc.clear()
    rc.put("k", 1, scope="old")
    rc.put("k", 2, scope="new")
    assert rc.invalidate("old") == 0
    assert rc.get("k") == 2
    assert rc.invalidate("new") == 1


def test_compaction_evicts_only_retired_segments():
    cols = make_table(384, [5, 9], seed=9)
    w = IndexWriter(IndexSpec(k=1, row_order="lex"))
    for i in range(0, 384, 128):
        w.append([c[i : i + 128] for c in cols])
        w.seal()
    gens = w.index.generations()
    assert len(gens) == 3
    be = get_backend("numpy", cache_size=512)
    be.result_cache.clear()
    preds = [Eq(0, 1), And(Eq(0, 2), In(1, [1, 3]))]
    w.index.query_many(preds, backend="numpy", cache_size=512)
    scopes = set(be.result_cache.scopes())
    assert {("segment", g) for g in gens} <= scopes
    kept_gen = gens[2]
    kept_entries = {k for k in scopes if k == ("segment", kept_gen)}
    assert kept_entries
    w.compact(span=(0, 2))                               # retire gens 0 and 1
    remaining = set(be.result_cache.scopes())
    assert ("segment", gens[0]) not in remaining
    assert ("segment", gens[1]) not in remaining
    assert ("segment", kept_gen) in remaining            # untouched: kept
    # the kept segment's entries still HIT after compaction (preds[1] is an
    # internal-node plan; bare-leaf k=1 Eq plans are never cached)
    hits_before = be.result_cache.hits
    rows, _ = w.index.query(preds[1], backend="numpy", cache_size=512)
    assert be.result_cache.hits > hits_before
    np.testing.assert_array_equal(rows, expected_rows(preds[1], cols))


def test_invalidate_scope_reaches_registered_backends():
    cols = make_table(96, [4], seed=10)
    seg = Segment.seal(cols, IndexSpec(k=1, row_order="lex"))
    si = SegmentedIndex([seg])
    be = get_backend("numpy", cache_size=512)
    be.result_cache.clear()
    si.query(Not(Eq(0, 1)), backend="numpy", cache_size=512)
    assert seg.cache_scope in be.result_cache.scopes()
    assert invalidate_scope(seg.cache_scope) >= 1
    assert seg.cache_scope not in be.result_cache.scopes()


# -- segmented surface contract ----------------------------------------------


def test_segmented_index_checks_contiguity_and_alignment():
    cols = make_table(64, [4], seed=11)
    a = Segment.seal([c[:32] for c in cols], row_start=0)
    gap = Segment.seal([c[32:] for c in cols], row_start=64)
    with pytest.raises(ValueError, match="contiguous"):
        SegmentedIndex([a, gap]).query(Eq(0, 1))
    ragged = Segment.seal([c[:20] for c in cols], row_start=0)
    tail = Segment.seal([c[20:] for c in cols], row_start=20)
    with pytest.raises(ValueError, match="word-aligned"):
        SegmentedIndex([ragged, tail]).query(Eq(0, 1))
    # a ragged FINAL segment is fine (nothing concatenates after it)
    rows, _ = SegmentedIndex([a, Segment.seal([c[32:] for c in cols],
                                              row_start=32)]).query(Eq(0, 1))
    np.testing.assert_array_equal(rows, expected_rows(Eq(0, 1), cols))


# -- acceptance: >= 3 appends + 1 compaction vs monolithic -------------------


@pytest.mark.parametrize("backend", ["numpy", "jax"])
@pytest.mark.parametrize("k", [1, 2])
def test_acceptance_segmented_matches_monolithic(k, backend):
    """>= 3 appends + 1 compaction answers every predicate shape
    bit-identically to a monolithic build, and full compaction lands
    within 10% of the monolithic compressed size."""
    n = 4017                                             # not 32-aligned
    cols = make_table(n, [6, 11], seed=12 + k)
    spec = IndexSpec(k=k, row_order="grayfreq")
    mono = BitmapIndex.build(cols, spec)
    w = IndexWriter(spec)
    for i in range(0, n, 1000):                          # 5 appends
        w.append([c[i : i + 1000] for c in cols])
        w.seal()
    w.close()
    assert len(w.segments) >= 4
    w.compact(span=(0, len(w.segments)))                 # 1 compaction
    si = w.index
    for pred in PREDICATES:
        got, _ = si.query(pred, backend=backend)
        mono_rows, _ = mono.query(pred, backend=backend)
        np.testing.assert_array_equal(got, np.sort(mono.row_perm[mono_rows]))
    assert si.size_words() <= mono.size_words() * 1.10


# -- hypothesis: random append/seal/compact schedules ------------------------


@settings(max_examples=12, deadline=None)
@given(st.lists(st.integers(1, 120), min_size=3, max_size=6),
       st.integers(0, 10**6))
def test_random_schedules_match_monolithic_rebuild(chunks, seed):
    """Any append/seal/compact schedule answers every Eq/In/Range/And/Or/
    Not plan bit-for-bit identically to a monolithic rebuild over the same
    rows, on both backends (sealed segments, carried tails, open buffers,
    and compacted runs all included)."""
    r = np.random.default_rng(seed)
    cols = [np.concatenate([r.integers(0, c, size=sum(chunks))])
            for c in (4, 7)]
    spec = IndexSpec(k=1, row_order="lex")
    w = IndexWriter(spec)
    pos = 0
    for size in chunks:
        w.append([c[pos : pos + size] for c in cols])
        pos += size
        if r.integers(0, 2):                             # randomly seal
            w.seal()
    if len(w.segments) >= 2 and r.integers(0, 2):        # randomly compact
        lo = int(r.integers(0, len(w.segments) - 1))
        hi = int(r.integers(lo + 2, len(w.segments) + 1))
        w.compact(span=(lo, hi))
    si = w.index
    mono = BitmapIndex.build(cols, spec)
    preds = [Eq(0, 1), In(1, [0, 2, 5]), Range(1, 1, 4),
             And(Eq(0, 2), Not(Eq(1, 3))), Or(Eq(0, 0), Eq(1, 6)),
             Not(In(0, [0, 3]))]
    with sanitized():  # every compressed result structurally validated
        for backend in ("numpy", "jax"):
            for pred, (got, _) in zip(preds,
                                      si.query_many(preds, backend=backend)):
                mono_rows, _ = mono.query(pred, backend=backend)
                np.testing.assert_array_equal(
                    got, np.sort(mono.row_perm[mono_rows]))

# -- deletes (tombstones) ----------------------------------------------------


ALL_ROWS = In(0, [0, 1, 2, 3, 4, 5])                     # whole-domain query


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_delete_matches_dense_oracle(backend):
    """Deletes by ids and by predicate — over sealed segments and the open
    buffer — answer every predicate shape like a dense mask oracle."""
    cols = make_table(300, [5, 9], seed=20)
    w = IndexWriter(IndexSpec(k=1, row_order="grayfreq"), seal_rows=128)
    w.append(cols)                                       # 288 sealed + 12 buf
    alive = np.ones(300, dtype=bool)
    assert w.delete(row_ids=np.arange(10, 70)) == 60     # sealed
    assert w.delete(row_ids=np.arange(280, 295)) == 15   # buffered
    alive[10:70] = alive[280:295] = False
    assert w.delete(row_ids=np.arange(10, 70)) == 0      # idempotent
    kill = Eq(0, 3)
    expect_new = int((evaluate_mask(kill, cols) & alive).sum())
    assert w.delete(kill, backend=backend) == expect_new
    alive &= ~evaluate_mask(kill, cols)
    assert w.live_rows() == alive.sum()
    for pred in PREDICATES:
        rows, _ = w.index.query(pred, backend=backend)
        np.testing.assert_array_equal(
            rows, np.flatnonzero(evaluate_mask(pred, cols) & alive))
    assert w.index.count(kill, backend=backend) == 0


def test_delete_validation():
    w = IndexWriter()
    w.append([np.arange(40) % 4])
    with pytest.raises(ValueError, match="exactly one"):
        w.delete()
    with pytest.raises(ValueError, match="exactly one"):
        w.delete(Eq(0, 1), row_ids=[1])
    # deletes stay legal after close (an LSM keeps maintaining closed data)
    w.close()
    assert w.delete(row_ids=[0, 1]) == 2


def test_delete_costs_one_merge_pre_and_zero_post_compaction():
    """The acceptance bound: a delete adds exactly ONE merge per segment to
    every plan (the cached live mask ANDs into the root — an AND(root,
    NOT(tomb)) shape would count two), and an aligned purge removes even
    that (no tombstones left -> no live mask -> base cost)."""
    cols = make_table(256, [4, 6], seed=21)
    w = IndexWriter(IndexSpec(k=1, row_order="lex"))
    w.append([c[:128] for c in cols])
    w.seal()
    w.append([c[128:] for c in cols])
    w.seal()
    pred = And(Eq(0, 1), Eq(1, 2))
    seg = w.segments[0]
    base = count_merges(compile_plan(seg.index, pred).root)
    assert seg.live_stream() is None                     # no deletes yet
    w.delete(row_ids=np.arange(32))                      # 32 = word-aligned
    plan = with_live_mask(compile_plan(seg.index, pred), seg.live_stream())
    assert count_merges(plan.root) == base + 1
    w.compact(span=(0, 2))
    merged = w.segments[0]
    assert merged.tombstones is None                     # aligned: no fillers
    assert merged.live_stream() is None
    plan2 = compile_plan(merged.index, pred)
    assert count_merges(with_live_mask(plan2,
                                       merged.live_stream()).root) == base


# -- TTLs --------------------------------------------------------------------


def test_ttl_rows_expire_lazily_and_purge_at_compaction():
    fake = [1000.0]
    w = IndexWriter(IndexSpec(k=1, row_order="lex"), clock=lambda: fake[0])
    cols = make_table(256, [4, 6], seed=22)
    w.append([c[:128] for c in cols], ttl=50.0)          # deadline 1050
    w.seal()
    w.append([c[128:] for c in cols])
    w.seal()
    assert w.live_rows() == 256
    rows, _ = w.index.query(ALL_ROWS)
    assert len(rows) == 256
    fake[0] = 1100.0                                     # cross the deadline
    rows, _ = w.index.query(ALL_ROWS)
    np.testing.assert_array_equal(rows, np.arange(128, 256))
    assert w.live_rows() == 128
    merged = w.compact(span=(0, 2))                      # physical drop
    assert merged.n_rows == 128 and merged.deleted_count() == 0
    assert (merged.row_start, merged.row_stop) == (0, 256)  # span preserved
    rows, _ = w.index.query(ALL_ROWS)
    np.testing.assert_array_equal(rows, np.arange(128, 256))


def test_ttl_per_row_and_buffered_expiry():
    fake = [0.0]
    w = IndexWriter(clock=lambda: fake[0])
    w.append([np.arange(40) % 4], ttl=np.arange(40) + 1.0)  # deadlines 1..40
    fake[0] = 10.0                                       # rows 0..9 expired
    rows, _ = w.index.query(ALL_ROWS)
    np.testing.assert_array_equal(rows, np.arange(10, 40))
    assert w.live_rows() == 30
    seg = w.seal()                                       # expiry survives seal
    assert seg.expiry is not None
    fake[0] = 20.0
    rows, _ = w.index.query(ALL_ROWS)
    np.testing.assert_array_equal(rows, np.arange(20, 40))
    with pytest.raises(ValueError, match="ttl"):
        w.append([np.arange(5)], ttl=np.arange(3))


# -- purge / id stability ----------------------------------------------------


def test_purge_keeps_ids_stable_with_alignment_fillers():
    """An unaligned purge retains up to 31 dead rows as tombstoned fillers
    so the merged segment stays word-aligned, and every surviving ingest id
    answers at its original position."""
    cols = make_table(256, [5, 7], seed=23)
    w = IndexWriter(IndexSpec(k=1, row_order="grayfreq"), seal_rows=128)
    for i in range(0, 256, 128):
        w.append([c[i : i + 128] for c in cols])
    assert len(w.segments) == 2
    dead = np.array([3, 40, 100, 130, 200])
    w.delete(row_ids=dead)
    merged = w.compact(span=(0, 2))
    # 251 live + 5 fillers = 256 physical; the span still covers [0, 256)
    assert merged.n_rows == 256 and merged.deleted_count() == 5
    assert (merged.row_start, merged.row_stop) == (0, 256)
    alive = np.ones(256, dtype=bool)
    alive[dead] = False
    for backend in ("numpy", "jax"):
        for pred in PREDICATES:
            rows, _ = w.index.query(pred, backend=backend)
            np.testing.assert_array_equal(
                rows, np.flatnonzero(evaluate_mask(pred, cols) & alive))
    # later appends land after the span and deletes by id still resolve
    w.append([c[:64] for c in make_table(64, [5, 7], seed=24)])
    w.seal()
    assert w.segments[1].row_start == 256
    assert w.delete(row_ids=np.array([3, 40, 150])) == 1   # 3, 40 purged/dead


def test_fully_dead_span_compacts_to_zero_row_segment():
    cols = make_table(192, [4], seed=25)
    w = IndexWriter(IndexSpec(), seal_rows=64)
    for i in range(0, 192, 64):                          # 3 x 64
        w.append([cols[0][i : i + 64]])
    assert len(w.segments) == 3
    w.delete(row_ids=np.arange(128))                     # kill segments 0, 1
    merged = w.compact(span=(0, 2))
    assert merged.n_rows == 0 and merged.size_words() == 0
    assert (merged.row_start, merged.row_stop) == (0, 128)
    for backend in ("numpy", "jax"):
        rows, _ = w.index.query(ALL_ROWS, backend=backend)
        np.testing.assert_array_equal(rows, np.arange(128, 192))
    # the zero-row segment composes: compacting over it works too
    merged2 = w.compact(span=(0, 2))
    assert merged2.n_rows == 64
    assert (merged2.row_start, merged2.row_stop) == (0, 192)
    rows, _ = w.index.query(ALL_ROWS)
    np.testing.assert_array_equal(rows, np.arange(128, 192))


def test_all_deleted_buffer_seals_fully_tombstoned():
    w = IndexWriter()
    w.append([np.arange(40) % 4])
    assert w.delete(row_ids=np.arange(40)) == 40
    seg = w.seal()                                       # not None: physical
    assert seg is not None and seg.n_rows == 32
    assert seg.deleted_count() == 32
    rows, _ = w.index.query(ALL_ROWS)
    assert len(rows) == 0 and w.live_rows() == 0


# -- concurrency -------------------------------------------------------------


def test_queries_interleave_safely_with_compaction():
    """Readers racing repeated compactions always see a consistent segment
    list (old or new, never a mix) and always get exact answers."""
    cols = make_table(1024, [5, 9], seed=26)
    w = IndexWriter(IndexSpec(k=1, row_order="lex"), seal_rows=128)
    for i in range(0, 1024, 128):
        w.append([c[i : i + 128] for c in cols])
    assert len(w.segments) == 8
    w.delete(row_ids=np.arange(100, 150))
    alive = np.ones(1024, dtype=bool)
    alive[100:150] = False
    preds = PREDICATES[:4]
    want = [np.flatnonzero(evaluate_mask(p, cols) & alive) for p in preds]
    errors = []
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            for p, exp in zip(preds, want):
                rows, _ = w.index.query(p)
                if not np.array_equal(rows, exp):
                    errors.append((p, rows))
                    return

    threads = [threading.Thread(target=reader) for _ in range(3)]
    for t in threads:
        t.start()
    try:
        while len(w.segments) >= 2:
            w.compact(span=(0, 2))
    finally:
        stop.set()
        for t in threads:
            t.join()
    assert not errors
    assert len(w.segments) == 1
    for p, exp in zip(preds, want):
        rows, _ = w.index.query(p)
        np.testing.assert_array_equal(rows, exp)


def test_background_compactor_under_ingest_and_drain():
    cols = make_table(2048, [4, 6], seed=27)
    w = IndexWriter(IndexSpec(k=1, row_order="lex"), seal_rows=64)
    with BackgroundCompactor(w, interval=0.003, fanout=4, ratio=8.0) as bc:
        for i in range(0, 2048, 64):
            w.append([c[i : i + 64] for c in cols])
            if i == 1024:
                w.delete(row_ids=np.arange(32))
        time.sleep(0.03)
    assert not bc.running
    assert bc.stats["failures"] == 0
    assert bc.stats["compactions"] >= 1
    # drained to quiescence: no qualifying tier remains
    assert size_tiered_pick(w.segments, fanout=4, ratio=8.0) is None
    bc.close()                                           # idempotent
    alive = np.ones(2048, dtype=bool)
    alive[:32] = False
    for pred in PREDICATES:
        rows, _ = w.index.query(pred)
        np.testing.assert_array_equal(
            rows, np.flatnonzero(evaluate_mask(pred, cols) & alive))


def test_background_compactor_retries_after_transient_failures():
    w = IndexWriter(IndexSpec(), seal_rows=32)
    for _ in range(8):
        w.append([np.arange(32) % 4])
    boom = {"left": 3}
    real = w.compact

    def flaky(**kw):
        if boom["left"] > 0:
            boom["left"] -= 1
            raise RuntimeError("transient")
        return real(**kw)

    w.compact = flaky
    seen = []
    bc = BackgroundCompactor(w, interval=0.003, backoff=0.003,
                             max_backoff=0.02, on_error=seen.append)
    deadline = time.time() + 10.0
    while bc.stats["compactions"] == 0 and time.time() < deadline:
        time.sleep(0.003)
    bc.close()
    assert bc.stats["failures"] >= 3 and len(seen) >= 3
    assert all(isinstance(e, RuntimeError) for e in seen)
    assert bc.stats["compactions"] >= 1 and len(w.segments) < 8


# -- acceptance: the full LSM story vs a monolithic build of survivors ------


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_acceptance_lsm_engine_matches_monolithic_survivors(backend):
    """>= 3 appends + 2 deletes (ids + predicate) + 1 TTL expiry + background
    compaction answers every predicate bit-identically to a fresh monolithic
    build over the surviving rows — and the dist fan-out agrees."""
    fake = [1000.0]
    n = 1500
    cols = make_table(n, [6, 11], seed=30)
    spec = IndexSpec(k=1, row_order="grayfreq")
    w = IndexWriter(spec, seal_rows=128, clock=lambda: fake[0])
    alive = np.ones(n, dtype=bool)
    with BackgroundCompactor(w, interval=0.003, fanout=3, ratio=8.0):
        w.append([c[:500] for c in cols])                      # append 1
        w.append([c[500:1000] for c in cols], ttl=50.0)        # append 2
        w.append([c[1000:] for c in cols])                     # append 3
        assert w.delete(row_ids=np.arange(40, 140)) == 100     # delete 1
        alive[40:140] = False
        kill = Eq(0, 2)
        expect = int((evaluate_mask(kill, cols) & alive).sum())
        assert w.delete(kill, backend=backend) == expect       # delete 2
        alive &= ~evaluate_mask(kill, cols)
        fake[0] = 1100.0                                       # TTL expiry
        alive[500:1000] = False
        time.sleep(0.03)
    keep = np.flatnonzero(alive)
    mono = BitmapIndex.build([c[keep] for c in cols], spec)
    si = w.index
    assert w.live_rows() == len(keep)
    for pred in PREDICATES:
        got, _ = si.query(pred, backend=backend)
        mono_rows, _ = mono.query(pred, backend=backend)
        np.testing.assert_array_equal(
            got, keep[np.sort(mono.row_perm[mono_rows])])
    # dist fan-out over the survivors (purged id space) answers identically
    from repro.dist.query_fanout import ShardedIndex

    sh = ShardedIndex.build([c[keep] for c in cols], spec, n_shards=3,
                            row_ids=keep)
    for pred in PREDICATES:
        got, _ = sh.query(pred, backend=backend)
        want, _ = si.query(pred, backend=backend)
        np.testing.assert_array_equal(got, want)


# -- hypothesis: random LSM schedules vs a dense oracle ----------------------


@settings(max_examples=10, deadline=None)
@given(st.lists(st.sampled_from(["append", "append_ttl", "delete_ids",
                                 "delete_pred", "expire", "seal", "compact"]),
                min_size=4, max_size=14),
       st.integers(0, 10**6))
def test_random_lsm_schedules_match_dense_oracle(ops, seed):
    """Any interleaving of append / TTL append / delete / clock advance /
    seal / compact answers every plan shape identically to a dense numpy
    oracle over (values, alive-mask, expiry), on both backends."""
    r = np.random.default_rng(seed)
    fake = [0.0]
    w = IndexWriter(IndexSpec(k=1, row_order="lex"), clock=lambda: fake[0])
    vals: list = []                                      # per-column values
    alive: list = []                                     # permanent deletes
    expiry: list = []                                    # absolute deadlines
    for op in ops:
        if op in ("append", "append_ttl"):
            m = int(r.integers(1, 80))
            chunk = [r.integers(0, c, size=m) for c in (4, 7)]
            ttl = float(r.integers(1, 20)) if op == "append_ttl" else None
            w.append(chunk, ttl=ttl)
            vals.append(chunk)
            alive.append(np.ones(m, dtype=bool))
            expiry.append(np.full(m, fake[0] + ttl if ttl else np.inf))
        elif op == "delete_ids" and vals:
            n = sum(len(a) for a in alive)
            ids = np.unique(r.integers(0, n, size=int(r.integers(1, 30))))
            w.delete(row_ids=ids)
            flat = np.concatenate(alive)
            flat[ids] = False
            alive = [flat]
            vals = [[np.concatenate([c[i] for c in vals])
                     for i in range(2)]]
            vals = [vals[0]]
            expiry = [np.concatenate(expiry)]
        elif op == "delete_pred" and vals:
            v = int(r.integers(0, 4))
            w.delete(Eq(0, v))
            flat = np.concatenate(alive)
            flat[np.concatenate([c[0] for c in vals]) == v] = False
            alive = [flat]
            vals = [[np.concatenate([c[i] for c in vals])
                     for i in range(2)]]
            expiry = [np.concatenate(expiry)]
        elif op == "expire":
            fake[0] += float(r.integers(1, 15))
        elif op == "seal":
            w.seal()
        elif op == "compact" and len(w.segments) >= 2:
            lo = int(r.integers(0, len(w.segments) - 1))
            hi = int(r.integers(lo + 2, len(w.segments) + 1))
            w.compact(span=(lo, hi))
    if not vals:
        return
    cols = [np.concatenate([c[i] for c in vals]) for i in range(2)]
    mask = np.concatenate(alive) & (np.concatenate(expiry) > fake[0])
    preds = [Eq(0, 1), In(1, [0, 2, 5]), Range(1, 1, 4),
             And(Eq(0, 2), Not(Eq(1, 3))), Or(Eq(0, 0), Eq(1, 6)),
             Not(In(0, [0, 3]))]
    assert w.live_rows() == mask.sum()
    with sanitized():  # every compressed result structurally validated
        for backend in ("numpy", "jax"):
            for pred, (got, _) in zip(
                    preds, w.index.query_many(preds, backend=backend)):
                np.testing.assert_array_equal(
                    got, np.flatnonzero(evaluate_mask(pred, cols) & mask))
