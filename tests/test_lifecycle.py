"""Tests for the segmented index lifecycle (repro.core.lifecycle/segment).

Covers the writer state machine (append / seal / close, word-alignment
tail carrying), compaction (explicit spans, the size-tiered policy,
contiguity validation), the segmented query surface (sealed segments +
open buffer, original-row-space ids, both backends), the cache-invalidation
contract (generation scopes, compaction evicts only retired segments'
entries), and a hypothesis property test driving random
append/seal/compact schedules against a monolithic rebuild.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (And, BitmapIndex, Eq, In, IndexSpec, IndexWriter,
                        Not, Or, Range, Segment, SegmentedIndex, compact,
                        evaluate_mask, size_tiered_pick)
from repro.core.query import ResultCache, get_backend, invalidate_scope


def make_table(n, cards, seed):
    r = np.random.default_rng(seed)
    return [r.integers(0, c, size=n) for c in cards]


PREDICATES = [
    Eq(0, 3),
    In(1, [1, 5, 9]),
    Range(1, 2, 8),
    Range(0, 50, 40),                    # empty
    And(Eq(0, 2), Eq(1, 4)),
    Or(Eq(0, 1), Eq(0, 2), Eq(1, 0)),
    Not(Eq(0, 0)),
    And(In(0, [0, 1, 2]), Range(1, 0, 6), Not(Eq(1, 5))),
]


def expected_rows(pred, cols):
    return np.flatnonzero(evaluate_mask(pred, cols))


# -- writer state machine ----------------------------------------------------


def test_seal_carries_unaligned_tail():
    cols = make_table(100, [5, 7], seed=0)
    w = IndexWriter(IndexSpec(k=1, row_order="lex"))
    w.append(cols)
    assert w.buffered_rows == 100
    seg = w.seal()
    assert seg.n_rows == 96 and seg.row_start == 0       # 100 -> 96 + 4
    assert w.buffered_rows == 4 and w.sealed_rows == 96
    assert w.seal() is None                              # < 32 rows buffered
    w.append([c[:60] for c in make_table(60, [5, 7], seed=1)])
    seg2 = w.seal()
    assert seg2.n_rows == 64 and seg2.row_start == 96    # 4 + 60 -> 64 + 0
    final = w.close()
    assert final is None and w.closed                    # buffer was empty


def test_close_seals_everything_and_locks():
    cols = make_table(45, [4], seed=2)
    w = IndexWriter()
    w.append(cols)
    seg = w.close()
    assert seg.n_rows == 45                              # final may be ragged
    assert w.closed
    with pytest.raises(ValueError, match="closed"):
        w.append(cols)
    with pytest.raises(ValueError, match="closed"):
        w.seal()
    with pytest.raises(ValueError, match="closed"):
        w.close()


def test_append_validation():
    w = IndexWriter()
    with pytest.raises(ValueError, match="equal length"):
        w.append([np.arange(5), np.arange(6)])
    w.append([np.arange(5), np.arange(5)])
    with pytest.raises(ValueError, match="columns"):
        w.append([np.arange(5)])                         # column count fixed
    with pytest.raises(ValueError, match="names"):
        w.append({"a": np.arange(5)})                    # dict needs names
    wn = IndexWriter(names=("a", "b"))
    wn.append({"a": np.arange(5), "b": np.arange(5)})
    with pytest.raises(ValueError, match="missing"):
        wn.append({"a": np.arange(5)})


def test_auto_seal_threshold():
    cols = make_table(300, [4, 6], seed=3)
    w = IndexWriter(IndexSpec(), seal_rows=100)
    for i in range(0, 300, 50):
        w.append([c[i : i + 50] for c in cols])
    assert len(w.segments) >= 2
    assert all(s.n_rows % 32 == 0 for s in w.segments)
    assert w.n_rows == 300


def test_generations_are_monotonic():
    cols = make_table(128, [4], seed=4)
    w = IndexWriter()
    w.append(cols)
    a = w.seal()
    w.append(cols)
    b = w.seal()
    assert b.generation > a.generation
    assert w.index.generations() == (a.generation, b.generation)


# -- open buffer -------------------------------------------------------------


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_open_buffer_rows_are_queryable(backend):
    cols = make_table(150, [5, 11], seed=5)
    w = IndexWriter(IndexSpec(k=1, row_order="lex"))
    w.append([c[:100] for c in cols])
    w.seal()                                             # 96 sealed, 4 carried
    w.append([c[100:] for c in cols])                    # 54 in open buffer
    si = w.index
    assert si.n_rows == 150 and si.n_sealed_rows == 96
    for pred in PREDICATES:
        rows, _ = si.query(pred, backend=backend)
        np.testing.assert_array_equal(rows, expected_rows(pred, cols))
        _, merged = si.execute_compressed(pred, backend=backend)
        assert merged.n_rows == 150
        assert merged.count() == len(rows)


def test_empty_writer_queries():
    si = IndexWriter().index
    rows, scanned = si.query(Eq(0, 1))
    assert len(rows) == 0 and scanned == 0
    assert si.n_rows == 0 and si.size_words() == 0


def test_buffer_columns_empty_after_aligned_seal():
    """Regression: an aligned seal leaves zero chunks; buffer_columns must
    return [] (not crash on np.concatenate over nothing)."""
    w = IndexWriter()
    w.append([np.arange(64) % 5])
    w.seal()
    assert w.buffered_rows == 0
    assert w.buffer_columns() == []
    rows, _ = w.index.query(Eq(0, 1))             # buffer-free query works
    np.testing.assert_array_equal(rows, np.flatnonzero(np.arange(64) % 5 == 1))


def test_segments_without_row_store_cannot_compact():
    """keep_columns=False drops the raw-column row store (the fan-out
    shard mode); such segments still query but refuse to compact."""
    cols = make_table(64, [4], seed=13)
    a = Segment.seal([c[:32] for c in cols], row_start=0, keep_columns=False)
    b = Segment.seal([c[32:] for c in cols], row_start=32, keep_columns=False)
    assert a.columns is None
    rows, _ = SegmentedIndex([a, b]).query(Eq(0, 1))
    np.testing.assert_array_equal(rows, expected_rows(Eq(0, 1), cols))
    with pytest.raises(ValueError, match="keep_columns"):
        compact([a, b])


# -- compaction --------------------------------------------------------------


def test_compact_requires_adjacent_segments():
    cols = make_table(64, [4], seed=6)
    a = Segment.seal([c[:32] for c in cols], row_start=0)
    b = Segment.seal([c[32:] for c in cols], row_start=64)  # gap: 32..64
    with pytest.raises(ValueError, match="adjacent"):
        compact([a, b])
    with pytest.raises(ValueError, match="at least 2"):
        compact([a])


def test_compact_merges_and_resorts():
    cols = make_table(512, [4, 9], seed=7)
    spec = IndexSpec(k=1, row_order="lex")
    w = IndexWriter(spec)
    for i in range(0, 512, 128):
        w.append([c[i : i + 128] for c in cols])
        w.seal()
    assert len(w.segments) == 4
    merged = w.compact(span=(1, 3))
    assert [s.row_start for s in w.segments] == [0, 128, 384]
    assert merged.n_rows == 256 and merged.row_start == 128
    for pred in PREDICATES:
        rows, _ = w.index.query(pred)
        np.testing.assert_array_equal(rows, expected_rows(pred, cols))
    # full compaction reaches the monolithic sort exactly
    w.compact(span=(0, 3))
    mono = BitmapIndex.build(cols, spec)
    assert w.size_words() == mono.size_words()


def test_size_tiered_pick():
    class Fake:
        def __init__(self, words):
            self._w = words

        def size_words(self):
            return self._w

    segs = [Fake(100), Fake(10), Fake(12), Fake(11), Fake(13), Fake(500)]
    assert size_tiered_pick(segs, fanout=4, ratio=4.0) == (1, 5)
    assert size_tiered_pick(segs[:3], fanout=4) is None  # too few
    assert size_tiered_pick([Fake(1), Fake(100), Fake(1), Fake(100)],
                            fanout=2, ratio=2.0) is None
    with pytest.raises(ValueError, match="fanout"):
        size_tiered_pick(segs, fanout=1)


def test_writer_compact_policy_end_to_end():
    cols = make_table(640, [4, 6], seed=8)
    w = IndexWriter(IndexSpec(k=1, row_order="lex"), seal_rows=128)
    for i in range(0, 640, 64):
        w.append([c[i : i + 64] for c in cols])
    n_before = len(w.segments)
    assert n_before >= 4
    merged = w.compact(fanout=4, ratio=8.0)
    assert merged is not None and len(w.segments) < n_before
    rows, _ = w.index.query(Eq(0, 1))
    np.testing.assert_array_equal(rows, expected_rows(Eq(0, 1), cols))


# -- cache invalidation ------------------------------------------------------


def test_result_cache_scopes():
    rc = ResultCache(maxsize=4)
    rc.put("k1", "v1", scope="a")
    rc.put("k2", "v2", scope="a")
    rc.put("k3", "v3", scope="b")
    rc.put("k4", "v4")                                   # unscoped
    assert rc.get("k1") == "v1"
    assert rc.invalidate("a") == 2
    assert rc.get("k1") is None and rc.get("k2") is None
    assert rc.get("k3") == "v3" and rc.get("k4") == "v4"
    assert rc.invalidate("a") == 0                       # idempotent
    assert rc.stats()["invalidated"] == 2
    # LRU eviction cleans the scope maps too
    rc.clear()
    for i in range(6):
        rc.put(f"k{i}", i, scope=("s", i))
    assert len(rc) == 4
    assert ("s", 0) not in rc.scopes() and ("s", 5) in rc.scopes()
    # re-putting a key under a new scope detaches the old one
    rc.clear()
    rc.put("k", 1, scope="old")
    rc.put("k", 2, scope="new")
    assert rc.invalidate("old") == 0
    assert rc.get("k") == 2
    assert rc.invalidate("new") == 1


def test_compaction_evicts_only_retired_segments():
    cols = make_table(384, [5, 9], seed=9)
    w = IndexWriter(IndexSpec(k=1, row_order="lex"))
    for i in range(0, 384, 128):
        w.append([c[i : i + 128] for c in cols])
        w.seal()
    gens = w.index.generations()
    assert len(gens) == 3
    be = get_backend("numpy", cache_size=512)
    be.result_cache.clear()
    preds = [Eq(0, 1), And(Eq(0, 2), In(1, [1, 3]))]
    w.index.query_many(preds, backend="numpy", cache_size=512)
    scopes = set(be.result_cache.scopes())
    assert {("segment", g) for g in gens} <= scopes
    kept_gen = gens[2]
    kept_entries = {k for k in scopes if k == ("segment", kept_gen)}
    assert kept_entries
    w.compact(span=(0, 2))                               # retire gens 0 and 1
    remaining = set(be.result_cache.scopes())
    assert ("segment", gens[0]) not in remaining
    assert ("segment", gens[1]) not in remaining
    assert ("segment", kept_gen) in remaining            # untouched: kept
    # the kept segment's entries still HIT after compaction (preds[1] is an
    # internal-node plan; bare-leaf k=1 Eq plans are never cached)
    hits_before = be.result_cache.hits
    rows, _ = w.index.query(preds[1], backend="numpy", cache_size=512)
    assert be.result_cache.hits > hits_before
    np.testing.assert_array_equal(rows, expected_rows(preds[1], cols))


def test_invalidate_scope_reaches_registered_backends():
    cols = make_table(96, [4], seed=10)
    seg = Segment.seal(cols, IndexSpec(k=1, row_order="lex"))
    si = SegmentedIndex([seg])
    be = get_backend("numpy", cache_size=512)
    be.result_cache.clear()
    si.query(Not(Eq(0, 1)), backend="numpy", cache_size=512)
    assert seg.cache_scope in be.result_cache.scopes()
    assert invalidate_scope(seg.cache_scope) >= 1
    assert seg.cache_scope not in be.result_cache.scopes()


# -- segmented surface contract ----------------------------------------------


def test_segmented_index_checks_contiguity_and_alignment():
    cols = make_table(64, [4], seed=11)
    a = Segment.seal([c[:32] for c in cols], row_start=0)
    gap = Segment.seal([c[32:] for c in cols], row_start=64)
    with pytest.raises(ValueError, match="contiguous"):
        SegmentedIndex([a, gap]).query(Eq(0, 1))
    ragged = Segment.seal([c[:20] for c in cols], row_start=0)
    tail = Segment.seal([c[20:] for c in cols], row_start=20)
    with pytest.raises(ValueError, match="word-aligned"):
        SegmentedIndex([ragged, tail]).query(Eq(0, 1))
    # a ragged FINAL segment is fine (nothing concatenates after it)
    rows, _ = SegmentedIndex([a, Segment.seal([c[32:] for c in cols],
                                              row_start=32)]).query(Eq(0, 1))
    np.testing.assert_array_equal(rows, expected_rows(Eq(0, 1), cols))


# -- acceptance: >= 3 appends + 1 compaction vs monolithic -------------------


@pytest.mark.parametrize("backend", ["numpy", "jax"])
@pytest.mark.parametrize("k", [1, 2])
def test_acceptance_segmented_matches_monolithic(k, backend):
    """>= 3 appends + 1 compaction answers every predicate shape
    bit-identically to a monolithic build, and full compaction lands
    within 10% of the monolithic compressed size."""
    n = 4017                                             # not 32-aligned
    cols = make_table(n, [6, 11], seed=12 + k)
    spec = IndexSpec(k=k, row_order="grayfreq")
    mono = BitmapIndex.build(cols, spec)
    w = IndexWriter(spec)
    for i in range(0, n, 1000):                          # 5 appends
        w.append([c[i : i + 1000] for c in cols])
        w.seal()
    w.close()
    assert len(w.segments) >= 4
    w.compact(span=(0, len(w.segments)))                 # 1 compaction
    si = w.index
    for pred in PREDICATES:
        got, _ = si.query(pred, backend=backend)
        mono_rows, _ = mono.query(pred, backend=backend)
        np.testing.assert_array_equal(got, np.sort(mono.row_perm[mono_rows]))
    assert si.size_words() <= mono.size_words() * 1.10


# -- hypothesis: random append/seal/compact schedules ------------------------


@settings(max_examples=12, deadline=None)
@given(st.lists(st.integers(1, 120), min_size=3, max_size=6),
       st.integers(0, 10**6))
def test_random_schedules_match_monolithic_rebuild(chunks, seed):
    """Any append/seal/compact schedule answers every Eq/In/Range/And/Or/
    Not plan bit-for-bit identically to a monolithic rebuild over the same
    rows, on both backends (sealed segments, carried tails, open buffers,
    and compacted runs all included)."""
    r = np.random.default_rng(seed)
    cols = [np.concatenate([r.integers(0, c, size=sum(chunks))])
            for c in (4, 7)]
    spec = IndexSpec(k=1, row_order="lex")
    w = IndexWriter(spec)
    pos = 0
    for size in chunks:
        w.append([c[pos : pos + size] for c in cols])
        pos += size
        if r.integers(0, 2):                             # randomly seal
            w.seal()
    if len(w.segments) >= 2 and r.integers(0, 2):        # randomly compact
        lo = int(r.integers(0, len(w.segments) - 1))
        hi = int(r.integers(lo + 2, len(w.segments) + 1))
        w.compact(span=(lo, hi))
    si = w.index
    mono = BitmapIndex.build(cols, spec)
    preds = [Eq(0, 1), In(1, [0, 2, 5]), Range(1, 1, 4),
             And(Eq(0, 2), Not(Eq(1, 3))), Or(Eq(0, 0), Eq(1, 6)),
             Not(In(0, [0, 3]))]
    for backend in ("numpy", "jax"):
        for pred, (got, _) in zip(preds,
                                  si.query_many(preds, backend=backend)):
            mono_rows, _ = mono.query(pred, backend=backend)
            np.testing.assert_array_equal(
                got, np.sort(mono.row_perm[mono_rows]))
