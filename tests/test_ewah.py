"""EWAH codec correctness: roundtrip, logical ops, size identities."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ewah

rng = np.random.default_rng(0)


from helpers import random_words


@pytest.mark.parametrize("n", [0, 1, 2, 31, 32, 33, 100, 1000])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_roundtrip(n, seed):
    words = random_words(n, seed=seed)
    stream = ewah.compress(words)
    out = ewah.decompress(stream)
    np.testing.assert_array_equal(out, words)


def test_all_clean_and_all_full():
    zeros = np.zeros(1000, dtype=np.uint32)
    s = ewah.compress(zeros)
    assert len(s) == 1  # one marker encodes 1000 clean words
    np.testing.assert_array_equal(ewah.decompress(s), zeros)
    ones = np.full(1000, ewah.FULL, dtype=np.uint32)
    s = ewah.compress(ones)
    assert len(s) == 1
    np.testing.assert_array_equal(ewah.decompress(s), ones)


def test_never_expands_much():
    """Paper: EWAH never expands beyond ~0.1% (1 marker per 32767 dirty)."""
    words = rng.integers(1, 0xFFFFFFFE, size=100_000, dtype=np.uint32)
    s = ewah.compress(words)
    assert len(s) <= len(words) * 1.001 + 1


def test_marker_overflow_clean():
    n = ewah.MAX_CLEAN + 5
    words = np.zeros(n, dtype=np.uint32)
    s = ewah.compress(words)
    assert len(s) == 2
    np.testing.assert_array_equal(ewah.decompress(s), words)


def test_marker_overflow_dirty():
    n = ewah.MAX_DIRTY + 7
    words = np.full(n, 0x5, dtype=np.uint32)
    s = ewah.compress(words)
    assert len(s) == n + 2  # two markers
    np.testing.assert_array_equal(ewah.decompress(s), words)


@pytest.mark.parametrize("op", ["and", "or", "xor"])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_logical_ops(op, seed):
    a = random_words(500, seed=seed)
    b = random_words(500, seed=seed + 100)
    ca, cb = ewah.compress(a), ewah.compress(b)
    res, scanned = ewah.logical_op(ca, cb, op)
    expect = {"and": a & b, "or": a | b, "xor": a ^ b}[op]
    np.testing.assert_array_equal(ewah.decompress(res), expect)
    assert scanned <= len(ca) + len(cb)


def test_logical_op_size_bounds():
    """|A AND B| <= min(|A|,|B|) + eps;  |A OR B| <= |A| + |B| (paper §3)."""
    for seed in range(5):
        a = random_words(2000, p_clean=0.8, seed=seed)
        b = random_words(2000, p_clean=0.8, seed=seed + 50)
        ca, cb = ewah.compress(a), ewah.compress(b)
        res_and, _ = ewah.logical_op(ca, cb, "and")
        res_or, _ = ewah.logical_op(ca, cb, "or")
        # the paper states the bounds on *bitmap* sizes; in compressed words
        # an AND may split runs into a few extra markers, so allow ~2% slack
        assert len(res_and) <= min(len(ca), len(cb)) * 1.02 + 4
        assert len(res_or) <= len(ca) + len(cb)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 3), min_size=0, max_size=200), st.integers(0, 5))
def test_roundtrip_property(kinds, seed):
    r = np.random.default_rng(seed)
    lut = np.array([0, 0xFFFFFFFF, 0x12345678, 0], dtype=np.uint32)
    words = lut[np.asarray(kinds, dtype=np.int64)] if kinds else np.zeros(0, np.uint32)
    dirty = words == 0x12345678
    words = np.where(dirty, r.integers(1, 0xFFFFFFFE, size=len(words), dtype=np.uint32), words)
    s = ewah.compress(words)
    np.testing.assert_array_equal(ewah.decompress(s), words)
    if len(words):
        assert ewah.unpack_marker(s[0])  # stream begins with a marker


def test_pack_unpack_bits():
    bits = rng.random(1000) < 0.3
    words = ewah.pack_bits(bits)
    np.testing.assert_array_equal(ewah.unpack_bits(words, 1000), bits)


def test_positions_to_words():
    pos = np.array([0, 1, 33, 64, 95])
    words = ewah.positions_to_words(pos, 96)
    assert words[0] == 0b11
    assert words[1] == 0b10
    assert words[2] == (1 | (1 << 31))
