"""Histogram edge cases (regression: empty columns crashed column_histogram)."""

import numpy as np

from repro.core.histogram import column_histogram, freq_rank_keys, value_order

EMPTY = np.array([], dtype=np.int64)


def test_empty_column_infers_zero_length_histogram():
    # regression: col.max() on a zero-length array raised ValueError
    hist = column_histogram(EMPTY)
    assert hist.shape == (0,)


def test_empty_column_explicit_n_values():
    hist = column_histogram(EMPTY, n_values=5)
    np.testing.assert_array_equal(hist, np.zeros(5, dtype=np.int64))


def test_empty_column_freq_rank_keys():
    hist = column_histogram(EMPTY)
    assert freq_rank_keys(EMPTY, hist).shape == (0,)


def test_counts_match_bincount():
    col = np.array([3, 0, 3, 1, 3, 1])
    np.testing.assert_array_equal(column_histogram(col), [1, 2, 0, 3])
    # explicit n_values pads the tail with zeros
    np.testing.assert_array_equal(column_histogram(col, n_values=6),
                                  [1, 2, 0, 3, 0, 0])


def test_value_order_freq_descending_with_id_tiebreak():
    hist = np.array([2, 5, 2, 7])
    order = value_order(hist, "freq")
    np.testing.assert_array_equal(order, [3, 1, 0, 2])
    assert np.all(np.diff(hist[order]) <= 0)
