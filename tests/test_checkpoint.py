"""Checkpoint fault-tolerance behaviour."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import checkpoint as ckpt


def make_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (16, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                   "c": jax.random.normal(k, (4,)).astype(jnp.bfloat16)},
    }


def trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip(tmp_path):
    tree = make_tree()
    ckpt.save(str(tmp_path), 5, tree, extra={"note": "hi"})
    restored, step, extra = ckpt.restore(str(tmp_path), tree)
    assert step == 5 and extra["note"] == "hi"
    trees_equal(tree, restored)


def test_bfloat16_leaf_roundtrip(tmp_path):
    tree = make_tree()
    ckpt.save(str(tmp_path), 1, tree)
    restored, _, _ = ckpt.restore(str(tmp_path), tree)
    assert restored["nested"]["c"].dtype == jnp.bfloat16


def test_retention(tmp_path):
    tree = make_tree()
    for s in range(6):
        ckpt.save(str(tmp_path), s, tree, keep=3)
    assert ckpt.available_steps(str(tmp_path)) == [3, 4, 5]


def test_corruption_falls_back(tmp_path):
    tree = make_tree()
    ckpt.save(str(tmp_path), 1, tree, keep=5)
    ckpt.save(str(tmp_path), 2, tree, keep=5)
    # corrupt the newest step's first leaf
    victim = os.path.join(str(tmp_path), "step_00000002", "leaf_00000.npy")
    arr = np.load(victim, allow_pickle=False)
    raw = arr.view(np.uint8) if arr.dtype != np.dtype("V2") else arr
    np.save(victim, np.zeros_like(np.load(victim).view(np.uint8)))
    restored, step, _ = ckpt.restore(str(tmp_path), tree)
    assert step == 1  # fell back to the older intact checkpoint
    trees_equal(tree, restored)


def test_async_save(tmp_path):
    tree = make_tree()
    t = ckpt.save_async(str(tmp_path), 7, tree)
    t.join(timeout=60)
    restored, step, _ = ckpt.restore(str(tmp_path), tree)
    assert step == 7
    trees_equal(tree, restored)


def test_restore_rejects_layout_mismatch(tmp_path):
    """A saved leaf whose shape disagrees with tree_like fails loudly —
    e.g. param-shaped optimizer moments written before the flat-ZeRO-1
    layout must not be silently placed under the new shardings."""
    tree = make_tree()
    ckpt.save(str(tmp_path), 3, tree)
    new_layout = dict(tree, a=jnp.zeros((130,), jnp.float32))  # 16*8 -> flat+pad
    with pytest.raises(ValueError, match="layout"):
        ckpt.restore(str(tmp_path), new_layout)


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path / "nope"), make_tree())


def test_atomicity_no_partial_dirs(tmp_path):
    """tmp dirs are never left behind after successful saves."""
    tree = make_tree()
    for s in range(3):
        ckpt.save(str(tmp_path), s, tree)
    leftovers = [d for d in os.listdir(tmp_path) if d.startswith("tmp.")]
    assert leftovers == []


def test_retention_survives_crash_before_pointer_flip(tmp_path, monkeypatch):
    """Crash-safety regression: retention must retire old steps only
    AFTER the new step's LATEST pointer flip is durable.  A crash
    injected between the data write and the flip leaves every previously
    committed step on disk and the pointer on the old step — the old
    failure mode pruned first and could leave zero loadable steps."""
    tree = make_tree()
    for s in range(3):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert ckpt.available_steps(str(tmp_path)) == [1, 2]
    assert ckpt.latest_step(str(tmp_path)) == 2

    with monkeypatch.context() as m:
        def boom(directory, step):
            raise RuntimeError("injected crash before LATEST flip")

        m.setattr(ckpt, "flip_latest", boom)
        with pytest.raises(RuntimeError, match="injected crash"):
            ckpt.save(str(tmp_path), 3, tree, keep=2)

    # nothing was pruned and the pointer still names the old commit
    assert ckpt.available_steps(str(tmp_path)) == [1, 2, 3]
    assert ckpt.latest_step(str(tmp_path)) == 2
    restored, step, _ = ckpt.restore(str(tmp_path), tree)
    trees_equal(tree, restored)

    # the next successful save commits and only then retires old steps
    ckpt.save(str(tmp_path), 4, tree, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    assert ckpt.available_steps(str(tmp_path)) == [3, 4]


def test_latest_pointer_never_moves_backwards(tmp_path):
    tree = make_tree()
    ckpt.save(str(tmp_path), 9, tree)
    ckpt.flip_latest(str(tmp_path), 3)  # stale flip (e.g. replayed host)
    assert ckpt.latest_step(str(tmp_path)) == 9
