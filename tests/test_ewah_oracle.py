"""Randomized EWAH oracle agreement: the vectorized JAX codec vs ewah.py.

~200 seeded cases sweep bit densities 0.001-0.999 and stream lengths around
word and run-capacity boundaries.  For every case the JAX compressor, its
in-graph size-only path, and the numpy oracle must agree *exactly*; the
oracle itself must round-trip.  Lengths beyond the vectorized path's
single-marker restriction (clean runs >= MAX_CLEAN, dirty runs >= MAX_DIRTY)
exercise the oracle's multi-marker emission.
"""

import numpy as np
import pytest

from repro.core import ewah, ewah_jax

DENSITIES = [0.001, 0.01, 0.05, 0.2, 0.5, 0.8, 0.95, 0.99, 0.999]
# crossing the 32-bit word boundary (31/32/33) and generic lengths
LENGTHS = [1, 2, 31, 32, 33, 100, 1000, 4095]
SEEDS = [0, 1, 2]


def density_words(n_words, density, seed):
    """Pack Bernoulli(density) bits: sparse -> clean-0 runs, dense -> clean-1."""
    rng = np.random.default_rng(seed)
    bits = rng.random(n_words * ewah.WORD_BITS) < density
    return ewah.pack_bits(bits)


# 8 lengths x 9 densities x 3 seeds = 216 randomized cases
@pytest.mark.parametrize("n", LENGTHS)
@pytest.mark.parametrize("density", DENSITIES)
@pytest.mark.parametrize("seed", SEEDS)
def test_jax_matches_oracle(n, density, seed):
    words = density_words(n, density, seed * 7919 + n)
    expect = ewah.compress(words)
    # oracle self-consistency: exact round-trip
    np.testing.assert_array_equal(ewah.decompress(expect, n), words)
    # vectorized compressor: same stream, same length (capacity n+2 always
    # suffices: worst case is a leading dirty marker + alternating groups)
    stream, length = ewah_jax.compress(words, n + 2)
    assert int(length) == len(expect)
    np.testing.assert_array_equal(np.asarray(stream)[: int(length)], expect)
    # in-graph size-only path (what the sorting heuristics minimize)
    assert int(ewah_jax.compressed_size(words)) == len(expect)


def test_jax_at_max_supported_length():
    """The vectorized path's documented ceiling: exactly MAX_DIRTY words."""
    n = ewah.MAX_DIRTY
    words = density_words(n, 0.5, seed=11)
    expect = ewah.compress(words)
    stream, length = ewah_jax.compress(words, n + 2)
    assert int(length) == len(expect)
    np.testing.assert_array_equal(np.asarray(stream)[: int(length)], expect)
    assert int(ewah_jax.compressed_size(words)) == len(expect)


@pytest.mark.parametrize("ctype", [0, 1])
@pytest.mark.parametrize("extra", [-1, 0, 1, 17])
def test_oracle_clean_run_crosses_max_clean(ctype, extra):
    """Clean runs longer than one marker's 16-bit capacity split correctly."""
    n = ewah.MAX_CLEAN + extra
    pat = np.uint32(0xFFFFFFFF) if ctype else np.uint32(0)
    words = np.full(n, pat, dtype=np.uint32)
    words = np.concatenate([words, np.asarray([5], dtype=np.uint32)])
    stream = ewah.compress(words)
    expect_markers = -(-n // ewah.MAX_CLEAN)  # ceil
    assert len(stream) == expect_markers + 1  # + the dirty tail word
    np.testing.assert_array_equal(ewah.decompress(stream, len(words)), words)


@pytest.mark.parametrize("extra", [-1, 0, 1, 23])
def test_oracle_dirty_run_crosses_max_dirty(extra):
    """Dirty runs longer than one marker's 15-bit capacity chain markers."""
    n = ewah.MAX_DIRTY + extra
    rng = np.random.default_rng(extra + 100)
    words = rng.integers(2, 0xFFFFFFFF - 1, size=n, dtype=np.uint32)
    stream = ewah.compress(words)
    expect_markers = max(1, -(-n // ewah.MAX_DIRTY))
    assert len(stream) == n + expect_markers
    np.testing.assert_array_equal(ewah.decompress(stream, n), words)


def test_oracle_mixed_overlong_runs_roundtrip():
    """Clean-1 > MAX_CLEAN, then dirty > MAX_DIRTY, then clean-0 tail."""
    rng = np.random.default_rng(7)
    words = np.concatenate([
        np.full(ewah.MAX_CLEAN + 3, 0xFFFFFFFF, dtype=np.uint32),
        rng.integers(2, 0xFFFFFFFF - 1, size=ewah.MAX_DIRTY + 5, dtype=np.uint32),
        np.zeros(40, dtype=np.uint32),
    ])
    stream = ewah.compress(words)
    np.testing.assert_array_equal(ewah.decompress(stream, len(words)), words)
    assert len(stream) < len(words)  # markers amortize over the clean run


@pytest.mark.parametrize("n", [1, 33, 4095])
@pytest.mark.parametrize("pattern", ["zeros", "ones", "alternating"])
def test_degenerate_patterns(n, pattern):
    if pattern == "zeros":
        words = np.zeros(n, dtype=np.uint32)
    elif pattern == "ones":
        words = np.full(n, 0xFFFFFFFF, dtype=np.uint32)
    else:  # word-level alternation: every word its own run
        words = np.where(np.arange(n) % 2 == 0, np.uint32(0xAAAAAAAA),
                         np.uint32(0)).astype(np.uint32)
    expect = ewah.compress(words)
    stream, length = ewah_jax.compress(words, n + 2)
    assert int(length) == len(expect)
    np.testing.assert_array_equal(np.asarray(stream)[: int(length)], expect)
    assert int(ewah_jax.compressed_size(words)) == len(expect)
    np.testing.assert_array_equal(ewah.decompress(expect, n), words)
