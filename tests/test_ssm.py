"""Mamba2 SSD: chunked form vs naive sequential recurrence oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.ssm import mamba2_block, mamba2_decode, init_mamba2, ssd_chunked


def naive_ssd(x, dt, A, B, C, D):
    """Token-by-token recurrence: S_t = exp(dt_t A) S_{t-1} + dt_t B_t x_t^T."""
    b, s, h, p = x.shape
    g, N = B.shape[2], B.shape[3]
    rep = h // g
    Bh = np.repeat(np.asarray(B), rep, axis=2)
    Ch = np.repeat(np.asarray(C), rep, axis=2)
    xn, dtn = np.asarray(x), np.asarray(dt)
    An = np.asarray(A)
    S = np.zeros((b, h, p, N))
    y = np.zeros_like(xn)
    for t in range(s):
        dA = np.exp(dtn[:, t] * An)  # (b, h)
        xdt = xn[:, t] * dtn[:, t][..., None]  # (b,h,p)
        S = S * dA[..., None, None] + np.einsum("bhp,bhN->bhpN", xdt, Bh[:, t])
        y[:, t] = np.einsum("bhpN,bhN->bhp", S, Ch[:, t]) + xn[:, t] * np.asarray(D)[None, :, None]
    return y, S


@pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (64, 64), (128, 32)])
def test_ssd_chunked_matches_naive(s, chunk):
    r = np.random.default_rng(0)
    b, h, p, g, N = 2, 4, 8, 1, 16
    x = jnp.asarray(r.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(r.uniform(0.1, 0.9, size=(b, s, h)), jnp.float32)
    A = -jnp.asarray(r.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    B = jnp.asarray(r.normal(size=(b, s, g, N)), jnp.float32)
    C = jnp.asarray(r.normal(size=(b, s, g, N)), jnp.float32)
    D = jnp.ones((h,), jnp.float32)
    y, S = ssd_chunked(x, dt, A, B, C, D, chunk)
    y_ref, S_ref = naive_ssd(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S), S_ref, rtol=2e-4, atol=2e-4)


def test_mamba2_decode_matches_block():
    """Sequential decode through mamba2_decode == chunked forward."""
    cfg = get_config("mamba2-1.3b").smoke()
    params = init_mamba2(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 1, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model))
    y_fwd = mamba2_block(params, cfg, x)

    d_in = cfg.ssm_expand * cfg.d_model
    conv_dim = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
    hp = d_in // cfg.ssm_heads
    conv_state = jnp.zeros((b, 3, conv_dim))
    ssm_state = jnp.zeros((b, cfg.ssm_heads, hp, cfg.ssm_state))
    outs = []
    for t in range(s):
        y, conv_state, ssm_state = mamba2_decode(
            params, cfg, x[:, t : t + 1], conv_state, ssm_state)
        outs.append(y)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec, np.float32),
                               np.asarray(y_fwd, np.float32),
                               rtol=2e-3, atol=2e-3)
