"""Oracle tests for the predicate-algebra query plane.

Every predicate plan is checked against an uncompressed numpy row-mask
recomputation on randomized tables, and the numpy (streaming compressed
domain) and jax (batched in-graph) backends are checked against each other.
"""

import numpy as np
import pytest

from repro.core import And, BitmapIndex, Eq, In, IndexSpec, Not, Or, Range
from repro.core import index_size_report
from repro.core.bitmap_index import assign_codes
from repro.core.query import backend_names, compile_plan, get_backend
from repro.core.sorting import order_rows
from repro.core.strategies import (get_strategy, register_row_order,
                                   strategy_names, unregister_strategy)


def make_table(n, cards, seed):
    r = np.random.default_rng(seed)
    return [r.integers(0, c, size=n) for c in cards]


def oracle_mask(pred, data):
    """Recompute the predicate over uncompressed (reordered-space) columns."""
    if isinstance(pred, Eq):
        return data[pred.col] == pred.value
    if isinstance(pred, In):
        return np.isin(data[pred.col], pred.values)
    if isinstance(pred, Range):
        return (data[pred.col] >= pred.lo) & (data[pred.col] <= pred.hi)
    if isinstance(pred, And):
        m = oracle_mask(pred.children[0], data)
        for c in pred.children[1:]:
            m = m & oracle_mask(c, data)
        return m
    if isinstance(pred, Or):
        m = oracle_mask(pred.children[0], data)
        for c in pred.children[1:]:
            m = m | oracle_mask(c, data)
        return m
    if isinstance(pred, Not):
        return ~oracle_mask(pred.child, data)
    raise TypeError(pred)


PREDICATES = [
    Eq(0, 3),
    In(1, [1, 5, 9, 9]),            # duplicate values collapse
    Range(2, 4, 11),
    Range(2, 50, 40),               # empty range -> no rows
    Range(1, -5, 10**9),            # clamped to domain, never materialized
    And(Eq(0, 2), Eq(1, 4)),
    Or(Eq(0, 1), Eq(0, 2), Eq(1, 0)),
    Not(Eq(0, 0)),
    And(In(0, [0, 1, 2]), Range(1, 0, 6), Not(Eq(2, 5))),
    Or(And(Eq(0, 1), Eq(1, 1)), Not(In(2, [0, 1, 2]))),
    Eq(2, 10_000),                  # out of domain -> no rows
]


@pytest.mark.parametrize("row_order", ["unsorted", "lex", "grayfreq"])
@pytest.mark.parametrize("k", [1, 2])
def test_plans_match_uncompressed_oracle(k, row_order):
    # 1237 rows: deliberately not a multiple of 32 (Not must respect the tail)
    cols = make_table(1237, [7, 11, 31], seed=k * 10 + len(row_order))
    idx = BitmapIndex.build(cols, IndexSpec(k=k, row_order=row_order))
    data = {c: cols[c][idx.row_perm] for c in range(3)}
    for pred in PREDICATES:
        rows, scanned = idx.query(pred, backend="numpy")
        expect = np.flatnonzero(oracle_mask(pred, data))
        np.testing.assert_array_equal(rows, expect)
        assert scanned >= 1


@pytest.mark.parametrize("k", [1, 2])
def test_numpy_and_jax_backends_agree(k):
    cols = make_table(900, [5, 13, 40], seed=k)
    idx = BitmapIndex.build(cols, IndexSpec(k=k, row_order="lex"))
    data = {c: cols[c][idx.row_perm] for c in range(3)}
    np_res = idx.query_many(PREDICATES, backend="numpy")
    jax_res = idx.query_many(PREDICATES, backend="jax")
    for pred, (rn, _), (rj, _) in zip(PREDICATES, np_res, jax_res):
        expect = np.flatnonzero(oracle_mask(pred, data))
        np.testing.assert_array_equal(rn, expect)
        np.testing.assert_array_equal(rj, expect)


def test_jax_batches_same_signature_different_child_order():
    """Regression: two plans with equal structural signatures but different
    source child order — And(Eq, Or(Eq, Eq)) vs And(Or(Eq, Eq), Eq) —
    used to batch into one jax group compiled from the first plan's root,
    evaluating the second with the wrong leaf-to-stream mapping.  Canonical
    leaf numbering makes equal signatures imply identical roots."""
    cols = make_table(1500, [6, 40], seed=7)
    idx = BitmapIndex.build(cols, IndexSpec(k=1, row_order="lex"))
    data = {c: cols[c][idx.row_perm] for c in range(2)}
    # column 0 is the sorted primary (tiny streams), column 1 is high-card
    # (long streams), so cost order is Or-then-Eq in both plans and the two
    # signatures collide deterministically
    preds = [
        And(Eq(1, 2), Or(Eq(0, 1), Eq(0, 2))),
        And(Or(Eq(0, 3), Eq(0, 4)), Eq(1, 5)),
    ]
    p1, p2 = (compile_plan(idx, p) for p in preds)
    assert p1.signature() == p2.signature()
    assert p1.root == p2.root  # canonical numbering -> shared batch program
    jax_res = idx.query_many(preds, backend="jax")
    np_res = idx.query_many(preds, backend="numpy")
    for pred, (rj, _), (rn, _) in zip(preds, jax_res, np_res):
        expect = np.flatnonzero(oracle_mask(pred, data))
        np.testing.assert_array_equal(rj, expect)
        np.testing.assert_array_equal(rn, expect)


def test_and_of_eqs_acceptance():
    """Acceptance: And(Eq, Eq) returns identical row ids on both backends."""
    cols = make_table(2000, [9, 17], seed=42)
    idx = BitmapIndex.build(cols, IndexSpec(k=2, row_order="grayfreq"))
    pred = And(Eq(0, 3), Eq(1, 5))
    rows_np, _ = idx.query(pred, backend="numpy")
    rows_jax, _ = idx.query(pred, backend="jax")
    np.testing.assert_array_equal(rows_np, rows_jax)
    data = {c: cols[c][idx.row_perm] for c in range(2)}
    np.testing.assert_array_equal(
        rows_np, np.flatnonzero(oracle_mask(pred, data)))


def test_operator_sugar():
    cols = make_table(400, [4, 6], seed=3)
    idx = BitmapIndex.build(cols, IndexSpec())
    data = {c: cols[c][idx.row_perm] for c in range(2)}
    pred = (Eq(0, 1) & Eq(1, 2)) | ~Eq(0, 3)
    rows, _ = idx.query(pred)
    np.testing.assert_array_equal(rows, np.flatnonzero(oracle_mask(
        Or(And(Eq(0, 1), Eq(1, 2)), Not(Eq(0, 3))), data)))


def test_plan_flattens_kofn_fanin():
    """And(Eq, Eq) at k=2 compiles to ONE 4-stream AND fan-in (the k-of-N
    AND folds into the plan), children cost-ordered smallest-first."""
    cols = make_table(500, [30, 40], seed=0)
    idx = BitmapIndex.build(cols, IndexSpec(k=2, row_order="lex"))
    plan = compile_plan(idx, And(Eq(0, 1), Eq(1, 2)))
    assert plan.root[0] == "and"
    assert len(plan.root[1]) == 4
    assert all(c[0] == "leaf" for c in plan.root[1])
    sizes = [len(plan.streams[c[1]]) for c in plan.root[1]]
    assert sizes == sorted(sizes)


def test_single_stream_root_scan_cost():
    """A k=1 equality is a bare-leaf plan; its scan cost is the stream
    length (the old equality_query special case, now planner policy)."""
    cols = make_table(800, [6], seed=1)
    idx = BitmapIndex.build(cols, IndexSpec(k=1, row_order="lex"))
    plan = compile_plan(idx, Eq(0, 2))
    assert plan.root[0] == "leaf"
    rows, scanned = idx.query(Eq(0, 2))
    assert scanned == len(plan.streams[0]) >= 1
    rows2, scanned2 = idx.equality_query(0, 2)
    np.testing.assert_array_equal(
        rows2, np.flatnonzero(cols[idx.original_column(0)][idx.row_perm] == 2))


def test_column_names_resolution():
    cols = make_table(300, [4, 9], seed=5)
    idx = BitmapIndex.build(cols, IndexSpec())
    names = ("alpha", "beta")
    rows_by_name, _ = idx.query(Eq("beta", 3), names=names)
    rows_by_pos, _ = idx.query(Eq(1, 3))
    np.testing.assert_array_equal(rows_by_name, rows_by_pos)
    with pytest.raises(ValueError, match="alpha, beta"):
        idx.query(Eq("gamma", 0), names=names)
    with pytest.raises(ValueError, match="no column names"):
        idx.query(Eq("beta", 0))
    with pytest.raises(ValueError, match="out of range"):
        idx.query(Eq(7, 0))


def test_unmaterialized_index_rejects_queries():
    cols = make_table(200, [4], seed=0)
    idx = BitmapIndex.build(cols, IndexSpec(), materialize=False)
    with pytest.raises(ValueError, match="materialize"):
        idx.query(Eq(0, 1))


# -- strategy registry -------------------------------------------------------


def test_unknown_strategy_errors_list_names():
    cols = make_table(100, [3, 5], seed=0)
    with pytest.raises(ValueError, match="grayfreq"):
        order_rows(cols, "bogus")
    with pytest.raises(ValueError, match="gray, lex"):
        assign_codes(10, 1, code_order="bogus")
    with pytest.raises(ValueError, match="alpha, freq"):
        assign_codes(10, 1, value_policy="bogus", hist=np.ones(10, np.int64))
    with pytest.raises(ValueError, match="heuristic"):
        BitmapIndex.build(cols, IndexSpec(column_order="bogus"))
    with pytest.raises(ValueError, match="jax, numpy"):
        get_backend("bogus")
    assert "lex" in strategy_names("row_order")


def test_custom_strategy_plugs_in():
    @register_row_order("reverse")
    def _reverse(columns, hists=None):
        return np.arange(len(columns[0]))[::-1]

    try:
        assert get_strategy("row_order", "reverse") is _reverse
        cols = make_table(50, [4], seed=0)
        idx = BitmapIndex.build(cols, IndexSpec(row_order="reverse"))
        np.testing.assert_array_equal(idx.row_perm, np.arange(50)[::-1])
    finally:
        unregister_strategy("row_order", "reverse")
    with pytest.raises(ValueError):
        get_strategy("row_order", "reverse")


def test_indexspec_serialization_roundtrip():
    for spec in (IndexSpec(),
                 IndexSpec(k=2, row_order="grayfreq"),
                 IndexSpec(column_order=(1, 0)),
                 IndexSpec(column_order=None)):
        assert IndexSpec.from_dict(spec.to_dict()) == spec
    assert IndexSpec(column_order=None).column_order == "given"
    assert IndexSpec(column_order=[1, 0]).column_order == (1, 0)
    # value-policy auto resolution couples Gray-Frequency to 'freq'
    assert IndexSpec(row_order="grayfreq").resolved_value_policy() == "freq"
    assert IndexSpec(row_order="lex").resolved_value_policy() == "alpha"
    with pytest.raises(ValueError, match="k must be"):
        IndexSpec(k=0)


# -- legacy string-kwargs API: removed ---------------------------------------


def test_legacy_kwargs_are_removed_with_guidance():
    """The PR-2 deprecation shims are gone: string kwargs raise TypeError
    pointing at IndexSpec, for both build and index_size_report."""
    cols = make_table(100, [6, 12], seed=9)
    with pytest.raises(TypeError, match="IndexSpec"):
        BitmapIndex.build(cols, k=2, row_order="grayfreq")
    with pytest.raises(TypeError, match="IndexSpec"):
        BitmapIndex.build(cols, row_order="lex")
    with pytest.raises(TypeError, match="IndexSpec"):
        index_size_report(cols, k=1, row_order="lex")
    with pytest.raises(TypeError, match="unexpected keyword"):
        BitmapIndex.build(cols, bogus_option=3)
    assert not hasattr(IndexSpec, "from_legacy_kwargs")


def test_build_is_seal_once_over_writer():
    """BitmapIndex.build == one writer append + close (a single sealed
    segment), and the index carries a cache scope for invalidation."""
    from repro.core import IndexWriter

    cols = make_table(700, [6, 12], seed=9)
    spec = IndexSpec(k=2, row_order="grayfreq")
    idx = BitmapIndex.build(cols, spec)
    w = IndexWriter(spec)
    w.append(cols)
    seg = w.close()
    assert w.segments == [seg] and seg.n_rows == 700
    assert seg.index.size_words() == idx.size_words()
    np.testing.assert_array_equal(seg.index.row_perm, idx.row_perm)
    assert idx.cache_scope is not None and idx.cache_scope[0] == "segment"


# -- metadata index ----------------------------------------------------------


def test_metadata_index_query_through_planner():
    from repro.data.metadata_index import MetadataIndex

    r = np.random.default_rng(0)
    mi = MetadataIndex()
    raw = {c: [] for c in MetadataIndex.COLS}
    for _ in range(3):
        batch = {
            "source": r.integers(0, 4, 256),
            "domain": r.integers(0, 8, 256),
            "quality_bin": r.integers(0, 16, 256),
            "length_bin": r.integers(0, 6, 256),
        }
        for c, v in batch.items():
            raw[c].append(v)
        mi.add_batch(batch)
    assert mi.index.n_segments >= 3      # one sealed segment per batch
    assert mi.n_rows == 768
    cols = {c: np.concatenate(raw[c]) for c in mi.COLS}

    # segmented queries answer in original ingest row space
    rows, scanned = mi.query(where={"domain": 3, "quality_bin": 8})
    expect = np.flatnonzero((cols["domain"] == 3) & (cols["quality_bin"] == 8))
    np.testing.assert_array_equal(rows, expect)
    assert scanned >= 1

    rows_jax, _ = mi.query(where={"domain": 3, "quality_bin": 8},
                           backend="jax")
    np.testing.assert_array_equal(rows_jax, expect)

    # quality_bin >= 8 as a Range predicate by column name
    rows, _ = mi.query_pred(And(Eq("domain", 3), Range("quality_bin", 8, 15)))
    expect = np.flatnonzero((cols["domain"] == 3) & (cols["quality_bin"] >= 8))
    np.testing.assert_array_equal(rows, expect)

    empty, scanned = mi.query()
    assert len(empty) == 0 and scanned == 0

    with pytest.raises(ValueError, match="unknown columns"):
        mi.query(where={"bogus": 1})

    # compaction keeps answers identical and shrinks the segment count
    before = mi.index.n_segments
    mi.compact(span=(0, before))
    assert mi.index.n_segments < before
    rows2, _ = mi.query(where={"domain": 3, "quality_bin": 8})
    np.testing.assert_array_equal(
        rows2,
        np.flatnonzero((cols["domain"] == 3) & (cols["quality_bin"] == 8)))


def test_metadata_index_query_legacy_shims_removed():
    """The PR-4 one-release shims are gone: conditions as bare kwargs and
    the backend as _backend= raise TypeError (plain unexpected-keyword),
    and nothing in the call emits a DeprecationWarning anymore."""
    import warnings

    from repro.data.metadata_index import MetadataIndex

    r = np.random.default_rng(3)
    mi = MetadataIndex()
    mi.add_batch({c: r.integers(0, 4, 96) for c in MetadataIndex.COLS})
    with pytest.raises(TypeError):
        mi.query(domain=2)
    with pytest.raises(TypeError):
        mi.query(where={"domain": 2}, _backend="numpy")
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # the supported spelling is silent
        rows, _ = mi.query(where={"domain": 2}, backend="numpy")
    assert len(rows) > 0


# -- serving plane -----------------------------------------------------------


def test_pack_batches_query_plane():
    from repro.launch.serve import pack_batches, padding_waste

    r = np.random.default_rng(1)
    lengths = r.integers(8, 96, size=101)
    naive = pack_batches(lengths, 8, histogram_aware=False)
    packed = pack_batches(lengths, 8, histogram_aware=True)
    order = np.concatenate(packed)
    assert sorted(order.tolist()) == list(range(101))
    assert padding_waste(lengths, packed) <= padding_waste(lengths, naive)
    packed_jax = pack_batches(lengths, 8, histogram_aware=True, backend="jax")
    for a, b in zip(packed, packed_jax):
        np.testing.assert_array_equal(a, b)
    # streaming admission (writer lifecycle) packs identically to rebuild
    packed_seg = pack_batches(lengths, 8, histogram_aware=True,
                              admission="segmented")
    for a, b in zip(packed, packed_seg):
        np.testing.assert_array_equal(a, b)
    with pytest.raises(ValueError, match="admission"):
        pack_batches(lengths, 8, admission="bogus")
    with pytest.raises(ValueError, match="pick one"):
        pack_batches(lengths, 8, admission="segmented", query_fanout=2)


def test_segmented_admission_compactor_and_retire():
    """The streaming admission queue is a full LSM surface: a background
    compactor merges sealed admission segments without changing any pack,
    and retire() tombstones served requests so later packs skip them."""
    from repro.launch.serve import SegmentedAdmission, pack_batches

    r = np.random.default_rng(3)
    lengths = r.integers(8, 96, size=300)
    base = pack_batches(lengths, 16, admission="rebuild")
    with_compactor = pack_batches(lengths, 16, admission="segmented",
                                  compactor=True)
    assert len(base) == len(with_compactor)
    for a, b in zip(base, with_compactor):
        np.testing.assert_array_equal(a, b)
    with pytest.raises(ValueError, match="compactor"):
        pack_batches(lengths, 16, compactor=True)     # rebuild has no writer
    q = SegmentedAdmission(seal_rows=64, compactor=True)
    try:
        q.admit(lengths[:200])
        served = np.concatenate(q.pack(16)[:3])
        assert q.retire(served) == len(served)
        rest = np.concatenate(q.pack(16))
        assert not np.intersect1d(rest, served).size
        assert len(rest) == 200 - len(served)
    finally:
        q.close()


# -- kernels -----------------------------------------------------------------


@pytest.mark.parametrize("op", ["and", "or", "xor"])
@pytest.mark.parametrize("m", [1, 2, 3, 5, 8])
def test_wordops_fold_matches_reduce(op, m):
    import jax.numpy as jnp

    from repro.kernels import ops

    r = np.random.default_rng(m)
    stacked = r.integers(0, 2**32, size=(m, 200), dtype=np.uint32)
    out = np.asarray(ops.wordops_fold(jnp.asarray(stacked), op))
    fn = {"and": np.bitwise_and, "or": np.bitwise_or,
          "xor": np.bitwise_xor}[op]
    expect = stacked[0]
    for i in range(1, m):
        expect = fn(expect, stacked[i])
    np.testing.assert_array_equal(out, expect)


def test_backend_registry_introspection():
    assert backend_names() == ("jax", "numpy")
