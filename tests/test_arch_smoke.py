"""Per-architecture smoke tests: reduced config, one forward + train step on
CPU, output shapes + no NaNs.  Full configs are exercised via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import transformer
from repro.optim import OptConfig, init_opt_state
from repro.train import serve_step, train_step

ARCHS = list_archs()


def make_batch(cfg, key, b=2, s=32):
    kt, kl, kp = jax.random.split(key, 3)
    batch = {
        "inputs": jax.random.randint(kt, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(kl, (b, s), 0, cfg.vocab_size),
    }
    if cfg.frontend != "none":
        # stub modality frontend: precomputed patch/frame embeddings
        batch["patches"] = jax.random.normal(kp, (b, 8, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        batch["mrope_positions"] = jnp.stack([pos, pos, pos])
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = get_config(arch).smoke()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, aux = transformer.forward(
        params, cfg, batch["inputs"],
        mrope_positions=batch.get("mrope_positions"),
        patches=batch.get("patches"))
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).smoke()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = OptConfig(total_steps=10, warmup_steps=2)
    opt_state = init_opt_state(params)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    step = jax.jit(
        lambda p, o, b: train_step(p, o, b, cfg=cfg, opt_cfg=opt_cfg))
    p2, o2, metrics = step(params, opt_state, batch)
    assert float(metrics["loss"]) > 0
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed (some leaf must move; embed gets no gradient
    # for embedding-input frontends, so check across all leaves)
    changed = any(
        not np.allclose(np.asarray(b, np.float32), np.asarray(a, np.float32))
        for b, a in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert changed


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(arch):
    cfg = get_config(arch).smoke()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    b, max_len = 2, 64
    cache = transformer.init_decode_cache(cfg, b, max_len)
    tokens = jnp.zeros((b, 1), jnp.int32)
    step = jax.jit(lambda t, c, l: serve_step(params, t, c, l, cfg=cfg))
    tok, cache = step(tokens, cache, jnp.int32(0))
    assert tok.shape == (b, 1)
    tok2, cache = step(tok, cache, jnp.int32(1))
    assert tok2.shape == (b, 1)
    assert int(tok.max()) < cfg.vocab_size


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-1.3b", "zamba2-1.2b"])
def test_decode_matches_forward(arch):
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = get_config(arch).smoke()
    if cfg.family in ("ssm", "hybrid"):
        cfg = cfg  # ssm decode vs chunked forward: compared below with tol
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    s = 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, s), 0, cfg.vocab_size)
    logits_fwd, _ = transformer.forward(params, cfg, toks)
    cache = transformer.init_decode_cache(cfg, 1, 32)
    outs = []
    cache_len = jnp.int32(0)
    for t in range(s):
        logits, cache = transformer.decode_step(
            params, cfg, toks[:, t : t + 1], cache, cache_len)
        outs.append(logits)
        cache_len = cache_len + 1
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_fwd, np.float32), rtol=0.15, atol=0.15)


def test_param_counts_full_configs():
    """Full-config parameter counts are in the advertised ballpark."""
    import repro.models.transformer as T

    expected = {
        "qwen2-7b": 7.6e9, "tinyllama-1.1b": 1.1e9, "qwen2.5-14b": 14.7e9,
        "phi3-medium-14b": 14e9, "mamba2-1.3b": 1.3e9, "zamba2-1.2b": 1.2e9,
        "olmoe-1b-7b": 6.9e9, "qwen2-moe-a2.7b": 14.3e9,
        "musicgen-medium": 1.5e9, "qwen2-vl-7b": 7.6e9,
    }
    for arch, target in expected.items():
        cfg = get_config(arch)
        shapes = jax.eval_shape(
            lambda k: T.init_params(k, cfg), jax.random.PRNGKey(0))
        n = sum(np.prod(l.shape) for l in jax.tree.leaves(shapes))
        assert 0.5 * target < n < 1.7 * target, (arch, n, target)
