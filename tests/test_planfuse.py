"""Plan-level megakernel fusion: the lower_plan instruction tape, the
planfuse Pallas kernel, the jax backend's fused execution path (bit
identity vs the per-stage path and the numpy oracle across every
encoding, segmented/tombstoned plans, sanitized boundaries), the VMEM /
tape-length fallback gate, the result-cache contract, and the PlanStats
capacity autotuner."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analysis.runtime import sanitized
from repro.core import (And, BitmapIndex, Eq, In, IndexSpec, IndexWriter,
                        Not, Or, Range)
from repro.core import query as Q
from repro.core.query import (JaxBackend, NumpyBackend, compile_plan,
                              lower_plan)
from repro.kernels import planfuse


def make_index(n=2011, cards=(7, 12, 30), k=1, seed=3, **spec):
    r = np.random.default_rng(seed)
    cols = [r.integers(0, c, size=n) for c in cards]
    return BitmapIndex.build(
        cols, IndexSpec(k=k, row_order="lex", **spec)), cols


PREDICATES = [
    Eq(0, 3),
    Not(Eq(1, 2)),
    In(1, [1, 5, 9]),
    Range(2, 4, 21),
    And(Eq(0, 2), Eq(1, 4)),
    Or(Eq(0, 1), Eq(0, 2), Eq(1, 0)),
    And(In(0, [0, 1, 2]), Range(1, 0, 6), Not(Eq(2, 5))),
    Or(And(Eq(0, 1), Eq(1, 1)), Not(In(2, [0, 1, 2]))),
]


# ---------------------------------------------------------------------------
# tape constants + lower_plan
# ---------------------------------------------------------------------------


def test_tape_opcodes_agree_with_kernel():
    """query.py duplicates the opcode ids so the numpy-only path never
    imports jax; the two definitions must stay identical."""
    assert (Q.TAPE_PUSH, Q.TAPE_NOT, Q.TAPE_OP) == (
        planfuse.PUSH, planfuse.NOT, planfuse.OP)
    assert Q._TAPE_OP_IDS == {"and": planfuse.OP_AND, "or": planfuse.OP_OR,
                              "xor": planfuse.OP_XOR}


def test_lower_plan_leaf():
    assert lower_plan(("leaf", 4)) == (((Q.TAPE_PUSH, 4),), 1)


def test_lower_plan_not_and_fanin():
    tape, depth = lower_plan(("not", ("leaf", 0)))
    assert tape == ((Q.TAPE_PUSH, 0), (Q.TAPE_NOT, 0)) and depth == 1

    tape, depth = lower_plan(
        ("and", (("leaf", 0), ("leaf", 1), ("leaf", 2))))
    # left fold: push 0, then (push k, AND) per further child
    assert tape == ((Q.TAPE_PUSH, 0), (Q.TAPE_PUSH, 1),
                    (Q.TAPE_OP, planfuse.OP_AND), (Q.TAPE_PUSH, 2),
                    (Q.TAPE_OP, planfuse.OP_AND))
    assert depth == 2  # left fold keeps at most two live operands


def test_lower_plan_fold_keeps_bit_order():
    root = ("fold", ("xor", "or"),
            (("leaf", 0), ("leaf", 1), ("leaf", 2)))
    tape, _ = lower_plan(root)
    assert tape == ((Q.TAPE_PUSH, 0), (Q.TAPE_PUSH, 1),
                    (Q.TAPE_OP, planfuse.OP_XOR), (Q.TAPE_PUSH, 2),
                    (Q.TAPE_OP, planfuse.OP_OR))


def test_lower_plan_depth_tracks_right_heavy_tree():
    # ((leaf and leaf) or (leaf and leaf)): right subtree evaluates while
    # the left result is live -> peak three operands
    root = ("or", (("and", (("leaf", 0), ("leaf", 1))),
                   ("and", (("leaf", 2), ("leaf", 3)))))
    _, depth = lower_plan(root)
    assert depth == 3


def test_lower_plan_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown plan-node kind"):
        lower_plan(("nand", (("leaf", 0), ("leaf", 1))))


def test_lower_plan_on_real_compiled_plans():
    idx, _ = make_index()
    for pred in PREDICATES:
        plan = compile_plan(idx, pred)
        tape, depth = lower_plan(plan.root)
        pushes = [arg for opcode, arg in tape if opcode == Q.TAPE_PUSH]
        # tape visits leaves exactly in the planner's canonical numbering
        assert pushes == list(range(len(plan.streams)))
        assert 1 <= depth <= len(plan.streams)


# ---------------------------------------------------------------------------
# megakernel vs a straight numpy stack machine
# ---------------------------------------------------------------------------


def _numpy_tape_eval(planes, tape):
    stack = []
    for opcode, arg in tape:
        if opcode == Q.TAPE_PUSH:
            stack.append(planes[arg])
        elif opcode == Q.TAPE_NOT:
            stack.append(stack.pop() ^ np.uint32(0xFFFFFFFF))
        else:
            b, a = stack.pop(), stack.pop()
            stack.append([np.bitwise_and, np.bitwise_or,
                          np.bitwise_xor][arg](a, b))
    return stack.pop()


@pytest.mark.parametrize("seed", range(3))
def test_planfuse_kernel_matches_numpy_stack_machine(seed):
    import jax.numpy as jnp

    r = np.random.default_rng(seed)
    m, N, C = 4, planfuse.ROW_TILE * 2, planfuse.LANE_TILE
    planes = r.integers(0, 2**32, size=(m, N, C), dtype=np.uint32)
    # sprinkle clean-0 / clean-1 tiles so every kind class appears
    planes[0, :, :] = 0
    planes[1, : planfuse.ROW_TILE, :] = 0xFFFFFFFF
    tape = ((Q.TAPE_PUSH, 0), (Q.TAPE_PUSH, 1), (Q.TAPE_OP, planfuse.OP_OR),
            (Q.TAPE_PUSH, 2), (Q.TAPE_NOT, 0),
            (Q.TAPE_OP, planfuse.OP_AND), (Q.TAPE_PUSH, 3),
            (Q.TAPE_OP, planfuse.OP_XOR))
    res, kind = planfuse.planfuse_kernel(jnp.asarray(planes), tape)
    want = _numpy_tape_eval(planes.reshape(m, -1), tape).reshape(N, C)
    np.testing.assert_array_equal(np.asarray(res), want)
    want_kind = np.where(want == 0, 0, np.where(want == 0xFFFFFFFF, 1, 2))
    np.testing.assert_array_equal(np.asarray(kind), want_kind)


# ---------------------------------------------------------------------------
# fused vs per-stage vs numpy: bit-identical EwahStreams
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("encoding", ["equality", "bitsliced", "binned"])
def test_fused_bit_identical_across_encodings(encoding):
    idx, _ = make_index(cards=(7, 12, 64), encoding=encoding)
    preds = PREDICATES + [Range(2, 3, 40)]
    plans = [compile_plan(idx, p) for p in preds]
    fused = JaxBackend()
    stage = JaxBackend(fuse=False)
    oracle = NumpyBackend()
    for plan in plans:
        s_f = fused.execute_compressed(plan)
        s_s = stage.execute_compressed(plan)
        s_n = oracle.execute_compressed(plan)
        np.testing.assert_array_equal(s_f.data, s_n.data)
        np.testing.assert_array_equal(s_f.data, s_s.data)
        assert s_f.n_rows == s_n.n_rows
    # the row-id path flows through the same fused program
    for (rows_f, _), (rows_n, _) in zip(fused.execute_many(plans),
                                        [oracle.execute(p) for p in plans]):
        np.testing.assert_array_equal(rows_f, rows_n)


def test_fused_tape_actually_used():
    """The fused path must really be on: the backend lowers a tape for
    these plans (guards against silently falling back everywhere)."""
    idx, _ = make_index()
    plan = compile_plan(idx, PREDICATES[-1])
    be = JaxBackend()
    be.execute_compressed(plan)
    assert be._fused_tape(plan.root) is not None
    assert JaxBackend(fuse=False)._fused_tape(plan.root) is None


def test_fused_segmented_and_tombstoned_plans():
    """Segmented views route per-segment plans (live-mask wrapped after a
    delete) through the fused path; answers must match the dense oracle."""
    from repro.core import evaluate_mask

    r = np.random.default_rng(5)
    n = 1600
    cols = [r.integers(0, c, size=n) for c in (6, 11, 23)]
    spec = IndexSpec(k=1, row_order="lex", column_order="given")
    w = IndexWriter(spec)
    step = -(-n // 3)
    for i in range(0, n, step):
        w.append([c[i : i + step] for c in cols])
        w.seal()
    w.close()
    view = w.index
    alive = np.ones(n, dtype=bool)
    preds = [And(Eq(0, 2), In(1, [1, 3, 5])), Or(Eq(2, 4), Not(Eq(0, 1)))]

    def check():
        got = view.query_many(preds, backend="jax")
        for p, (rows, _) in zip(preds, got):
            want = np.flatnonzero(evaluate_mask(p, cols) & alive)
            np.testing.assert_array_equal(rows, want)

    check()
    dead = np.arange(64, 256)          # tombstone inside segment 0
    w.delete(row_ids=dead)
    alive[dead] = False
    check()                            # live-mask plans, still fused-path


def test_fused_under_sanitizer():
    """REPRO_SANITIZE=1 structurally validates every stream crossing the
    fused boundary — canonical-form bugs in the fused recompress epilogue
    would throw here."""
    idx, _ = make_index()
    plans = [compile_plan(idx, p) for p in PREDICATES]
    with sanitized():
        for s in JaxBackend().execute_compressed_many(plans):
            s.validate(origin="test_fused_under_sanitizer")


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_fused_matches_numpy_oracle_schedule(seed):
    """Property test: random tables + random nested predicates, fused jax
    streams bit-identical to the numpy backend."""
    r = np.random.default_rng(seed)
    n = int(r.integers(64, 1200))
    cards = [int(c) for c in r.integers(2, 24, size=3)]
    cols = [r.integers(0, c, size=n) for c in cards]
    idx = BitmapIndex.build(cols, IndexSpec(k=1, row_order="lex"))

    def rand_pred(depth=0):
        kind = r.integers(0, 6 if depth < 2 else 3)
        col = int(r.integers(0, len(cards)))
        card = cards[col]
        if kind == 0:
            return Eq(col, int(r.integers(0, card)))
        if kind == 1:
            vals = r.integers(0, card, size=int(r.integers(1, 4)))
            return In(col, [int(v) for v in vals])
        if kind == 2:
            lo = int(r.integers(0, card))
            return Range(col, lo, lo + int(r.integers(0, card)))
        if kind == 3:
            return Not(rand_pred(depth + 1))
        cls = And if kind == 4 else Or
        return cls(*(rand_pred(depth + 1)
                     for _ in range(int(r.integers(2, 4)))))

    plans = [compile_plan(idx, rand_pred()) for _ in range(4)]
    fused = JaxBackend().execute_compressed_many(plans)
    ref = NumpyBackend().execute_compressed_many(plans)
    for s_f, s_n in zip(fused, ref):
        np.testing.assert_array_equal(s_f.data, s_n.data)


# ---------------------------------------------------------------------------
# fallback gate: tape length / VMEM budget
# ---------------------------------------------------------------------------


def test_vmem_model_prices_stack_peak():
    assert planfuse.tape_vmem_bytes(1, 1) == \
        4 * planfuse.ROW_TILE * planfuse.LANE_TILE * 4
    assert planfuse.fits_vmem(4, 3)
    assert not planfuse.fits_vmem(4, 3, budget=0)


def test_fallback_when_tape_too_long(monkeypatch):
    idx, _ = make_index()
    plan = compile_plan(idx, And(Eq(0, 2), Eq(1, 4)))
    monkeypatch.setattr(planfuse, "MAX_TAPE_LEN", 1)
    be = JaxBackend()
    assert be._fused_tape(plan.root) is None      # falls back per-stage
    s = be.execute_compressed(plan)
    np.testing.assert_array_equal(
        s.data, NumpyBackend().execute_compressed(plan).data)


def test_fallback_when_vmem_budget_exceeded(monkeypatch):
    idx, _ = make_index()
    plan = compile_plan(idx, And(Eq(0, 2), Eq(1, 4)))
    monkeypatch.setattr(planfuse, "VMEM_BUDGET_BYTES", 1)
    monkeypatch.setattr(
        planfuse, "fits_vmem",
        lambda m, d, budget=None: planfuse.tape_vmem_bytes(m, d) <= 1)
    be = JaxBackend()
    assert be._fused_tape(plan.root) is None
    s = be.execute_compressed(plan)
    np.testing.assert_array_equal(
        s.data, NumpyBackend().execute_compressed(plan).data)


# ---------------------------------------------------------------------------
# result-cache contract: fused execution populates/hits the same entries
# ---------------------------------------------------------------------------


def _cascade_hit_rate(be, plans):
    be.execute_compressed_many(plans)              # cold populate
    be.result_cache.hits = be.result_cache.misses = 0
    be.execute_compressed_many(plans)              # warm cascade
    return be.result_cache.hit_rate


def test_warm_cascade_hit_rate_unchanged_by_fusion():
    idx, cols = make_index()
    card0 = int(cols[0].max()) + 1
    shared = In(1, [1, 2, 3])
    plans = [compile_plan(idx, And(shared, Eq(0, v % card0)))
             for v in range(12)]
    fused_rate = _cascade_hit_rate(JaxBackend(), plans)
    stage_rate = _cascade_hit_rate(JaxBackend(fuse=False), plans)
    assert fused_rate == stage_rate == 1.0


def test_fused_cache_respects_generation_invalidation():
    """Same predicate, mutated index (new generation -> new leaf digests):
    the fused path must MISS, not serve the stale stream."""
    r = np.random.default_rng(9)
    n = 512
    cols = [r.integers(0, 6, size=n)]
    spec = IndexSpec(k=1, row_order="lex", column_order="given")
    w = IndexWriter(spec)
    w.append(cols)
    w.seal()
    be = JaxBackend()
    pred = Eq(0, 3)
    plan0 = compile_plan(w.segments[0].index, pred)
    s1 = be.execute_compressed(plan0)
    be.result_cache.hits = be.result_cache.misses = 0
    assert be.execute_compressed(plan0) == s1     # warm: same entry hits
    assert be.result_cache.hits == 1
    extra = [r.integers(0, 6, size=128)]
    w.append(extra)
    w.seal()
    seg = w.segments[-1].index
    be.result_cache.hits = be.result_cache.misses = 0
    s2 = be.execute_compressed(compile_plan(seg, pred))
    assert be.result_cache.misses == 1            # new digests: no stale hit
    want = np.flatnonzero(extra[0] == 3)
    np.testing.assert_array_equal(np.sort(seg.row_perm[s2.to_rows()]), want)
    assert s1.n_rows == n and s2.n_rows == 128


# ---------------------------------------------------------------------------
# PlanStats: recording, autotuned buckets, persistence, grouping
# ---------------------------------------------------------------------------


def test_plan_stats_records_and_autotunes():
    ps = Q.PlanStats()

    class FakePlan:
        def __init__(self, lens):
            self.streams = [np.zeros(l, np.uint32) for l in lens]

    for l in [3] * 40 + [100] * 40:
        ps.record(FakePlan([l, 1]))
    assert ps.recorded == 80 and ps.boundaries == ()
    assert ps.capacity_for(3) == Q._capacity_bucket(3)   # cold: pow2
    bounds = ps.autotune(max_buckets=4)
    assert bounds == ps.boundaries and bounds
    assert all(b % 8 == 0 for b in bounds)               # padded to 8
    assert bounds[-1] >= 100
    assert ps.capacity_for(2) == bounds[0]
    # past the top boundary: the pow2 fallback, never a too-small bucket
    assert ps.capacity_for(bounds[-1] + 1) == \
        Q._capacity_bucket(bounds[-1] + 1)


def test_plan_stats_eviction_keeps_newest_half():
    ps = Q.PlanStats()

    class FakePlan:
        def __init__(self, l):
            self.streams = [np.zeros(l, np.uint32)]

    for l in range(ps.MAX_SAMPLES + 10):
        ps.record(FakePlan(1 + l % 7))
    assert ps.recorded == ps.MAX_SAMPLES + 10
    assert len(ps.stats()["boundaries"]) == 0
    assert ps.stats()["samples"] <= ps.MAX_SAMPLES


def test_plan_stats_save_load_roundtrip(tmp_path):
    ps = Q.PlanStats()

    class FakePlan:
        def __init__(self, l):
            self.streams = [np.zeros(l, np.uint32)]

    for l in (4, 9, 200):
        ps.record(FakePlan(l))
    ps.autotune()
    path = tmp_path / "plan_stats.json"
    ps.save(path)
    fresh = Q.PlanStats()
    assert fresh.load(path)
    assert fresh.boundaries == ps.boundaries
    fresh.autotune()                     # sample tail restored too
    assert fresh.boundaries
    assert not Q.PlanStats().load(tmp_path / "missing.json")


def test_compile_plan_feeds_global_recorder():
    idx, _ = make_index()
    before = Q.PLAN_STATS.recorded
    compile_plan(idx, Eq(0, 1))
    assert Q.PLAN_STATS.recorded == before + 1


def test_autotuned_buckets_drive_jax_grouping(monkeypatch):
    """With trained boundaries the backend pads to the quantile bucket,
    not the power of two — and answers stay identical."""
    idx, _ = make_index()
    plans = [compile_plan(idx, p) for p in PREDICATES[:4]]
    ml = max(max(len(s) for s in p.streams) for p in plans)
    ps = Q.PlanStats()
    monkeypatch.setattr(Q, "PLAN_STATS", ps)
    for p in plans:
        ps.record(p)
    ps.autotune(max_buckets=2)
    cap = ps.capacity_for(ml)
    assert cap % 8 == 0 and cap >= ml
    be = JaxBackend()
    groups = be._group(plans)
    assert all(key[1] in set(ps.boundaries) | {Q._capacity_bucket(ml)}
               for key in groups)
    for s, p in zip(be.execute_compressed_many(plans), plans):
        np.testing.assert_array_equal(
            s.data, NumpyBackend().execute_compressed(p).data)
