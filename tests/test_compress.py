"""int8 error-feedback gradient compression: bounds + convergence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.compress import (compress_grads, init_error_feedback,
                                  wire_bytes)


def test_quantization_error_bounded():
    r = np.random.default_rng(0)
    g = {"w": jnp.asarray(r.normal(size=(64, 64)), jnp.float32)}
    ef = init_error_feedback(g)
    gq, ef2 = compress_grads(g, ef)
    err = np.abs(np.asarray(gq["w"]) - np.asarray(g["w"]))
    # per-tensor int8: error <= scale/2 = max|g| / 254
    assert err.max() <= float(jnp.abs(g["w"]).max()) / 254 + 1e-6


def test_error_feedback_corrects_bias():
    """Sum of compressed grads converges to the sum of true grads."""
    r = np.random.default_rng(1)
    true_sum = np.zeros((32,))
    comp_sum = np.zeros((32,))
    g_tree = {"w": jnp.zeros((32,))}
    ef = init_error_feedback(g_tree)
    for i in range(200):
        g = {"w": jnp.asarray(r.normal(size=(32,)) * 0.01, jnp.float32)}
        gq, ef = compress_grads(g, ef)
        true_sum += np.asarray(g["w"])
        comp_sum += np.asarray(gq["w"])
    # residual is bounded by the (one-step) error feedback buffer
    resid = np.abs(true_sum - comp_sum).max()
    assert resid <= float(jnp.abs(ef["w"]).max()) + 1e-5
    assert resid < 0.01


def test_wire_savings():
    g = {"a": jnp.zeros((1024, 1024)), "b": jnp.zeros((512,))}
    assert wire_bytes(g, False) / wire_bytes(g, True) > 3.9


def test_training_with_compression_converges():
    """Linear-regression sanity: EF-compressed SGD still reaches the optimum."""
    r = np.random.default_rng(2)
    X = jnp.asarray(r.normal(size=(256, 8)), jnp.float32)
    w_true = jnp.asarray(r.normal(size=(8,)), jnp.float32)
    y = X @ w_true
    w = {"w": jnp.zeros(8)}
    ef = init_error_feedback(w)

    def loss(w):
        return jnp.mean((X @ w["w"] - y) ** 2)

    for i in range(300):
        g = jax.grad(loss)(w)
        gq, ef = compress_grads(g, ef)
        w = jax.tree.map(lambda p, gg: p - 0.05 * gg, w, gq)
    assert float(loss(w)) < 1e-3
