"""Property tests: EWAH compressed-domain ops obey boolean algebra."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from helpers import random_words
from repro.core import ewah


def comp(words):
    return ewah.compress(words)


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 400), st.integers(0, 50), st.integers(0, 50))
def test_commutativity(n, s1, s2):
    a, b = random_words(n, seed=s1), random_words(n, seed=s2 + 1000)
    for op in ("and", "or", "xor"):
        r1, _ = ewah.logical_op(comp(a), comp(b), op)
        r2, _ = ewah.logical_op(comp(b), comp(a), op)
        np.testing.assert_array_equal(ewah.decompress(r1), ewah.decompress(r2))


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 300), st.integers(0, 50))
def test_idempotence_and_annihilation(n, seed):
    a = random_words(n, seed=seed)
    ca = comp(a)
    r_and, _ = ewah.logical_op(ca, ca, "and")
    np.testing.assert_array_equal(ewah.decompress(r_and), a)
    r_xor, _ = ewah.logical_op(ca, ca, "xor")
    assert ewah.decompress(r_xor).sum() == 0


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 300), st.integers(0, 30), st.integers(0, 30),
       st.integers(0, 30))
def test_de_morgan(n, s1, s2, s3):
    """(A AND B) OR C == NOT(NOT(A AND B) AND NOT C) — via XOR with ones."""
    a, b, c = (random_words(n, seed=s) for s in (s1, s2 + 100, s3 + 200))
    ones = np.full(n, 0xFFFFFFFF, dtype=np.uint32)
    ab, _ = ewah.logical_op(comp(a), comp(b), "and")
    lhs, _ = ewah.logical_op(ab, comp(c), "or")
    nab, _ = ewah.logical_op(ab, comp(ones), "xor")
    nc, _ = ewah.logical_op(comp(c), comp(ones), "xor")
    inner, _ = ewah.logical_op(nab, nc, "and")
    rhs, _ = ewah.logical_op(inner, comp(ones), "xor")
    np.testing.assert_array_equal(ewah.decompress(lhs), ewah.decompress(rhs))


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 300), st.integers(0, 30), st.integers(0, 30))
def test_associativity_many(n, s1, s2):
    a, b, c = (random_words(n, seed=s) for s in (s1, s1 + 7, s2 + 99))
    r1, _ = ewah.logical_many([comp(a), comp(b), comp(c)], "or")
    bc, _ = ewah.logical_op(comp(b), comp(c), "or")
    r2, _ = ewah.logical_op(comp(a), bc, "or")
    np.testing.assert_array_equal(ewah.decompress(r1), ewah.decompress(r2))
