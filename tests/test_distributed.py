"""Distributed-layer tests on a small fake-device mesh.

Runs in a subprocess with XLA_FLAGS host-device-count (so the main pytest
process keeps 1 device for everything else).
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.dist.sharding import (batch_shardings, cache_shardings,
                                 opt_shardings, param_shardings)
from repro.models import transformer
from repro.models.common import ShardingCtx
from repro.optim import OptConfig, init_opt_state
from repro.train import train_step
from functools import partial

results = {}
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_config("tinyllama-1.1b").smoke()

with ShardingCtx(mesh):
    p_sh = param_shardings(mesh, cfg)
    o_sh = opt_shardings(mesh, cfg)
    params = jax.jit(lambda k: transformer.init_params(k, cfg),
                     out_shardings=p_sh)(jax.random.PRNGKey(0))
    opt = jax.jit(init_opt_state, out_shardings=o_sh)(params)
    # param sharding places ff dim on model axis
    wg = params["layers"]["ffn"]["w_gate"]
    results["ffn_sharded"] = "model" in str(wg.sharding.spec)
    # ZeRO: moments pick up the data axis somewhere
    mm = opt["m"]["layers"]["ffn"]["w_gate"]
    results["zero1"] = "data" in str(mm.sharding.spec)

    b_sh = batch_shardings(mesh, cfg, "train")
    batch = {
        "inputs": jax.device_put(
            np.random.randint(0, cfg.vocab_size, (8, 32)), b_sh["inputs"]),
        "labels": jax.device_put(
            np.random.randint(0, cfg.vocab_size, (8, 32)), b_sh["labels"]),
    }
    opt_cfg = OptConfig(total_steps=10, warmup_steps=1)
    step = jax.jit(partial(train_step, cfg=cfg, opt_cfg=opt_cfg,
                           microbatches=2, grad_shardings=o_sh["m"]),
                   in_shardings=(p_sh, o_sh, b_sh),
                   out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))
    p2, o2, m = step(params, opt, batch)
    results["loss_finite"] = bool(np.isfinite(float(m["loss"])))
    results["sharded_loss"] = float(m["loss"])

# single-device reference: same math without mesh
cfg1 = cfg
params1 = transformer.init_params(jax.random.PRNGKey(0), cfg1)
opt1 = init_opt_state(params1)
batch1 = {k: np.asarray(v) for k, v in batch.items()}
p1, o1, m1 = jax.jit(partial(train_step, cfg=cfg1, opt_cfg=opt_cfg,
                             microbatches=2))(params1, opt1, batch1)
results["ref_loss"] = float(m1["loss"])
print("RESULTS:" + json.dumps(results))
"""


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULTS:")][0]
    return json.loads(line[len("RESULTS:"):])


def test_param_tp_sharding(dist_results):
    assert dist_results["ffn_sharded"]


def test_zero1_moment_sharding(dist_results):
    assert dist_results["zero1"]


def test_sharded_step_runs(dist_results):
    assert dist_results["loss_finite"]


def test_sharded_matches_single_device(dist_results):
    """Distribution must not change the math (same seed, same loss)."""
    np.testing.assert_allclose(
        dist_results["sharded_loss"], dist_results["ref_loss"],
        rtol=2e-2, atol=2e-2)
