"""Distributed-layer tests on a small fake-device mesh.

Runs in a subprocess with XLA_FLAGS host-device-count (so the main pytest
process keeps 1 device for everything else).
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.dist.sharding import (batch_shardings, cache_shardings,
                                 opt_shardings, param_shardings,
                                 zero_pad_for)
from repro.models import transformer
from repro.models.common import ShardingCtx
from repro.optim import OptConfig, init_opt_state
from repro.train import train_step
from functools import partial

results = {}
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_config("tinyllama-1.1b").smoke()

with ShardingCtx(mesh):
    p_sh = param_shardings(mesh, cfg)
    o_sh = opt_shardings(mesh, cfg)
    zp = zero_pad_for(mesh)
    params = jax.jit(lambda k: transformer.init_params(k, cfg),
                     out_shardings=p_sh)(jax.random.PRNGKey(0))
    opt = jax.jit(partial(init_opt_state, zero_pad=zp),
                  out_shardings=o_sh)(params)
    # param sharding places ff dim on model axis
    wg = params["layers"]["ffn"]["w_gate"]
    results["ffn_sharded"] = "model" in str(wg.sharding.spec)
    # ZeRO: moments pick up the data axis somewhere
    mm = opt["m"]["layers"]["ffn"]["w_gate"]
    results["zero1"] = "data" in str(mm.sharding.spec)
    # flat ZeRO-1: EVERY moment leaf is 1-D, padded to the data-axis
    # size, and actually sharded over "data" — dimension divisibility
    # no longer decides which leaves shard
    results["zero1_pad"] = zp
    m_leaves = jax.tree.leaves(opt["m"])
    results["zero1_all_flat"] = all(
        l.ndim == 1 and l.shape[0] % zp == 0 for l in m_leaves)
    results["zero1_all_sharded"] = all(
        "data" in str(l.sharding.spec) for l in m_leaves)

    b_sh = batch_shardings(mesh, cfg, "train")
    rng = np.random.default_rng(0)
    batch = {
        "inputs": jax.device_put(
            rng.integers(0, cfg.vocab_size, (8, 32)), b_sh["inputs"]),
        "labels": jax.device_put(
            rng.integers(0, cfg.vocab_size, (8, 32)), b_sh["labels"]),
    }
    opt_cfg = OptConfig(total_steps=10, warmup_steps=1)
    step = jax.jit(partial(train_step, cfg=cfg, opt_cfg=opt_cfg,
                           microbatches=2, grad_shardings=o_sh["m"]),
                   in_shardings=(p_sh, o_sh, b_sh),
                   out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))
    p2, o2, m = step(params, opt, batch)
    results["loss_finite"] = bool(np.isfinite(float(m["loss"])))
    results["sharded_loss"] = float(m["loss"])

# single-device reference: same math without mesh
cfg1 = cfg
params1 = transformer.init_params(jax.random.PRNGKey(0), cfg1)
opt1 = init_opt_state(params1)
batch1 = {k: np.asarray(v) for k, v in batch.items()}
p1, o1, m1 = jax.jit(partial(train_step, cfg=cfg1, opt_cfg=opt_cfg,
                             microbatches=2))(params1, opt1, batch1)
results["ref_loss"] = float(m1["loss"])
print("RESULTS:" + json.dumps(results))
"""


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULTS:")][0]
    return json.loads(line[len("RESULTS:"):])


def test_param_tp_sharding(dist_results):
    assert dist_results["ffn_sharded"]


def test_zero1_moment_sharding(dist_results):
    assert dist_results["zero1"]


def test_zero1_flat_shards_every_leaf(dist_results):
    """Regression (ROADMAP): flat ZeRO-1 — moments store 1-D, padded to
    the data-axis size, and every leaf shards over "data", including
    leaves whose dims the old placement could not divide."""
    assert dist_results["zero1_pad"] == 4
    assert dist_results["zero1_all_flat"]
    assert dist_results["zero1_all_sharded"]


def test_zero1_flat_apply_updates_matches_param_shaped():
    """The flat+padded moment storage computes bit-for-bit the same update
    as param-shaped moments (padding lanes stay exactly zero), including
    leaves whose sizes do not divide the pad multiple."""
    import jax
    import jax.numpy as jnp

    from repro.optim import OptConfig, apply_updates, init_opt_state

    r = np.random.default_rng(0)
    # 15, 7, 1: none divisible by 4 — the shapes the old placement skipped
    params = {"a": jnp.asarray(r.normal(size=(5, 3)), jnp.float32),
              "b": jnp.asarray(r.normal(size=(7,)), jnp.float32),
              "c": jnp.asarray(r.normal(size=(1,)), jnp.float32),
              "d": jnp.asarray(r.normal(size=(4, 2)), jnp.float32)}
    grads = jax.tree.map(
        lambda p: jnp.asarray(r.normal(size=p.shape), jnp.float32), params)
    cfg = OptConfig(total_steps=10, warmup_steps=1)

    s_ref = init_opt_state(params)
    s_flat = init_opt_state(params, zero_pad=4)
    assert all(l.ndim == 1 and l.shape[0] % 4 == 0
               for l in jax.tree.leaves(s_flat["m"]))

    for _ in range(3):  # a few steps so moments are non-trivial
        p_ref, s_ref, _ = apply_updates(cfg, params, grads, s_ref)
        p_flat, s_flat, _ = apply_updates(cfg, params, grads, s_flat)
        jax.tree.map(np.testing.assert_array_equal, p_ref, p_flat)
    # moments agree after unflattening, and the padding stays zero
    for key in ("m", "v"):
        for name, ref_leaf in s_ref[key].items():
            flat_leaf = s_flat[key][name]
            np.testing.assert_array_equal(
                np.asarray(flat_leaf)[: ref_leaf.size].reshape(ref_leaf.shape),
                np.asarray(ref_leaf))
            np.testing.assert_array_equal(
                np.asarray(flat_leaf)[ref_leaf.size:], 0.0)


def test_sharded_step_runs(dist_results):
    assert dist_results["loss_finite"]


def test_sharded_matches_single_device(dist_results):
    """Distribution must not change the math (same seed, same loss)."""
    np.testing.assert_allclose(
        dist_results["sharded_loss"], dist_results["ref_loss"],
        rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# Query fan-out over row-range index shards (repro.dist.query_fanout) —
# in-process, no mesh needed.
# ---------------------------------------------------------------------------


def _fanout_fixture(n=4017, seed=11, k=2):
    from repro.core import BitmapIndex, IndexSpec
    from repro.dist.query_fanout import ShardedIndex

    r = np.random.default_rng(seed)
    cols = [r.integers(0, c, size=n) for c in (6, 11, 29)]
    spec = IndexSpec(k=k, row_order="grayfreq")
    return cols, BitmapIndex.build(cols, spec), \
        ShardedIndex.build(cols, spec, n_shards=4)


def test_shard_ranges_word_aligned():
    from repro.dist.query_fanout import shard_ranges

    for n, s in [(1000, 4), (31, 4), (64, 2), (65, 4), (100_000, 7), (32, 1)]:
        ranges = shard_ranges(n, s)
        assert ranges[0][0] == 0 and ranges[-1][1] == n
        assert all(start % 32 == 0 for start, _ in ranges)
        assert all(b == c for (_, b), (c, _) in zip(ranges, ranges[1:]))


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_fanout_4_shards_matches_single(backend):
    """Fan-out over a 4-shard split returns identical row ids to
    single-shard execution, for every predicate shape."""
    from repro.core import And, Eq, In, Not, Or, Range

    cols, single, sharded = _fanout_fixture()
    assert sharded.n_shards == 4
    preds = [
        Eq(0, 3), In(1, [1, 5, 9]), Range(2, 4, 25), Range(2, 2, 27),
        Not(Eq(0, 0)),
        And(In(0, [0, 1, 2]), Range(1, 0, 6), Not(Eq(2, 5))),
        Or(And(Eq(0, 1), Eq(1, 1)), Not(In(2, [0, 1, 2]))),
    ]
    for pred in preds:
        rows_single, _ = single.query(pred, backend=backend)
        expect = np.sort(single.row_perm[rows_single])
        got, scanned = sharded.query(pred, backend=backend)
        np.testing.assert_array_equal(got, expect)
        assert scanned >= 0


def test_fanout_ships_compressed_and_coalesces():
    """Shards ship EWAH streams; the merge is concatenation with clean-run
    coalescing, so the merged stream counts exactly the matched rows and
    is no longer than the sum of its parts."""
    from repro.core import Eq, Not

    cols, single, sharded = _fanout_fixture()
    for pred in (Eq(0, 3), Not(Eq(1, 2))):
        results, merged = sharded.execute_compressed(pred)
        assert len(results) == 4
        assert merged.n_rows == len(cols[0])
        rows_single, _ = single.query(pred)
        assert merged.count() == len(rows_single)
        assert len(merged) <= sum(len(r) for r in results)
        # per-shard word alignment: every shard but the last covers a
        # multiple of 32 rows
        assert all(sh.n_rows % 32 == 0 for sh in sharded.shards[:-1])
    # shards are Segments sealed WITHOUT the raw-column row store (they
    # are never compacted; keeping the arrays would double memory)
    assert all(sh.columns is None for sh in sharded.shards)


def test_fanout_shard_local_value_domains():
    """A value only some shards ever saw still resolves globally (missing
    shards compile it to a constant-empty plan)."""
    from repro.core import Eq
    from repro.core.strategies import IndexSpec
    from repro.dist.query_fanout import ShardedIndex

    col = np.zeros(256, dtype=np.int64)
    col[200:210] = 7                    # value 7 exists only in shard 4
    sharded = ShardedIndex.build([col], IndexSpec(k=1, row_order="unsorted",
                                                  column_order="given"),
                                 n_shards=4)
    rows, _ = sharded.query(Eq(0, 7))
    np.testing.assert_array_equal(rows, np.arange(200, 210))


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_fanout_query_many_batches_across_predicates(backend):
    """query_many sends all predicates' per-shard plans to the backend in
    one call and matches per-predicate query() results."""
    from repro.core import Eq, In

    cols, single, sharded = _fanout_fixture()
    preds = [Eq(0, v) for v in range(4)] + [In(1, [1, 5])]
    batched = sharded.query_many(preds, backend=backend)
    for pred, (rows, scanned) in zip(preds, batched):
        one_rows, one_scanned = sharded.query(pred, backend=backend)
        np.testing.assert_array_equal(rows, one_rows)
        rows_single, _ = single.query(pred, backend=backend)
        np.testing.assert_array_equal(
            rows, np.sort(single.row_perm[rows_single]))


def test_metadata_index_query_fanout():
    """MetadataIndex(query_fanout=N) routes queries through the sharded
    path (original-row-space ids) and guards the single-index accessor."""
    from repro.core import In
    from repro.data.metadata_index import MetadataIndex

    r = np.random.default_rng(5)
    meta = {c: r.integers(0, k, size=500) for c, k in
            zip(MetadataIndex.COLS, (4, 8, 16, 6))}
    plain = MetadataIndex(k=1)
    plain.add_batch(meta)
    fanned = MetadataIndex(k=1, query_fanout=4)
    fanned.add_batch(meta)

    # both modes answer in original ingest row space
    rows_plain, _ = plain.query(where={"domain": 3, "quality_bin": 8})
    expect = np.flatnonzero((meta["domain"] == 3) & (meta["quality_bin"] == 8))
    np.testing.assert_array_equal(rows_plain, expect)
    rows_fan, _ = fanned.query(where={"domain": 3, "quality_bin": 8})
    np.testing.assert_array_equal(rows_fan, expect)
    rows_pred, _ = fanned.query_pred(In("domain", [1, 3]), backend="jax")
    np.testing.assert_array_equal(
        rows_pred, np.flatnonzero(np.isin(meta["domain"], [1, 3])))
    assert fanned.sharded.n_shards == 4
    assert fanned.size_words() > 0
    with pytest.raises(ValueError, match="sharded"):
        fanned.index  # would silently build a second, inconsistent surface


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_fanout_deletes_and_ttl(backend):
    """ShardedIndex.delete tombstones across the fan-out — each shard ORs
    its share into its compressed tombstone bitmap and later queries AND
    the live mask in — and expiry deadlines fold lazily on the build
    clock.  Answers track a dense oracle throughout."""
    from repro.core import Eq, In, Range, evaluate_mask
    from repro.core.strategies import IndexSpec
    from repro.dist.query_fanout import ShardedIndex

    r = np.random.default_rng(9)
    cols = [r.integers(0, 6, size=500), r.integers(0, 11, size=500)]
    fake = [1000.0]
    expiry = np.full(500, np.inf)
    expiry[100:200] = 1050.0
    sharded = ShardedIndex.build(
        cols, IndexSpec(k=1, row_order="unsorted", column_order="given"),
        n_shards=4, expiry=expiry, clock=lambda: fake[0])
    alive = np.ones(500, dtype=bool)
    assert sharded.delete(row_ids=np.arange(40, 80)) == 40
    alive[40:80] = False
    kill = Eq(0, 2)
    expect = int((evaluate_mask(kill, cols) & alive).sum())
    assert sharded.delete(kill, backend=backend) == expect
    alive &= ~evaluate_mask(kill, cols)
    preds = [Eq(0, 3), In(1, [1, 5, 9]), Range(1, 2, 8)]
    for p in preds:
        rows, _ = sharded.query(p, backend=backend)
        np.testing.assert_array_equal(
            rows, np.flatnonzero(evaluate_mask(p, cols) & alive))
    fake[0] = 1100.0                             # cross the TTL deadline
    alive[100:200] = False
    for p in preds:
        rows, _ = sharded.query(p, backend=backend)
        np.testing.assert_array_equal(
            rows, np.flatnonzero(evaluate_mask(p, cols) & alive))


def test_metadata_index_fanout_lsm_matches_single():
    """MetadataIndex deletes / TTLs / compaction answer identically through
    the fan-out and the single segmented path (the fan-out view rebuilds
    over the surviving ingest ids, so ids stay stable across purges)."""
    from repro.data.metadata_index import MetadataIndex

    r = np.random.default_rng(11)

    def batch(n):
        return {c: r.integers(0, k, size=n) for c, k in
                zip(MetadataIndex.COLS, (4, 8, 16, 6))}

    fake = [1000.0]
    fan = MetadataIndex(query_fanout=3)
    fan.writer.clock = lambda: fake[0]
    single = MetadataIndex()
    single.writer.clock = lambda: fake[0]
    batches = [batch(100) for _ in range(3)]
    for i, b in enumerate(batches):
        ttl = 50.0 if i == 1 else None
        fan.add_batch(b, ttl=ttl)
        single.add_batch(b, ttl=ttl)
    assert fan.delete(where={"domain": 2}) == \
        single.delete(where={"domain": 2})
    fan.delete(row_ids=np.arange(10, 40))
    single.delete(row_ids=np.arange(10, 40))
    queries = [{"source": 1}, {"quality_bin": 5, "source": 2}]
    for q in queries:
        a, _ = fan.query(q)
        b, _ = single.query(q)
        np.testing.assert_array_equal(a, b)
    _ = fan.sharded                              # build pre-expiry
    fake[0] = 1100.0                             # batch 1 TTLs out lazily
    for q in queries:
        a, _ = fan.query(q)
        b, _ = single.query(q)
        np.testing.assert_array_equal(a, b)
        assert not ((a >= 100) & (a < 200)).any()
    single.compact(span=(0, len(single.writer.segments)))  # physical purge
    fan._sharded = None                          # rebuild over survivors
    for backend in ("numpy", "jax"):
        for q in queries:
            a, _ = fan.query(q, backend=backend)
            b, _ = single.query(q, backend=backend)
            np.testing.assert_array_equal(a, b)
