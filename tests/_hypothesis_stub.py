"""Deterministic fallback for `hypothesis` when it isn't installed.

conftest.py aliases this module into sys.modules *only* when the real
package is missing, so environments with hypothesis keep full shrinking /
database behaviour.  The stub covers exactly the subset this suite uses —
``@settings(max_examples=, deadline=)`` over ``@given`` with
``st.integers(lo, hi)``, ``st.sampled_from(seq)``,
``st.tuples(*elems)``, and ``st.lists(elem, min_size=, max_size=)`` —
drawing examples from a per-test fixed-seed RNG (seeded by the test name)
so failures reproduce across runs.  Boundary values (all-lo / all-hi) are
always tried first, standing in for hypothesis's shrinking toward simple
examples.
"""

from __future__ import annotations

import types
import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw, lo=None, hi=None):
        self._draw = draw
        self._lo = lo  # simplest example (shrink target stand-in)
        self._hi = hi

    def example(self, rng):
        return self._draw(rng)


def _integers(min_value, max_value):
    return _Strategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)),
        lo=min_value, hi=max_value)


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(
        lambda rng: elements[int(rng.integers(0, len(elements)))],
        lo=elements[0], hi=elements[-1])


def _tuples(*strats):
    def draw(rng):
        return tuple(s.example(rng) for s in strats)

    lo = (tuple(s._lo for s in strats)
          if all(s._lo is not None for s in strats) else None)
    hi = (tuple(s._hi for s in strats)
          if all(s._hi is not None for s in strats) else None)
    return _Strategy(draw, lo=lo, hi=hi)


def _lists(elements, min_size=0, max_size=10):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(n)]

    lo = [elements._lo] * min_size if elements._lo is not None else []
    return _Strategy(draw, lo=lo, hi=None)


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.lists = _lists
strategies.sampled_from = _sampled_from
strategies.tuples = _tuples


def settings(max_examples: int = 100, deadline=None, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*strats):
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_stub_max_examples", 100)
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            cases = []
            if all(s._lo is not None for s in strats):
                cases.append([s._lo for s in strats])
            if all(s._hi is not None for s in strats):
                cases.append([s._hi for s in strats])
            while len(cases) < n:
                cases.append([s.example(rng) for s in strats])
            for i, args in enumerate(cases[:n]):
                try:
                    fn(*args)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} falsified on example {i}: "
                        f"args={args!r}") from e

        # plain attribute copies (functools.wraps would expose the original
        # argful signature via __wrapped__ and pytest would demand fixtures)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
