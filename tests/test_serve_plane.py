"""Multi-host serve plane: cross-process fan-out, compressed wire
shipping, and the sharded two-phase checkpoint commit.

The process harness spawns real worker subprocesses (loopback TCP, the
production transport) and proves every query surface bit-identical to
the single-process ``SegmentedIndex`` over an identically-built writer —
across all encodings, with tombstones, TTLs, an open buffer, and live
compaction racing queries.
"""

import os
import socket
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ewah
from repro.core.ewah_stream import EwahStream, concat_streams
from repro.core.lifecycle import BackgroundCompactor, IndexWriter
from repro.core.query import And, Eq, In, Not, Or, Range
from repro.core.segment import Segment, SegmentedIndex
from repro.core.strategies import IndexSpec
from repro.dist import checkpoint as ckpt
from repro.dist.query_fanout import assign_segments
from repro.dist.serve_plane import (ServePlane, WireError, recv_msg,
                                    seal_from_state, segment_state,
                                    send_msg)

KINDS = ["equality", "bitsliced", "bitsliced-gray", "binned", "roaring"]

PREDS = [
    Eq(0, 5),
    Eq(1, 117),
    Range(1, 40, 160),
    In(2, [1, 7, 23]),
    And(Eq(0, 3), Not(Eq(2, 2))),
    Or(Range(1, 0, 30), Eq(2, 31)),
    Not(Eq(0, 0)),
]

T0 = 1000.0


def build_writer(clock, n_per: int = 224):
    """Deterministic writer: one segment per encoding kind (the chooser
    pinned), three histogram-auto segments, staggered TTL deadlines, and
    a non-word-aligned open-buffer tail.  Two calls build bit-identical
    states (modulo segment generations)."""
    spec = IndexSpec(encoding="auto")
    rng = np.random.default_rng(42)
    segs, pos = [], 0
    for i, kind in enumerate(KINDS + [None, None, None]):
        cols = [rng.integers(0, 12, n_per), rng.integers(0, 200, n_per),
                rng.integers(0, 40, n_per)]
        expiry = np.full(n_per, np.inf)
        expiry[::9] = T0 + 5.0 * (i + 1)
        chooser = None if kind is None else (
            lambda c, h, k, _k=kind: _k)
        segs.append(Segment.seal(cols, spec, row_start=pos, expiry=expiry,
                                 encoding_chooser=chooser))
        pos += n_per
    w = IndexWriter.from_parts(spec, segments=tuple(segs), clock=clock)
    tail = [rng.integers(0, 12, 40), rng.integers(0, 200, 40),
            rng.integers(0, 40, 40)]
    w.append(tail, ttl=200.0)
    return w


def assert_plane_matches(ref: IndexWriter, plane: ServePlane, now,
                         backend: str = "numpy", **opts):
    """Every query surface agrees bit-for-bit with the single-process
    engine: row ids, merged streams, and compressed-domain counts.

    ``words_scanned`` is deliberately NOT compared: the result cache keys
    on leaf *content*, so scan counts depend on what the executing
    process ran before (a hit reports fewer scanned words) — the single
    process gets cross-segment hits that isolated workers cannot share.
    """
    want = ref.index.execute_compressed_many(PREDS, backend=backend,
                                             now=now, **opts)
    got = plane.execute_compressed_many(PREDS, backend=backend, now=now,
                                        **opts)
    for pred, (_, wm), (_, gm) in zip(PREDS, want, got):
        assert wm == gm, f"merged stream for {pred}"  # content equality
    want_rows = ref.index.query_many(PREDS, backend=backend, now=now,
                                     **opts)
    got_rows = plane.query_many(PREDS, backend=backend, now=now, **opts)
    for pred, (wr, _), (gr, gs) in zip(PREDS, want_rows, got_rows):
        np.testing.assert_array_equal(wr, gr, err_msg=f"rows for {pred}")
        assert gs >= 0
    want_counts = [ref.index.count(p, backend=backend, now=now, **opts)
                   for p in PREDS]
    assert plane.count_many(PREDS, backend=backend, now=now,
                            **opts) == want_counts


# ---------------------------------------------------------------------------
# The 8-host acceptance matrix
# ---------------------------------------------------------------------------


def test_eight_host_lifecycle_bit_identity():
    """8 worker processes, every encoding kind (pinned + histogram-auto),
    tombstones, TTL expiry, an open buffer, and compaction — every stage
    bit-identical to the single-process engine."""
    clock = [T0]
    ref = build_writer(lambda: clock[0])
    with ServePlane(build_writer(lambda: clock[0]), n_hosts=8) as plane:
        assert plane.world_size == 8
        assert_plane_matches(ref, plane, now=clock[0])
        # the fleet actually shares the load: segments spread over ranks
        assert len(set(plane._owner_of.values())) >= 4

        # deletes: sealed segments + open buffer, broadcast to owners
        ids = np.concatenate([np.arange(50, 400, 7),
                              np.arange(1800, 1835)])  # buffer span too
        assert ref.delete(row_ids=ids) == plane.delete(row_ids=ids)
        assert_plane_matches(ref, plane, now=clock[0])

        # predicate delete resolves to the identical row set
        assert ref.delete(Eq(2, 9), now=clock[0]) == \
            plane.delete(Eq(2, 9), now=clock[0])
        assert_plane_matches(ref, plane, now=clock[0])

        # TTLs: advance the shared clock past three segments' deadlines;
        # workers fold expiry against the coordinator's "now"
        clock[0] = T0 + 16.0
        assert_plane_matches(ref, plane, now=None)

        # compaction: explicit span, then the size-tiered policy — both
        # re-encode from merged histograms and re-home ownership
        assert ref.compact(span=(0, 3)) is not None
        assert plane.compact(span=(0, 3)) is not None
        assert_plane_matches(ref, plane, now=clock[0])
        assert (ref.compact(fanout=4, ratio=50.0) is None) == \
            (plane.compact(fanout=4, ratio=50.0) is None)
        assert_plane_matches(ref, plane, now=clock[0])

        # close the writer: the final (non-aligned) segment seals and the
        # plane keeps serving it
        ref.close()
        plane.writer_close()
        assert_plane_matches(ref, plane, now=clock[0])

        stats = plane.stats()
        assert stats["result_bytes_compressed"] > 0
        assert stats["ship_bytes"] > 0


def test_two_host_jax_fused_bit_identity():
    """The jax backend (megakernel fusion on) runs inside workers and
    still merges bit-identically with the numpy reference."""
    clock = [T0]
    ref = build_writer(lambda: clock[0], n_per=96)
    with ServePlane(build_writer(lambda: clock[0], n_per=96),
                    n_hosts=2) as plane:
        want = ref.index.query_many(PREDS, backend="numpy", now=clock[0])
        got = plane.query_many(PREDS, backend="jax", now=clock[0])
        for (wr, _), (gr, _) in zip(want, got):
            np.testing.assert_array_equal(wr, gr)


def test_compaction_races_queries():
    """A background compactor keeps merging (and the plane keeps
    re-homing segments) while queries stream; every answer equals the
    precomputed truth — readers never see a torn segment list."""
    clock = [T0]
    w = build_writer(lambda: clock[0])
    expected = [rows for rows, _ in w.index.query_many(PREDS, now=T0)]
    with ServePlane(w, n_hosts=2) as plane:
        compactor = BackgroundCompactor(w, interval=0.001, fanout=2,
                                        ratio=50.0)
        try:
            deadline = time.monotonic() + 30.0
            rounds = 0
            while (compactor.stats["compactions"] < 2
                   and time.monotonic() < deadline):
                got = plane.query_many(PREDS, now=T0)
                for want_rows, (rows, _) in zip(expected, got):
                    np.testing.assert_array_equal(want_rows, rows)
                rounds += 1
        finally:
            compactor.close()
        assert compactor.stats["compactions"] >= 1
        assert rounds >= 1
        got = plane.query_many(PREDS, now=T0)
        for want_rows, (rows, _) in zip(expected, got):
            np.testing.assert_array_equal(want_rows, rows)


# ---------------------------------------------------------------------------
# Sharded two-phase checkpoint commit
# ---------------------------------------------------------------------------


def test_sharded_checkpoint_roundtrip_and_resharding(tmp_path):
    """Each host writes only the segment dirs it owns; the commit barrier
    flips LATEST only after every CRC ack; restore reassembles the full
    writer and re-shards over a *smaller* world (a host lost since the
    save is tolerated by design)."""
    clock = [T0]
    ref = build_writer(lambda: clock[0])
    ref.delete(row_ids=np.arange(0, 500, 11))
    with ServePlane(build_writer(lambda: clock[0]), n_hosts=4) as plane:
        plane.delete(row_ids=np.arange(0, 500, 11))
        plane.save_checkpoint(str(tmp_path), 1)
        want_step1 = ref.index.query_many(PREDS, now=T0)

        # mutate past the save point, save again
        ref.delete(row_ids=np.arange(600, 900, 5))
        plane.delete(row_ids=np.arange(600, 900, 5))
        plane.save_checkpoint(str(tmp_path), 2, keep=2)
        want_step2 = ref.index.query_many(PREDS, now=T0)

    assert ckpt.latest_step(str(tmp_path)) == 2
    step2 = os.path.join(str(tmp_path), "step_00000002")
    # per-host sharding really happened: one dir per segment + manifest
    seg_dirs = [d for d in os.listdir(step2) if d.startswith("segment_")]
    assert len(seg_dirs) == 8
    import json
    with open(os.path.join(step2, "manifest.json")) as f:
        manifest = json.load(f)
    assert sorted(set(manifest["owners"])) != [0]  # spread over hosts

    # restore at HALF the world size: ownership re-shards over 2 hosts
    with ServePlane.restore(str(tmp_path), n_hosts=2,
                            clock=lambda: clock[0]) as restored:
        assert restored.restored_step == 2
        got = restored.query_many(PREDS, now=T0)
        for (wr, _), (gr, _) in zip(want_step2, got):
            np.testing.assert_array_equal(wr, gr)
        assert len(set(restored._owner_of.values())) <= 2

    # corrupt one shard of the newest step: load falls back to step 1
    victim = os.path.join(step2, "segment_00003", "state.npz")
    with open(victim, "r+b") as f:
        f.seek(30)
        byte = f.read(1)
        f.seek(30)
        f.write(bytes([byte[0] ^ 0xFF]))
    with ServePlane.restore(str(tmp_path), n_hosts=2,
                            clock=lambda: clock[0]) as fallback:
        assert fallback.restored_step == 1
        got = fallback.query_many(PREDS, now=T0)
        for (wr, _), (gr, _) in zip(want_step1, got):
            np.testing.assert_array_equal(wr, gr)


# ---------------------------------------------------------------------------
# Wire framing + state shipping (no subprocesses)
# ---------------------------------------------------------------------------


def test_wire_roundtrip_and_crc():
    a, b = socket.socketpair()
    try:
        payload = {"xs": np.arange(5), "s": "héllo", "n": 7}
        send_msg(a, "ship", payload)
        op, got, n = recv_msg(b)
        assert op == "ship" and got["n"] == 7 and got["s"] == "héllo"
        np.testing.assert_array_equal(got["xs"], np.arange(5))
        assert n > 0

        # flip one payload byte: the CRC must catch it
        import pickle
        import struct
        import zlib
        from repro.dist import serve_plane as sp
        body = pickle.dumps(("ship", payload))
        frame = sp._FRAME.pack(sp._FRAME_MAGIC, sp._FRAME_VERSION, 0, 0,
                               len(body), zlib.crc32(body))
        corrupted = bytearray(body)
        corrupted[3] ^= 0xFF
        a.sendall(frame + bytes(corrupted))
        with pytest.raises(WireError, match="CRC"):
            recv_msg(b)

        # wrong magic is rejected before any payload read
        a.sendall(sp._FRAME.pack(b"NOPE", sp._FRAME_VERSION, 0, 0, 0, 0))
        with pytest.raises(WireError, match="magic"):
            recv_msg(b)
    finally:
        a.close()
        b.close()


def test_segment_state_reseal_is_bit_identical():
    """segment_state -> seal_from_state reproduces the exact index —
    row permutation, per-column encodings, compressed size — including
    tombstones, TTLs, and a purged (row_ids) span, regardless of which
    chooser originally picked the encodings."""
    rng = np.random.default_rng(3)
    n = 160
    keep = np.sort(rng.choice(200, size=n, replace=False)).astype(np.int64)
    expiry = np.full(n, np.inf)
    expiry[::5] = T0 + 3
    seg = Segment.seal(
        [rng.integers(0, 9, n), rng.integers(0, 300, n)],
        IndexSpec(encoding="auto"), row_start=int(keep[0]),
        span_stop=205, row_ids=keep, expiry=expiry,
        encoding_chooser=lambda c, h, k: "roaring" if c == 0 else None)
    seg.delete_ids(keep[::7])

    rebuilt = seal_from_state(segment_state(seg), IndexSpec(encoding="auto"))
    np.testing.assert_array_equal(seg.index.row_perm,
                                  rebuilt.index.row_perm)
    assert seg.index.encodings() == rebuilt.index.encodings()
    assert seg.index.size_words() == rebuilt.index.size_words()
    assert seg.row_stop == rebuilt.row_stop
    np.testing.assert_array_equal(seg.ingest_ids(), rebuilt.ingest_ids())
    for surface in (seg, rebuilt):
        surface.fold_expired(T0 + 10)
    assert seg.tombstones == rebuilt.tombstones
    np.testing.assert_array_equal(seg.dead_ids(T0 + 10),
                                  rebuilt.dead_ids(T0 + 10))


def test_segment_state_rejects_dropped_row_store():
    seg = Segment.seal([np.arange(64) % 5], None, keep_columns=False)
    with pytest.raises(ValueError, match="keep_columns"):
        segment_state(seg)


def test_zero_row_segment_state_roundtrip():
    empty = Segment.empty(96, 160)
    rebuilt = seal_from_state(segment_state(empty), None)
    assert rebuilt.n_rows == 0
    assert (rebuilt.row_start, rebuilt.row_stop) == (96, 160)


# ---------------------------------------------------------------------------
# Placement policy
# ---------------------------------------------------------------------------


class _FakeSeg:
    def __init__(self, words):
        self._words = words

    def size_words(self):
        return self._words


def test_assign_segments_contiguous_and_balanced():
    owners = assign_segments([_FakeSeg(100)] * 8, 8)
    assert owners == list(range(8))          # equal sizes: one each
    owners = assign_segments([_FakeSeg(50)] * 16, 4)
    assert owners == sorted(owners)          # contiguous runs per host
    assert all(owners.count(r) == 4 for r in range(4))
    # skew: one huge segment pulls the boundary, small ones pack together
    owners = assign_segments(
        [_FakeSeg(10_000)] + [_FakeSeg(10)] * 6, 2)
    assert owners[0] == 0 and owners[-1] == 1
    assert owners == sorted(owners)


def test_assign_segments_edges():
    assert assign_segments([], 4) == []
    assert assign_segments([_FakeSeg(5)], 8) == [0]
    owners = assign_segments([_FakeSeg(0), _FakeSeg(0)], 2)  # floor 1
    assert owners == sorted(owners) and set(owners) <= {0, 1}
    with pytest.raises(ValueError):
        assign_segments([_FakeSeg(1)], 0)


# ---------------------------------------------------------------------------
# Satellite: any word-aligned partition concatenates bit-identically
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10**6),
       st.lists(st.tuples(st.integers(0, 6),
                          st.sampled_from(["random", "zeros", "ones"])),
                min_size=1, max_size=6))
def test_concat_any_word_aligned_partition(seed, parts):
    """concat_streams over ANY word-aligned partition — including
    zero-row shards (empty parts) and fully-tombstoned shards (all-zero
    result parts) — is bit-identical to compressing the unpartitioned
    whole."""
    rng = np.random.default_rng(seed)
    pieces = []
    for n_words, style in parts:
        if style == "random":
            piece = rng.integers(0, 1 << 32, n_words, dtype=np.uint64)
            piece = piece.astype(np.uint32)
        elif style == "zeros":
            piece = np.zeros(n_words, dtype=np.uint32)
        else:
            piece = np.full(n_words, 0xFFFFFFFF, dtype=np.uint32)
        pieces.append(piece)
    whole = (np.concatenate(pieces) if pieces
             else np.zeros(0, dtype=np.uint32))
    merged = concat_streams([ewah.compress(p) for p in pieces])
    np.testing.assert_array_equal(merged, ewah.compress(whole))
    n_rows = len(whole) * 32
    assert (EwahStream(merged, n_rows).count()
            == int(np.bitwise_count(whole).sum()))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10**6),
       st.lists(st.integers(0, 4), min_size=1, max_size=5))
def test_partitioned_segments_query_like_one(seed, weights):
    """Query-level partition property: segments sealed over any
    word-aligned split of the same rows (zero-row shards included, one
    shard fully tombstoned) return the same ingest-order row ids and
    live counts as a single-segment seal."""
    rng = np.random.default_rng(seed)
    sizes = [w * 32 for w in weights]
    n = sum(sizes)
    cols = [rng.integers(0, 6, n), rng.integers(0, 40, n)]
    spec = IndexSpec(encoding="auto")

    whole = SegmentedIndex([Segment.seal(cols, spec, row_start=0)]
                           if n else [Segment.empty(0, 0)])
    segs, pos = [], 0
    for s in sizes:
        segs.append(Segment.empty(pos, pos) if s == 0 else
                    Segment.seal([c[pos:pos + s] for c in cols], spec,
                                 row_start=pos))
        pos += s
    view = SegmentedIndex(segs)

    kill = segs[seed % len(segs)]
    dead = np.arange(kill.row_start, kill.row_stop, dtype=np.int64)
    for surface in (whole, view):
        surface.delete(row_ids=dead)

    for pred in (Eq(0, 2), Range(1, 5, 25), Not(Eq(0, 0))):
        want, _ = whole.query(pred, now=T0)
        got, _ = view.query(pred, now=T0)
        np.testing.assert_array_equal(want, got)
        assert whole.count(pred, now=T0) == view.count(pred, now=T0)
