"""Shared test utilities."""

import numpy as np

from repro.core import ewah


def random_words(n, p_clean=0.6, seed=0):
    """uint32 word streams with a mix of clean/dirty runs."""
    r = np.random.default_rng(seed)
    kind = r.random(n)
    words = r.integers(1, 0xFFFFFFFF, size=n, dtype=np.uint32)
    words = np.where(kind < p_clean / 2, np.uint32(0), words)
    words = np.where((kind >= p_clean / 2) & (kind < p_clean), ewah.FULL, words)
    reps = r.integers(1, 6, size=n)
    return np.repeat(words, reps)[:n].astype(np.uint32)
