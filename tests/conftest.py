import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:  # prefer the real property-testing engine when available
    import hypothesis  # noqa: F401
except ImportError:  # CI image has no hypothesis; alias the local stub
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies
