"""COLLECTIVE_budget.json coverage: every *runnable* nightly dryrun cell
(mesh x arch x shape, minus the cells ``launch.shapes.runnable`` skips by
spec) must carry a committed collective-bytes ceiling, and no committed
entry may point at a cell that can no longer run.

This locks the audited state: the budget file covers the runnable grid
exactly, so ``repro.launch.dryrun --budget`` never reports an
unbudgeted-cell finding on a nightly sweep.  Adding an arch or a shape
without extending the budget (``--update-budget``) fails here instead of
silently weakening the collective-volume gate.
"""

import json
import os

from repro.configs import get_config, list_archs
from repro.launch.dryrun import budget_key
from repro.launch.shapes import SHAPES, runnable

BUDGET_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                           "benchmarks", "COLLECTIVE_budget.json")


def grid():
    for mesh in ("16x16", "2x16x16"):
        for arch in sorted(list_archs()):
            cfg = get_config(arch)
            for shape in SHAPES.values():
                ok, _ = runnable(cfg, shape)
                yield ({"mesh": mesh, "arch": arch, "shape": shape.name},
                       ok)


def test_every_runnable_cell_has_a_budget_entry():
    with open(BUDGET_PATH) as f:
        budget = json.load(f)
    missing = [budget_key(rec) for rec, ok in grid()
               if ok and budget_key(rec) not in budget]
    assert not missing, (
        f"{len(missing)} runnable dryrun cells lack a collective-bytes "
        f"ceiling (run dryrun --update-budget and commit): {missing[:6]}")


def test_no_stale_budget_entries():
    with open(BUDGET_PATH) as f:
        budget = json.load(f)
    runnable_keys = {budget_key(rec) for rec, ok in grid() if ok}
    stale = sorted(set(budget) - runnable_keys)
    assert not stale, f"budget entries for non-runnable cells: {stale[:6]}"


def test_skipped_cells_stay_skipped_by_spec():
    """The only non-runnable cells are long_500k on full-attention archs
    (quadratic history, skipped per DESIGN.md) — a change here means the
    applicability spec moved and the budget grid must be revisited."""
    skipped = [rec for rec, ok in grid() if not ok]
    assert skipped, "no skipped cells: did runnable() lose its spec gate?"
    assert all(rec["shape"] == "long_500k" for rec in skipped)
    assert all(not get_config(rec["arch"]).subquadratic for rec in skipped)


def test_budget_entries_are_well_formed():
    with open(BUDGET_PATH) as f:
        budget = json.load(f)
    assert budget, "empty budget file"
    for key, entry in budget.items():
        assert entry["total_bytes"] > 0, key
        assert isinstance(entry["counts"], dict), key
