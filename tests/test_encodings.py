"""The pluggable encoding layer (repro.core.encodings).

Core contract: every encoding answers every predicate **bit-identically**
— equality k-of-N bitmaps, bit-sliced planes (binary and Gray), and
histogram-equalized bins must be indistinguishable through the query
surface, on both backends.  Checked against the dense oracle and each
other, with hypothesis property tests over random tables and ranges
(domain edges and empty ranges included), plus the acceptance bound: a
range over a cardinality-1024 bit-sliced column costs at most
2 * ceil(log2 1024) stream merges.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import And, BitmapIndex, Eq, In, IndexSpec, Not, Or, Range
from repro.core import IndexWriter, index_size_report
from repro.core.encodings import (BinnedEncoding, BitSlicedEncoding,
                                  build_encoding, encoding_kinds)
from repro.core.query import compile_plan, count_merges, evaluate_mask
from repro.core.strategies import get_strategy

ENCODINGS = ("equality", "bitsliced", "bitsliced-gray", "binned")


def spec_for(enc, k=1, row_order="lex"):
    return IndexSpec(k=k, row_order=row_order, column_order="given",
                     encoding=enc)


def make_cols(n, cards, seed):
    r = np.random.default_rng(seed)
    return [r.integers(0, c, size=n) for c in cards]


def original_rows(idx, pred, backend):
    rows, _ = idx.query(pred, backend=backend)
    return np.sort(idx.row_perm[rows])


PREDICATES = [
    Eq(0, 3), Eq(0, 10**6),                      # in / out of domain
    In(0, [1, 5, 9]), In(1, [0]), In(1, range(200)),
    Range(0, 4, 25), Range(0, 25, 4),            # empty range
    Range(1, 0, 10**9),                          # whole domain, clamped
    Range(1, 1, 1), Range(0, 0, 0),              # single-value ranges
    And(Range(0, 2, 27), Not(Eq(1, 3))),
    Or(Eq(0, 1), Range(1, 10, 60)),
]


@pytest.mark.parametrize("encoding", ENCODINGS)
def test_encoding_matches_dense_oracle(encoding):
    cols = make_cols(1237, [29, 101], seed=7)   # n deliberately % 32 != 0
    idx = BitmapIndex.build(cols, spec_for(encoding))
    for pred in PREDICATES:
        got = original_rows(idx, pred, "numpy")
        expect = np.flatnonzero(evaluate_mask(pred, cols))
        np.testing.assert_array_equal(got, expect, err_msg=f"{encoding} {pred}")


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_encodings_bit_identical_across_backends(backend):
    """Every encoding returns the same original-space rows for every
    predicate shape, on both backends."""
    cols = make_cols(900, [13, 300], seed=3)
    results = {}
    for enc in ENCODINGS:
        idx = BitmapIndex.build(cols, spec_for(enc))
        results[enc] = [original_rows(idx, p, backend) for p in PREDICATES]
    for enc in ENCODINGS[1:]:
        for p, a, b in zip(PREDICATES, results["equality"], results[enc]):
            np.testing.assert_array_equal(a, b, err_msg=f"{enc} {p}")


def test_bitsliced_range_merge_bound_acceptance():
    """Acceptance: a Range over a cardinality-1024 bit-sliced column
    executes with <= 2 * ceil(log2 1024) stream merges — vs ~card/2 OR
    merges for the equality encoding — and both give identical rows on
    both backends."""
    card = 1024
    cols = [np.random.default_rng(0).integers(0, card, size=4000)]
    bs = BitmapIndex.build(cols, spec_for("bitsliced"))
    eq = BitmapIndex.build(cols, spec_for("equality"))
    pred = Range(0, 100, 800)

    plan = compile_plan(bs, pred)
    assert count_merges(plan.root) <= 2 * 10      # 2 * ceil(log2 1024)
    eq_plan = compile_plan(eq, pred)
    assert count_merges(eq_plan.root) > 100       # the OR fan-in it replaces

    expect = np.flatnonzero(evaluate_mask(pred, cols))
    for backend in ("numpy", "jax"):
        np.testing.assert_array_equal(original_rows(bs, pred, backend), expect)
        np.testing.assert_array_equal(original_rows(eq, pred, backend), expect)


def test_bitsliced_plane_count_and_sizes():
    cols = [np.arange(1000) % 37]
    idx = BitmapIndex.build(cols, spec_for("bitsliced"))
    enc = idx.columns[0].encoding
    assert isinstance(enc, BitSlicedEncoding)
    assert enc.n_bits == 6                        # ceil(log2 37)
    assert idx.columns[0].N == 6
    assert idx.size_words() == int(enc.sizes.sum()) > 0


def test_bitsliced_gray_planes_use_gray_codes():
    """Gray planes hold to_gray(value) bits — the same transform the
    kernels/gray.py Pallas kernel computes — and adjacent values differ in
    exactly one plane."""
    from repro.core.encoding import to_gray
    from repro.kernels import ops as kops

    card = 16
    col = np.repeat(np.arange(card), 4)           # sorted runs of each value
    idx = BitmapIndex.build([col], spec_for("bitsliced-gray",
                                            row_order="unsorted"))
    enc = idx.columns[0].encoding
    assert enc.gray
    # the on-device Gray kernel and the host transform agree on the codes
    import jax.numpy as jnp
    keys = np.asarray(kops.gray(jnp.arange(card, dtype=jnp.uint32)))
    np.testing.assert_array_equal(keys, to_gray(np.arange(card)))
    # decode plane membership back per value: bit i of gray(v)
    from repro.core import ewah
    for i, stream in enumerate(enc.streams):
        bits = ewah.unpack_bits(ewah.decompress(stream), len(col))
        per_value = bits.reshape(card, 4)[:, 0]
        np.testing.assert_array_equal(
            per_value, (keys >> np.uint32(i)) & 1, err_msg=f"plane {i}")


def test_binned_histogram_equalized_bins():
    """Bin boundaries follow the cumulative histogram: a heavily skewed
    column still gets ~equal rows per bin, and every bin bitmap counts
    exactly its rows."""
    from repro.core import ewah

    r = np.random.default_rng(5)
    col = r.choice(100, size=4000, p=np.arange(1, 101) / np.arange(1, 101).sum())
    idx = BitmapIndex.build([col], spec_for("binned"))
    enc = idx.columns[0].encoding
    assert isinstance(enc, BinnedEncoding)
    counts = []
    for b, stream in enumerate(enc.streams):
        bits = ewah.unpack_bits(ewah.decompress(stream), len(col))
        lo, hi = enc.edges[b], enc.edges[b + 1] - 1
        sorted_col = col[idx.row_perm]
        np.testing.assert_array_equal(
            bits, (sorted_col >= lo) & (sorted_col <= hi))
        counts.append(int(bits.sum()))
    assert sum(counts) == len(col)
    # equalization: no bin holds more than ~3x the even share
    assert max(counts) <= 3 * len(col) / enc.n_bins


def test_auto_chooser_reads_histogram():
    chooser = get_strategy("encoding", "auto")
    n = 10_000
    flat_mid = np.full(60, n // 60)
    assert chooser(flat_mid, 1) == "binned"
    high_card = np.full(512, n // 512)
    assert chooser(high_card, 1) == "bitsliced"
    small = np.full(8, n // 8)
    assert chooser(small, 1) == "equality"
    skewed = np.asarray([n - 59] + [1] * 59)      # 60 values, one dominates
    assert chooser(skewed, 1) == "equality"


def test_auto_spec_mixes_encodings_per_column():
    cols = make_cols(3000, [512, 8, 60], seed=1)
    idx = BitmapIndex.build(cols, spec_for("auto"))
    assert idx.encodings() == ("bitsliced", "equality", "binned")
    # and the mixed index still answers correctly
    pred = And(Range(0, 50, 400), Range(2, 10, 40), Not(Eq(1, 2)))
    np.testing.assert_array_equal(
        original_rows(idx, pred, "numpy"),
        np.flatnonzero(evaluate_mask(pred, cols)))


def test_unknown_encoding_errors_list_names():
    cols = make_cols(100, [10], seed=0)
    with pytest.raises(ValueError, match="auto"):
        BitmapIndex.build(cols, spec_for("bogus"))
    with pytest.raises(ValueError, match="bitsliced"):
        build_encoding("bogus", cols[0], 10, np.bincount(cols[0]),
                       IndexSpec())
    assert "equality" in encoding_kinds()


def test_indexspec_encoding_serialization():
    spec = IndexSpec(k=2, row_order="grayfreq", encoding="auto")
    assert IndexSpec.from_dict(spec.to_dict()) == spec
    assert IndexSpec().encoding == "equality"     # default preserves paper
    # old serialized specs (no encoding key) load as equality
    d = IndexSpec(k=2).to_dict()
    d.pop("encoding")
    assert IndexSpec.from_dict(d).encoding == "equality"


def test_index_size_report_carries_encodings():
    cols = make_cols(2000, [512, 8], seed=2)
    rep = index_size_report(cols, spec_for("auto"))
    assert rep["encodings"] == ["bitsliced", "equality"]
    assert rep["k_effective"][0] is None          # k is an equality concept
    assert rep["k_effective"][1] == 1
    assert rep["total_words"] > 0


def test_unmaterialized_nonequality_rejects_queries():
    cols = make_cols(500, [300], seed=0)
    for enc in ("bitsliced", "bitsliced-gray", "binned"):
        idx = BitmapIndex.build(cols, spec_for(enc), materialize=False)
        # the size-only path is exact: no streams, same word counts
        full = BitmapIndex.build(cols, spec_for(enc))
        np.testing.assert_array_equal(idx.columns[0].sizes,
                                      full.columns[0].sizes)
        assert idx.columns[0].streams is None
        assert idx.size_words() == full.size_words() > 0
        with pytest.raises(ValueError, match="materialize"):
            idx.query(Eq(0, 1))


# -- segments / lifecycle: mixed encodings ----------------------------------


def test_mixed_encoding_segments_query_and_compact():
    """Different segments of one auto-spec writer may choose different
    encodings for the same column (segment-local histograms); queries
    stitch bit-identically and compaction re-chooses over the merged
    histogram."""
    r = np.random.default_rng(9)
    spec = spec_for("auto")
    w = IndexWriter(spec)
    lo = r.integers(0, 8, size=640)               # low-card batch: equality
    hi = r.integers(0, 900, size=640)             # high-card batch: bitsliced
    w.append([lo])
    w.seal()
    w.append([hi])
    w.seal()
    view = w.index
    (enc_a,), (enc_b,) = view.encodings()
    assert enc_a == "equality" and enc_b == "bitsliced"

    full = np.concatenate([lo, hi])
    for pred in (Range(0, 2, 500), Eq(0, 3), Not(In(0, [0, 1, 700]))):
        for backend in ("numpy", "jax"):
            rows, _ = view.query(pred, backend=backend)
            np.testing.assert_array_equal(
                rows, np.flatnonzero(evaluate_mask(pred, [full])))

    w.compact(span=(0, 2))
    assert view.n_segments == 1
    assert view.encodings() == (("bitsliced",),)  # merged card is high
    rows, _ = view.query(Range(0, 2, 500))
    np.testing.assert_array_equal(
        rows, np.flatnonzero(evaluate_mask(Range(0, 2, 500), [full])))


def test_fanout_carries_encoding_choice():
    """The spec's encoding travels through dist.query_fanout: a bit-sliced
    fan-out answers ranges identically to a single bit-sliced index."""
    from repro.dist.query_fanout import ShardedIndex

    cols = make_cols(2017, [400], seed=4)
    spec = spec_for("bitsliced")
    single = BitmapIndex.build(cols, spec)
    sharded = ShardedIndex.build(cols, spec, n_shards=4)
    assert all(sh.index.encodings() == ("bitsliced",)
               for sh in sharded.shards)
    for pred in (Range(0, 17, 350), Not(Range(0, 100, 399))):
        got, _ = sharded.query(pred)
        np.testing.assert_array_equal(
            got, np.flatnonzero(evaluate_mask(pred, cols)))


# -- kernels: the batched slice-fold entry point ----------------------------


@pytest.mark.parametrize("ops", [("and",), ("or", "and"),
                                 ("xor", "or", "and", "or"),
                                 ("xor", "xor", "xor")])
def test_slice_fold_matches_sequential(ops):
    import jax.numpy as jnp

    from repro.kernels import ops as kops

    m = len(ops) + 1
    r = np.random.default_rng(m)
    stacked = r.integers(0, 2**32, size=(m, 333), dtype=np.uint32)
    fns = {"and": np.bitwise_and, "or": np.bitwise_or, "xor": np.bitwise_xor}
    expect = stacked[0]
    for i, op in enumerate(ops):
        expect = fns[op](expect, stacked[i + 1])
    got = np.asarray(kops.slice_fold(jnp.asarray(stacked), ops))
    np.testing.assert_array_equal(got, expect)
    ref = np.asarray(kops.slice_fold(jnp.asarray(stacked), ops,
                                     use_kernel=False))
    np.testing.assert_array_equal(ref, expect)


def test_slice_fold_rejects_bad_op_count():
    import jax.numpy as jnp

    from repro.kernels import ops as kops

    with pytest.raises(ValueError, match="planes"):
        kops.slice_fold(jnp.zeros((3, 8), jnp.uint32), ("and",))


# -- property tests ---------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 600), st.integers(0, 10**6), st.integers(33, 400),
       st.integers(-5, 605), st.integers(-5, 605))
def test_property_range_bit_identical_across_encodings(card, seed, n, lo, hi):
    """Eq/In/Range agree bit-for-bit across all encodings (numpy backend),
    including domain edges (lo/hi outside [0, card)) and empty ranges."""
    cols = make_cols(n, [card], seed % 2**31)
    preds = [Range(0, lo, hi), Eq(0, lo), In(0, [v % card for v in
                                                 (lo, hi, seed)])]
    expect = [np.flatnonzero(evaluate_mask(p, cols)) for p in preds]
    for enc in ENCODINGS:
        idx = BitmapIndex.build(cols, spec_for(enc))
        for p, e in zip(preds, expect):
            np.testing.assert_array_equal(
                original_rows(idx, p, "numpy"), e,
                err_msg=f"{enc} card={card} n={n} {p}")


@settings(max_examples=6, deadline=None)
@given(st.integers(200, 1100), st.integers(0, 10**6))
def test_property_backends_agree_on_bitsliced_ranges(card, seed):
    """Both backends return identical rows for random ranges over random
    bit-sliced columns (the jax slice_fold path vs streaming merges)."""
    r = np.random.default_rng(seed % 2**31)
    cols = [r.integers(0, card, size=500)]
    idx = BitmapIndex.build(cols, spec_for("bitsliced"))
    lo = int(r.integers(0, card))
    hi = int(r.integers(0, card))
    preds = [Range(0, min(lo, hi), max(lo, hi)), Range(0, hi, hi),
             Not(Range(0, min(lo, hi), max(lo, hi)))]
    for p in preds:
        np.testing.assert_array_equal(original_rows(idx, p, "numpy"),
                                      original_rows(idx, p, "jax"))
        np.testing.assert_array_equal(
            original_rows(idx, p, "numpy"),
            np.flatnonzero(evaluate_mask(p, cols)))


def test_binned_refines_without_raw_columns():
    """Regression for the raw-column-free binned-segment bug: the binned
    encoding's exact boundary-bin refinement must be self-contained.  The
    old CSR refinement silently retained 2 x int64/row of base data, which
    pinned raw values into segments sealed with ``keep_columns=False`` (the
    fan-out shard mode); the row-value surface is part of the encoding
    (int32 for int32-range cardinalities) and refines lazily per query."""
    from repro.core import Segment, SegmentedIndex

    cols = make_cols(1000, [64], seed=11)
    seg = Segment.seal(cols, spec_for("binned"), keep_columns=False)
    assert seg.columns is None                  # no raw row store survives
    enc = seg.index.columns[0].encoding
    assert isinstance(enc, BinnedEncoding)
    assert enc._values.dtype == np.int32        # 4x smaller than the CSR
    si = SegmentedIndex([seg])
    for pred in [Range(0, 5, 40), Range(0, 7, 7), Eq(0, 13),
                 In(0, [2, 9, 63]), Range(0, 0, 10**9),
                 And(Range(0, 2, 50), Not(Eq(0, 30)))]:
        for backend in ("numpy", "jax"):
            rows, _ = si.query(pred, backend=backend)
            np.testing.assert_array_equal(
                rows, np.flatnonzero(evaluate_mask(pred, cols)))
