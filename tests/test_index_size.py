"""Sparse O(nk+L) size computation vs dense oracle; BitmapIndex behaviour."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import IndexSpec, ewah
from repro.core.bitmap_index import BitmapIndex, _materialize_streams, assign_codes
from repro.core.index_size import column_bitmap_sizes
from repro.core.sorting import order_rows


def dense_sizes(col, codes, N, n_rows):
    streams = _materialize_streams(col, codes, N, n_rows)
    return np.array([len(s) for s in streams], dtype=np.int64)


@pytest.mark.parametrize("k", [1, 2, 3])
@pytest.mark.parametrize("n,card,seed", [
    (100, 7, 0), (1000, 50, 1), (4096, 10, 2), (333, 333, 3), (2000, 3, 4),
])
def test_sparse_matches_dense(n, card, seed, k):
    r = np.random.default_rng(seed)
    col = r.integers(0, card, size=n)
    # ensure all value ids present so cardinality is well-defined
    col[:card] = np.arange(card)
    codes, N, k_eff = assign_codes(card, k, "gray", "alpha")
    sizes, markers, dirty = column_bitmap_sizes(col, codes, N)
    expect = dense_sizes(col, codes, N, n)
    np.testing.assert_array_equal(sizes, expect)
    assert sizes.sum() == markers + dirty


@pytest.mark.parametrize("k", [1, 2])
def test_sparse_matches_dense_sorted(k):
    r = np.random.default_rng(7)
    col = np.sort(r.integers(0, 40, size=5000))
    codes, N, _ = assign_codes(40, k, "gray", "alpha")
    sizes, _, _ = column_bitmap_sizes(col, codes, N)
    expect = dense_sizes(col, codes, N, len(col))
    np.testing.assert_array_equal(sizes, expect)


@settings(max_examples=30, deadline=None)
@given(
    st.integers(1, 400),    # rows
    st.integers(1, 20),     # cardinality
    st.integers(1, 3),      # k
    st.integers(0, 10_000), # seed
)
def test_sparse_matches_dense_property(n, card, k, seed):
    r = np.random.default_rng(seed)
    col = r.integers(0, card, size=n)
    card_eff = int(col.max()) + 1
    codes, N, _ = assign_codes(card_eff, k, "gray", "alpha")
    sizes, _, _ = column_bitmap_sizes(col, codes, N)
    expect = dense_sizes(col, codes, N, n)
    np.testing.assert_array_equal(sizes, expect)


def test_prop2_bound():
    """Proposition 2: sorted column has <= 2*n_i dirty words; storage cost
    <= 4*n_i + ceil(k * n_i^(1/k))."""
    r = np.random.default_rng(0)
    for k in (1, 2, 3):
        for card in (10, 100, 500):
            col = np.sort(r.integers(0, card, size=20_000))
            card_eff = int(col.max()) + 1
            codes, N, k_eff = assign_codes(card_eff, k, "gray", "alpha")
            sizes, markers, dirty = column_bitmap_sizes(col, codes, N)
            assert dirty <= 2 * card_eff
            # storage cost model: 2*dirty + clean-run sequences <= 4n_i + N
            assert sizes.sum() <= 4 * card_eff + N + 1


def test_sorting_shrinks_index():
    """The headline claim: lexicographic sort shrinks the index (here >2x
    on a shuffled zipf-ish table; the paper reports up to 9x on KJV)."""
    r = np.random.default_rng(1)
    n = 50_000
    # KJV-4grams-like: rows drawn (with heavy duplication) from a tuple pool
    pool = np.stack([r.integers(0, 30, 2000), r.integers(0, 300, 2000),
                     r.integers(0, 3000, 2000)], axis=1)
    rows = pool[r.integers(0, 2000, n)]
    cols = [rows[:, j] for j in range(3)]
    unsorted = BitmapIndex.build(
        cols, IndexSpec(k=1, row_order="unsorted", column_order="given"),
        materialize=False)
    slex = BitmapIndex.build(
        cols, IndexSpec(k=1, row_order="lex", column_order="given"),
        materialize=False)
    assert slex.size_words() < unsorted.size_words() / 2


def test_equality_query_correct():
    r = np.random.default_rng(2)
    n = 3000
    cols = [r.integers(0, 9, n), r.integers(0, 57, n)]
    for k in (1, 2):
        idx = BitmapIndex.build(
            cols, IndexSpec(k=k, row_order="lex", column_order="given"))
        reordered = [cols[idx.original_column(i)] for i in range(2)]
        perm = idx.row_perm
        for ci in range(2):
            for v in (0, 3, 5):
                rows, scanned = idx.equality_query(ci, v)
                expect = np.flatnonzero(reordered[ci][perm] == v)
                np.testing.assert_array_equal(rows, expect)
                assert scanned >= 1


def test_row_orderings_are_permutations():
    r = np.random.default_rng(3)
    cols = [r.integers(0, 5, 500), r.integers(0, 50, 500)]
    for method in ("unsorted", "lex", "grayfreq", "freqcomp"):
        perm = order_rows(cols, method)
        assert sorted(perm.tolist()) == list(range(500))


def test_grayfreq_clusters_by_frequency():
    """Gray-Frequency clusters equal-frequency values: the paper's example
    afcocadeaceabe -> aaaacccceeebdf (frequent values first, in runs)."""
    s = "afcocadeaceabe"
    vals = np.array([ord(c) - ord("a") for c in s])
    perm = order_rows([vals], "grayfreq")
    out = "".join(chr(v + ord("a")) for v in vals[perm])
    # a:4 c:3 e:3 b:1 d:1 f:1 o:1  (desc freq, value-id tiebreak)
    assert out == "aaaaccceeebdfo"
