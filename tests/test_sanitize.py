"""REPRO_SANITIZE runtime sanitizers: EwahStream.validate structural
rules, the execute_compressed boundary hook, and the lock-order wrapper.

Also the lock regression tests for the races the static pass surfaced:
concurrent seal/append buffer accounting, admission admit/retire/pack,
and compactor stats snapshots.
"""

import threading

import numpy as np
import pytest

from repro.analysis.runtime import (LockOrderError, make_lock,
                                    maybe_validate, reset_order_graph,
                                    sanitize_enabled, sanitized)
from repro.core import And, BitmapIndex, Eq, IndexSpec, IndexWriter, ewah
from repro.core import ewah_stream as es
from repro.core.ewah import FULL, MAX_CLEAN, MAX_DIRTY, make_marker
from repro.core.ewah_stream import EwahStream, EwahValidationError
from repro.core.lifecycle import BackgroundCompactor
from repro.core.query import compile_plan, get_backend
from repro.launch.serve import SegmentedAdmission


# ---------------------------------------------------------------------------
# EwahStream.validate
# ---------------------------------------------------------------------------


def compress_random(n_rows, seed=0, p_clean=0.6):
    rng = np.random.default_rng(seed)
    n_words = -(-n_rows // 32)
    words = rng.integers(1, FULL, n_words, dtype=np.uint32)
    clean = rng.random(n_words) < p_clean
    words[clean] = np.where(rng.random(int(clean.sum())) < 0.5,
                            0, FULL).astype(np.uint32)
    return ewah.compress(words), words


@pytest.mark.parametrize("n_rows", [0, 1, 31, 32, 33, 4096, 100_003])
def test_validate_accepts_compressor_output(n_rows):
    stream, _ = compress_random(n_rows, seed=n_rows)
    EwahStream(stream, n_rows).validate(origin="test")


def test_validate_accepts_overflow_chains():
    n = (MAX_CLEAN + 7) * 32
    EwahStream(ewah.compress(np.zeros(MAX_CLEAN + 7, np.uint32)),
               n).validate()
    rng = np.random.default_rng(1)
    dirty = rng.integers(1, FULL, MAX_DIRTY + 9, dtype=np.uint32)
    EwahStream(ewah.compress(dirty), len(dirty) * 32).validate()


def test_validate_accepts_stream_ops_output():
    a, _ = compress_random(2048, seed=2)
    b, _ = compress_random(2048, seed=3)
    for op in ("and", "or", "xor"):
        r, _ = es.logical_op(a, b, op)
        EwahStream(r, 2048).validate()
    r, _ = es.logical_not(a, 64)
    EwahStream(r, 2048).validate()
    c = es.concat_streams([a, b])
    EwahStream(c, 4096).validate()


def test_validate_malformed_marker():
    bad = EwahStream(np.array([make_marker(0, 0, 3)], np.uint32), 96)
    with pytest.raises(EwahValidationError, match="3 verbatim words"):
        bad.validate()


def test_validate_clean_word_encoded_dirty():
    bad = EwahStream(
        np.array([make_marker(0, 1, 1), 0], np.uint32), 64)
    with pytest.raises(EwahValidationError, match="clean run"):
        bad.validate()


def test_validate_uncoalesced_clean_runs():
    bad = EwahStream(np.array([make_marker(1, 1, 0),
                               make_marker(1, 1, 0)], np.uint32), 64)
    with pytest.raises(EwahValidationError, match="uncoalesced"):
        bad.validate()


def test_validate_split_dirty_run():
    w = np.uint32(0xDEADBEEF)
    bad = EwahStream(np.array([make_marker(0, 0, 1), w,
                               make_marker(0, 0, 1), w], np.uint32), 64)
    with pytest.raises(EwahValidationError, match="dirty continuation"):
        bad.validate()


def test_validate_length_mismatch():
    s = ewah.compress(np.zeros(4, np.uint32))
    with pytest.raises(EwahValidationError, match="decodes 4 words"):
        EwahStream(s, 10 * 32).validate()


def test_validate_popcount_cross_check():
    """count() (compressed-domain cursor walk) and to_bits().sum() (dense
    decompress) are independent implementations; the dense check catches
    one of them drifting."""
    stream, _ = compress_random(1024, seed=5)
    EwahStream(stream, 1024).validate(dense_check=True)

    class Lying(EwahStream):
        def count(self):
            return super().count() + 1

    with pytest.raises(EwahValidationError, match="popcount"):
        Lying(stream, 1024).validate(dense_check=True)


# ---------------------------------------------------------------------------
# sanitize gating + the execute_compressed boundary
# ---------------------------------------------------------------------------


def test_sanitized_context_flips_env(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert not sanitize_enabled()
    with sanitized():
        assert sanitize_enabled()
        with sanitized(False):
            assert not sanitize_enabled()
        assert sanitize_enabled()
    assert not sanitize_enabled()


def test_maybe_validate_gates_on_env():
    bad = EwahStream(np.array([make_marker(0, 0, 3)], np.uint32), 96)
    with sanitized(False):
        assert maybe_validate(bad, origin="off") is bad  # no-op when off
    with sanitized():
        with pytest.raises(EwahValidationError, match="boundary"):
            maybe_validate(bad, origin="boundary")


def _small_plan(seed=0):
    rng = np.random.default_rng(seed)
    cols = [rng.integers(0, 6, 512), rng.integers(0, 9, 512)]
    idx = BitmapIndex.build(cols, IndexSpec(k=2))
    return compile_plan(idx, And(Eq(0, 1), Eq(1, 2)))


def test_numpy_boundary_catches_corrupt_merge(monkeypatch):
    from repro.core import query as q

    plan = _small_plan()
    be = q.NumpyBackend(cache_size=4)
    bad = np.array([make_marker(0, 0, 3)], np.uint32)
    monkeypatch.setattr(q.ewah_stream, "logical_many",
                        lambda streams, op="and": (bad, 1))
    # sanitizer off: the corrupt stream sails through the boundary
    with sanitized(False):
        assert len(be.execute_compressed(plan).data) == 1
    be.result_cache.clear()
    with sanitized():
        with pytest.raises(EwahValidationError,
                           match="NumpyBackend.execute_compressed"):
            be.execute_compressed(plan)


def test_backends_validate_clean_results_under_sanitize():
    plan = _small_plan(seed=1)
    with sanitized():
        for name in ("numpy", "jax"):
            be = get_backend(name)
            stream = be.execute_compressed(plan)
            stream.validate(origin=name)  # idempotent re-check
            assert stream.n_rows == plan.n_rows


# ---------------------------------------------------------------------------
# lock-order sanitizer
# ---------------------------------------------------------------------------


@pytest.fixture
def fresh_graph():
    reset_order_graph()
    yield
    reset_order_graph()


def test_make_lock_plain_when_off(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    lock = make_lock("plain")
    assert isinstance(lock, type(threading.RLock()))


def test_lock_order_inversion_raises(fresh_graph):
    with sanitized():
        a = make_lock("order.a")
        b = make_lock("order.b")
        with a:
            with b:
                pass
        with pytest.raises(LockOrderError, match="order.a"):
            with b:
                with a:
                    pass


def test_lock_order_consistent_and_reentrant_ok(fresh_graph):
    with sanitized():
        a = make_lock("order.a")
        b = make_lock("order.b")
        c = make_lock("order.c", reentrant=False)
        for _ in range(3):
            with a:
                with a:  # reentrant re-acquire adds no edge
                    with b:
                        with c:
                            pass


def test_lock_order_transitive_cycle(fresh_graph):
    with sanitized():
        a, b, c = (make_lock(f"tri.{n}") for n in "abc")
        with a:
            with b:
                pass
        with b:
            with c:
                pass
        with pytest.raises(LockOrderError):
            with c:
                with a:
                    pass


def test_lifecycle_locks_order_clean_under_sanitize(fresh_graph):
    """Writer/compactor/admission churn acquires writer._lock,
    writer._compact_lock, admission._lock and result_cache in a single
    consistent order — the instrumented run must not raise."""
    rng = np.random.default_rng(7)
    with sanitized():
        q = SegmentedAdmission(seal_rows=64, compactor=True,
                               compact_interval=0.005)
        try:
            for wave in range(12):
                q.admit(rng.integers(8, 96, 48))
                q.pack(16)
                if wave % 3 == 2:
                    live = q.writer.n_rows
                    q.retire(rng.integers(0, max(live, 1), 8))
        finally:
            q.close()
        assert q.writer.compact() or True  # drain remaining tiers


# ---------------------------------------------------------------------------
# satellite regressions: the races the lock pass surfaced
# ---------------------------------------------------------------------------


def test_concurrent_seal_append_conserves_buffer():
    """Two racing seals computing n_seal from an unlocked read used to
    drive _buffered negative (rows double-sealed)."""
    for trial in range(8):
        w = IndexWriter(IndexSpec())
        stop = threading.Event()
        errors = []

        def hammer_seal():
            while not stop.is_set():
                try:
                    w.seal()
                except Exception as exc:  # noqa: BLE001
                    errors.append(exc)
                    return

        threads = [threading.Thread(target=hammer_seal) for _ in range(3)]
        for t in threads:
            t.start()
        total = 0
        rng = np.random.default_rng(trial)
        for _ in range(60):
            n = int(rng.integers(1, 70))
            w.append([rng.integers(0, 5, n)])
            total += n
            assert w.buffered_rows >= 0
        stop.set()
        for t in threads:
            t.join()
        assert not errors
        w.seal()
        assert w.buffered_rows >= 0
        assert w.n_rows == total
        assert sum(s.n_rows for s in w.segments) + w.buffered_rows == total


def test_append_close_race_never_loses_rows():
    """close() now seals and flips _closed under _lock, so an append
    either lands before the final seal or raises writer-closed."""
    for trial in range(12):
        w = IndexWriter(IndexSpec())
        accepted = []
        barrier = threading.Barrier(2)

        def appender():
            rng = np.random.default_rng(trial)
            barrier.wait()
            for k in range(40):
                n = int(rng.integers(1, 20))
                try:
                    w.append([rng.integers(0, 4, n)])
                except ValueError:
                    return
                accepted.append(n)

        t = threading.Thread(target=appender)
        t.start()
        barrier.wait()
        w.close()
        t.join()
        sealed = sum(s.n_rows for s in w.segments)
        leftover = w.buffered_rows
        assert sealed + leftover == sum(accepted)


def test_admission_concurrent_admit_retire_pack():
    """_lengths and the writer's rows must stay in lockstep under
    concurrent admits (the shadow store was unguarded)."""
    q = SegmentedAdmission(seal_rows=128)
    errors = []

    def worker(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(25):
                q.admit(rng.integers(8, 96, rng.integers(1, 12)))
                q.pack(8)
        except Exception as exc:  # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(q.lengths) == q.writer.n_rows
    batches = q.pack(16)
    packed = np.concatenate(batches) if batches else np.zeros(0, int)
    assert len(packed) == q.writer.live_rows()


def test_compactor_stats_snapshot_consistent():
    w = IndexWriter(IndexSpec(), seal_rows=64)
    rng = np.random.default_rng(3)
    with BackgroundCompactor(w, interval=0.002) as comp:
        for _ in range(30):
            w.append([rng.integers(0, 6, 48)])
            snap = comp.stats
            assert set(snap) == {"cycles", "compactions", "failures"}
            assert all(isinstance(v, int) and v >= 0 for v in snap.values())
    final = comp.stats
    assert final["failures"] == 0
    # snapshot is a copy, not the live dict
    final["cycles"] += 100
    assert comp.stats["cycles"] != final["cycles"]
