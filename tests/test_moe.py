"""MoE dispatch correctness: capacity gather/scatter vs dense oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.moe import (grayfreq_token_order, init_moe, moe_ffn,
                              padded_experts, routing_bitmap_words)


def dense_moe_oracle(p, cfg, x):
    """Every expert computes every token; combine with top-k gates."""
    b, s, d = x.shape
    T = b * s
    xf = np.asarray(x.reshape(T, d), np.float32)
    logits = xf @ np.asarray(p["router"], np.float32)
    k = cfg.top_k
    eids = np.argsort(-logits, axis=1)[:, :k]
    gv = np.take_along_axis(logits, eids, axis=1)
    gates = np.exp(gv - gv.max(1, keepdims=True))
    gates /= gates.sum(1, keepdims=True)
    wg = np.asarray(p["w_gate"], np.float32)
    wu = np.asarray(p["w_up"], np.float32)
    wd = np.asarray(p["w_down"], np.float32)
    y = np.zeros_like(xf)
    for e in range(cfg.n_experts):
        h = xf @ wg[e]
        h = h / (1 + np.exp(-h)) * (xf @ wu[e])
        out = h @ wd[e]
        for j in range(k):
            sel = eids[:, j] == e
            y[sel] += out[sel] * gates[sel, j : j + 1]
    if cfg.n_shared_experts:
        sp = p["shared"]
        sh = xf @ np.asarray(sp["w_gate"], np.float32)
        sh = sh / (1 + np.exp(-sh)) * (xf @ np.asarray(sp["w_up"], np.float32))
        y += sh @ np.asarray(sp["w_down"], np.float32)
    return y.reshape(b, s, d)


@pytest.mark.parametrize("arch", ["olmoe-1b-7b", "qwen2-moe-a2.7b"])
@pytest.mark.parametrize("dispatch", ["gather", "scatter"])
def test_moe_matches_dense_oracle(arch, dispatch):
    cfg = get_config(arch).smoke()
    # float32 for a tight comparison
    from dataclasses import replace
    cfg = replace(cfg, dtype="float32")
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.3
    # capacity_factor high enough that nothing drops -> exact match
    y, aux = moe_ffn(p, cfg, x, capacity_factor=8.0, dispatch=dispatch)
    y_ref = dense_moe_oracle(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y, np.float32), y_ref,
                               rtol=2e-4, atol=2e-4)
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("route_sort", ["none", "grayfreq"])
def test_route_sort_does_not_change_output(route_sort):
    """Token ordering inside dispatch is a locality optimization — the
    numerical result must be identical (capacity permitting)."""
    cfg = get_config("olmoe-1b-7b").smoke()
    from dataclasses import replace
    cfg = replace(cfg, dtype="float32")
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.3
    y0, _ = moe_ffn(p, cfg, x, capacity_factor=8.0, route_sort="none")
    y1, _ = moe_ffn(p, cfg, x, capacity_factor=8.0, route_sort=route_sort)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-5, atol=1e-5)


def test_capacity_drops_overflow():
    """With tiny capacity, outputs differ but remain finite (tokens drop)."""
    cfg = get_config("olmoe-1b-7b").smoke()
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                          jnp.bfloat16)
    y, _ = moe_ffn(p, cfg, x, capacity_factor=0.25)
    assert np.isfinite(np.asarray(y, np.float32)).all()


def test_padded_experts():
    assert padded_experts(60) == 64
    assert padded_experts(64) == 64
    assert padded_experts(8) == 8
    assert padded_experts(17) == 32


def test_routing_bitmap_words_matches_kernel_ref():
    from repro.kernels import ref
    r = np.random.default_rng(0)
    eids = jnp.asarray(r.integers(0, 64, size=(128, 8), dtype=np.int32))
    words = routing_bitmap_words(eids, 64)  # (E, W)
    expect = np.asarray(ref.moe_route(eids, 64)).T  # ref is (W, E)
    np.testing.assert_array_equal(np.asarray(words), expect)


def test_grayfreq_order_is_permutation():
    r = np.random.default_rng(1)
    eids = jnp.asarray(r.integers(0, 16, size=(200, 4), dtype=np.int32))
    perm = np.asarray(grayfreq_token_order(eids, 16))
    assert sorted(perm.tolist()) == list(range(200))
