"""Training-step invariants: accumulation equivalence, loss math, schedule."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from repro.configs import get_config
from repro.models import transformer
from repro.models.attention import _blockwise_attn, _dense_attn
from repro.optim import OptConfig, init_opt_state, lr_schedule
from repro.train import cross_entropy, train_step


@pytest.fixture(scope="module")
def setup():
    cfg = replace(get_config("tinyllama-1.1b").smoke(), dtype="float32")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    k = jax.random.PRNGKey(1)
    batch = {
        "inputs": jax.random.randint(k, (8, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(k, (8, 32), 0, cfg.vocab_size),
    }
    return cfg, params, opt, batch


@pytest.mark.parametrize("mb,accum", [(4, "scan"), (4, "unroll"), (8, "scan")])
def test_microbatch_accumulation_equivalence(setup, mb, accum):
    """mb=1 and mb=N produce (nearly) the same update."""
    cfg, params, opt, batch = setup
    oc = OptConfig(total_steps=10, warmup_steps=1)
    p1, _, m1 = jax.jit(
        lambda p, o, b: train_step(p, o, b, cfg=cfg, opt_cfg=oc))(params, opt, batch)
    pn, _, mn = jax.jit(
        lambda p, o, b: train_step(p, o, b, cfg=cfg, opt_cfg=oc,
                                   microbatches=mb, accum=accum))(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(mn["loss"]),
                               rtol=1e-5)
    a = np.asarray(jax.tree.leaves(p1)[1], np.float32)
    b_ = np.asarray(jax.tree.leaves(pn)[1], np.float32)
    np.testing.assert_allclose(a, b_, rtol=1e-3, atol=1e-5)


def test_cross_entropy_matches_manual():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(2, 5, 11)),
                         jnp.float32)
    labels = jnp.asarray([[1, 2, 3, 4, 5], [0, 0, 1, 1, 2]])
    ce = cross_entropy(logits, labels)
    p = jax.nn.log_softmax(logits, axis=-1)
    manual = -np.take_along_axis(
        np.asarray(p), np.asarray(labels)[..., None], axis=-1).mean()
    np.testing.assert_allclose(float(ce), manual, rtol=1e-6)


def test_cross_entropy_mask():
    logits = jnp.zeros((1, 4, 7))
    labels = jnp.zeros((1, 4), jnp.int32)
    mask = jnp.asarray([[1, 1, 0, 0]])
    ce = cross_entropy(logits, labels, mask)
    np.testing.assert_allclose(float(ce), np.log(7), rtol=1e-6)


def test_lr_schedule_shape():
    oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(lr_schedule(oc, s)) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup
    assert lrs[2] > lrs[3] > lrs[4]          # cosine decay
    assert lrs[4] >= 0.099                   # floor


def test_grad_clipping_bounds_update(setup):
    cfg, params, opt, batch = setup
    oc = OptConfig(total_steps=10, warmup_steps=1, clip_norm=1e-6)
    _, _, m = jax.jit(
        lambda p, o, b: train_step(p, o, b, cfg=cfg, opt_cfg=oc))(params, opt, batch)
    assert float(m["grad_norm"]) > 1e-6  # raw norm reported, clip applied


def test_blockwise_attention_matches_dense():
    r = np.random.default_rng(0)
    b, s, h, kvh, hd = 2, 256, 4, 2, 16
    q = jnp.asarray(r.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(r.normal(size=(b, s, kvh, hd)), jnp.float32)
    v = jnp.asarray(r.normal(size=(b, s, kvh, hd)), jnp.float32)
    dense = _dense_attn(q, k, v, kvh, None)
    blockwise = _blockwise_attn(q, k, v, kvh, None, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(blockwise), np.asarray(dense),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_attention():
    r = np.random.default_rng(1)
    b, s, h, hd = 1, 128, 2, 8
    q = jnp.asarray(r.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(r.normal(size=(b, s, h, hd)), jnp.float32)
    v = jnp.asarray(r.normal(size=(b, s, h, hd)), jnp.float32)
    dense = _dense_attn(q, k, v, h, 32)
    blockwise = _blockwise_attn(q, k, v, h, 32, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(blockwise), np.asarray(dense),
                               rtol=2e-4, atol=2e-4)
