"""Workload telemetry + cost model (repro.workload) and its public
query-surface API (``query.workload_snapshot`` / ``workload_reset``).

Covers the full self-tuning loop: the planner records predicate events on
plans, executed queries attribute wall time into :data:`WORKLOAD_STATS`,
:class:`CostModel` fits per-encoding lines and ranks candidates per
observed mix, and ``IndexWriter.compact(workload_stats=...)`` re-encodes
the merged segment — the chosen encoding *flips* when the mix flips from
point lookups to wide ranges.  Persistence mirrors PlanStats
(``serve --workload-stats``): save/load round-trips, missing files are a
cold start.
"""

import json

import numpy as np
import pytest

from repro.core import (BitmapIndex, Eq, In, IndexSpec, IndexWriter, Not,
                        Range)
from repro.core.query import (compile_plan, workload_reset,
                              workload_snapshot)
from repro.workload import (CANDIDATES, CostModel, WORKLOAD_STATS,
                            WorkloadStats, column_mixes, estimate_merges,
                            make_compaction_chooser, merge_snapshots,
                            record_execution)


def spec_for(enc, k=1):
    return IndexSpec(k=k, row_order="lex", column_order="given",
                     encoding=enc)


def make_cols(n, cards, seed):
    r = np.random.default_rng(seed)
    return [r.integers(0, c, size=n) for c in cards]


# -- the public counter API -------------------------------------------------


def test_workload_snapshot_counts_planner_events():
    workload_reset()
    cols = make_cols(400, [8, 50], seed=0)
    idx = BitmapIndex.build(cols, spec_for("equality"))
    idx.query(Eq(0, 3))
    idx.query(Eq(0, 3))
    idx.query(Range(1, 5, 20))
    snap = workload_snapshot()
    eq_cell = snap[(0, "eq", "equality")]
    assert eq_cell["count"] == 2 and eq_cell["width"] == 2  # summed widths
    rg_cell = snap[(1, "range", "equality")]
    assert rg_cell["count"] == 1 and rg_cell["width"] == 16
    assert rg_cell["merges"] > 0
    # snapshot is a copy: mutating it does not corrupt the counters
    snap[(0, "eq", "equality")]["count"] = 999
    assert workload_snapshot()[(0, "eq", "equality")]["count"] == 2
    workload_reset()
    assert workload_snapshot() == {}


def test_plan_carries_workload_events():
    cols = make_cols(300, [8, 8], seed=1)
    idx = BitmapIndex.build(cols, spec_for("equality"))
    plan = compile_plan(idx, Not(In(0, [1, 2, 3])))
    assert len(plan.workload) == 1
    col, shape, width, enc, merges = plan.workload[0]
    assert (col, shape, width, enc) == (0, "in", 3, "equality")
    assert merges >= 2


# -- WorkloadStats bounding + persistence -----------------------------------


def test_stats_record_bounds_and_persistence(tmp_path):
    stats = WorkloadStats()
    for i in range(stats.MAX_SAMPLES + 10):
        stats.record(0, "eq", 1, "equality", i, 10.0)
    # past the cap the newest half is kept
    assert len(stats) == stats.MAX_SAMPLES // 2 + 10
    assert stats.samples()[-1][4] == stats.MAX_SAMPLES + 9
    assert stats.stats()["recorded"] == stats.MAX_SAMPLES + 10

    path = tmp_path / "workload.json"
    stats.save(path)
    fresh = WorkloadStats()
    assert fresh.load(path)
    assert fresh.samples()[-2048:] == stats.samples()[-2048:]
    assert not WorkloadStats().load(tmp_path / "missing.json")  # cold start

    stats.clear()
    assert len(stats) == 0 and stats.stats()["recorded"] == 0


def test_record_execution_attributes_time():
    stats = WorkloadStats()
    cols = make_cols(200, [8], seed=2)
    idx = BitmapIndex.build(cols, spec_for("equality"))
    plans = [compile_plan(idx, Eq(0, 1)), compile_plan(idx, Eq(0, 2))]
    record_execution(plans, 0.004, stats=stats)       # 2000 us per plan
    samples = stats.samples()
    assert len(samples) == 2
    assert all(abs(s[5] - 2000.0) < 1e-6 for s in samples)
    record_execution([], 1.0, stats=stats)            # no-op, no divide
    assert len(stats) == 2


# -- the analytic merge estimator -------------------------------------------


def test_estimate_merges_orderings():
    # point lookups: roaring folds containers, equality pays k-1
    assert estimate_merges("roaring", "eq", 1, 300) == 0
    assert estimate_merges("equality", "eq", 1, 300, k=2) == 1
    # wide ranges: bit-sliced O(log card) beats value-per-value fan-ins
    wide_bs = estimate_merges("bitsliced", "range", 200, 1024)
    wide_eq = estimate_merges("equality", "range", 200, 1024)
    assert wide_bs < wide_eq
    # the over-half-domain complement trick caps equality/roaring ranges
    assert estimate_merges("equality", "range", 290, 300) <= 11
    with pytest.raises(ValueError, match="unknown encoding kind"):
        estimate_merges("bogus", "eq", 1, 10)


# -- cost-model fit + ranking -----------------------------------------------


def synthetic_samples(n_per=40):
    """Equality samples whose cost grows with merges (slope 3 us/merge)."""
    out = []
    for i in range(n_per):
        merges = i % 7
        out.append((0, "range", 8, "equality", merges, 5.0 + 3.0 * merges))
    return out


def test_cost_model_fit_and_predict():
    model = CostModel.fit(synthetic_samples())
    a, b = model.coef["equality"]
    assert abs(a - 5.0) < 1e-6 and abs(b - 3.0) < 1e-6
    assert model.predict("equality", 10) == pytest.approx(35.0)
    # unseen kinds use the pooled default, and cost grows with merges
    assert model.predict("roaring", 4) > model.predict("roaring", 0) - 1e-9
    with pytest.raises(ValueError, match="zero samples"):
        CostModel.fit([])


def test_cost_model_degenerate_mix_still_ranks():
    """All samples at one merge count: the through-origin fallback keeps
    fewer-merge candidates cheaper instead of dividing by zero variance."""
    samples = [(0, "in", 4, "equality", 3, 30.0)] * 20
    model = CostModel.fit(samples)
    assert model.predict("equality", 0) < model.predict("equality", 3)


def test_rank_flips_with_mix():
    """The core adaptive claim at model level: a point-lookup mix ranks
    roaring first, a wide-range mix on the same column ranks bitsliced
    first."""
    model = CostModel.fit(synthetic_samples())
    card = 300
    point = model.rank([("eq", 1, 100)], card)
    assert point[0][0] == "roaring"
    ranged = model.rank([("range", 250, 100)], card)
    assert ranged[0][0] == "bitsliced"
    assert [k for k, _ in point] != [k for k, _ in ranged]
    assert set(k for k, _ in point) == set(CANDIDATES)


def test_column_mixes_aggregates_per_column():
    samples = [(0, "eq", 1, "equality", 0, 10.0)] * 3 + \
              [(0, "range", 20, "equality", 19, 50.0),
               (1, "in", 4, "binned", 3, 20.0)]
    mixes = column_mixes(samples)
    assert ("eq", 1, 3) in mixes[0] and ("range", 20, 1) in mixes[0]
    assert mixes[1] == [("in", 4, 1)]


# -- the compaction hook ----------------------------------------------------


def test_chooser_needs_samples_and_known_columns():
    stats = WorkloadStats()
    assert make_compaction_chooser(stats) is None     # too few samples
    for _ in range(40):
        stats.record(0, "eq", 1, "equality", 1, 25.0)
    chooser = make_compaction_chooser(stats)
    assert chooser(0, np.ones(300), 1) == "roaring"
    assert chooser(5, np.ones(300), 1) is None        # untouched column


@pytest.mark.parametrize("mix,expect", [
    ("point", "roaring"),       # eq-only mix: container folds win
    ("range", "bitsliced"),     # wide ranges on card 300: log-card circuit
])
def test_compaction_reencodes_toward_mix(mix, expect):
    """The full loop: record a mix, compact with workload_stats, and the
    merged segment's encoding follows the mix — flipping when it flips."""
    r = np.random.default_rng(7)
    stats = WorkloadStats()
    for i in range(64):
        if mix == "point":
            stats.record(0, "eq", 1, "equality", 1, 40.0 + i % 3)
        else:
            stats.record(0, "range", 250, "equality", 249, 400.0 + i % 3)
    w = IndexWriter(IndexSpec(), workload_stats=stats)
    w.append([r.integers(0, 300, size=256)])
    w.seal()
    w.append([r.integers(0, 300, size=256)])
    w.seal()
    seg = w.compact(span=(0, 2))
    assert seg.index.encodings() == (expect,)
    # and the re-encoded segment still answers correctly
    rows, _ = w.index.query(Range(0, 10, 200))
    full = np.concatenate([c for c in [w.index.segments[0].columns[0]]])
    np.testing.assert_array_equal(
        rows, np.flatnonzero((full >= 10) & (full <= 200)))


def test_compaction_without_stats_keeps_static_choice():
    r = np.random.default_rng(8)
    w = IndexWriter(IndexSpec())                      # no workload_stats
    w.append([r.integers(0, 300, size=256)])
    w.seal()
    w.append([r.integers(0, 300, size=256)])
    w.seal()
    static = w.compact(span=(0, 2))
    assert static.index.encodings() == ("equality",)  # spec default


# -- the global recorder fed by the query surface ---------------------------


def test_queries_feed_global_workload_stats():
    WORKLOAD_STATS.clear()
    workload_reset()
    cols = make_cols(300, [20], seed=3)
    idx = BitmapIndex.build(cols, spec_for("roaring"))
    idx.query(Eq(0, 5))
    idx.query_compressed(Range(0, 2, 9))
    idx.query_many([Eq(0, 1), Eq(0, 2)])
    samples = WORKLOAD_STATS.samples()
    assert len(samples) == 4
    assert all(s[3] == "roaring" and s[5] > 0 for s in samples)

    w = IndexWriter(spec_for("equality"))
    w.append(cols)
    w.seal()
    WORKLOAD_STATS.clear()
    w.index.query(Eq(0, 5))
    assert len(WORKLOAD_STATS) == 1                   # segmented path records
    WORKLOAD_STATS.clear()
    workload_reset()


# -- cross-host snapshot / drain / merge (serve-plane wire payloads) --------


def _fill(stats, column, n, us=10.0):
    for i in range(n):
        stats.record(column, "eq", 1, "equality", 3 + i, us)


def test_snapshot_is_a_copy_and_json_round_trips():
    s = WorkloadStats()
    _fill(s, 0, 5)
    snap = s.snapshot()
    assert snap["recorded"] == 5 and len(snap["samples"]) == 5
    # wire payload must survive a JSON hop unchanged
    assert json.loads(json.dumps(snap)) == snap
    snap["samples"].clear()
    assert len(s) == 5                       # copy, not a view


def test_drain_ships_each_sample_exactly_once():
    worker = WorkloadStats()
    coord = WorkloadStats()
    _fill(worker, 1, 4)
    first = worker.drain()
    assert len(worker) == 0 and worker.stats()["recorded"] == 0
    assert worker.drain() == {"recorded": 0, "samples": []}  # nothing twice
    _fill(worker, 1, 2)
    second = worker.drain()
    merge_snapshots([first, None, second], stats=coord)      # None = no reply
    assert len(coord) == 6
    assert coord.stats()["recorded"] == 6


def test_merge_snapshot_preserves_bounded_surplus():
    """A host whose buffer already dropped old samples still reports how
    many it recorded; the coordinator's `recorded` counts them all."""
    host = WorkloadStats()
    snap = host.snapshot()
    snap["recorded"] = 100                   # 97 samples were bounded away
    snap["samples"] = [[2, "range", 4, "binned", 7, 12.5]] * 3
    coord = WorkloadStats()
    assert coord.merge_snapshot(snap) == 3
    assert len(coord) == 3
    assert coord.stats()["recorded"] == 100
    assert coord.samples()[0] == (2, "range", 4, "binned", 7, 12.5)


def test_merge_snapshots_defaults_to_global_recorder():
    WORKLOAD_STATS.clear()
    h = WorkloadStats()
    _fill(h, 3, 2)
    out = merge_snapshots([h.snapshot()])
    assert out is WORKLOAD_STATS and len(WORKLOAD_STATS) == 2
    WORKLOAD_STATS.clear()


def test_merge_applies_bounding_across_hosts():
    coord = WorkloadStats()
    snap = {"recorded": WorkloadStats.MAX_SAMPLES + 10,
            "samples": [[0, "eq", 1, "equality", 1, 1.0]]
            * (WorkloadStats.MAX_SAMPLES + 10)}
    coord.merge_snapshot(snap)
    assert len(coord) <= WorkloadStats.MAX_SAMPLES
    assert coord.stats()["recorded"] == WorkloadStats.MAX_SAMPLES + 10
