"""Roaring-style containers (repro.core.containers) + RoaringEncoding.

Three layers of oracle checks: the container algebra against dense numpy
set ops (4096-boundary class selection, run coalescing across merges,
galloping intersections), the batched jax/Pallas container fold against
the numpy streaming fold (bit-identical canonical EWAH at every plan
root), and the full encoding against EqualityEncoding through the query
surface — monolithic, segmented + tombstoned, and fan-out sharded —
under ``REPRO_SANITIZE`` structural validation on both backends.
Unknown container classes and merge ops must raise in both backends,
never fall through (enforced statically by
``repro.analysis.containercheck``, probed dynamically here).
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analysis.runtime import sanitized
from repro.core import (And, BitmapIndex, Eq, In, IndexSpec, IndexWriter,
                        Not, Or, Range, ewah)
from repro.core import containers as C
from repro.core.encodings import RoaringEncoding
from repro.core.query import evaluate_mask, get_backend

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def spec_for(enc, k=1):
    return IndexSpec(k=k, row_order="lex", column_order="given",
                     encoding=enc)


def make_cols(n, cards, seed):
    r = np.random.default_rng(seed)
    return [r.integers(0, c, size=n) for c in cards]


def random_positions(n_rows, density, seed):
    r = np.random.default_rng(seed)
    mask = r.random(n_rows) < density
    return np.flatnonzero(mask).astype(np.int64)


# -- container class selection ----------------------------------------------


def test_array_bitmap_4096_boundary():
    """Stride-2 positions have no runs, so the class flips from array to
    bitmap exactly past ARRAY_MAX=4096 set rows."""
    cls, payload = C.make_chunk(np.arange(C.ARRAY_MAX) * 2)
    assert cls == C.ARRAY and payload.dtype == np.uint16
    cls, payload = C.make_chunk(np.arange(C.ARRAY_MAX + 1) * 2)
    assert cls == C.BITMAP and len(payload) == C.CHUNK_WORDS


def test_run_rule_and_boundaries():
    # one contiguous run: 2*1 + 1 = 3 < min(n, 4096)
    cls, payload = C.make_chunk(np.arange(4, 5000))
    assert cls == C.RUN
    np.testing.assert_array_equal(payload, [[4, 4999]])
    # 2r + 1 not strictly cheaper -> array wins (3 positions, 1 run)
    cls, _ = C.make_chunk(np.asarray([7, 8, 9]))
    assert cls == C.ARRAY
    with pytest.raises(ValueError, match="empty"):
        C.make_chunk(np.empty(0, dtype=np.int64))


def test_from_positions_chunk_split_roundtrip():
    pos = np.concatenate([
        np.arange(0, 70_000, 3),            # spans chunks 0 and 1
        np.arange(200_000, 201_000),        # a run chunk far away
        [6 * C.CHUNK_ROWS - 1, 6 * C.CHUNK_ROWS],   # chunk-boundary pair
    ]).astype(np.int64)
    pos = np.unique(pos)
    cs = C.from_positions(pos, 7 * C.CHUNK_ROWS)
    assert list(cs.keys) == sorted(set(int(p) >> C.CHUNK_BITS for p in pos))
    np.testing.assert_array_equal(C.to_positions(cs), pos)
    assert cs.n_set() == len(pos)
    with pytest.raises(ValueError, match="range"):
        C.from_positions(np.asarray([70]), 64)


def test_run_coalescing_across_merges():
    """ORing two adjacent run halves re-chooses the class: the merged
    chunk coalesces back to ONE run, not an array or a bitmap."""
    n = C.CHUNK_ROWS
    a = C.from_positions(np.arange(0, 30_000, dtype=np.int64), n)
    b = C.from_positions(np.arange(30_000, 60_000, dtype=np.int64), n)
    merged = C.merge(a, b, "or")
    assert list(merged.classes) == [C.RUN]
    np.testing.assert_array_equal(merged.payloads[0], [[0, 59_999]])


# -- galloping intersections ------------------------------------------------


def test_gallop_intersect_matches_numpy():
    r = np.random.default_rng(3)
    for na, nb in [(10, 5000), (5000, 10), (0, 50), (300, 300)]:
        a = np.unique(r.integers(0, 10_000, size=na)) if na else \
            np.empty(0, dtype=np.int64)
        b = np.unique(r.integers(0, 10_000, size=nb)) if nb else \
            np.empty(0, dtype=np.int64)
        np.testing.assert_array_equal(C.gallop_intersect(a, b),
                                      np.intersect1d(a, b))


def test_array_bitmap_intersect_matches_dense():
    r = np.random.default_rng(4)
    dense = np.flatnonzero(r.random(C.CHUNK_ROWS) < 0.4).astype(np.int64)
    words = ewah.positions_to_words(dense, C.CHUNK_ROWS)
    sparse = np.unique(r.integers(0, C.CHUNK_ROWS, size=500))
    np.testing.assert_array_equal(C.array_bitmap_intersect(sparse, words),
                                  np.intersect1d(sparse, dense))


# -- merges vs dense set oracles --------------------------------------------


@pytest.mark.parametrize("op,oracle", [
    ("and", np.intersect1d),
    ("or", np.union1d),
    ("andnot", lambda a, b: np.setdiff1d(a, b, assume_unique=True)),
])
def test_merge_matches_set_oracle(op, oracle):
    n = 3 * C.CHUNK_ROWS + 777                  # unaligned final chunk
    for da, db, seed in [(0.001, 0.3, 0), (0.3, 0.001, 1), (0.08, 0.08, 2),
                         (0.9, 0.9, 3)]:
        pa = random_positions(n, da, seed)
        pb = random_positions(n, db, seed + 100)
        got = C.merge(C.from_positions(pa, n), C.from_positions(pb, n), op)
        np.testing.assert_array_equal(C.to_positions(got), oracle(pa, pb),
                                      err_msg=f"{op} {da}/{db}")


def test_to_stream_is_canonical_ewah():
    """The plan-root bridge emits exactly what ewah.compress produces over
    the dense words — so downstream caches/sanitizers see canonical form."""
    n = 2 * C.CHUNK_ROWS + 45
    pos = random_positions(n, 0.2, 7)
    cs = C.from_positions(pos, n)
    dense = ewah.positions_to_words(pos, n)
    np.testing.assert_array_equal(C.to_stream(cs), ewah.compress(dense))
    # and fold over several sets matches folding the dense masks
    sets = [C.from_positions(random_positions(n, d, 20 + i), n)
            for i, d in enumerate([0.01, 0.4, 0.1])]
    ops = ("or", "andnot")
    masks = [np.isin(np.arange(n), C.to_positions(s)) for s in sets]
    expect = (masks[0] | masks[1]) & ~masks[2]
    got = ewah.unpack_bits(ewah.decompress(C.fold(sets, ops, n)), n)
    np.testing.assert_array_equal(got, expect)


def test_fold_of_nothing_is_zero_stream():
    stream = C.fold([], (), 100)
    assert ewah.unpack_bits(ewah.decompress(stream), 100).sum() == 0


# -- unknown classes / ops raise in both backends ---------------------------


def test_unknown_container_class_raises():
    payload = np.zeros(4, dtype=np.uint16)
    for fn in (C.chunk_positions, C.chunk_words, C.chunk_cardinality,
               C._chunk_cost_u16):
        with pytest.raises(ValueError, match="unknown container class"):
            fn(7, payload)


def test_unknown_merge_op_raises_numpy_and_jax():
    n = C.CHUNK_ROWS
    sets = [C.from_positions(np.arange(10, dtype=np.int64) * i1, n)
            for i1 in (1, 2)]
    with pytest.raises(ValueError, match="unknown container merge op"):
        C.merge(sets[0], sets[1], "xor")
    with pytest.raises(ValueError, match="unknown container merge op"):
        C.fold(sets, ("xor",), n)
    jax_backend = get_backend("jax", interpret=True)
    with pytest.raises(ValueError, match="unknown container merge op"):
        jax_backend._container_fold(sets, ("xor",), n)


def test_kernel_container_pairs_rejects_unknown_op():
    import jax.numpy as jnp

    from repro.kernels import ops as kops

    a = jnp.zeros((2, C.CHUNK_WORDS), jnp.uint32)
    with pytest.raises(ValueError, match="unknown container merge op"):
        kops.container_pairs(a, a, "xor")


# -- the batched jax fold vs the numpy streaming fold -----------------------


def test_jax_container_fold_bit_identical_to_numpy():
    n = 2 * C.CHUNK_ROWS + 901
    jax_backend = get_backend("jax", interpret=True)
    r = np.random.default_rng(11)
    for trial in range(4):
        k = int(r.integers(2, 5))
        sets = [C.from_positions(
            random_positions(n, float(r.uniform(0.001, 0.6)),
                             int(r.integers(0, 2**31))), n)
            for _ in range(k)]
        ops = tuple(r.choice(["and", "or", "andnot"], size=k - 1))
        np.testing.assert_array_equal(
            jax_backend._container_fold(sets, ops, n),
            C.fold(sets, tuple(ops), n), err_msg=f"trial {trial} ops={ops}")


def test_kernel_gallop_matches_dense_membership():
    import jax.numpy as jnp  # noqa: F401 (device arrays round-trip below)

    from repro.kernels import ops as kops

    r = np.random.default_rng(13)
    dense = [np.flatnonzero(r.random(C.CHUNK_ROWS) < d)
             for d in (0.1, 0.5, 0.0)]
    words = np.stack([ewah.positions_to_words(d, C.CHUNK_ROWS)
                      for d in dense])
    pos = np.full((3, 64), -1, dtype=np.int32)
    queries = []
    for i in range(3):
        q = np.unique(r.integers(0, C.CHUNK_ROWS, size=40))
        pos[i, : len(q)] = q
        queries.append(q)
    for use_kernel in (True, False):
        hits = np.asarray(kops.container_gallop(pos, words,
                                                use_kernel=use_kernel,
                                                interpret=True))
        for i, q in enumerate(queries):
            got = q[hits[i, : len(q)].astype(bool)]
            np.testing.assert_array_equal(got, np.intersect1d(q, dense[i]))
        # padding lanes never report hits
        assert not hits[pos < 0].any()


# -- RoaringEncoding through the query surface ------------------------------


PREDICATES = [
    Eq(0, 3), Eq(0, 10**6), In(0, [1, 5, 9]), In(1, [0]),
    In(1, range(200)), Range(0, 4, 25), Range(0, 25, 4),
    Range(1, 0, 10**9), Range(1, 1, 1),
    And(Range(0, 2, 27), Not(Eq(1, 3))),
    Or(Eq(0, 1), Range(1, 10, 60)),
]


def original_rows(idx, pred, backend):
    rows, _ = idx.query(pred, backend=backend)
    return np.sort(idx.row_perm[rows])


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_roaring_bit_identical_to_equality(backend):
    cols = make_cols(900, [29, 300], seed=5)
    eq = BitmapIndex.build(cols, spec_for("equality"))
    ro = BitmapIndex.build(cols, spec_for("roaring"))
    assert ro.encodings() == ("roaring", "roaring")
    assert isinstance(ro.columns[0].encoding, RoaringEncoding)
    with sanitized():
        for pred in PREDICATES:
            np.testing.assert_array_equal(
                original_rows(ro, pred, backend),
                original_rows(eq, pred, backend), err_msg=f"{pred}")
            np.testing.assert_array_equal(
                original_rows(ro, pred, backend),
                np.flatnonzero(evaluate_mask(pred, cols)))


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_roaring_segmented_tombstoned(backend):
    """Multi-segment writer + tombstones: roaring answers in global ingest
    ids exactly like the dense oracle minus deleted rows, sanitized."""
    cols = make_cols(700, [11, 40], seed=6)
    w = IndexWriter(spec_for("roaring"), seal_rows=256)
    w.append(cols)
    w.seal()
    alive = np.ones(700, dtype=bool)
    w.delete(row_ids=np.arange(40, 120))
    alive[40:120] = False
    si = w.index
    with sanitized():
        for pred in (Eq(0, 3), Range(1, 5, 30), Not(In(0, [0, 2])),
                     And(Range(0, 1, 8), Not(Eq(1, 7)))):
            rows, _ = si.query(pred, backend=backend)
            expect = np.flatnonzero(evaluate_mask(pred, cols) & alive)
            np.testing.assert_array_equal(rows, expect, err_msg=f"{pred}")


def test_roaring_fanout_sharded():
    from repro.dist.query_fanout import ShardedIndex

    cols = make_cols(2017, [150], seed=8)
    sharded = ShardedIndex.build(cols, spec_for("roaring"), n_shards=4)
    assert all(sh.index.encodings() == ("roaring",)
               for sh in sharded.shards)
    with sanitized():
        for pred in (Range(0, 17, 120), Not(Range(0, 40, 149)),
                     In(0, [3, 77, 149])):
            got, _ = sharded.query(pred)
            np.testing.assert_array_equal(
                got, np.flatnonzero(evaluate_mask(pred, cols)))


def test_roaring_compaction_and_cache_reuse():
    """Compaction over roaring segments re-seals correctly, and repeated
    compressed queries hit the lowered-cfold result cache."""
    cols = make_cols(600, [17], seed=9)
    w = IndexWriter(spec_for("roaring"))
    w.append([c[:300] for c in cols])
    w.seal()
    w.append([c[300:] for c in cols])
    w.seal()
    w.compact(span=(0, 2))
    si = w.index
    with sanitized():
        for pred in (Eq(0, 4), Range(0, 3, 12)):
            _, a = si.execute_compressed(pred)
            _, b = si.execute_compressed(pred)     # cached cfold result
            np.testing.assert_array_equal(a.to_rows(), b.to_rows())
            rows, _ = si.query(pred)
            np.testing.assert_array_equal(
                rows, np.flatnonzero(evaluate_mask(pred, cols)))


def test_roaring_size_only_build():
    cols = make_cols(500, [60], seed=10)
    full = BitmapIndex.build(cols, spec_for("roaring"))
    lean = BitmapIndex.build(cols, spec_for("roaring"), materialize=False)
    np.testing.assert_array_equal(lean.columns[0].sizes,
                                  full.columns[0].sizes)
    assert lean.columns[0].streams is None
    assert lean.size_words() == full.size_words() > 0


# -- property tests ---------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 3 * C.CHUNK_ROWS - 1), min_size=0,
                max_size=400),
       st.lists(st.integers(0, 3 * C.CHUNK_ROWS - 1), min_size=0,
                max_size=400),
       st.sampled_from(["and", "or", "andnot"]))
def test_property_merge_matches_set_algebra(pa, pb, op):
    n = 3 * C.CHUNK_ROWS
    pa = np.unique(np.asarray(pa, dtype=np.int64))
    pb = np.unique(np.asarray(pb, dtype=np.int64))
    a, b = C.from_positions(pa, n), C.from_positions(pb, n)
    oracle = {"and": np.intersect1d, "or": np.union1d,
              "andnot": lambda x, y: np.setdiff1d(x, y, assume_unique=True)}
    np.testing.assert_array_equal(C.to_positions(C.merge(a, b, op)),
                                  oracle[op](pa, pb))
    # and the stream bridge stays canonical
    np.testing.assert_array_equal(
        C.to_stream(a), ewah.compress(ewah.positions_to_words(pa, n)))


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 40), st.integers(0, 10**6), st.integers(100, 900))
def test_property_roaring_equality_agree(card, seed, n):
    cols = make_cols(n, [card], seed % 2**31)
    r = np.random.default_rng(seed % 2**31 + 1)
    lo, hi = sorted(int(v) for v in r.integers(-2, card + 2, size=2))
    preds = [Eq(0, lo % card), Range(0, lo, hi), Not(Range(0, lo, hi))]
    eq = BitmapIndex.build(cols, spec_for("equality"))
    ro = BitmapIndex.build(cols, spec_for("roaring"))
    for p in preds:
        np.testing.assert_array_equal(original_rows(ro, p, "numpy"),
                                      original_rows(eq, p, "numpy"),
                                      err_msg=f"card={card} n={n} {p}")
