"""Compressed-domain execution engine: execute_compressed vs the row-id
path, marker-flip Not (with a densification guard), the Range->Not planner
rewrite, xor folds, and the LRU result cache."""

import numpy as np
import pytest

from helpers import random_words
from repro.analysis.runtime import sanitized
from repro.core import (And, BitmapIndex, Eq, In, IndexSpec, Not, Or, Range,
                        ewah)
from repro.core import ewah_stream as es
from repro.core.query import (JaxBackend, NumpyBackend, backend_names,
                              compile_plan, get_backend)


def make_index(n=3001, cards=(8, 13, 40), k=2, seed=0):
    r = np.random.default_rng(seed)
    cols = [r.integers(0, c, size=n) for c in cards]
    return BitmapIndex.build(cols, IndexSpec(k=k, row_order="grayfreq")), cols


PREDICATES = [
    Eq(0, 3),
    In(1, [1, 5, 9]),
    Range(2, 4, 11),                     # narrow: straight OR fan-in
    Range(2, 2, 38),                     # wide: Not(In(complement))
    Range(1, -5, 10**9),                 # full domain
    Range(2, 50, 40),                    # empty
    And(Eq(0, 2), Eq(1, 4)),
    Or(Eq(0, 1), Eq(0, 2), Eq(1, 0)),
    Not(Eq(0, 0)),
    Not(Not(Eq(1, 2))),
    And(In(0, [0, 1, 2]), Range(1, 0, 6), Not(Eq(2, 5))),
    Or(And(Eq(0, 1), Eq(1, 1)), Not(In(2, [0, 1, 2]))),
]


@pytest.fixture(scope="module")
def indexed():
    return make_index()


# ---------------------------------------------------------------------------
# execute_compressed agrees bit-for-bit with the row-id path, every backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", sorted(backend_names()))
@pytest.mark.parametrize("pred", PREDICATES, ids=repr)
def test_compressed_matches_rowid_path(indexed, backend, pred):
    idx, _ = indexed
    plan = compile_plan(idx, pred)
    be = get_backend(backend)
    rows, _ = be.execute(plan)
    stream = be.execute_compressed(plan)
    assert stream.n_rows == idx.n_rows
    np.testing.assert_array_equal(stream.to_rows(), rows)
    assert stream.count() == len(rows)


@pytest.mark.parametrize("pred", PREDICATES, ids=repr)
def test_backends_agree_on_streams(indexed, pred):
    """numpy and jax compressed results are the same words, bit for bit."""
    idx, _ = indexed
    plan = compile_plan(idx, pred)
    s_np = get_backend("numpy").execute_compressed(plan)
    s_jx = get_backend("jax").execute_compressed(plan)
    np.testing.assert_array_equal(s_np.to_words(), s_jx.to_words())
    np.testing.assert_array_equal(s_np.data, s_jx.data)


def test_compressed_many_batches(indexed):
    idx, _ = indexed
    plans = [compile_plan(idx, p) for p in PREDICATES]
    for backend in sorted(backend_names()):
        be = get_backend(backend)
        singles = [be.execute(p)[0] for p in plans]
        batched = be.execute_compressed_many(plans)
        for rows, stream in zip(singles, batched):
            np.testing.assert_array_equal(stream.to_rows(), rows)


def test_count_handles_final_word_padding():
    """n_rows not a multiple of 32: Not sets the padding bits; count() and
    to_rows() must truncate them."""
    idx, cols = make_index(n=997, cards=(5, 7, 9), k=1, seed=3)
    plan = compile_plan(idx, Not(Eq(0, 1)))
    stream = get_backend("numpy").execute_compressed(plan)
    rows, _ = get_backend("numpy").execute(plan)
    assert stream.count() == len(rows) == int(np.sum(cols[0] != 1))


# ---------------------------------------------------------------------------
# Not: marker-type flipping, never a dense complement
# ---------------------------------------------------------------------------


def test_not_never_densifies(indexed, monkeypatch):
    """Densification guard: the compressed path must finish a Not plan
    without ever calling decompress/unpack_bits (no dense complement, no
    XOR against a materialized all-ones bitmap)."""
    idx, _ = indexed
    pred = Not(Or(Eq(0, 1), In(2, [3, 4, 5])))
    plan = compile_plan(idx, pred)
    expected, _ = get_backend("numpy").execute(plan)

    def boom(*a, **k):
        raise AssertionError("compressed path densified a bitmap")

    monkeypatch.setattr(ewah, "decompress", boom)
    monkeypatch.setattr(ewah, "unpack_bits", boom)
    be = NumpyBackend()
    # the REPRO_SANITIZE boundary check densifies on purpose (dense
    # popcount cross-check); this guard is about the engine, not the
    # sanitizer, so probe with it off
    with sanitized(False):
        stream = be.execute_compressed(plan)
    monkeypatch.undo()
    np.testing.assert_array_equal(stream.to_rows(), expected)


def test_logical_not_is_marker_flip():
    """The complement has exactly the input's run structure: same compressed
    length, one pass, involution."""
    for seed in range(5):
        w = random_words(300, seed=seed)
        c = ewah.compress(w)
        nc, scanned = es.logical_not(c, len(w))
        assert len(nc) == len(c)          # same size: pure marker flip
        assert scanned == len(c)          # one pass over the stream itself
        np.testing.assert_array_equal(ewah.decompress(nc, len(w)), ~w)
        back, _ = es.logical_not(nc, len(w))
        np.testing.assert_array_equal(back, c)


def test_logical_not_pads_short_stream():
    """A short stream's implicit zero tail complements to clean-1s."""
    c = ewah.compress(np.zeros(4, dtype=np.uint32))
    nc, _ = es.logical_not(c, 10)  # complement over 10 words
    np.testing.assert_array_equal(
        ewah.decompress(nc, 10), np.full(10, 0xFFFFFFFF, dtype=np.uint32))


# ---------------------------------------------------------------------------
# Range -> Not(In(complement)) planner rewrite
# ---------------------------------------------------------------------------


def _count_leaves(node):
    if node[0] == "leaf":
        return 1
    if node[0] == "not":
        return _count_leaves(node[1])
    return sum(_count_leaves(c) for c in node[1])


def test_wide_range_compiles_to_not(indexed):
    idx, cols = indexed
    card = int(cols[2].max()) + 1  # 40 values on column 2
    wide = compile_plan(idx, Range(2, 2, card - 2))     # 37 of 40 values
    narrow = compile_plan(idx, Range(2, 4, 11))
    assert wide.root[0] == "not"
    assert narrow.root[0] != "not"
    # fan-in blowup fixed: the wide plan enumerates the 3-value complement,
    # not the 37-value range
    k = idx.columns[2].k
    assert _count_leaves(wide.root) <= 3 * k
    assert _count_leaves(narrow.root) == 8 * k


def test_full_domain_range_is_constant(indexed):
    idx, _ = indexed
    plan = compile_plan(idx, Range(1, -5, 10**9))
    assert plan.root[0] == "leaf" and len(plan.streams) == 1
    rows, _ = get_backend("numpy").execute(plan)
    assert len(rows) == idx.n_rows


@pytest.mark.parametrize("lo,hi", [(0, 39), (1, 38), (5, 35), (0, 19),
                                   (20, 39), (17, 23), (39, 39), (0, 0)])
def test_range_rewrite_oracle(indexed, lo, hi):
    idx, cols = indexed
    expect = np.flatnonzero((cols[2] >= lo) & (cols[2] <= hi))
    for backend in sorted(backend_names()):
        rows, _ = idx.query(Range(2, lo, hi), backend=backend)
        np.testing.assert_array_equal(np.sort(idx.row_perm[rows]), expect)


# ---------------------------------------------------------------------------
# xor fold
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [2, 3, 5, 8])
def test_logical_many_xor_oracle(m):
    """xor fold over many streams against the unpacked-bits oracle."""
    words = [random_words(257, seed=s) for s in range(m)]
    streams = [ewah.compress(w) for w in words]
    res, scanned = es.logical_many(streams, "xor")
    expect = words[0].copy()
    for w in words[1:]:
        expect ^= w
    np.testing.assert_array_equal(ewah.decompress(res, 257), expect)
    assert scanned > 0


def test_logical_many_unknown_op():
    with pytest.raises(ValueError, match="unknown op"):
        es.logical_many([ewah.compress(np.zeros(4, np.uint32))] * 2, "nand")


def test_logical_many_single_stream_passthrough():
    c = ewah.compress(random_words(64, seed=1))
    res, scanned = es.logical_many([c], "xor")
    np.testing.assert_array_equal(res, c)
    assert scanned == 0


# ---------------------------------------------------------------------------
# LRU result cache
# ---------------------------------------------------------------------------


def test_cache_reuses_whole_plans(indexed):
    idx, _ = indexed
    be = NumpyBackend()
    pred = And(Eq(0, 2), Eq(1, 4))
    plan = compile_plan(idx, pred)
    first = be.execute_compressed(plan)
    assert first.words_scanned > 0
    again = be.execute_compressed(compile_plan(idx, pred))
    assert be.result_cache.hits >= 1
    assert again.words_scanned == 0          # reused, nothing scanned
    np.testing.assert_array_equal(first.data, again.data)


def test_cache_shares_subplans_across_predicates(indexed):
    """Cascaded queries: the same In selector AND'd with different filters
    reuses the selector's OR fan-in result."""
    idx, _ = indexed
    be = NumpyBackend()
    shared = In(2, list(range(12)))
    be.execute_compressed(compile_plan(idx, And(shared, Eq(0, 1))))
    h0 = be.result_cache.hits
    stream = be.execute_compressed(compile_plan(idx, And(shared, Eq(0, 2))))
    assert be.result_cache.hits > h0          # the In sub-plan hit
    rows, _ = be.execute(compile_plan(idx, And(shared, Eq(0, 2))))
    np.testing.assert_array_equal(stream.to_rows(), rows)


def test_cache_differentiates_indexes():
    """Same predicate over different data must not collide (leaf digests)."""
    idx_a, cols_a = make_index(seed=1)
    idx_b, cols_b = make_index(seed=2)
    be = NumpyBackend()
    ra = be.execute_compressed(compile_plan(idx_a, Eq(0, 3)))
    rb = be.execute_compressed(compile_plan(idx_b, Eq(0, 3)))
    np.testing.assert_array_equal(
        np.sort(idx_a.row_perm[ra.to_rows()]), np.flatnonzero(cols_a[0] == 3))
    np.testing.assert_array_equal(
        np.sort(idx_b.row_perm[rb.to_rows()]), np.flatnonzero(cols_b[0] == 3))


def test_cache_lru_eviction(indexed):
    idx, _ = indexed
    be = NumpyBackend(cache_size=4)
    for v in range(8):
        be.execute_compressed(compile_plan(idx, And(Eq(0, v % 8), Eq(1, 1))))
    assert len(be.result_cache) <= 4
    assert be.result_cache.stats()["entries"] <= 4


def test_jax_cache_and_in_graph_recompress(indexed):
    """The jax backend's compressed path caches by the same canonical keys
    and its in-graph recompression round-trips."""
    idx, _ = indexed
    be = JaxBackend()
    pred = Or(Eq(0, 1), Eq(1, 2))
    plan = compile_plan(idx, pred)
    first = be.execute_compressed(plan)
    again = be.execute_compressed(compile_plan(idx, pred))
    assert be.result_cache.hits >= 1
    assert again.words_scanned == 0
    np.testing.assert_array_equal(first.data, again.data)
    rows, _ = be.execute(plan)
    np.testing.assert_array_equal(first.to_rows(), rows)
