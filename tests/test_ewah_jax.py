"""JAX EWAH vs numpy oracle."""

import numpy as np
import pytest

from repro.core import ewah, ewah_jax

from helpers import random_words


@pytest.mark.parametrize("n", [1, 2, 32, 100, 1000])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_compress_matches_oracle(n, seed):
    words = random_words(n, seed=seed)
    expect = ewah.compress(words)
    cap = len(expect) + 8
    stream, length = ewah_jax.compress(words, cap)
    assert int(length) == len(expect)
    np.testing.assert_array_equal(np.asarray(stream)[: int(length)], expect)


@pytest.mark.parametrize("seed", range(4))
def test_size_matches_oracle(seed):
    words = random_words(700, seed=seed)
    assert int(ewah_jax.compressed_size(words)) == len(ewah.compress(words))


@pytest.mark.parametrize("n", [1, 33, 256, 999])
@pytest.mark.parametrize("seed", [0, 3])
def test_decompress_roundtrip(n, seed):
    words = random_words(n, seed=seed)
    stream, length = ewah_jax.compress(words, n + 8)
    out = ewah_jax.decompress(stream, length, n)
    np.testing.assert_array_equal(np.asarray(out), words)


@pytest.mark.parametrize("op", ["and", "or", "xor"])
def test_logical_op(op):
    a = random_words(500, seed=1)
    b = random_words(500, seed=2)
    ca, la = ewah_jax.compress(a, 520)
    cb, lb = ewah_jax.compress(b, 520)
    res, length = ewah_jax.logical_op(ca, la, cb, lb, 500, op, 520)
    fn = {"and": np.bitwise_and, "or": np.bitwise_or, "xor": np.bitwise_xor}[op]
    expect = ewah.compress(fn(a, b))
    np.testing.assert_array_equal(np.asarray(res)[: int(length)], expect)


def test_all_clean():
    words = np.zeros(1000, dtype=np.uint32)
    stream, length = ewah_jax.compress(words, 8)
    assert int(length) == 1
    out = ewah_jax.decompress(stream, length, 1000)
    np.testing.assert_array_equal(np.asarray(out), words)
