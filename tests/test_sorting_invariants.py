"""Invariants of the row-reordering heuristics (paper §4).

Every ordering must be a permutation; lexicographic sort must not inflate
the compressed index on clustered synthetic tables (the paper's whole
premise); Gray-Frequency must cluster rows exactly by the
(frequency, value) classes that freq_rank_keys defines.
"""

import numpy as np
import pytest

from repro.core import sorting
from repro.core.encoding import choose_N, clamp_k, gray_kofn_codes
from repro.core.histogram import column_histogram, freq_rank_keys
from repro.core.index_size import table_index_size
from repro.data.tables import make_zipf_table


def clustered_table(n=2048, seed=0):
    """Low-cardinality skewed columns: long value runs once sorted."""
    return make_zipf_table(n, (4, 16, 64), (1.2, 1.0, 0.8), seed=seed)


def kofn_codes(columns, k=1):
    codes, Ls = [], []
    for c in columns:
        card = int(c.max()) + 1
        kk = clamp_k(card, k)
        N = choose_N(card, kk)
        codes.append(gray_kofn_codes(N, kk, card))
        Ls.append(N)
    return codes, Ls


def assert_permutation(perm, n):
    assert perm.shape == (n,)
    np.testing.assert_array_equal(np.sort(perm), np.arange(n))


# --- every order_* returns a valid permutation -----------------------------


@pytest.mark.parametrize("method", sorted(sorting.ORDERINGS))
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_order_is_permutation(method, seed):
    cols = clustered_table(n=513, seed=seed)  # odd n: no block alignment
    perm = sorting.order_rows(cols, method)
    assert_permutation(perm, 513)


@pytest.mark.parametrize("method", sorted(sorting.ORDERINGS))
def test_order_handles_duplicate_heavy_tables(method):
    # single repeated row: any valid ordering is the identity multiset
    cols = [np.zeros(97, dtype=np.int64), np.full(97, 3, dtype=np.int64)]
    assert_permutation(sorting.order_rows(cols, method), 97)


def test_gray_code_order_is_permutation():
    cols = [c[:48] for c in clustered_table(n=48, seed=3)]
    codes, _ = kofn_codes(cols, k=2)
    perm = sorting.order_gray_code(cols, codes)
    assert_permutation(perm, 48)


# --- lexicographic sort never inflates the index on clustered tables -------


@pytest.mark.parametrize("k", [1, 2])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_lex_index_never_larger_than_unsorted(k, seed):
    cols = clustered_table(n=4096, seed=seed)
    codes, Ls = kofn_codes(cols, k=k)

    def index_words(perm):
        return table_index_size([c[perm] for c in cols], codes, Ls)["total_words"]

    unsorted = index_words(sorting.order_unsorted(cols))
    lexed = index_words(sorting.order_lex(cols))
    assert lexed <= unsorted
    # and on this kind of data it should be a real win, not a tie
    assert lexed < unsorted


# --- Gray-Frequency clusters equal-frequency values ------------------------


@pytest.mark.parametrize("seed", [0, 1])
def test_grayfreq_primary_keys_nondecreasing(seed):
    cols = clustered_table(n=1024, seed=seed)
    perm = sorting.order_gray_frequency(cols)
    hist = column_histogram(cols[0])
    keys = freq_rank_keys(cols[0], hist)[perm]
    assert np.all(np.diff(keys) >= 0)  # primary column sorted by freq rank


@pytest.mark.parametrize("seed", [0, 1])
def test_grayfreq_clusters_each_value_contiguously(seed):
    cols = clustered_table(n=1024, seed=seed)
    perm = sorting.order_gray_frequency(cols)
    primary = cols[0][perm]
    # freq_rank_keys assigns one rank per value id, so after the sort each
    # distinct primary value must occupy exactly one contiguous run
    n_runs = int(np.count_nonzero(np.diff(primary)) + 1)
    assert n_runs == len(np.unique(cols[0]))
    # and runs appear in descending frequency order (id tie-break)
    hist = column_histogram(cols[0])
    run_values = primary[np.concatenate([[0], np.flatnonzero(np.diff(primary)) + 1])]
    run_freqs = hist[run_values]
    assert np.all(np.diff(run_freqs) <= 0)
