"""Fused prefill-with-cache == token-by-token decode prefill."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from repro.configs import get_config
from repro.models import transformer
from repro.serve.prefill import prefill_with_cache


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "olmoe-1b-7b"])
def test_prefill_matches_decode_loop(arch):
    # high capacity factor: no token drops, so the two paths agree exactly
    cfg = replace(get_config(arch).smoke(), dtype="float32",
                  moe_capacity_factor=8.0)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    b, s, max_len = 2, 12, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)

    logits_p, cache_p = prefill_with_cache(params, cfg, toks, max_len)

    cache = transformer.init_decode_cache(cfg, b, max_len)
    cache_len = jnp.int32(0)
    for t in range(s):
        logits_d, cache = transformer.decode_step(
            params, cfg, toks[:, t : t + 1], cache, cache_len)
        cache_len = cache_len + 1
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_d),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(
        np.asarray(cache_p["k"][:, :, :s], np.float32),
        np.asarray(cache["k"][:, :, :s], np.float32), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b"])
def test_prefill_then_decode_continues(arch):
    """Generate 4 tokens after a fused prefill; must equal the pure decode
    path's generation."""
    cfg = replace(get_config(arch).smoke(), dtype="float32")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    b, s, max_len = 1, 8, 24
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size)

    # path A: fused prefill -> greedy decode
    logits, cache = prefill_with_cache(params, cfg, toks, max_len)
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_a = [int(nxt[0, 0])]
    cache_len = jnp.int32(s)
    for _ in range(3):
        logits, cache = transformer.decode_step(params, cfg, nxt, cache, cache_len)
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        cache_len = cache_len + 1
        out_a.append(int(nxt[0, 0]))

    # path B: decode everything token-by-token
    cache = transformer.init_decode_cache(cfg, b, max_len)
    cache_len = jnp.int32(0)
    for t in range(s):
        logits, cache = transformer.decode_step(
            params, cfg, toks[:, t : t + 1], cache, cache_len)
        cache_len = cache_len + 1
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_b = [int(nxt[0, 0])]
    for _ in range(3):
        logits, cache = transformer.decode_step(params, cfg, nxt, cache, cache_len)
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        cache_len = cache_len + 1
        out_b.append(int(nxt[0, 0]))

    assert out_a == out_b
