"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ewah
from repro.kernels import ops, ref


@pytest.mark.parametrize("R,C", [(256, 128), (512, 256), (256, 384), (768, 128)])
@pytest.mark.parametrize("seed", [0, 1])
def test_bitpack_aligned(R, C, seed):
    r = np.random.default_rng(seed)
    bits = jnp.asarray(r.random((R, C)) < 0.3)
    out = ops.bitpack(bits)
    expect = ref.bitpack(bits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("R,C", [(100, 50), (33, 129), (1, 1), (300, 200)])
def test_bitpack_unaligned(R, C):
    r = np.random.default_rng(2)
    bits = jnp.asarray(r.random((R, C)) < 0.5)
    out = ops.bitpack(bits)
    padded = jnp.pad(bits, ((0, (-R) % 32), (0, 0)))
    expect = ref.bitpack(padded)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_bitpack_matches_cpu_codec():
    """Kernel output bit layout == the numpy codec's pack_bits layout."""
    r = np.random.default_rng(3)
    bits = r.random((96, 4)) < 0.4
    out = np.asarray(ops.bitpack(jnp.asarray(bits)))
    for c in range(4):
        np.testing.assert_array_equal(out[:, c], ewah.pack_bits(bits[:, c]))


@pytest.mark.parametrize("op", ["and", "or", "xor"])
@pytest.mark.parametrize("n", [128, 1000, 8192, 33])
def test_wordops(op, n):
    r = np.random.default_rng(4)
    a = jnp.asarray(r.integers(0, 2**32, size=n, dtype=np.uint32))
    b = jnp.asarray(r.integers(0, 2**32, size=n, dtype=np.uint32))
    # seed some clean words
    a = a.at[::7].set(0).at[::11].set(0xFFFFFFFF)
    rk, ck = ops.wordops(a, b, op)
    rr, cr = ref.wordops(a, b, op)
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(rr))
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))


@pytest.mark.parametrize("inverse", [False, True])
@pytest.mark.parametrize("n", [64, 1000, 4096])
def test_gray_kernel(inverse, n):
    r = np.random.default_rng(5)
    x = jnp.asarray(r.integers(0, 2**32, size=n, dtype=np.uint32))
    out = ops.gray(x, inverse)
    expect = ref.gray(x, inverse)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_gray_roundtrip_kernel():
    x = jnp.arange(2048, dtype=jnp.uint32)
    g = ops.gray(x)
    back = ops.gray(g, inverse=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@pytest.mark.parametrize("T,V", [(512, 128), (2048, 256), (1000, 100), (512, 91)])
def test_histogram(T, V):
    r = np.random.default_rng(6)
    vals = jnp.asarray(r.integers(0, V, size=T, dtype=np.int32))
    out = ops.histogram(vals, V)
    expect = np.bincount(np.asarray(vals), minlength=V)
    np.testing.assert_array_equal(np.asarray(out).astype(np.int64), expect)
    assert float(np.asarray(out).sum()) == T


@pytest.mark.parametrize("T,E,k", [(256, 128, 4), (512, 60, 4), (300, 64, 8), (256, 60, 1)])
def test_moe_route_bitmap(T, E, k):
    r = np.random.default_rng(7)
    eids = jnp.asarray(r.integers(0, E, size=(T, k), dtype=np.int32))
    out = ops.moe_route_bitmap(eids, E)
    expect = ref.moe_route(eids, E)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))
    # row/word cross-check against the numpy codec
    words = np.asarray(out)
    e0 = int(eids[0, 0])
    assert words[0, e0] & 1


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 300), st.integers(1, 200), st.integers(0, 100))
def test_bitpack_property(R, C, seed):
    r = np.random.default_rng(seed)
    bits = jnp.asarray(r.random((R, C)) < 0.5)
    out = np.asarray(ops.bitpack(bits))
    # unpack and compare
    back = ewah.unpack_bits(out[:, 0], R)
    np.testing.assert_array_equal(back, np.asarray(bits)[:, 0])


def test_kernel_feeds_ewah_pipeline():
    """bitpack kernel words -> numpy EWAH compress -> roundtrip."""
    r = np.random.default_rng(8)
    col = np.sort(r.integers(0, 12, size=2000))
    onehot = col[:, None] == np.arange(12)[None, :]
    words = np.asarray(ops.bitpack(jnp.asarray(onehot)))
    for c in range(12):
        stream = ewah.compress(words[:, c])
        back = ewah.decompress(stream)
        np.testing.assert_array_equal(back, words[:, c])
