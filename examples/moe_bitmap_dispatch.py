"""MoE routing as the paper's k-of-N bitmap encoding (DESIGN.md §4).

Routes a token batch through the OLMoE router shape (8-of-64), packs the
dispatch matrix into EWAH-ready words with the fused Pallas kernel, and
shows how Gray-Frequency token ordering shrinks the compressed dispatch
metadata — the paper's Table-4 experiment transplanted to the MoE plane.

  PYTHONPATH=src python examples/moe_bitmap_dispatch.py
"""

import os
import sys

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.bench_moe_dispatch import (compressed_dispatch_size,
                                           routed_assignments)
from repro.core import ewah
from repro.kernels import ops
from repro.models.moe import grayfreq_token_order

T, E, k = 8192, 64, 8
eids = routed_assignments(T, E, k, skew=1.2)
print(f"{T} tokens routed top-{k} over {E} experts (zipf-popular experts)")

words = np.asarray(ops.moe_route_bitmap(jnp.asarray(eids), E))
print(f"dispatch bitmap: {words.shape[0]} words x {E} experts "
      f"= {words.size:,} uncompressed words")

for name, order in (
    ("arrival order", None),
    ("expert-sorted", np.argsort(eids[:, 0], kind="stable")),
    ("gray-frequency", np.asarray(grayfreq_token_order(jnp.asarray(eids), E))),
):
    size = compressed_dispatch_size(eids, E, order)
    print(f"  {name:<15} EWAH {size:>8,} words "
          f"({size / words.size:.1%} of uncompressed)")

# compressed-domain query: which token-words hit expert 0 AND expert 1?
s0 = ewah.compress(words[:, 0])
s1 = ewah.compress(words[:, 1])
both, scanned = ewah.logical_op(s0, s1, "and")
hits = ewah.unpack_bits(ewah.decompress(both), T).sum()
print(f"\ntokens routed to experts 0 AND 1: {hits} "
      f"({scanned} compressed words scanned)")
