"""Batched serving with histogram-aware request packing (paper §4.2 applied
to the serving plane): requests are admitted in Gray-Frequency order of
their length bins, cutting padding waste vs arrival order.

  PYTHONPATH=src python examples/serve_batched.py
"""

import sys

from repro.launch import serve as serve_mod

if __name__ == "__main__":
    serve_mod.main(["--arch", "tinyllama-1.1b", "--requests", "32",
                    "--batch", "8", "--gen-tokens", "8"])
