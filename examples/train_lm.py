"""End-to-end training driver: train a ~100M-param LM for a few hundred
steps with checkpointing, fault tolerance and the bitmap-indexed data plane.

CPU-friendly default is a ~10M reduced model (--full-100m selects the real
thing if you have the cycles/hardware); either way this exercises the whole
stack: TokenPipeline -> sharded train_step -> atomic checkpoints ->
metadata bitmap index queries.

  PYTHONPATH=src python examples/train_lm.py --steps 100
  PYTHONPATH=src python examples/train_lm.py --steps 300 --full-100m
"""

import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    argv = [
        "--arch", "tinyllama-1.1b",
        "--steps", str(args.steps),
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "50",
        "--resume",
        "--log-every", "10",
        "--metrics-out", "/tmp/repro_train_lm_metrics.json",
    ]
    if args.full_100m:
        # ~100M: full tinyllama width, fewer layers, small batch
        argv += ["--no-smoke", "--batch", "2", "--seq", "256"]
    else:
        argv += ["--batch", "8", "--seq", "128"]
    metrics = train_mod.main(argv)
    first, last = metrics[0]["loss"], metrics[-1]["loss"]
    assert last < first, "loss did not decrease"
    print(f"OK: loss {first:.3f} -> {last:.3f} over {len(metrics)} steps")


if __name__ == "__main__":
    main()
