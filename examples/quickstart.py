"""Quickstart: the paper in 60 lines.

Builds EWAH-compressed bitmap indexes over a synthetic warehouse table,
compares row-ordering heuristics (unsorted / lexicographic Gray-Lex /
Gray-Frequency), picks the column order with the §4.3 histogram-aware
heuristic, and runs compressed-domain equality queries.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import BitmapIndex, index_size_report
from repro.core.column_order import heuristic_score
from repro.data.tables import make_census_like

n = 100_000
cols = make_census_like(n)
cards = [int(c.max()) + 1 for c in cols]
print(f"table: {n} rows, cardinalities {cards}")

print("\ncolumn-order heuristic scores (higher = sort earlier):")
for i, c in enumerate(cards):
    print(f"  col{i}: card={c:<7} score={heuristic_score(c, k=1):.5f}")

print("\nindex sizes (32-bit words), k=1:")
for method in ("unsorted", "lex", "grayfreq", "freqcomp"):
    rep = index_size_report(cols, k=1, row_order=method)
    print(f"  {method:<10} {rep['total_words']:>10,} words "
          f"(column order {rep['column_order']})")

print("\nk-of-N tradeoff (Gray-Frequency rows):")
for k in (1, 2, 3, 4):
    rep = index_size_report(cols, k=k, row_order="grayfreq")
    print(f"  k={k}: {rep['total_words']:>10,} words, "
          f"{sum(rep['bitmaps'])} bitmaps")

print("\nequality queries over the compressed index (k=2):")
idx = BitmapIndex.build(cols, k=2, row_order="grayfreq")
for col, val in ((0, 5), (1, 17), (2, 3)):
    rows, scanned = idx.equality_query(col, val)
    print(f"  col{idx.original_column(col)} == {val}: {len(rows):>6} rows, "
          f"{scanned} compressed words scanned")
