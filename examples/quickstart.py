"""Quickstart: the paper in 60 lines.

Builds EWAH-compressed bitmap indexes over a synthetic warehouse table,
compares row-ordering strategies (unsorted / lexicographic Gray-Lex /
Gray-Frequency) through the IndexSpec strategy registry, picks the column
order with the §4.3 histogram-aware heuristic, and runs compressed-domain
predicate queries (Eq / In / And) on both execution backends.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (And, BitmapIndex, Eq, In, IndexSpec,
                        index_size_report)
from repro.core.column_order import heuristic_score
from repro.core.strategies import strategy_names
from repro.data.tables import make_census_like

n = 100_000
cols = make_census_like(n)
cards = [int(c.max()) + 1 for c in cols]
print(f"table: {n} rows, cardinalities {cards}")
print(f"registered row orders: {', '.join(strategy_names('row_order'))}")

print("\ncolumn-order heuristic scores (higher = sort earlier):")
for i, c in enumerate(cards):
    print(f"  col{i}: card={c:<7} score={heuristic_score(c, k=1):.5f}")

print("\nindex sizes (32-bit words), k=1:")
for method in ("unsorted", "lex", "grayfreq", "freqcomp"):
    rep = index_size_report(cols, IndexSpec(k=1, row_order=method))
    print(f"  {method:<10} {rep['total_words']:>10,} words "
          f"(column order {rep['column_order']})")

print("\nk-of-N tradeoff (Gray-Frequency rows):")
for k in (1, 2, 3, 4):
    rep = index_size_report(cols, IndexSpec(k=k, row_order="grayfreq"))
    print(f"  k={k}: {rep['total_words']:>10,} words, "
          f"{sum(rep['bitmaps'])} bitmaps")

print("\npredicate queries over the compressed index (k=2):")
idx = BitmapIndex.build(cols, IndexSpec(k=2, row_order="grayfreq"))
for pred in (Eq(0, 5), In(1, [3, 17, 40]), And(Eq(0, 5), Eq(2, 3))):
    rows, scanned = idx.query(pred, backend="numpy")
    print(f"  {pred}: {len(rows):>6} rows, "
          f"{scanned} compressed words scanned")

print("\nnumpy vs jax backend (batched) on And(Eq, Eq):")
preds = [And(Eq(0, v), Eq(2, 3)) for v in range(5)]
np_rows = [r for r, _ in idx.query_many(preds, backend="numpy")]
jax_rows = [r for r, _ in idx.query_many(preds, backend="jax")]
agree = all(np.array_equal(a, b) for a, b in zip(np_rows, jax_rows))
print(f"  {len(preds)} queries, row ids agree: {agree}")
