from .adamw import OptConfig, apply_updates, init_opt_state, lr_schedule
from .compress import compress_grads, init_error_feedback, wire_bytes
