"""int8 error-feedback gradient compression for slow cross-pod links.

At 2+ pods the gradient all-reduce crosses the inter-pod links (DESIGN §6:
in-pod reduce-scatter, cross-pod all-reduce on 1/16 shards).  Quantizing
the cross-pod stage to int8 with per-tensor scale cuts its wire bytes 4x;
the quantization residual is carried in an error-feedback buffer and added
to the next step's gradient (Seide et al. / EF-SGD), so the bias vanishes
asymptotically rather than accumulating.

Usage (train_step):
    ef    = init_error_feedback(params)
    g_q, ef = compress_grads(grads, ef)     # before the cross-pod reduce
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, error_feedback):
    """Returns (quantize-dequantized grads, new error feedback).

    The returned grads are exactly what the receiving side reconstructs, so
    training math is identical on every host; the int8+scale pair is what
    crosses the slow link (4.03x smaller than f32)."""

    def one(g, ef):
        g = g.astype(jnp.float32) + ef
        q, scale = _quantize(g)
        deq = _dequantize(q, scale)
        return deq, g - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_feedback)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))


def wire_bytes(grads, compressed: bool) -> int:
    tot = 0
    for g in jax.tree.leaves(grads):
        tot += g.size * (1 if compressed else 4) + (4 if compressed else 0)
    return tot
