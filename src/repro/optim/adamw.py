"""AdamW + cosine schedule + global-norm clipping (pure functional).

ZeRO-1 moment storage: ``init_opt_state(params, zero_pad=d)`` with d > 1
stores "m"/"v" leaves **1-D flattened and zero-padded** to a multiple of d
(the data-axis size, ``dist.sharding.zero_pad_for``), so the moment tree
shards evenly over the data axis whatever the parameter dimensions are.
``apply_updates`` detects flat leaves by shape, reshapes them back to the
parameter shape for the update math, and re-pads on the way out — the
padding lanes stay exactly zero, so flat and param-shaped states compute
identical updates.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def _flat_size(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def init_opt_state(params, error_feedback: bool = False, zero_pad: int = 1):
    """Fresh AdamW state.  ``zero_pad > 1`` stores the moments 1-D
    flattened and zero-padded to a multiple of ``zero_pad`` (ZeRO-1 flat
    sharding — see dist/sharding.py); the "ef" residual stays param-shaped
    (it feeds the gradient compressor, which works in parameter space)."""
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    if zero_pad > 1:
        moment = lambda p: jnp.zeros((_flat_size(p.size, zero_pad),),
                                     jnp.float32)
    else:
        moment = zeros
    state = {
        "m": jax.tree.map(moment, params),
        "v": jax.tree.map(moment, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if error_feedback:
        # residual buffer for int8 cross-pod gradient compression
        state["ef"] = jax.tree.map(zeros, params)
    return state


def lr_schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: OptConfig, params, grads, state):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1, b2 = cfg.betas
    lr = lr_schedule(cfg, step)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        # ZeRO-1 flat storage: moments whose shape differs from the param
        # are the flattened+padded form — unpad for the math, re-pad after
        # (1-D leaves of divisible size need no pad, so equal shapes always
        # mean the values coincide too)
        flat = m.shape != p.shape
        if flat:
            stored = m.shape[0]
            m = m[: p.size].reshape(p.shape)
            v = v[: p.size].reshape(p.shape)
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if flat:
            pad = (0, stored - p.size)
            m = jnp.pad(m.reshape(-1), pad)
            v = jnp.pad(v.reshape(-1), pad)
        return new_p, m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    for k in state:
        if k not in new_state:
            new_state[k] = state[k]  # pass through extra keys (e.g. "ef")
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
