"""Assigned input shapes (one set for all LM-family archs)."""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models import transformer


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

SDS = jax.ShapeDtypeStruct


def runnable(cfg, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether this (arch, shape) cell runs (DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: 500k-token decode is quadratic-"
                       "history; skipped per spec (see DESIGN.md)")
    return True, ""


def cell_config(cfg, shape: ShapeSpec):
    """Shape-dependent config adjustments (documented adaptations)."""
    if shape.name == "long_500k" and cfg.family == "hybrid":
        # Zamba2 long-context: shared attention uses a sliding window
        from dataclasses import replace
        cfg = replace(cfg, sliding_window=4096)
    return cfg


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Returns (cfg, kind, specs_dict).  No device allocation happens here.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cfg = cell_config(cfg, shape)
    B, S = shape.global_batch, shape.seq_len
    n_patches = min(1024, S)  # frontend-stub block per sample

    if shape.kind in ("train", "prefill"):
        batch = {"inputs": SDS((B, S), jnp.int32),
                 "labels": SDS((B, S), jnp.int32)}
        if cfg.frontend != "none":
            # precomputed patch/frame embeddings (stub modality frontend)
            batch["patches"] = SDS((B, n_patches, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["mrope_positions"] = SDS((3, B, S), jnp.int32)
        if shape.kind == "prefill":
            batch.pop("labels")
        return cfg, shape.kind, {"batch": batch}

    # decode: one new token against a seq_len KV cache
    tokens = SDS((B, 1), jnp.int32)
    cache = jax.eval_shape(
        lambda: transformer.init_decode_cache(cfg, B, S))
    return cfg, "decode", {
        "tokens": tokens,
        "cache": cache,
        "cache_len": SDS((), jnp.int32),
    }
