"""Mesh construction for the production topology (TPU v5e target).

Importing this module never touches jax device state; meshes are built
lazily inside functions.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (requires the host-device count to allow it)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_cli_mesh(spec: str | None = None):
    """Mesh from a "data,model" CLI spec; default is all devices data-parallel.

    Shared by the train/serve launchers so both planes agree on axis names.
    """
    if spec:
        try:
            d, m = (int(x) for x in spec.split(","))
        except ValueError:
            raise SystemExit(
                f"--mesh expects 'data,model' (e.g. '4,2'), got {spec!r}")
    else:
        d, m = len(jax.devices()), 1
    return jax.make_mesh((d, m), ("data", "model"))
