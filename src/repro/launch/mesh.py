"""Mesh construction for the production topology (TPU v5e target).

Importing this module never touches jax device state; meshes are built
lazily inside functions.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (requires the host-device count to allow it)."""
    return jax.make_mesh((data, model), ("data", "model"))
