"""End-to-end training driver with fault tolerance.

Runnable at CPU scale (smoke configs) and structured for the production
mesh: sharded jit step, atomic checkpoints + auto-resume, heartbeat files
for the cluster monitor, straggler detection, simulated-failure injection
for restart testing.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt --resume
"""

from __future__ import annotations

import argparse
import json
import os
import time
from dataclasses import replace
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.metadata_index import MetadataIndex
from repro.data.tokens import TokenPipeline
from repro.dist import checkpoint as ckpt
from repro.dist.sharding import (batch_shardings, opt_shardings,
                                 param_shardings, zero_pad_for)
from repro.launch.mesh import make_cli_mesh
from repro.models import transformer
from repro.models.common import ShardingCtx
from repro.optim import OptConfig, init_opt_state
from repro.train import train_step


class Heartbeat:
    """Per-host liveness + progress file for the cluster monitor.

    A real deployment points this at shared storage; the monitor restarts
    hosts whose heartbeat goes stale and triggers elastic re-entry."""

    def __init__(self, path, host_id=0):
        self.path = path
        self.host_id = host_id

    def beat(self, step, status="ok", **kv):
        rec = {"host": self.host_id, "step": step, "t": time.time(),
               "status": status, **kv}
        tmp = f"{self.path}.tmp"
        with open(tmp, "w") as f:
            json.dump(rec, f)
        os.replace(tmp, self.path)


class StragglerMonitor:
    """Flags steps slower than ``factor`` x the running median.

    On TPU pods the mitigation is to exclude the slow host at the next
    checkpoint boundary (elastic re-entry with n-1 hosts); here we record
    the event so the launcher can act."""

    def __init__(self, factor=3.0, warmup=5):
        self.durations = []
        self.factor = factor
        self.warmup = warmup
        self.events = []

    def observe(self, step, dt):
        self.durations.append(dt)
        if len(self.durations) <= self.warmup:
            return False
        med = float(np.median(self.durations[-50:]))
        if dt > self.factor * med:
            self.events.append({"step": step, "dt": dt, "median": med})
            return True
        return False


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default=None, help="data,model (default: all devices data-parallel)")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--heartbeat", default=None)
    ap.add_argument("--simulate-failure-at", type=int, default=0,
                    help="crash at this step (restart/fault-tolerance test)")
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    cfg = replace(cfg, remat=True)

    mesh = make_cli_mesh(args.mesh)
    opt_cfg = OptConfig(lr=args.lr, total_steps=max(args.steps, 10),
                        warmup_steps=max(2, args.steps // 20))

    with ShardingCtx(mesh):
        p_sh = param_shardings(mesh, cfg)
        o_sh = opt_shardings(mesh, cfg)
        b_sh = batch_shardings(mesh, cfg, "train")
        b_sh.pop("patches", None)
        b_sh.pop("mrope_positions", None)

        params = jax.jit(
            lambda k: transformer.init_params(k, cfg),
            out_shardings=p_sh)(jax.random.PRNGKey(0))
        # ZeRO-1 flat moments: pad to the data-axis size so every leaf
        # shards (dist/sharding.py opt_shardings)
        opt_state = jax.jit(
            partial(init_opt_state, zero_pad=zero_pad_for(mesh)),
            out_shardings=o_sh)(params)

        step_fn = jax.jit(
            partial(train_step, cfg=cfg, opt_cfg=opt_cfg,
                    microbatches=args.microbatches),
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1))

        pipeline = TokenPipeline(cfg.vocab_size, args.batch, args.seq)
        meta_index = MetadataIndex()
        start_step = 0

        if args.resume and args.ckpt_dir and ckpt.available_steps(args.ckpt_dir):
            state_like = {"params": params, "opt": opt_state}
            restored, start_step, extra = ckpt.restore(
                args.ckpt_dir, state_like,
                shardings={"params": p_sh, "opt": o_sh})
            params, opt_state = restored["params"], restored["opt"]
            if "pipeline" in extra:
                pipeline.restore(extra["pipeline"])
            print(f"[train] resumed from step {start_step}", flush=True)
            if start_step >= args.steps:
                # restart of an already-finished run (cluster monitors do
                # this); exit cleanly instead of entering an empty loop
                print(f"[train] already at step {start_step} >= --steps "
                      f"{args.steps}; nothing to do", flush=True)
                return []

        hb = Heartbeat(args.heartbeat) if args.heartbeat else None
        straggler = StragglerMonitor()
        metrics_log = []
        t_start = time.time()

        for step in range(start_step, args.steps):
            if args.simulate_failure_at and step == args.simulate_failure_at:
                print(f"[train] simulating failure at step {step}", flush=True)
                os._exit(42)
            t0 = time.time()
            batch_np, meta = pipeline.next_batch()
            meta_index.add_batch(meta)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            params, opt_state, m = step_fn(params, opt_state, batch)
            loss = float(m["loss"])
            dt = time.time() - t0
            if straggler.observe(step, dt):
                print(f"[train] straggler step {step}: {dt:.2f}s", flush=True)
            if hb:
                hb.beat(step, loss=loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d}  loss {loss:.4f}  "
                      f"gnorm {float(m['grad_norm']):.3f}  {dt*1e3:.0f} ms",
                      flush=True)
            metrics_log.append({"step": step, "loss": loss, "dt": dt})
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                ckpt.save_async(
                    args.ckpt_dir, step + 1,
                    {"params": params, "opt": opt_state},
                    extra={"pipeline": pipeline.snapshot()})

        ckpt.wait_pending()
        if args.ckpt_dir:
            ckpt.save(args.ckpt_dir, args.steps,
                      {"params": params, "opt": opt_state},
                      extra={"pipeline": pipeline.snapshot()})

        # data-plane bitmap index demo: curation query over trained batches
        # (add_batch sealed segments incrementally; no monolithic build)
        rows, scanned = meta_index.query(where={"domain": 3})
        elapsed = time.time() - t_start
        print(f"[train] done in {elapsed:.1f}s; metadata index "
              f"{meta_index.size_words()} words; domain=3 -> {len(rows)} rows "
              f"({scanned} compressed words scanned)", flush=True)
        if args.metrics_out:
            with open(args.metrics_out, "w") as f:
                json.dump({"metrics": metrics_log,
                           "stragglers": straggler.events}, f)
        first, last = metrics_log[0]["loss"], metrics_log[-1]["loss"]
        print(f"[train] loss {first:.4f} -> {last:.4f}", flush=True)
        return metrics_log


if __name__ == "__main__":
    main()
