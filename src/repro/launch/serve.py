"""Batched serving driver with histogram-aware request packing.

Requests arrive with varying prompt lengths; batching equal-length-bin
requests together minimizes padding waste.  We sort the admission queue by
(length-bin frequency, length) — Gray-Frequency (paper §4.2) applied to the
serving plane: popular length classes form dense runs and batches.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke
"""

from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import BitmapIndex, Eq, IndexSpec
from repro.dist.sharding import (batch_shardings, cache_shardings,
                                 param_shardings, replicated)
from repro.launch.mesh import make_cli_mesh
from repro.models import transformer
from repro.models.common import ShardingCtx
from repro.serve.prefill import prefill_with_cache
from repro.train import serve_step


def make_requests(n, rng, max_len=96):
    """Synthetic request stream with a skewed length distribution."""
    bins = np.array([16, 24, 32, 48, 64, 96])
    probs = np.array([0.35, 0.25, 0.2, 0.1, 0.07, 0.03])
    lens = bins[rng.choice(len(bins), size=n, p=probs)]
    jitter = rng.integers(-4, 4, size=n)
    return np.clip(lens + jitter, 8, max_len)


def pack_batches(lengths, batch_size, histogram_aware=True, backend="numpy",
                 query_fanout=0):
    """Return list of index-batches; histogram-aware = Gray-Frequency order.

    The histogram-aware path runs through the bitmap query plane: a bitmap
    index over the length-bin column, one Eq(bin) plan per bin, bins admitted
    in descending frequency (paper §4.2 applied to serving), lengths
    ascending within a bin.  With backend="jax" all per-bin plans share one
    batched device dispatch (same plan shape -> one padded kernel launch).
    With query_fanout > 1 the admission index shards over word-aligned row
    ranges (repro.dist.query_fanout) and every per-bin plan fans out, each
    shard shipping its compressed result stream — the multi-host admission
    topology, exercised in-process.
    """
    lengths = np.asarray(lengths)
    n = len(lengths)
    if histogram_aware:
        bins = lengths // 8
        spec = IndexSpec(row_order="unsorted", column_order="given")
        uniq, counts = np.unique(bins, return_counts=True)
        by_freq = uniq[np.lexsort((uniq, -counts))]
        if query_fanout > 1:
            from repro.dist.query_fanout import ShardedIndex

            sidx = ShardedIndex.build([bins], spec, n_shards=query_fanout)
            # unsorted row order keeps row_perm the identity, so fan-out's
            # original-space ids are directly comparable to the single
            # path; query_many keeps all bins' per-shard plans in one
            # backend call (same-shape plans batch across bins and shards)
            results = sidx.query_many([Eq(0, int(b)) for b in by_freq],
                                      backend=backend)
        else:
            idx = BitmapIndex.build([bins], spec)
            results = idx.query_many([Eq(0, int(b)) for b in by_freq],
                                     backend=backend)
        order = np.concatenate(
            [rows[np.argsort(lengths[rows], kind="stable")]
             for rows, _ in results])
    else:
        order = np.arange(n)
    return [order[i : i + batch_size] for i in range(0, n, batch_size)]


def padding_waste(lengths, batches):
    total = 0
    used = 0
    for b in batches:
        l = lengths[b]
        total += int(l.max()) * len(b)
        used += int(l.sum())
    return 1.0 - used / max(total, 1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--mesh", default=None,
                    help="data,model (default: all devices data-parallel)")
    ap.add_argument("--query-backend", default="numpy",
                    choices=("numpy", "jax"),
                    help="query-plane backend for admission packing")
    ap.add_argument("--query-fanout", type=int, default=0,
                    help="shard the admission index over N word-aligned row "
                         "ranges and fan every packing query out across "
                         "them (0/1 = single index)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    rng = np.random.default_rng(0)

    mesh = make_cli_mesh(args.mesh)
    dp = mesh.shape["data"]
    # batches smaller than the data axis fall back to replication
    rules = {"batch": None} if args.batch % dp else None

    with ShardingCtx(mesh, rules):
        p_sh = param_shardings(mesh, cfg, rules=rules)
        c_sh = cache_shardings(mesh, cfg, rules=rules)
        tok_sh = batch_shardings(mesh, cfg, "decode", rules=rules)["tokens"]
        params = jax.jit(lambda k: transformer.init_params(k, cfg),
                         out_shardings=p_sh)(jax.random.PRNGKey(0))

        lengths = make_requests(args.requests, rng)
        for mode in (False, True):
            batches = pack_batches(lengths, args.batch, histogram_aware=mode,
                                   backend=args.query_backend,
                                   query_fanout=args.query_fanout)
            waste = padding_waste(lengths, batches)
            print(f"packing histogram_aware={mode} "
                  f"(query backend {args.query_backend}, "
                  f"fanout {args.query_fanout}): "
                  f"padding waste {waste:.1%}")

        batches = pack_batches(lengths, args.batch, histogram_aware=True,
                               backend=args.query_backend,
                               query_fanout=args.query_fanout)
        step = jax.jit(partial(serve_step, cfg=cfg),
                       in_shardings=(p_sh, tok_sh, c_sh, replicated(mesh)),
                       out_shardings=(tok_sh, c_sh), donate_argnums=(2,))
        prefill = jax.jit(
            lambda p, toks: prefill_with_cache(p, cfg, toks, args.max_len),
            in_shardings=(p_sh, tok_sh), out_shardings=(None, c_sh))
        t0 = time.time()
        generated = 0
        for bi, idx in enumerate(batches):
            b = len(idx)
            # ragged tail: pad to the full batch (one compiled shape, and the
            # data axis always divides); surplus rows are dropped on count
            if b < args.batch:
                idx = np.concatenate([idx, np.repeat(idx[-1], args.batch - b)])
            # pad to a 16-token bucket so jit reuses compiled prefill variants
            prompt_len = min(-(-int(lengths[idx].max()) // 16) * 16,
                             args.max_len - args.gen_tokens)
            prompts = rng.integers(0, cfg.vocab_size,
                                   size=(args.batch, prompt_len),
                                   dtype=np.int32)
            # fused prefill: one forward pass fills the whole KV cache
            logits, cache = prefill(params, jnp.asarray(prompts))
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            cache_len = jnp.int32(prompt_len)
            generated += b
            for t in range(args.gen_tokens - 1):
                tok, cache = step(params, tok, cache, cache_len)
                cache_len += 1
                generated += b
    dt = time.time() - t0
    print(f"served {len(lengths)} requests, {generated} tokens "
          f"in {dt:.1f}s ({generated/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
