"""Batched serving driver with histogram-aware request packing.

Requests arrive with varying prompt lengths; batching equal-length-bin
requests together minimizes padding waste.  We sort the admission queue by
(length-bin frequency, length) — Gray-Frequency (paper §4.2) applied to the
serving plane: popular length classes form dense runs and batches.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke
"""

from __future__ import annotations

import argparse
import time
from contextlib import contextmanager, nullcontext
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.runtime import make_lock
from repro.configs import get_config
from repro.core import BitmapIndex, Eq, IndexSpec, IndexWriter
from repro.core.lifecycle import BackgroundCompactor
from repro.core.query import PLAN_STATS
from repro.dist.sharding import (batch_shardings, cache_shardings,
                                 param_shardings, replicated)
from repro.launch.mesh import make_cli_mesh
from repro.models import transformer
from repro.models.common import ShardingCtx
from repro.serve.prefill import prefill_with_cache
from repro.train import serve_step
from repro.workload import WORKLOAD_STATS


def make_requests(n, rng, max_len=96):
    """Synthetic request stream with a skewed length distribution."""
    bins = np.array([16, 24, 32, 48, 64, 96])
    probs = np.array([0.35, 0.25, 0.2, 0.1, 0.07, 0.03])
    lens = bins[rng.choice(len(bins), size=n, p=probs)]
    jitter = rng.integers(-4, 4, size=n)
    return np.clip(lens + jitter, 8, max_len)


BIN_WIDTH = 8  # length-bin granularity for admission packing


class SegmentedAdmission:
    """In-flight re-binning admission queue (the streaming serving plane).

    New requests ``admit`` into the **open segment** of an
    :class:`~repro.core.lifecycle.IndexWriter` — queryable immediately,
    no index rebuild — and every ``seal_rows`` admitted requests the
    word-aligned prefix seals into an immutable segment that serves
    concurrently through the compressed engine.  Each ``pack`` re-bins the
    *entire* queue against the live length-bin histogram (bins in
    descending frequency, the paper's Gray-Frequency order applied to
    serving), so a length class that becomes popular mid-stream promotes
    earlier requests too: admission order is re-derived in flight, never
    frozen at arrival.

    With ``compactor=True`` a
    :class:`~repro.core.lifecycle.BackgroundCompactor` merges the sealed
    admission segments off-thread (size-tiered), so sustained ingest never
    pauses for index maintenance; ``retire(row_ids)`` tombstones served
    requests (one compressed merge — the compactor purges them later), so
    the queue drains without rebuilds.  ``close()`` drains the compactor.

    With ``hosts >= 2`` the sealed segments serve through a
    :class:`~repro.dist.serve_plane.ServePlane` — a fleet of worker
    *processes*, each owning a word-aligned contiguous run of segments
    (re-homed whenever the compactor changes the segment list) and
    shipping only compressed result streams back; packs are bit-identical
    to the in-process path (docs/dist.md).
    """

    def __init__(self, backend: str = "numpy", seal_rows: int = 256,
                 compactor: bool = False, compact_interval: float = 0.02,
                 hosts: int = 0):
        self.spec = IndexSpec(row_order="unsorted", column_order="given")
        # feed the process-wide workload telemetry into compactions: the
        # background compactor re-encodes merged admission segments toward
        # the live predicate mix once enough samples accumulate
        self.writer = IndexWriter(self.spec, seal_rows=seal_rows,
                                  workload_stats=WORKLOAD_STATS)
        self._plane = None
        if hosts >= 2:
            from repro.dist.serve_plane import ServePlane

            self._plane = ServePlane(self.writer, n_hosts=hosts)
        self.backend = backend
        # _lock keeps the shadow length store and the writer append one
        # atomic admission (a pack between the two would otherwise see a
        # row the histogram doesn't, and index row ids would drift from
        # _lengths positions); ordered before the writer's own lock
        self._lock = make_lock("admission._lock")
        self._lengths: list = []       # guarded-by: _lock
        self._compactor = (BackgroundCompactor(self.writer,  # guarded-by: _lock
                                               interval=compact_interval)
                           if compactor else None)

    def admit(self, lengths) -> None:
        """Append arriving request lengths to the open segment."""
        lengths = np.asarray(lengths)
        if len(lengths):
            with self._lock:
                self._lengths.append(lengths)
                self.writer.append([lengths // BIN_WIDTH])

    def retire(self, row_ids) -> int:
        """Tombstone served requests so later packs skip them; returns the
        newly-retired count."""
        row_ids = np.asarray(row_ids, dtype=np.int64)
        if self._plane is not None:
            # the plane broadcasts the tombstones to owning workers too
            return self._plane.delete(row_ids=row_ids)
        return self.writer.delete(row_ids=row_ids)

    def close(self) -> None:
        """Drain and stop the background compactor, if one is running,
        then shut down the serve-plane worker fleet (plane mode)."""
        with self._lock:
            comp, self._compactor = self._compactor, None
        if comp is not None:
            # off-lock: draining joins the scheduler thread, whose
            # compactions must not wait on an admission-held lock
            comp.close()
        if self._plane is not None:
            self._plane.close()

    @property
    def lengths(self) -> np.ndarray:
        with self._lock:
            return (np.concatenate(self._lengths) if self._lengths
                    else np.zeros(0, dtype=np.int64))

    @property
    def n_segments(self) -> int:
        return len(self.writer.segments)

    def pack(self, batch_size: int) -> list:
        """Re-bin the whole queue and emit index-batches (one Eq(bin) plan
        per bin over sealed segments + the open buffer, bins in descending
        frequency, lengths ascending within a bin)."""
        # _lock spans the lengths snapshot AND the index query: an admit
        # landing between the two would return row ids the snapshot does
        # not cover yet (lengths[rows] IndexError / wrong-bin placement)
        with self._lock:
            lengths = (np.concatenate(self._lengths) if self._lengths
                       else np.zeros(0, dtype=np.int64))
            if not len(lengths):
                return []
            bins = lengths // BIN_WIDTH
            uniq, counts = np.unique(bins, return_counts=True)
            by_freq = uniq[np.lexsort((uniq, -counts))]
            preds = [Eq(0, int(b)) for b in by_freq]
            # plane mode fans the per-bin plans out across the worker
            # processes; results are bit-identical to the local engine
            surface = (self._plane if self._plane is not None
                       else self.writer.index)
            results = surface.query_many(preds, backend=self.backend)
        order = np.concatenate(
            [rows[np.argsort(lengths[rows], kind="stable")]
             for rows, _ in results])
        return [order[i : i + batch_size]
                for i in range(0, len(order), batch_size)]


def pack_batches(lengths, batch_size, histogram_aware=True, backend="numpy",
                 query_fanout=0, admission="rebuild", compactor=False,
                 hosts=0):
    """Return list of index-batches; histogram-aware = Gray-Frequency order.

    The histogram-aware path runs through the bitmap query plane: a bitmap
    index over the length-bin column, one Eq(bin) plan per bin, bins admitted
    in descending frequency (paper §4.2 applied to serving), lengths
    ascending within a bin.  With backend="jax" all per-bin plans share one
    batched device dispatch (same plan shape -> one padded kernel launch).
    With query_fanout > 1 the admission index shards over word-aligned row
    ranges (repro.dist.query_fanout) and every per-bin plan fans out, each
    shard shipping its compressed result stream — the multi-host admission
    topology, exercised in-process.

    ``admission="segmented"`` exercises the streaming path instead of a
    one-shot rebuild: lengths arrive in waves through
    :class:`SegmentedAdmission` (appends to the open segment, auto-seals,
    sealed segments serve concurrently) and the final ``pack`` re-bins
    everything in flight.  ``compactor=True`` (segmented mode only) runs a
    :class:`~repro.core.lifecycle.BackgroundCompactor` during the waves, so
    packing also exercises concurrent off-thread compaction.  Batches are
    identical to the rebuild path — the lifecycle changes *when* index work
    happens, not the answer.

    ``hosts >= 2`` (segmented mode only) serves the sealed admission
    segments through a :class:`~repro.dist.serve_plane.ServePlane` worker
    fleet — each pack's per-bin plans fan out across processes and only
    compressed result streams come back.
    """
    lengths = np.asarray(lengths)
    n = len(lengths)
    if compactor and admission != "segmented":
        raise ValueError(
            "compactor=True requires admission='segmented' (the rebuild "
            "path has no writer to compact)")
    if hosts >= 2 and admission != "segmented":
        raise ValueError(
            "hosts>=2 requires admission='segmented' (the serve plane "
            "wraps the segmented writer)")
    if not histogram_aware:
        order = np.arange(n)
        return [order[i : i + batch_size] for i in range(0, n, batch_size)]
    if admission == "segmented":
        if query_fanout > 1:
            raise ValueError(
                "segmented admission and query_fanout are separate "
                "topologies; pick one")
        q = SegmentedAdmission(backend=backend, compactor=compactor,
                               hosts=hosts)
        try:
            waves = max(1, min(4, n // max(batch_size, 1)))
            for chunk in np.array_split(lengths, waves):
                q.admit(chunk)
            return q.pack(batch_size)
        finally:
            q.close()
    if admission != "rebuild":
        raise ValueError(f"unknown admission mode {admission!r}; "
                         "known: rebuild, segmented")
    bins = lengths // BIN_WIDTH
    spec = IndexSpec(row_order="unsorted", column_order="given")
    uniq, counts = np.unique(bins, return_counts=True)
    by_freq = uniq[np.lexsort((uniq, -counts))]
    if query_fanout > 1:
        from repro.dist.query_fanout import ShardedIndex

        sidx = ShardedIndex.build([bins], spec, n_shards=query_fanout)
        # unsorted row order keeps row_perm the identity, so fan-out's
        # original-space ids are directly comparable to the single
        # path; query_many keeps all bins' per-shard plans in one
        # backend call (same-shape plans batch across bins and shards)
        results = sidx.query_many([Eq(0, int(b)) for b in by_freq],
                                  backend=backend)
    else:
        idx = BitmapIndex.build([bins], spec)
        results = idx.query_many([Eq(0, int(b)) for b in by_freq],
                                 backend=backend)
    order = np.concatenate(
        [rows[np.argsort(lengths[rows], kind="stable")]
         for rows, _ in results])
    return [order[i : i + batch_size] for i in range(0, n, batch_size)]


class PhaseProfile:
    """Wall-clock accounting per serving phase — the top-ops summary
    ``serve --profile`` prints next to the JAX profiler trace (the trace
    has per-HLO detail for TensorBoard; this table answers "where did the
    wall time go" without leaving the terminal).  Spans are cheap enough
    to always run; callers block on device results inside a span only
    when profiling, so honest per-phase attribution never perturbs the
    unprofiled path's async dispatch pipelining."""

    def __init__(self):
        self.acc: dict = {}

    @contextmanager
    def span(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.acc[name] = (self.acc.get(name, 0.0)
                              + time.perf_counter() - t0)

    def report(self, total: float | None = None) -> None:
        tot = total or sum(self.acc.values()) or 1.0
        print("# top serving phases (wall-clock)")
        for name, s in sorted(self.acc.items(), key=lambda kv: -kv[1]):
            print(f"  {name:<12} {s * 1e3:9.1f} ms  {s / tot:6.1%}")


def padding_waste(lengths, batches):
    total = 0
    used = 0
    for b in batches:
        l = lengths[b]
        total += int(l.max()) * len(b)
        used += int(l.sum())
    return 1.0 - used / max(total, 1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--mesh", default=None,
                    help="data,model (default: all devices data-parallel)")
    ap.add_argument("--query-backend", default="numpy",
                    choices=("numpy", "jax"),
                    help="query-plane backend for admission packing")
    ap.add_argument("--query-fanout", type=int, default=0,
                    help="shard the admission index over N word-aligned row "
                         "ranges and fan every packing query out across "
                         "them (0/1 = single index)")
    ap.add_argument("--admission", default="rebuild",
                    choices=("rebuild", "segmented"),
                    help="'segmented' streams requests through an "
                         "IndexWriter (in-flight re-binning: appends hit "
                         "the open segment, sealed segments serve "
                         "concurrently) instead of rebuilding the "
                         "admission index per pack")
    ap.add_argument("--compactor", action="store_true",
                    help="run a background compactor thread over the "
                         "segmented admission writer while requests stream "
                         "in (requires --admission segmented)")
    ap.add_argument("--hosts", type=int, default=0,
                    help="serve sealed admission segments through a "
                         "multi-process ServePlane with N segment-owning "
                         "worker processes; only compressed result streams "
                         "cross the wire (requires --admission segmented; "
                         "0/1 = in-process)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="emit a JAX profiler trace of the serving loop to "
                         "DIR (read with: tensorboard --logdir DIR) plus a "
                         "wall-clock top-phase summary on stdout; see "
                         "docs/fusion.md for the reading workflow")
    ap.add_argument("--plan-stats", default=None, metavar="PATH",
                    help="persist the query plan-shape recorder "
                         "(core.query.PLAN_STATS): load at startup so the "
                         "jax backend warms up with last run's autotuned "
                         "capacity buckets, autotune + save at exit")
    ap.add_argument("--workload-stats", default=None, metavar="PATH",
                    help="persist the workload telemetry recorder "
                         "(repro.workload.WORKLOAD_STATS): load at startup "
                         "so compaction's cost model starts warm with last "
                         "run's predicate mix, save at exit")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    rng = np.random.default_rng(0)

    if args.plan_stats:
        warm = PLAN_STATS.load(args.plan_stats)
        print(f"plan-stats {'loaded from' if warm else 'cold start at'} "
              f"{args.plan_stats}: buckets {list(PLAN_STATS.boundaries)}")

    if args.workload_stats:
        warm = WORKLOAD_STATS.load(args.workload_stats)
        print(f"workload-stats {'loaded from' if warm else 'cold start at'} "
              f"{args.workload_stats}: {WORKLOAD_STATS.stats()}")

    mesh = make_cli_mesh(args.mesh)
    dp = mesh.shape["data"]
    # batches smaller than the data axis fall back to replication
    rules = {"batch": None} if args.batch % dp else None

    with ShardingCtx(mesh, rules):
        p_sh = param_shardings(mesh, cfg, rules=rules)
        c_sh = cache_shardings(mesh, cfg, rules=rules)
        tok_sh = batch_shardings(mesh, cfg, "decode", rules=rules)["tokens"]
        params = jax.jit(lambda k: transformer.init_params(k, cfg),
                         out_shardings=p_sh)(jax.random.PRNGKey(0))

        lengths = make_requests(args.requests, rng)
        for mode in (False, True):
            batches = pack_batches(lengths, args.batch, histogram_aware=mode,
                                   backend=args.query_backend,
                                   query_fanout=args.query_fanout,
                                   admission=args.admission,
                                   compactor=args.compactor,
                                   hosts=args.hosts if mode else 0)
            waste = padding_waste(lengths, batches)
            print(f"packing histogram_aware={mode} "
                  f"(query backend {args.query_backend}, "
                  f"fanout {args.query_fanout}, "
                  f"admission {args.admission}, "
                  f"hosts {args.hosts}): "
                  f"padding waste {waste:.1%}")

        prof = PhaseProfile()
        with prof.span("pack"):
            batches = pack_batches(lengths, args.batch, histogram_aware=True,
                                   backend=args.query_backend,
                                   query_fanout=args.query_fanout,
                                   admission=args.admission,
                                   compactor=args.compactor,
                                   hosts=args.hosts)
        step = jax.jit(partial(serve_step, cfg=cfg),
                       in_shardings=(p_sh, tok_sh, c_sh, replicated(mesh)),
                       out_shardings=(tok_sh, c_sh), donate_argnums=(2,))
        prefill = jax.jit(
            lambda p, toks: prefill_with_cache(p, cfg, toks, args.max_len),
            in_shardings=(p_sh, tok_sh), out_shardings=(None, c_sh))
        # --profile wraps the loop in a JAX profiler trace (per-HLO detail
        # for TensorBoard); spans block on device results only then, so
        # the unprofiled path keeps its async dispatch pipelining
        trace_cm = (jax.profiler.trace(args.profile) if args.profile
                    else nullcontext())
        t0 = time.time()
        generated = 0
        with trace_cm:
            for bi, idx in enumerate(batches):
                b = len(idx)
                # ragged tail: pad to the full batch (one compiled shape,
                # and the data axis always divides); surplus rows are
                # dropped on count
                if b < args.batch:
                    idx = np.concatenate(
                        [idx, np.repeat(idx[-1], args.batch - b)])
                # pad to a 16-token bucket so jit reuses compiled prefill
                # variants
                prompt_len = min(-(-int(lengths[idx].max()) // 16) * 16,
                                 args.max_len - args.gen_tokens)
                prompts = rng.integers(0, cfg.vocab_size,
                                       size=(args.batch, prompt_len),
                                       dtype=np.int32)
                # fused prefill: one forward pass fills the whole KV cache
                with prof.span("prefill"):
                    logits, cache = prefill(params, jnp.asarray(prompts))
                    if args.profile:
                        jax.block_until_ready(cache)
                tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
                cache_len = jnp.int32(prompt_len)
                generated += b
                for t in range(args.gen_tokens - 1):
                    with prof.span("decode"):
                        tok, cache = step(params, tok, cache, cache_len)
                        if args.profile:
                            jax.block_until_ready(tok)
                    cache_len += 1
                    generated += b
    dt = time.time() - t0
    print(f"served {len(lengths)} requests, {generated} tokens "
          f"in {dt:.1f}s ({generated/dt:.1f} tok/s)")
    if args.profile:
        print(f"profiler trace written to {args.profile} "
              f"(tensorboard --logdir {args.profile})")
        prof.report()
    if args.plan_stats:
        PLAN_STATS.autotune()
        PLAN_STATS.save(args.plan_stats)
        print(f"plan-stats saved to {args.plan_stats}: {PLAN_STATS.stats()}")
    if args.workload_stats:
        WORKLOAD_STATS.save(args.workload_stats)
        print(f"workload-stats saved to {args.workload_stats}: "
              f"{WORKLOAD_STATS.stats()}")


if __name__ == "__main__":
    main()
