import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM and unsupported collectives all fail here.
Emits per-cell JSON (memory analysis, cost analysis, collective bytes
parsed from the post-SPMD HLO) consumed by benchmarks/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""

import argparse
import json
import re
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro.analysis.findings import Finding, render_findings
from repro.configs import list_archs
from repro.dist.sharding import (batch_shardings, cache_shardings,
                                 grad_shardings_zero, opt_shardings,
                                 param_shardings, replicated, zero_pad_for)
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, input_specs, runnable
from repro.models import transformer
from repro.models.common import ShardingCtx
from repro.optim import OptConfig, init_opt_state
from repro.train import prefill_step, serve_step, train_step

SDS = jax.ShapeDtypeStruct

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_computations(hlo_text: str):
    """Split post-SPMD HLO text into {computation_name: [lines]}."""
    comps = {}
    current = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace() and ("{" in line or "->" in line):
            cm = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", line)
            if cm:
                current = cm.group(1)
                comps[current] = []
        elif current is not None:
            comps[current].append(line)
    return comps


def _effective_multipliers(comps: dict) -> dict:
    """comp name -> product of trip counts of all enclosing while loops.

    lax.scan lowers to while(condition=%c, body=%b); the condition compares
    the induction variable to a constant trip count.  Multipliers compose
    across nesting (e.g. microbatch scan x layer scan)."""
    parent = {}
    trip_of_body = {}
    for cname, lines in comps.items():
        for line in lines:
            m = re.search(r"while\(.*?condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)", line)
            if not m:
                m2 = re.search(r"body=%?([\w\.\-]+),\s*condition=%?([\w\.\-]+)", line)
                if m2:
                    body, cond = m2.group(1), m2.group(2)
                else:
                    continue
            else:
                cond, body = m.group(1), m.group(2)
            parent[body] = cname
            n = None
            for cl in comps.get(cond, []):
                cc = re.search(r"compare\(.*\)", cl)
                km = re.search(r"constant\((\d+)\)", cl)
                if km:
                    v = int(km.group(1))
                    if 1 < v <= 65536:
                        n = v
            trip_of_body[body] = n or 1

    mult = {}

    def eff(c):
        if c in mult:
            return mult[c]
        m = trip_of_body.get(c, 1)
        p = parent.get(c)
        mult[c] = m * (eff(p) if p else 1)
        return mult[c]

    for c in comps:
        eff(c)
    return mult


def collective_stats(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in post-SPMD HLO,
    scaled by the product of enclosing while-loop trip counts (scan bodies
    execute trip-count times but appear once in the HLO text)."""
    counts = {c: 0 for c in _COLLECTIVES}
    bytes_ = {c: 0 for c in _COLLECTIVES}
    ops = {c: [] for c in _COLLECTIVES}
    comps = _parse_computations(hlo_text)
    mults = _effective_multipliers(comps)
    for cname, lines in comps.items():
        mult = mults.get(cname, 1)
        for line in lines:
            for c in _COLLECTIVES:
                if re.search(rf"=\s+[^=]*\b{c}(?:-start)?\(", line):
                    if f"{c}-done" in line:
                        continue  # counted at -start
                    lhs = line.split("=")[1] if "=" in line else line
                    shape_part = lhs.split(c)[0]
                    b = _shape_bytes(shape_part)
                    counts[c] += mult
                    bytes_[c] += b * mult
                    ops[c].append({"bytes": b, "mult": mult,
                                   "line": line.strip()[:160]})
    return {"counts": counts, "bytes": bytes_,
            "total_bytes": sum(bytes_.values()), "ops": ops}


def build_step(cfg, kind, specs, mesh, microbatches: int = 1,
               grad_zero: bool = False):
    """Returns (jitted_fn, example_args, sharding-rule overrides)."""
    if kind == "train":
        opt_cfg = OptConfig()
        p_sh = param_shardings(mesh, cfg)
        o_sh = opt_shardings(mesh, cfg)
        b_sh = batch_shardings(mesh, cfg, "train")
        params_s = jax.eval_shape(
            lambda k: transformer.init_params(k, cfg), SDS((2,), jnp.uint32))
        opt_s = jax.eval_shape(
            partial(init_opt_state, zero_pad=zero_pad_for(mesh)), params_s)

        fn = partial(train_step, cfg=cfg, opt_cfg=opt_cfg,
                     microbatches=microbatches,
                     grad_shardings=(grad_shardings_zero(mesh, cfg)
                                     if grad_zero else None))
        jitted = jax.jit(
            fn, in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1))
        return jitted, (params_s, opt_s, specs["batch"]), None

    if kind == "prefill":
        p_sh = param_shardings(mesh, cfg)
        b_sh = batch_shardings(mesh, cfg, "prefill")
        params_s = jax.eval_shape(
            lambda k: transformer.init_params(k, cfg), SDS((2,), jnp.uint32))
        fn = partial(prefill_step, cfg=cfg)
        jitted = jax.jit(fn, in_shardings=(p_sh, b_sh), out_shardings=None)
        return jitted, (params_s, specs["batch"]), None

    # decode: small batches (long_500k has B=1) fall back to replication
    B = specs["tokens"].shape[0]
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = 1
    for a in data_axes:
        dp *= mesh.shape[a]
    rules = {"batch": None} if B % dp else None
    p_sh = param_shardings(mesh, cfg, rules=rules)
    c_sh = cache_shardings(mesh, cfg, rules=rules)
    tok_sh = jax.NamedSharding(
        mesh, jax.sharding.PartitionSpec(data_axes if B % dp == 0 else None))
    params_s = jax.eval_shape(
        lambda k: transformer.init_params(k, cfg), SDS((2,), jnp.uint32))
    fn = partial(serve_step, cfg=cfg)
    jitted = jax.jit(
        fn, in_shardings=(p_sh, tok_sh, c_sh, replicated(mesh)),
        out_shardings=(tok_sh, c_sh), donate_argnums=(2,))
    return jitted, (params_s, specs["tokens"], specs["cache"],
                    specs["cache_len"]), rules


def run_cell(arch: str, shape_name: str, multi_pod: bool, hlo_dir=None,
             microbatches: int = 1, remat_policy: str | None = None,
             moe_dispatch: str | None = None, grad_zero: bool = False) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg, kind, specs = input_specs(arch, shape_name)
    from dataclasses import replace as _rp
    if remat_policy:
        cfg = _rp(cfg, remat_policy=remat_policy)
    if moe_dispatch:
        cfg = _rp(cfg, moe_dispatch=moe_dispatch)
    ok, reason = runnable(cfg, SHAPES[shape_name])
    rec = {
        "arch": arch, "shape": shape_name, "kind": kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.size,
        "microbatches": microbatches, "remat_policy": cfg.remat_policy,
        "moe_dispatch": cfg.moe_dispatch, "grad_zero": grad_zero,
    }
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    try:
        jitted, args, rules = build_step(cfg, kind, specs, mesh, microbatches,
                                         grad_zero)
        with mesh, ShardingCtx(mesh, rules):
            lowered = jitted.lower(*args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):  # jax<0.5 returns [dict]
                cost = cost[0] if cost else {}
            hlo = compiled.as_text()
        coll = collective_stats(hlo)
        coll_light = {k: v for k, v in coll.items() if k != "ops"}
        rec.update(
            status="ok",
            lower_s=round(t_lower - t0, 2),
            compile_s=round(t_compile - t_lower, 2),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            },
            cost={k: cost.get(k) for k in
                  ("flops", "bytes accessed", "transcendentals",
                   "bytes accessed operand 0 {}", "utilization operand 0 {}")
                  if k in cost} | {"flops": cost.get("flops"),
                                   "bytes_accessed": cost.get("bytes accessed")},
            collectives=coll_light,
        )
        if hlo_dir:
            os.makedirs(hlo_dir, exist_ok=True)
            fname = f"{arch}_{shape_name}_{rec['mesh']}.hlo.txt.gz"
            import gzip
            with gzip.open(os.path.join(hlo_dir, fname), "wt") as f:
                f.write(hlo)
            rec["hlo_file"] = fname
    except Exception as e:  # noqa: BLE001 — report the failure in results
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def budget_key(rec: dict) -> str:
    return f"{rec['mesh']}__{rec['arch']}__{rec['shape']}"


def check_budget(rec: dict, budget: dict) -> str:
    """Assert a cell's HLO-collective volume against its committed ceiling.

    Returns 'ok' (within budget), 'exceeded', or 'unbudgeted' (no entry for
    this cell yet — informational, so the budget file can grow cell by cell
    via ``--update-budget``).  Only collective *bytes* are gated: op counts
    are a compiler choice (e.g. all-reduce vs reduce-scatter+all-gather),
    bytes moved are the cost model.
    """
    entry = budget.get(budget_key(rec))
    if entry is None:
        return "unbudgeted"
    got = rec["collectives"]["total_bytes"]
    limit = entry["total_bytes"]
    rec["budget"] = {"total_bytes_limit": limit, "total_bytes": got}
    return "exceeded" if got > limit else "ok"


def update_budget(path: str, results: list, slack: float) -> None:
    """Write observed collective volumes (x ``slack``) as the new ceilings,
    merging over any existing entries so partial sweeps extend the file."""
    budget = {}
    if os.path.exists(path):
        with open(path) as f:
            budget = json.load(f)
    for rec in results:
        if rec.get("status") == "ok":
            budget[budget_key(rec)] = {
                "total_bytes": int(rec["collectives"]["total_bytes"] * slack),
                "counts": rec["collectives"]["counts"],
            }
    with open(path, "w") as f:
        json.dump(dict(sorted(budget.items())), f, indent=1)
    print(f"budget {path}: {len(budget)} cells "
          f"(ceilings = observed bytes x {slack})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat-policy", default=None, choices=[None, "dots", "full"])
    ap.add_argument("--moe-dispatch", default=None, choices=[None, "gather", "scatter"])
    ap.add_argument("--grad-zero", action="store_true")
    ap.add_argument("--budget", default=None,
                    help="HLO-collective budget json "
                         "(benchmarks/COLLECTIVE_budget.json): fail any "
                         "cell whose collective bytes exceed its committed "
                         "ceiling; cells without an entry are reported but "
                         "don't fail")
    ap.add_argument("--update-budget", default=None, metavar="PATH",
                    help="after the sweep, write observed collective "
                         "volumes x --budget-slack as the new ceilings "
                         "(merges over existing entries)")
    ap.add_argument("--budget-slack", type=float, default=1.25)
    args = ap.parse_args()

    budget = None
    if args.budget:
        with open(args.budget) as f:
            budget = json.load(f)

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    results = []
    unbudgeted = []  # report-only, rendered in repro.analysis finding format
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mp,
                               hlo_dir=os.path.join(args.out, "hlo")
                               if args.save_hlo else None,
                               microbatches=args.microbatches,
                               remat_policy=args.remat_policy,
                               moe_dispatch=args.moe_dispatch,
                               grad_zero=args.grad_zero)
                results.append(rec)
                tag = f"{rec['mesh']} {arch} {shape}"
                if rec["status"] == "ok":
                    note = ""
                    if budget is not None:
                        verdict = check_budget(rec, budget)
                        rec["budget_status"] = verdict
                        if verdict == "exceeded":
                            note = (f"  BUDGET EXCEEDED "
                                    f"(limit {rec['budget']['total_bytes_limit']:.3e}B)")
                        elif verdict == "unbudgeted":
                            note = "  (no budget entry)"
                            unbudgeted.append(Finding(
                                rule="budget/unbudgeted-cell",
                                path=args.budget, line=1,
                                message=("cell compiled but has no "
                                         "collective-bytes ceiling; accept "
                                         "with --update-budget"),
                                detail=budget_key(rec)))
                    print(f"[ok]   {tag}  lower={rec['lower_s']}s "
                          f"compile={rec['compile_s']}s "
                          f"flops={rec['cost'].get('flops'):.3e} "
                          f"coll={rec['collectives']['total_bytes']:.3e}B"
                          f"{note}",
                          flush=True)
                elif rec["status"] == "skipped":
                    print(f"[skip] {tag}  {rec['reason']}", flush=True)
                else:
                    print(f"[ERR]  {tag}  {rec['error']}", flush=True)
                fname = f"{rec['mesh'].replace('x','_')}__{arch}__{shape}.json"
                with open(os.path.join(args.out, fname), "w") as f:
                    json.dump(rec, f, indent=1)
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(results, f, indent=1)
    if args.update_budget:
        update_budget(args.update_budget, results, args.budget_slack)
    n_err = sum(r["status"] == "error" for r in results)
    n_over = sum(r.get("budget_status") == "exceeded" for r in results)
    n_unbudgeted = sum(r.get("budget_status") == "unbudgeted"
                       for r in results)
    if unbudgeted:
        # same file:line [rule] shape the static analyzer prints, so the
        # nightly log is greppable with one pattern; still report-only
        print("\n".join(render_findings(unbudgeted)), flush=True)
    msg = f"done: {len(results)} cells, {n_err} errors"
    if budget is not None:
        msg += (f", {n_over} over collective budget "
                f"({n_unbudgeted} unbudgeted)")
    print(msg, flush=True)
    sys.exit(1 if (n_err or n_over) else 0)


if __name__ == "__main__":
    main()
