"""Mixture-of-Experts with bitmap-encoded dispatch (the paper, transplanted).

A top-k router over E experts assigns each token a k-of-E code — exactly the
paper's k-of-N bitmap encoding (qwen2-moe: 4-of-60, olmoe: 8-of-64).  The
(tokens x experts) dispatch matrix is a bitmap index whose rows we reorder:

  * ``route_sort="expert"``   — plain sort by first expert id (Alpha-Lex).
  * ``route_sort="grayfreq"`` — Gray-Frequency: tokens sorted by the
    frequency-rank of their expert set, clustering tokens with identical
    (and popular) expert sets so the EWAH-compressed dispatch metadata
    shrinks and expert gathers become runs (benchmarks/bench_moe_dispatch).

Experts are sharded over the "model" axis (EP); capacity-based gather /
scatter dispatch keeps memory bounded and lets GSPMD lower the token
movement to all-to-all.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, lshard, silu


def padded_experts(n_experts: int) -> int:
    """Pad the expert dim to a multiple of 16 so EP shards evenly on the
    production model axis (padded experts receive no tokens)."""
    if n_experts <= 16:
        return n_experts
    return -(-n_experts // 16) * 16


def init_moe(key, cfg, dtype):
    d, ff, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ep = padded_experts(e)
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (ep, d, ff), dtype=dtype),
        "w_up": dense_init(ks[2], (ep, d, ff), dtype=dtype),
        "w_down": dense_init(ks[3], (ep, ff, d), dtype=dtype),
    }
    if cfg.n_shared_experts:
        sff = cfg.shared_d_ff
        k2 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(k2[0], (d, sff), dtype=dtype),
            "w_up": dense_init(k2[1], (d, sff), dtype=dtype),
            "w_down": dense_init(k2[2], (sff, d), dtype=dtype),
        }
    return p


def moe_axes(cfg):
    ax = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", None),
        "w_up": ("experts", "embed", None),
        "w_down": ("experts", None, "embed"),
    }
    if cfg.n_shared_experts:
        ax["shared"] = {
            "w_gate": ("embed", "ff"),
            "w_up": ("embed", "ff"),
            "w_down": ("ff", "embed"),
        }
    return ax


def _route(p, cfg, xf):
    """Router: top-k expert ids + normalized gates. xf: (T, d) float32."""
    logits = xf.astype(jnp.float32) @ p["router"]  # (T, E)
    gates, eids = jax.lax.top_k(logits, cfg.top_k)  # (T, k)
    gates = jax.nn.softmax(gates, axis=-1)
    return eids, gates, logits


def routing_bitmap_words(eids, n_experts: int):
    """k-of-E routing bitmaps packed to uint32 words: (E, ceil(T/32)).

    Column-per-expert layout, rows = tokens — the dispatch matrix as a
    bitmap index (paper §2); compressed sizes measured by the benchmark.
    """
    T, k = eids.shape
    n_words = (T + 31) // 32
    onehot = jax.nn.one_hot(eids, n_experts, dtype=jnp.uint32).sum(1)  # (T, E)
    onehot = jnp.minimum(onehot, 1)  # duplicate expert ids still set one bit
    pad = n_words * 32 - T
    onehot = jnp.pad(onehot, ((0, pad), (0, 0)))
    m = onehot.reshape(n_words, 32, n_experts)
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :, None]
    return (m << shifts).sum(1).astype(jnp.uint32).T  # (E, n_words)


def grayfreq_token_order(eids, n_experts: int):
    """Gray-Frequency row ordering for the dispatch bitmap index.

    Token key = (frequency-rank of its expert-set class, expert ids);
    tokens with identical popular expert sets become adjacent runs
    (paper §4.2 applied to the routing table).
    """
    T, k = eids.shape
    se = jnp.sort(eids, axis=1)  # canonical (sorted) expert set per token
    # group identical expert sets via lexsort over the k id columns
    order = jnp.lexsort(tuple(se[:, i] for i in range(k - 1, -1, -1)))
    sse = se[order]
    new = jnp.concatenate(
        [jnp.ones(1, bool), jnp.any(sse[1:] != sse[:-1], axis=1)])
    grp = jnp.cumsum(new) - 1
    counts = jax.ops.segment_sum(jnp.ones(T, jnp.int32), grp, num_segments=T)
    freq = counts[grp]  # set-class frequency, aligned with sorted order
    # final key: descending frequency, group id tiebreak (last key primary)
    reorder = jnp.lexsort((grp, -freq))
    return order[reorder]  # token permutation


def moe_ffn(p, cfg, x, capacity_factor=None, route_sort="none",
            dispatch="gather"):
    """x: (b, s, d) -> (b, s, d).

    dispatch="gather" (default, §Perf hillclimb #1): build a replicated
    (E, cap) slot->token index, then GATHER tokens into the EP-sharded
    (E, cap, d) buffer — with x replicated across the model axis each
    expert shard reads its slice locally, and the only collective is the
    (T, d) all-reduce of the combine (same volume as a dense Megatron
    MLP).  dispatch="scatter" is the paper-faithful-naive baseline whose
    scatter into an EP-sharded operand makes GSPMD all-gather the full
    token buffer per layer (measured 24x more collective bytes).
    """
    b, s, d = x.shape
    e, k = p["w_gate"].shape[0], cfg.top_k  # e includes EP padding
    if capacity_factor is None:
        capacity_factor = getattr(cfg, "moe_capacity_factor", 1.25)
    T = b * s
    xf = x.reshape(T, d)
    eids, gates, logits = _route(p, cfg, xf)

    if dispatch == "gather":
        # --- per-sequence (grouped) dispatch: §Perf iterations 2+5 --------
        # Every plan op is batched over the (data-sharded) batch dim, so
        # routing/sort/gather are shard-local; the only collective left is
        # the combine's (b_local, s, d) psum — same volume as a dense
        # Megatron MLP.  A global-batch plan forces GSPMD to all-gather
        # tokens across the data axis (measured 23x more collective bytes).
        cap = int(capacity_factor * s * k / cfg.n_experts + 0.5)
        cap = max(8, min(cap, s))
        be = eids.reshape(b, s, k)
        bg = gates.reshape(b, s, k).astype(x.dtype)
        a_eid = be.reshape(b, s * k)
        a_gate = bg.reshape(b, s * k)
        tok = jnp.broadcast_to(
            jnp.repeat(jnp.arange(s, dtype=jnp.int32), k), (b, s * k))
        if route_sort == "grayfreq":
            # cluster similar expert sets adjacently within the sequence
            # (per-shard approximation of Gray-Frequency keyed on the two
            # smallest expert ids; the exact global ordering is used for
            # the dispatch-metadata bitmaps, see grayfreq_token_order)
            se = jnp.sort(be, axis=2)
            raw = se[:, :, 0] * e + (se[:, :, 1] if k > 1 else 0)
            # dense-rank to keep the composite key within int32
            sub = jnp.argsort(jnp.argsort(raw, axis=1), axis=1)
            sub = jnp.repeat(sub, k, axis=1).astype(jnp.int32)
        else:
            sub = tok
        order = jnp.argsort(a_eid * (s * k) + sub, axis=1)
        a_eid = jnp.take_along_axis(a_eid, order, axis=1)
        a_gate = jnp.take_along_axis(a_gate, order, axis=1)
        tok = jnp.take_along_axis(tok, order, axis=1)

        # position within expert, per sequence
        idx = jnp.arange(s * k)
        new = jnp.concatenate(
            [jnp.ones((b, 1), bool), a_eid[:, 1:] != a_eid[:, :-1]], axis=1)
        seg_start = jax.lax.cummax(jnp.where(new, idx[None], 0), axis=1)
        pos = idx[None] - seg_start
        keep = pos < cap
        slot = jnp.where(keep, a_eid * cap + pos, e * cap)

        # slot -> token plan, built per sequence via vmap so the scatter /
        # gather carry an explicit batch dimension GSPMD keeps shard-local
        # (arange-indexed scatters defeat its batching detection and
        # reintroduce data-axis all-gathers — measured, see §Perf)
        def plan_row(slot_r, tok_r, gate_r):
            tfs = jnp.full((e * cap + 1,), s, jnp.int32
                           ).at[slot_r].set(tok_r, mode="drop")
            gfs = jnp.zeros((e * cap + 1,), x.dtype
                            ).at[slot_r].set(gate_r, mode="drop")
            return tfs[:-1], gfs[:-1]

        tok_for_slot, gate_for_slot = jax.vmap(plan_row)(slot, tok, a_gate)

        xpad = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
        buf = jax.vmap(lambda xp, t: xp[t])(xpad, tok_for_slot)
        buf = buf.reshape(b, e, cap, d)
        buf = lshard(buf, "batch", "experts", "expert_cap", "embed")

        h = jnp.einsum("becd,edf->becf", buf, p["w_gate"])
        u = jnp.einsum("becd,edf->becf", buf, p["w_up"])
        h = silu(h) * u
        out = jnp.einsum("becf,efd->becd", h, p["w_down"])
        out = lshard(out, "batch", "experts", "expert_cap", "embed")

        gated = (out * gate_for_slot.reshape(b, e, cap, 1)).reshape(b, e * cap, d)
        y = jax.vmap(
            lambda o, t: jnp.zeros((s + 1, d), x.dtype).at[t].add(o, mode="drop")
        )(gated, tok_for_slot)
        y = y[:, :s].reshape(T, d)
    else:
        # --- "scatter" baseline: global-batch plan + scatter into the
        # EP-sharded buffer (paper-faithful-naive; kept for §Perf A/B) ----
        xf = x.reshape(T, d)
        cap = int(capacity_factor * T * k / cfg.n_experts + 0.5)
        cap = max(8, min(cap, T))
        tok = jnp.repeat(jnp.arange(T), k)
        a_eid = eids.reshape(-1)
        a_gate = gates.reshape(-1)
        if route_sort == "grayfreq":
            perm = grayfreq_token_order(eids, e)
            inv_rank = jnp.zeros(T, jnp.int32).at[perm].set(
                jnp.arange(T, dtype=jnp.int32))
            sub = inv_rank[tok]
        else:
            sub = tok
        order = jnp.lexsort((sub, a_eid))
        a_eid, a_gate, tok = a_eid[order], a_gate[order], tok[order]
        new = jnp.concatenate([jnp.ones(1, bool), a_eid[1:] != a_eid[:-1]])
        seg_start = jax.lax.cummax(jnp.where(new, jnp.arange(T * k), 0))
        pos = jnp.arange(T * k) - seg_start
        keep = pos < cap
        slot = jnp.where(keep, a_eid * cap + pos, e * cap)
        buf = jnp.zeros((e * cap + 1, d), x.dtype)
        buf = buf.at[slot].set(xf[tok], mode="drop")
        buf = buf[:-1].reshape(e, cap, d)
        buf = lshard(buf, "experts", "expert_cap", "embed")
        h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
        h = silu(h) * u
        out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
        out = lshard(out, "experts", "expert_cap", "embed")
        outf = out.reshape(e * cap, d)
        contrib = outf[jnp.where(keep, a_eid * cap + pos, 0)] * \
            a_gate[:, None].astype(x.dtype)
        contrib = jnp.where(keep[:, None], contrib, 0)
        y = jnp.zeros((T, d), x.dtype).at[tok].add(contrib)

    # --- shared experts (qwen2-moe) ----------------------------------------
    if cfg.n_shared_experts:
        sp = p["shared"]
        # shared experts are fused into one wide FFN (width = n_shared * ff)
        sh = silu(xf @ sp["w_gate"]) * (xf @ sp["w_up"])
        y = y + sh @ sp["w_down"]
    y = y.reshape(b, s, d)

    # aux: load-balancing loss (Switch-style) so training is realistic
    probs = jax.nn.softmax(logits, axis=-1)
    load = jnp.zeros(cfg.n_experts).at[eids.reshape(-1)].add(1.0) / (T * k)
    importance = probs.mean(0)
    aux = cfg.n_experts * jnp.sum(load * importance)
    return lshard(y, "batch", "seq", "embed"), aux
