from . import attention, common, moe, ssm, transformer
from .transformer import (cache_axes, decode_step, forward, init_decode_cache,
                          init_params, n_params, params_axes)

__all__ = ["attention", "common", "moe", "ssm", "transformer", "forward",
           "decode_step", "init_params", "init_decode_cache", "params_axes",
           "cache_axes", "n_params"]
