"""GQA attention: blockwise (flash-style) training path + cached decode path.

Sharding (DESIGN.md §6): q heads -> "model" axis, KV heads replicated across
the model axis (GQA kv counts are small and rarely divisible by TP degree);
decode KV caches are sequence-sharded across "model" and GSPMD turns the
softmax/value reductions into the flash-decode collective pattern.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .common import apply_mrope, apply_rope, dense_init, lshard

NEG_INF = -1e30


def init_attention(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * hd), dtype=dtype),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def attention_axes(cfg):
    ax = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
    }
    if cfg.qkv_bias:
        ax.update({"bq": ("heads",), "bk": ("kv_heads",), "bv": ("kv_heads",)})
    return ax


def _project_qkv(p, cfg, x, positions, mrope_positions=None):
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"] + (p.get("bq", 0.0))
    k = x @ p["wk"] + (p.get("bk", 0.0))
    v = x @ p["wv"] + (p.get("bv", 0.0))
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    if mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
    elif cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = lshard(q, "batch", "seq", "heads", "head_dim")
    k = lshard(k, "batch", "seq", "kv_heads", "head_dim")
    v = lshard(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def _blockwise_attn(q, k, v, n_kv_heads, window, block_q=512, block_k=1024):
    """Online-softmax attention over KV blocks (flash-style, pure jnp/lax).

    q: (b, sq, h, hd)  k/v: (b, sk, kvh, hd).  Causal; optional sliding
    window.  Memory O(sq * block_k) instead of O(sq * sk).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    g = h // n_kv_heads
    scale = hd ** -0.5
    q = q.reshape(b, sq, n_kv_heads, g, hd) * scale

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    nq, nk = sq // block_q, sk // block_k

    def q_block(qi):
        qb = jax.lax.dynamic_slice_in_dim(q, qi * block_q, block_q, axis=1)
        q_pos = qi * block_q + jnp.arange(block_q)

        def kv_step(carry, ki):
            acc, m, l = carry
            kb = jax.lax.dynamic_slice_in_dim(k, ki * block_k, block_k, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, ki * block_k, block_k, axis=1)
            s_ = jnp.einsum("bqngd,bknd->bngqk", qb, kb,
                            preferred_element_type=jnp.float32)
            k_pos = ki * block_k + jnp.arange(block_k)
            mask = k_pos[None, :] <= q_pos[:, None]
            if window:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            s_ = jnp.where(mask[None, None, None], s_, NEG_INF)
            m_new = jnp.maximum(m, s_.max(-1))
            p_ = jnp.exp(s_ - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p_.sum(-1)
            pv = jnp.einsum("bngqk,bknd->bngqd", p_.astype(vb.dtype), vb)
            acc = acc * corr[..., None].astype(acc.dtype) + pv
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((b, n_kv_heads, g, block_q, hd), v.dtype)
        m0 = jnp.full((b, n_kv_heads, g, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, n_kv_heads, g, block_q), jnp.float32)
        # only kv blocks with k_start <= q_end are relevant (causal skip)
        hi = jnp.minimum((qi * block_q + block_q + block_k - 1) // block_k, nk)
        (acc, m, l), _ = jax.lax.scan(
            lambda c, i: jax.lax.cond(i < hi, lambda: kv_step(c, i), lambda: (c, None)),
            (acc0, m0, l0), jnp.arange(nk))
        return acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)

    if nq == 1:
        out = q_block(jnp.int32(0))  # (b, kvh, g, sq, hd)
    else:
        out = jax.lax.map(q_block, jnp.arange(nq))  # (nq, b, kvh, g, bq, hd)
        out = jnp.moveaxis(out, 0, 3).reshape(b, n_kv_heads, g, sq, hd)
    # (b, kvh, g, sq, hd) -> (b, sq, h, hd)
    return jnp.moveaxis(out, 3, 1).reshape(b, sq, h, hd)


def _dense_attn(q, k, v, n_kv_heads, window, q_offset=0):
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    g = h // n_kv_heads
    q = q.reshape(b, sq, n_kv_heads, g, hd) * hd**-0.5
    s_ = jnp.einsum("bqngd,bknd->bngqk", q, k, preferred_element_type=jnp.float32)
    q_pos = jnp.arange(sq)[:, None] + q_offset
    k_pos = jnp.arange(sk)[None, :]
    mask = k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s_ = jnp.where(mask[None, None, None], s_, NEG_INF)
    p_ = jax.nn.softmax(s_, axis=-1).astype(v.dtype)
    out = jnp.einsum("bngqk,bknd->bqngd", p_, v)
    return out.reshape(b, sq, h, hd)


def attention(p, cfg, x, positions, mrope_positions=None, impl="blockwise",
              return_kv=False):
    """Training / prefill attention. x: (b, s, d) -> (b, s, d).

    return_kv=True additionally returns the (k, v) projections so prefill
    can populate the decode cache in one pass (serve/prefill_with_cache)."""
    b, s, d = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions, mrope_positions)
    window = cfg.sliding_window or None
    if impl == "dense" or s <= 1024:
        o = _dense_attn(q, k, v, cfg.n_kv_heads, window)
    else:
        o = _blockwise_attn(q, k, v, cfg.n_kv_heads, window)
    o = lshard(o, "batch", "seq", "heads", "head_dim")
    out = o.reshape(b, s, cfg.n_heads * cfg.head_dim) @ p["wo"]
    out = lshard(out, "batch", "seq", "embed")
    if return_kv:
        return out, k, v
    return out


def decode_attention(p, cfg, x, cache_k, cache_v, cache_len, mrope_positions=None):
    """Single-token decode with KV cache.

    x: (b, 1, d); cache_k/v: (b, S, kvh, hd) seq-sharded over "model";
    cache_len: scalar int — current length (new token written at cache_len).
    Returns (out, new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), cache_len, dtype=jnp.int32)
    if mrope_positions is not None:
        mrope_positions = jnp.broadcast_to(
            jnp.full((3, b, 1), cache_len, jnp.int32), (3, b, 1))
    q, k, v = _project_qkv(p, cfg, x, positions, mrope_positions)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), cache_len, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), cache_len, axis=1)
    cache_k = lshard(cache_k, "batch", "kv_seq", "kv_heads", "head_dim")
    cache_v = lshard(cache_v, "batch", "kv_seq", "kv_heads", "head_dim")
    S = cache_k.shape[1]
    g = cfg.n_heads // cfg.n_kv_heads
    qh = q.reshape(b, 1, cfg.n_kv_heads, g, cfg.head_dim) * cfg.head_dim**-0.5
    s_ = jnp.einsum("bqngd,bknd->bngqk", qh, cache_k,
                    preferred_element_type=jnp.float32)
    k_pos = jnp.arange(S)[None, :]
    valid = k_pos <= cache_len
    if cfg.sliding_window:
        valid &= k_pos > cache_len - cfg.sliding_window
    s_ = jnp.where(valid[None, None, None], s_, NEG_INF)
    p_ = jax.nn.softmax(s_, axis=-1).astype(cache_v.dtype)
    o = jnp.einsum("bngqk,bknd->bqngd", p_, cache_v)
    out = o.reshape(b, 1, cfg.n_heads * cfg.head_dim) @ p["wo"]
    return lshard(out, "batch", "seq", "embed"), cache_k, cache_v
