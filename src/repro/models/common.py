"""Shared model components: norms, RoPE/M-RoPE, initializers, logical sharding."""

from __future__ import annotations

import threading

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# Logical-axis sharding.  Models annotate activations/params with *logical*
# axis names; the mesh context maps them to physical mesh axes.  Outside a
# mesh context the annotations are no-ops, so the same model code runs in
# single-device smoke tests and 512-device dry-runs.
# ---------------------------------------------------------------------------

_ctx = threading.local()

DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": None,        # GQA kv replicated across model axis (DESIGN §6)
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "experts": "model",      # expert parallelism
    "expert_cap": None,
    "kv_seq": "model",       # decode-time KV cache sequence sharding
    "ssm_inner": "model",
    "ssm_heads": "model",    # decode SSM state sharded by heads (§Perf #3)
    "ssm_state": None,
    "opt_zero": "data",      # ZeRO-1 axis for optimizer moments
    "conv_k": None,
}


class ShardingCtx:
    """Context manager activating logical->physical sharding inside a mesh."""

    def __init__(self, mesh, rules=None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)

    def __enter__(self):
        self._prev = current_ctx()
        _ctx.current = self
        return self

    def __exit__(self, *a):
        _ctx.current = self._prev


def current_ctx():
    return getattr(_ctx, "current", None)


def logical_to_spec(axes) -> P:
    ctx = current_ctx()
    if ctx is None:
        return P()
    phys = []
    for ax in axes:
        m = ctx.rules.get(ax) if ax is not None else None
        # drop mesh axes the current mesh doesn't have (e.g. "pod" on 2D mesh)
        if isinstance(m, tuple):
            m = tuple(x for x in m if x in ctx.mesh.axis_names)
            m = m if m else None
        elif m is not None and m not in ctx.mesh.axis_names:
            m = None
        phys.append(m)
    return P(*phys)


def lshard(x: jax.Array, *axes):
    """Constrain x to the logical sharding; no-op outside a mesh context."""
    ctx = current_ctx()
    if ctx is None or x.ndim != len(axes):
        return x
    # NamedSharding (not a bare spec) so no enclosing `with mesh:` is needed
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(ctx.mesh, logical_to_spec(axes)))


def spec_for(axes) -> P:
    """PartitionSpec for a parameter with the given logical axes."""
    return logical_to_spec(axes)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis=0, dtype=jnp.float32, scale=1.0):
    fan_in = shape[in_axis]
    std = scale / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * weight


def silu(x):
    return x * jax.nn.sigmoid(x)


def swiglu(x, w_gate, w_up, w_down):
    h = silu(x @ w_gate) * (x @ w_up)
    h = lshard(h, "batch", "seq", "ff")
    return h @ w_down


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 1e4):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions, sections=(16, 24, 24), theta: float = 1e4):
    """Qwen2-VL multimodal RoPE.

    positions: (3, ..., seq) — temporal/height/width position ids.  The
    rotary half-dim is partitioned into ``sections`` (sum = head_dim/2);
    each section rotates by its own position component.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    sec = jnp.concatenate(
        [jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)])
    # per rotary frequency, pick which position component (t/h/w) drives it
    comp = positions.astype(jnp.float32)  # (3, ..., seq)
    angles = comp[..., None] * freqs  # (3, ..., seq, hd/2)
    angles = jnp.take_along_axis(
        jnp.moveaxis(angles, 0, -1),  # (..., seq, hd/2, 3)
        sec[(None,) * (angles.ndim - 2) + (slice(None), None)].astype(jnp.int32),
        axis=-1,
    )[..., 0]  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def causal_mask(q_len, kv_len, q_offset=0, window: int | None = None):
    q = jnp.arange(q_len)[:, None] + q_offset
    k = jnp.arange(kv_len)[None, :]
    m = k <= q
    if window is not None and window > 0:
        m &= k > q - window
    return m
