"""Model assembly: embedding -> scanned layer stack -> head.

One code path serves all 10 assigned architectures; the config selects the
mixer (attention / MoE-FFN / Mamba2 / hybrid) per layer.  Layer parameters
are stacked on a leading axis and iterated with ``jax.lax.scan`` so the HLO
is O(1) in depth (critical for 512-device dry-run compiles).

Hybrid (Zamba2): a stack of Mamba2 layers with ONE weight-shared
(attention + MLP) block applied after every ``attn_every`` mamba layers —
implemented as segmented scans so forward and decode interleave identically.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .common import dense_init, embed_init, lshard, rms_norm, swiglu


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_layer(key, cfg, dtype):
    """One repeated block's params (stacked across layers by init_params)."""
    ks = jax.random.split(key, 4)
    p = {"ln1": jnp.ones((cfg.d_model,), dtype)}
    if cfg.family in ("ssm", "hybrid"):
        p["mixer"] = ssm_mod.init_mamba2(ks[0], cfg, dtype)
        return p  # no per-layer FFN: mamba2 mixer includes the expansion
    p["mixer"] = attn.init_attention(ks[0], cfg, dtype)
    p["ln2"] = jnp.ones((cfg.d_model,), dtype)
    if cfg.family == "moe":
        p["ffn"] = moe_mod.init_moe(ks[1], cfg, dtype)
    else:
        d, f = cfg.d_model, cfg.d_ff
        p["ffn"] = {
            "w_gate": dense_init(ks[1], (d, f), dtype=dtype),
            "w_up": dense_init(ks[2], (d, f), dtype=dtype),
            "w_down": dense_init(ks[3], (f, d), dtype=dtype),
        }
    return p


def layer_axes(cfg):
    ax = {"ln1": ("embed",)}
    if cfg.family in ("ssm", "hybrid"):
        ax["mixer"] = ssm_mod.mamba2_axes(cfg)
        return ax
    ax["mixer"] = attn.attention_axes(cfg)
    ax["ln2"] = ("embed",)
    if cfg.family == "moe":
        ax["ffn"] = moe_mod.moe_axes(cfg)
    else:
        ax["ffn"] = {"w_gate": ("embed", "ff"), "w_up": ("embed", "ff"),
                     "w_down": ("ff", "embed")}
    return ax


def _init_shared_block(key, cfg, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "ln1": jnp.ones((d,), dtype),
        "attn": attn.init_attention(k1, cfg, dtype),
        "ln2": jnp.ones((d,), dtype),
        "ffn": {
            "w_gate": dense_init(k2, (d, f), dtype=dtype),
            "w_up": dense_init(k3, (d, f), dtype=dtype),
            "w_down": dense_init(k4, (f, d), dtype=dtype),
        },
    }


def init_params(key, cfg):
    dtype = _dtype(cfg)
    k_emb, k_layers, k_head, k_shared = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg, dtype))(layer_keys)
    params = {
        "embed": embed_init(k_emb, (cfg.padded_vocab, cfg.d_model), dtype),
        "layers": layers,
        "ln_f": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.padded_vocab), dtype=dtype)
    if cfg.family == "hybrid" and cfg.attn_every:
        params["shared_attn"] = _init_shared_block(k_shared, cfg, dtype)
    return params


def params_axes(cfg):
    """Logical-axis pytree mirroring init_params (layer leaves get a leading
    None for the stacked layer dim)."""
    lax_ = layer_axes(cfg)
    stacked = jax.tree.map(
        lambda a: (None,) + tuple(a), lax_,
        is_leaf=lambda x: isinstance(x, tuple))
    axes = {
        "embed": ("vocab", "embed"),
        "layers": stacked,
        "ln_f": ("embed",),
    }
    if not cfg.tie_embeddings:
        axes["lm_head"] = ("embed", "vocab")
    if cfg.family == "hybrid" and cfg.attn_every:
        axes["shared_attn"] = {
            "ln1": ("embed",), "attn": attn.attention_axes(cfg),
            "ln2": ("embed",),
            "ffn": {"w_gate": ("embed", "ff"), "w_up": ("embed", "ff"),
                    "w_down": ("ff", "embed")},
        }
    return axes


def n_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def _sinusoid(positions, d):
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _block(lp, x, positions, mrope_positions, cfg):
    h = rms_norm(x, lp["ln1"])
    if cfg.family in ("ssm", "hybrid"):
        return x + ssm_mod.mamba2_block(lp["mixer"], cfg, h), 0.0
    mix = attn.attention(lp["mixer"], cfg, h, positions, mrope_positions,
                         impl=cfg.attn_impl)
    x = x + mix
    h = rms_norm(x, lp["ln2"])
    if cfg.family == "moe":
        y, aux = moe_mod.moe_ffn(lp["ffn"], cfg, h, route_sort=cfg.route_sort,
                                 dispatch=cfg.moe_dispatch)
    else:
        y, aux = swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                        lp["ffn"]["w_down"]), 0.0
    return x + y, aux


def _shared_apply(sp, cfg, x, positions):
    h = rms_norm(x, sp["ln1"])
    x = x + attn.attention(sp["attn"], cfg, h, positions, None, impl=cfg.attn_impl)
    h = rms_norm(x, sp["ln2"])
    return x + swiglu(h, sp["ffn"]["w_gate"], sp["ffn"]["w_up"], sp["ffn"]["w_down"])


def _scan_layers(layers_slice, cfg, x, positions, mrope_positions, n):
    def block(lp, x, positions, mrope_positions):
        return _block(lp, x, positions, mrope_positions, cfg)

    if cfg.remat:
        policy = (None if cfg.remat_policy == "full" else
                  jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        block = jax.checkpoint(block, policy=policy)

    def body(carry, lp):
        x, aux = carry
        x, a = block(lp, x, positions, mrope_positions)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), layers_slice)
    return x, aux


def _segments(cfg):
    """Hybrid layer segmentation: [(start, len, shared_after), ...]."""
    if cfg.family != "hybrid" or not cfg.attn_every:
        return [(0, cfg.n_layers, False)]
    segs = []
    i = 0
    while i < cfg.n_layers:
        ln = min(cfg.attn_every, cfg.n_layers - i)
        segs.append((i, ln, i + ln <= cfg.n_layers and ln == cfg.attn_every))
        i += ln
    return segs


def n_shared_slots(cfg):
    return sum(1 for _, _, s in _segments(cfg) if s)


def forward(params, cfg, inputs, positions=None, mrope_positions=None,
            patches=None):
    """inputs: token ids (b, s) int32, or precomputed embeddings (b, s, d).

    ``patches``: (b, P, d) precomputed frontend embeddings (vlm patch /
    audio frame stub per spec) written over the first P positions of the
    embedded sequence — the modality frontend itself is out of scope.
    Returns (logits (b, s, vocab), aux)."""
    if inputs.ndim == 2:
        x = params["embed"][inputs]
    else:
        x = inputs.astype(_dtype(cfg))
    if patches is not None:
        x = jax.lax.dynamic_update_slice(
            x, patches.astype(x.dtype), (0, 0, 0))
    b, s = x.shape[:2]
    x = lshard(x, "batch", "seq", "embed")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if not cfg.rope and cfg.family not in ("ssm", "hybrid"):
        # musicgen-style sinusoidal position embedding (no rotary)
        x = x + _sinusoid(positions, cfg.d_model).astype(x.dtype)

    aux = jnp.float32(0.0)
    for start, ln, shared_after in _segments(cfg):
        sl = jax.tree.map(lambda a: a[start : start + ln], params["layers"])
        x, a = _scan_layers(sl, cfg, x, positions, mrope_positions, ln)
        aux = aux + a
        if shared_after:
            x = _shared_apply(params["shared_attn"], cfg, x, positions)

    x = rms_norm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return lshard(logits, "batch", "seq", "vocab"), aux


# ---------------------------------------------------------------------------
# decode (single token, cached)
# ---------------------------------------------------------------------------


def init_decode_cache(cfg, batch: int, max_len: int, dtype=None):
    """Attention: K/V (layers, b, S, kvh, hd); SSM: conv tail + state."""
    dtype = dtype or _dtype(cfg)
    cache = {}
    if cfg.family in ("ssm", "hybrid"):
        d_in = cfg.ssm_expand * cfg.d_model
        conv_dim = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
        hp = d_in // cfg.ssm_heads
        cache["conv"] = jnp.zeros(
            (cfg.n_layers, batch, ssm_mod.CONV_K - 1, conv_dim), dtype)
        cache["state"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_heads, hp, cfg.ssm_state), jnp.float32)
        if cfg.family == "hybrid":
            slots = n_shared_slots(cfg)
            cache["k"] = jnp.zeros(
                (slots, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype)
            cache["v"] = jnp.zeros_like(cache["k"])
    else:
        cache["k"] = jnp.zeros(
            (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype)
        cache["v"] = jnp.zeros_like(cache["k"])
    return cache


def cache_axes(cfg):
    ax = {}
    if cfg.family in ("ssm", "hybrid"):
        ax["conv"] = (None, "batch", None, "ssm_inner")
        # state (layers, b, heads, p, N): heads across the model axis, so
        # the recurrent update is shard-local (was replicated -> per-layer
        # all-gather of the 268MB state; see EXPERIMENTS.md §Perf #3)
        ax["state"] = (None, "batch", "ssm_heads", None, None)
        if cfg.family == "hybrid":
            ax["k"] = (None, "batch", "kv_seq", "kv_heads", "head_dim")
            ax["v"] = (None, "batch", "kv_seq", "kv_heads", "head_dim")
    else:
        ax["k"] = (None, "batch", "kv_seq", "kv_heads", "head_dim")
        ax["v"] = (None, "batch", "kv_seq", "kv_heads", "head_dim")
    return ax


def _decode_attn_block(lp, cfg, x, ck, cv, cache_len):
    h = rms_norm(x, lp["ln1"])
    o, ck, cv = attn.decode_attention(lp["mixer"], cfg, h, ck, cv, cache_len)
    x = x + o
    h = rms_norm(x, lp["ln2"])
    if cfg.family == "moe":
        y, _ = moe_mod.moe_ffn(lp["ffn"], cfg, h, route_sort="none",
                               dispatch=cfg.moe_dispatch)
    else:
        y = swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"], lp["ffn"]["w_down"])
    return x + y, ck, cv


def decode_step(params, cfg, tokens, cache, cache_len):
    """One decode step. tokens: (b, 1) ids or (b, 1, d) embeddings.
    Returns (logits (b, vocab), new_cache)."""
    if tokens.ndim == 2:
        x = params["embed"][tokens]
    else:
        x = tokens.astype(_dtype(cfg))
    x = lshard(x, "batch", "seq", "embed")

    if cfg.family in ("ssm", "hybrid"):
        def body(x, inp):
            lp, conv, state = inp
            h = rms_norm(x, lp["ln1"])
            mix, conv, state = ssm_mod.mamba2_decode(lp["mixer"], cfg, h, conv, state)
            return x + mix, (conv, state)

        new_cache = {}
        slot = 0
        ks, vs, convs, states = [], [], [], []
        for start, ln, shared_after in _segments(cfg):
            sl = jax.tree.map(lambda a: a[start : start + ln], params["layers"])
            csl = (sl, cache["conv"][start : start + ln],
                   cache["state"][start : start + ln])
            x, (conv, state) = jax.lax.scan(body, x, csl)
            convs.append(conv)
            states.append(state)
            if shared_after:
                sp = params["shared_attn"]
                h = rms_norm(x, sp["ln1"])
                o, ck, cv = attn.decode_attention(
                    sp["attn"], cfg, h, cache["k"][slot], cache["v"][slot], cache_len)
                x = x + o
                h = rms_norm(x, sp["ln2"])
                x = x + swiglu(h, sp["ffn"]["w_gate"], sp["ffn"]["w_up"],
                               sp["ffn"]["w_down"])
                ks.append(ck)
                vs.append(cv)
                slot += 1
        new_cache["conv"] = jnp.concatenate(convs)
        new_cache["state"] = jnp.concatenate(states)
        if cfg.family == "hybrid":
            new_cache["k"] = jnp.stack(ks)
            new_cache["v"] = jnp.stack(vs)
    else:
        def body(x, inp):
            lp, ck, cv = inp
            x, ck, cv = _decode_attn_block(lp, cfg, x, ck, cv, cache_len)
            return x, (ck, cv)

        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs}

    x = rms_norm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head)[:, 0]
    return lshard(logits, "batch", "vocab"), new_cache
