"""Mamba2 SSD (state-space duality) block — chunked, matmul-friendly form.

Implements the 'minimal SSD' algorithm (Dao & Gu 2024, arXiv:2405.21060):
within-chunk quadratic (attention-like) term + inter-chunk recurrent state
pass.  The chunked form maps onto the MXU (two batched matmuls per chunk)
with an O(s/Q) sequential scan across chunks, giving O(s) total work.

Decode path keeps per-head state (b, h, p, N) and a depthwise-conv tail.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, lshard, rms_norm, silu

CONV_K = 4  # depthwise causal conv width (mamba2 default)


def init_mamba2(key, cfg, dtype):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    nh = cfg.ssm_heads
    N = cfg.ssm_state
    ng = cfg.ssm_groups
    ks = jax.random.split(key, 6)
    conv_dim = d_in + 2 * ng * N
    return {
        # order: [z (gate) | x | B | C | dt] fused input projection
        "w_in": dense_init(ks[0], (d, 2 * d_in + 2 * ng * N + nh), dtype=dtype),
        "conv_w": dense_init(ks[1], (CONV_K, conv_dim), dtype=dtype, scale=1.0),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_w": jnp.ones((d_in,), dtype),
        "w_out": dense_init(ks[2], (d_in, d), dtype=dtype),
    }


def mamba2_axes(cfg):
    return {
        "w_in": ("embed", "ssm_inner"),
        "conv_w": ("conv_k", "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm_w": ("ssm_inner",),
        "w_out": ("ssm_inner", "embed"),
    }


def _causal_conv(xBC, conv_w, conv_b):
    """Depthwise causal conv over seq: xBC (b, s, C), conv_w (K, C)."""
    K = conv_w.shape[0]
    out = xBC * conv_w[K - 1]
    for i in range(1, K):
        shifted = jnp.pad(xBC, ((0, 0), (i, 0), (0, 0)))[:, : xBC.shape[1]]
        out = out + shifted * conv_w[K - 1 - i]
    return silu(out + conv_b)


def ssd_chunked(x, dt, A, B, C, D, chunk: int):
    """SSD scan. x: (b,s,h,p), dt: (b,s,h), A: (h,) negative,
    B,C: (b,s,g,N). Returns (b,s,h,p) and final state (b,h,p,N)."""
    b, s, h, p = x.shape
    g, N = B.shape[2], B.shape[3]
    assert s % chunk == 0
    nc = s // chunk
    rep = h // g

    # discretize
    dA = dt * A  # (b,s,h), negative
    xdt = x * dt[..., None]

    # reshape into chunks
    cA = dA.reshape(b, nc, chunk, h)
    cx = xdt.reshape(b, nc, chunk, h, p)
    cB = B.reshape(b, nc, chunk, g, N)
    cC = C.reshape(b, nc, chunk, g, N)

    # cumulative decay within chunk
    csum = jnp.cumsum(cA, axis=2)  # (b,nc,Q,h)
    total = csum[:, :, -1]  # (b,nc,h)

    # ---- intra-chunk (quadratic, attention-like) term ----
    # L[i,j] = exp(csum_i - csum_j) for i >= j
    li = csum[:, :, :, None, :]  # (b,nc,Q,1,h)
    lj = csum[:, :, None, :, :]  # (b,nc,1,Q,h)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(li - lj), 0.0)
    # scores: C_i . B_j  (grouped)
    cBg = cB.reshape(b, nc, chunk, g, 1, N)
    cCg = cC.reshape(b, nc, chunk, g, 1, N)
    scores = jnp.einsum("bnigrN,bnjgrN->bnijg", cCg, cBg)  # (b,nc,Q,Q,g)
    scores = jnp.repeat(scores, rep, axis=-1)  # (b,nc,Q,Q,h)
    y_diag = jnp.einsum("bnijh,bnijh,bnjhp->bnihp", scores, L, cx)

    # ---- inter-chunk states ----
    # state contribution of chunk: sum_j exp(total - csum_j) * B_j x_j^T
    decay_b = jnp.exp(total[:, :, None] - csum)  # (b,nc,Q,h)
    Bh = jnp.repeat(cB, rep, axis=3)  # (b,nc,Q,h,N)
    chunk_state = jnp.einsum("bnqh,bnqhN,bnqhp->bnhpN", decay_b, Bh, cx)

    # recurrence across chunks: S_{c+1} = exp(total_c) * S_c + state_c
    def step(S, inp):
        tot, st = inp  # (b,h), (b,h,p,N)
        S_new = S * jnp.exp(tot)[:, :, None, None] + st
        return S_new, S  # emit state *before* chunk

    S0 = jnp.zeros((b, h, p, N), x.dtype)
    _, S_prev = jax.lax.scan(
        step, S0, (jnp.moveaxis(total, 1, 0), jnp.moveaxis(chunk_state, 1, 0)))
    S_prev = jnp.moveaxis(S_prev, 0, 1)  # (b,nc,h,p,N)

    # ---- inter-chunk output: C_i . S_prev, decayed ----
    Ch = jnp.repeat(cC, rep, axis=3)  # (b,nc,Q,h,N)
    decay_c = jnp.exp(csum)  # exp(csum_i)
    y_off = jnp.einsum("bnqhN,bnhpN,bnqh->bnqhp", Ch, S_prev, decay_c)

    y = (y_diag + y_off).reshape(b, s, h, p) + x * D[None, None, :, None]
    # final state for decode handoff
    S_final, _ = jax.lax.scan(
        step, S0, (jnp.moveaxis(total, 1, 0), jnp.moveaxis(chunk_state, 1, 0)))
    return y, S_final


def mamba2_block(p, cfg, x):
    """Full mamba2 mixer. x: (b, s, d) -> (b, s, d)."""
    b, s, d = x.shape
    d_in = cfg.ssm_expand * d
    ng, N, nh = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    hp = d_in // nh

    zxbcdt = x @ p["w_in"]
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in : d_in + d_in + 2 * ng * N]
    dt = zxbcdt[..., -nh:]
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xs = xBC[..., :d_in].reshape(b, s, nh, hp)
    B = xBC[..., d_in : d_in + ng * N].reshape(b, s, ng, N)
    C = xBC[..., d_in + ng * N :].reshape(b, s, ng, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y, _ = ssd_chunked(xs.astype(jnp.float32), dt, A,
                       B.astype(jnp.float32), C.astype(jnp.float32),
                       p["D"], cfg.ssm_chunk)
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = rms_norm(y * silu(z), p["norm_w"])
    y = lshard(y, "batch", "seq", "ssm_inner")
    return y @ p["w_out"]


def mamba2_decode(p, cfg, x, conv_state, ssm_state):
    """One-token decode. x: (b, 1, d); conv_state: (b, K-1, conv_dim);
    ssm_state: (b, h, p, N).  Returns (y, new_conv_state, new_ssm_state)."""
    b, _, d = x.shape
    d_in = cfg.ssm_expand * d
    ng, N, nh = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    hp = d_in // nh

    zxbcdt = x @ p["w_in"]
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in : d_in + d_in + 2 * ng * N]  # (b,1,conv_dim)
    dt = zxbcdt[..., -nh:]

    window = jnp.concatenate([conv_state, xBC], axis=1)  # (b,K,conv_dim)
    conv = (window * p["conv_w"][None]).sum(1, keepdims=True) + p["conv_b"]
    xBC1 = silu(conv)
    new_conv_state = window[:, 1:]

    xs = xBC1[..., :d_in].reshape(b, nh, hp)
    B = xBC1[..., d_in : d_in + ng * N].reshape(b, ng, N)
    C = xBC1[..., d_in + ng * N :].reshape(b, ng, N)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (b,h)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)  # (b,h)
    rep = nh // ng
    Bh = jnp.repeat(B, rep, axis=1)  # (b,h,N)
    Ch = jnp.repeat(C, rep, axis=1)
    xdt = xs * dt[..., None]  # (b,h,p)
    new_state = ssm_state * dA[..., None, None] + jnp.einsum("bhp,bhN->bhpN", xdt, Bh)
    new_state = lshard(new_state, "batch", "ssm_heads", None, None)
    y = jnp.einsum("bhpN,bhN->bhp", new_state, Ch) + xs * p["D"][None, :, None]
    y = y.reshape(b, 1, d_in).astype(x.dtype)
    y = rms_norm(y * silu(z), p["norm_w"])
    return y @ p["w_out"], new_conv_state, new_state
