"""`repro.analysis` — invariant lint passes + runtime sanitizers.

Static half (``python -m repro.analysis``): AST passes that machine-check
the contracts the test suite can only sample — lock discipline around the
writer/compactor/admission/checkpoint state, plan-node exhaustiveness
across both query backends, the Pallas kernel ruleset, and API hygiene.
Zero third-party deps; pure stdlib ``ast``/``tokenize``.

Runtime half (``REPRO_SANITIZE=1``): :func:`repro.analysis.runtime.
maybe_validate` structural EWAH checks at every ``execute_compressed``
boundary and :func:`repro.analysis.runtime.make_lock` order-tracked locks
that raise on acquisition-order inversion.

See ``docs/analysis.md`` for the rule catalog and baseline workflow.
"""

from __future__ import annotations

import os

from .findings import (Finding, load_baseline, new_findings,
                       render_findings, save_baseline)

__all__ = ["Finding", "RULES", "load_baseline", "new_findings",
           "render_findings", "run_analysis", "save_baseline"]

RULES = {
    "lock/unguarded-read":
        "read of a `# guarded-by:` field outside its `with <lock>` scope",
    "lock/unguarded-write":
        "write of a `# guarded-by:` field outside its `with <lock>` scope",
    "backend/missing-kind":
        "a registered backend does not dispatch on a declared plan-node "
        "kind",
    "backend/undeclared-kind":
        "planner code constructs a plan-node kind absent from "
        "PLAN_NODE_KINDS",
    "backend/missing-declaration":
        "PLAN_NODE_KINDS declaration not found",
    "container/missing-class":
        "a container-class dispatch covers only some CONTAINER_CLASSES "
        "and has no raise on the fall-through",
    "container/missing-declaration":
        "CONTAINER_CLASSES declaration not found",
    "kernel/traced-branch":
        "Python if/while/ternary on a traced value inside a kernel body",
    "kernel/host-callback":
        "host callback (print/debug.print/io_callback/...) inside a "
        "kernel body",
    "kernel/nonstatic-grid":
        "jnp/jax computation inside a pallas_call grid or BlockSpec shape",
    "kernel/ceil-div":
        "nested ceil-div one-liner instead of the two-step padding form",
    "api/deprecated-shim":
        "DeprecationWarning (removed compat shim) resurrected in src/",
    "api/unseeded-random":
        "test draws from numpy's global RNG instead of a seeded "
        "default_rng",
    "budget/unbudgeted-cell":
        "nightly dryrun cell has no COLLECTIVE_budget.json entry "
        "(report-only)",
}

# files the lock pass covers are discovered by annotation, so it is safe
# (and cheap) to run it over the whole tree
_BACKEND_FILES = ("src/repro/core/query.py", "src/repro/core/encodings.py")

# container-class dispatch sites: the numpy container module plus the jax
# backend's batched container fold (core/query.py)
_CONTAINER_FILES = ("src/repro/core/containers.py", "src/repro/core/query.py")


def _iter_py(root, rel):
    base = os.path.join(root, rel)
    for dirpath, _dirnames, filenames in os.walk(base):
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn)


def run_analysis(root: str = ".") -> list[Finding]:
    """Run every static pass over the tree at ``root``; returns findings
    with paths relative to ``root``."""
    from . import (apicheck, backendcheck, containercheck, kernelcheck,
                   locksafety)

    findings: list[Finding] = []

    def rel(path):
        return os.path.relpath(path, root)

    for path in _iter_py(root, "src/repro"):
        if os.sep + "analysis" + os.sep in path:
            continue  # the analyzer does not lint itself
        with open(path) as fh:
            source = fh.read()
        findings += [Finding(f.rule, rel(path), f.line, f.message, f.detail)
                     for f in locksafety.check_source(path, source)]
        findings += [Finding(f.rule, rel(path), f.line, f.message, f.detail)
                     for f in apicheck.check_deprecated_shims(path, source)]

    backend_sources = {}
    for relpath in _BACKEND_FILES:
        path = os.path.join(root, relpath)
        if os.path.exists(path):
            with open(path) as fh:
                backend_sources[relpath] = fh.read()
    findings += backendcheck.check_sources(backend_sources)

    container_sources = {}
    for relpath in _CONTAINER_FILES:
        path = os.path.join(root, relpath)
        if os.path.exists(path):
            with open(path) as fh:
                container_sources[relpath] = fh.read()
    findings += containercheck.check_sources(container_sources)

    for path in _iter_py(root, "src/repro/kernels"):
        with open(path) as fh:
            source = fh.read()
        findings += [Finding(f.rule, rel(path), f.line, f.message, f.detail)
                     for f in kernelcheck.check_source(path, source)]

    tests_dir = os.path.join(root, "tests")
    if os.path.isdir(tests_dir):
        for path in _iter_py(root, "tests"):
            with open(path) as fh:
                source = fh.read()
            findings += [Finding(f.rule, rel(path), f.line, f.message,
                                 f.detail)
                         for f in apicheck.check_unseeded_random(path,
                                                                 source)]
    return findings
