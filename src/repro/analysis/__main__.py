"""CLI: ``python -m repro.analysis [--baseline FILE] [--update-baseline]``.

Exit 0 when no findings beyond the baseline; exit 1 otherwise, printing
each new finding as ``path:line: [rule] message``.
"""

from __future__ import annotations

import argparse
import sys

from . import (RULES, load_baseline, new_findings, render_findings,
               run_analysis, save_baseline)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro invariant lint passes (see docs/analysis.md)")
    ap.add_argument("--root", default=".",
                    help="repo root to analyze (default: cwd)")
    ap.add_argument("--baseline", default=None,
                    help="suppression baseline JSON; only findings beyond "
                         "it fail the run")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite --baseline from the current findings and "
                         "exit 0")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        width = max(len(r) for r in RULES)
        for rule, desc in sorted(RULES.items()):
            print(f"{rule:<{width}}  {desc}")
        return 0

    findings = run_analysis(args.root)

    if args.update_baseline:
        if not args.baseline:
            ap.error("--update-baseline requires --baseline")
        baseline = save_baseline(args.baseline, findings)
        print(f"wrote {args.baseline}: {sum(baseline.values())} "
              f"suppressed finding(s)")
        return 0

    baseline = load_baseline(args.baseline) if args.baseline else {}
    fresh = new_findings(findings, baseline)
    for line in render_findings(fresh):
        print(line)
    suppressed = len(findings) - len(fresh)
    if fresh:
        print(f"\n{len(fresh)} new finding(s)"
              + (f" ({suppressed} baselined)" if suppressed else ""),
              file=sys.stderr)
        return 1
    print(f"repro.analysis: clean"
          + (f" ({suppressed} baselined finding(s))" if suppressed else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
