"""Lock-discipline pass: ``# guarded-by:`` annotation checker.

Fields are declared guarded where they are first assigned::

    class IndexWriter:
        def __init__(self):
            self._lock = make_lock("writer")
            self._segments = []   # guarded-by: _lock

After that, every ``self._segments`` read or write anywhere in the class
must happen lexically inside ``with self._lock:`` (rule
``lock/unguarded-read`` / ``lock/unguarded-write``).  Module-level names
work the same way (``_pending = []  # guarded-by: _pending_lock`` in
``dist/checkpoint.py``), guarded by a module-level ``with _pending_lock:``.

Escapes, both explicit and narrow:

* ``def _helper(self):  # holds-lock: _lock`` — the caller owns the lock;
  the body is checked as if the lock were held.
* ``x = self._segments  # analysis-ok: lock/unguarded-read <reason>`` —
  per-line suppression for intentional racy reads.
* ``__init__`` / ``__post_init__`` are construction, exempt.

The checker is lexical, not interprocedural: a nested ``def`` inside a
method starts with no held locks (it may run later, on another thread)
unless it carries its own ``holds-lock`` annotation.
"""

from __future__ import annotations

import ast
import io
import re
import tokenize

from .findings import Finding

_GUARDED_RE = re.compile(r"guarded-by:\s*([A-Za-z_]\w*)")
_HOLDS_RE = re.compile(r"holds-lock:\s*([A-Za-z_]\w*)")
_OK_RE = re.compile(r"analysis-ok\b")

_CTOR_NAMES = ("__init__", "__post_init__")


def _comments_by_line(source: str) -> dict[int, str]:
    out: dict[int, str] = {}
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                out[tok.start[0]] = tok.string
    except tokenize.TokenError:
        pass
    return out


class _Annotations:
    def __init__(self, source: str):
        self.comments = _comments_by_line(source)

    def guard_for(self, line: int):
        m = _GUARDED_RE.search(self.comments.get(line, ""))
        return m.group(1) if m else None

    def holds_for(self, line: int):
        m = _HOLDS_RE.search(self.comments.get(line, ""))
        return m.group(1) if m else None

    def suppressed(self, line: int) -> bool:
        return bool(_OK_RE.search(self.comments.get(line, "")))


def _assign_targets(node):
    if isinstance(node, ast.Assign):
        return node.targets
    if isinstance(node, (ast.AnnAssign, ast.AugAssign)):
        return [node.target]
    return []


def _self_attr(node):
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name) and node.value.id == "self"):
        return node.attr
    return None


def _with_locks(node, *, for_self: bool):
    """Lock names entered by a ``with`` statement (self-attribute locks
    for methods, bare names at module level; both always collected)."""
    names = []
    for item in node.items:
        expr = item.context_expr
        attr = _self_attr(expr)
        if attr is not None:
            names.append(attr)
        elif isinstance(expr, ast.Name):
            names.append(expr.id)
    return names


class _FunctionChecker(ast.NodeVisitor):
    """Walk one function body tracking the lexically-held lock set."""

    def __init__(self, pass_, guarded: dict[str, str], *, self_based: bool,
                 held: frozenset):
        self.pass_ = pass_
        self.guarded = guarded   # field name -> lock name
        self.self_based = self_based
        self.held = held

    def _check(self, name: str | None, node, ctx):
        if name is None or name not in self.guarded:
            return
        lock = self.guarded[name]
        if lock in self.held:
            return
        if self.pass_.ann.suppressed(node.lineno):
            return
        kind = "read" if isinstance(ctx, ast.Load) else "write"
        self.pass_.report(
            f"lock/unguarded-{kind}", node.lineno,
            f"access to {name!r} outside `with {lock}`",
            detail=f"{self.pass_.scope}:{name}:{kind}",
        )

    def visit_Attribute(self, node):
        if self.self_based:
            self._check(_self_attr(node), node, node.ctx)
        self.generic_visit(node)

    def visit_Name(self, node):
        if not self.self_based:
            self._check(node.id, node, node.ctx)
        # no children

    def visit_With(self, node):
        entered = _with_locks(node, for_self=self.self_based)
        for item in node.items:  # the lock expression itself is exempt
            self.generic_visit(item)
        inner = _FunctionChecker(self.pass_, self.guarded,
                                 self_based=self.self_based,
                                 held=self.held | frozenset(entered))
        for stmt in node.body:
            inner.visit(stmt)

    visit_AsyncWith = visit_With

    def _nested_scope(self, node):
        held = frozenset()
        holds = self.pass_.ann.holds_for(node.lineno)
        if holds:
            held = frozenset({holds})
        inner = _FunctionChecker(self.pass_, self.guarded,
                                 self_based=self.self_based, held=held)
        for stmt in node.body:
            inner.visit(stmt)

    def visit_FunctionDef(self, node):
        self._nested_scope(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_ClassDef(self, node):
        pass


class LockPass:
    def __init__(self, path: str, source: str):
        self.path = path
        self.ann = _Annotations(source)
        self.tree = ast.parse(source)
        self.findings: list[Finding] = []
        self.scope = ""

    def report(self, rule, line, message, detail=""):
        self.findings.append(
            Finding(rule, self.path, line, message, detail))

    # -- collection ------------------------------------------------------

    def _collect_class_guards(self, cls: ast.ClassDef) -> dict[str, str]:
        guarded: dict[str, str] = {}
        for stmt in ast.walk(cls):
            if (isinstance(stmt, ast.FunctionDef)
                    and stmt.name in _CTOR_NAMES):
                for sub in ast.walk(stmt):
                    for tgt in _assign_targets(sub):
                        name = _self_attr(tgt)
                        if name is None:
                            continue
                        lock = self.ann.guard_for(sub.lineno)
                        if lock:
                            guarded[name] = lock
        for stmt in cls.body:  # class-level declarations too
            for tgt in _assign_targets(stmt):
                if isinstance(tgt, ast.Name):
                    lock = self.ann.guard_for(stmt.lineno)
                    if lock:
                        guarded[tgt.id] = lock
        return guarded

    def _collect_module_guards(self) -> dict[str, str]:
        guarded: dict[str, str] = {}
        for stmt in self.tree.body:
            for tgt in _assign_targets(stmt):
                if isinstance(tgt, ast.Name):
                    lock = self.ann.guard_for(stmt.lineno)
                    if lock:
                        guarded[tgt.id] = lock
        return guarded

    # -- checking --------------------------------------------------------

    def _check_function(self, fn, guarded, *, self_based: bool):
        held = frozenset()
        holds = self.ann.holds_for(fn.lineno)
        if holds:
            held = frozenset({holds})
        checker = _FunctionChecker(self, guarded, self_based=self_based,
                                   held=held)
        for stmt in fn.body:
            checker.visit(stmt)

    def run(self) -> list[Finding]:
        module_guards = self._collect_module_guards()
        for node in self.tree.body:
            if isinstance(node, ast.ClassDef):
                guarded = self._collect_class_guards(node)
                if guarded:
                    self.scope = node.name
                    for stmt in node.body:
                        if (isinstance(stmt, (ast.FunctionDef,
                                              ast.AsyncFunctionDef))
                                and stmt.name not in _CTOR_NAMES):
                            self._check_function(stmt, guarded,
                                                 self_based=True)
            elif (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and module_guards):
                self.scope = "<module>"
                self._check_function(node, module_guards, self_based=False)
        return self.findings


def check_source(path: str, source: str) -> list[Finding]:
    return LockPass(path, source).run()


def check_file(path: str) -> list[Finding]:
    with open(path) as fh:
        return check_source(path, fh.read())
