"""Backend-exhaustiveness pass.

Plan nodes are plain tuples whose first element is the kind tag
(``("fold", ops, children)``, ``("leaf", i)``, ...).  The planner side
(``core/query.py`` + ``core/encodings.py``) declares the closed set in
``PLAN_NODE_KINDS`` and this pass cross-checks three things:

* every kind tag *constructed* by planner code appears in
  ``PLAN_NODE_KINDS`` (``backend/undeclared-kind`` — you added a node
  type without declaring it);
* every declared kind is *dispatched on* by every registered backend
  class (``backend/missing-kind`` — the PR-5 bug class where a new node
  silently falls through one backend's combine loop);
* the declaration itself exists (``backend/missing-declaration``).

"Dispatched on" means the kind string appears in a comparison
(``==/!=/in/not in``) inside the backend class body; the explicit
``raise ValueError`` guards on the generic and/or arms exist so this
lexical test is sound.

Cache/structure-key helpers (``_sig``, ``_node_key``) build look-alike
tuples that are not plan nodes; they are excluded by name, as are the
backend class bodies themselves (consuming a kind is not emitting it).
"""

from __future__ import annotations

import ast
import re

from .findings import Finding

DECL_NAME = "PLAN_NODE_KINDS"

# helper functions that build tuple keys which are not plan nodes
_EXCLUDED_FUNCS = {"_sig", "_node_key"}

_KIND_RE = re.compile(r"^[a-z][a-z_]{0,15}$")


def _is_backend_class(node: ast.ClassDef) -> bool:
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = getattr(target, "id", getattr(target, "attr", ""))
        if name == "register_backend":
            return True
    return node.name.endswith("Backend")


def _declared_kinds(tree: ast.Module):
    for node in ast.walk(tree):
        for tgt in (node.targets if isinstance(node, ast.Assign) else
                    [node.target] if isinstance(node, ast.AnnAssign) else []):
            if isinstance(tgt, ast.Name) and tgt.id == DECL_NAME:
                value = node.value
                if isinstance(value, (ast.Tuple, ast.List)):
                    return [e.value for e in value.elts
                            if isinstance(e, ast.Constant)
                            and isinstance(e.value, str)], node.lineno
    return None, 0


class _EmitCollector(ast.NodeVisitor):
    """Kind tags constructed by planner code (excluding key helpers and
    backend class bodies)."""

    def __init__(self):
        self.kinds: dict[str, int] = {}  # kind -> first line seen

    def visit_ClassDef(self, node):
        if not _is_backend_class(node):
            self.generic_visit(node)

    def visit_FunctionDef(self, node):
        if node.name not in _EXCLUDED_FUNCS:
            self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Tuple(self, node):
        all_str = all(isinstance(e, ast.Constant)
                      and isinstance(e.value, str) for e in node.elts)
        if (len(node.elts) >= 2 and not all_str  # all-string = __slots__ etc.
                and isinstance(node.elts[0], ast.Constant)
                and isinstance(node.elts[0].value, str)
                and _KIND_RE.match(node.elts[0].value)):
            self.kinds.setdefault(node.elts[0].value, node.lineno)
        self.generic_visit(node)

    def visit_Call(self, node):
        # _fanin("and", ...) constructs an ("and", children) node
        fn = getattr(node.func, "id", getattr(node.func, "attr", ""))
        if fn == "_fanin" and node.args:
            arg = node.args[0]
            if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                self.kinds.setdefault(arg.value, node.lineno)
        self.generic_visit(node)


def _dispatched_kinds(cls: ast.ClassDef) -> set[str]:
    kinds: set[str] = set()
    for node in ast.walk(cls):
        if not isinstance(node, ast.Compare):
            continue
        for expr in [node.left, *node.comparators]:
            if isinstance(expr, ast.Constant) and isinstance(expr.value, str):
                kinds.add(expr.value)
            elif isinstance(expr, (ast.Tuple, ast.List, ast.Set)):
                kinds.update(e.value for e in expr.elts
                             if isinstance(e, ast.Constant)
                             and isinstance(e.value, str))
    return kinds


def check_sources(sources: dict[str, str]) -> list[Finding]:
    """``sources`` maps display path -> source text; the declaration is
    looked up across all of them (it lives in query.py)."""
    findings: list[Finding] = []
    trees = {path: ast.parse(src) for path, src in sources.items()}

    declared, decl_path = None, ""
    for path, tree in trees.items():
        kinds, line = _declared_kinds(tree)
        if kinds is not None:
            declared, decl_path = kinds, path
            break
    if declared is None:
        first = next(iter(sources))
        findings.append(Finding(
            "backend/missing-declaration", first, 1,
            f"no {DECL_NAME} declaration found", detail=DECL_NAME))
        return findings

    emitted: dict[str, tuple[str, int]] = {}
    for path, tree in trees.items():
        col = _EmitCollector()
        col.visit(tree)
        for kind, line in col.kinds.items():
            emitted.setdefault(kind, (path, line))

    for kind, (path, line) in sorted(emitted.items()):
        if kind not in declared:
            findings.append(Finding(
                "backend/undeclared-kind", path, line,
                f"plan-node kind {kind!r} constructed but not in "
                f"{DECL_NAME}", detail=kind))

    for path, tree in trees.items():
        for node in tree.body:
            if isinstance(node, ast.ClassDef) and _is_backend_class(node):
                dispatched = _dispatched_kinds(node)
                for kind in declared:
                    if kind not in dispatched:
                        findings.append(Finding(
                            "backend/missing-kind", path, node.lineno,
                            f"{node.name} does not dispatch on plan-node "
                            f"kind {kind!r}", detail=f"{node.name}:{kind}"))
    return findings


def check_files(paths) -> list[Finding]:
    sources = {}
    for path in paths:
        with open(path) as fh:
            sources[str(path)] = fh.read()
    return check_sources(sources)
