"""Container-class exhaustiveness pass.

Roaring container dispatch is positional: ``ContainerSet.classes`` stores
small integer class ids and every consumer branches on the named constants
(``ARRAY`` / ``BITMAP`` / ``RUN``, derived from the ``CONTAINER_CLASSES``
declaration in ``core/containers.py``).  A new container class added to the
declaration but not to every dispatch site would silently fall through —
the exact bug class ``backendcheck`` guards for plan-node kinds, one level
down.

The rule: in the covered files (``core/containers.py`` and
``core/query.py``, which hosts the jax backend's batched container fold),
**any function that compares against a container-class constant must
either compare against all declared classes or contain a ``raise``** (the
unknown-class guard).  Partial dispatch with a trailing raise is fine —
``_merge_chunk`` fast-paths array/bitmap pairs and raises on unknown ops —
but partial dispatch that falls through silently is a finding
(``container/missing-class``).  A missing or malformed declaration is
``container/missing-declaration``.

Class constants are recognized both as bare names (``cls == ARRAY``) and
as module attributes (``{ca, cb} == {C.ARRAY, C.BITMAP}``), including
inside tuple/list/set comparators.
"""

from __future__ import annotations

import ast

from .findings import Finding

DECL_NAME = "CONTAINER_CLASSES"


def _declared_classes(tree: ast.Module):
    for node in ast.walk(tree):
        for tgt in (node.targets if isinstance(node, ast.Assign) else
                    [node.target] if isinstance(node, ast.AnnAssign) else []):
            if isinstance(tgt, ast.Name) and tgt.id == DECL_NAME:
                value = node.value
                if isinstance(value, (ast.Tuple, ast.List)):
                    return [e.value for e in value.elts
                            if isinstance(e, ast.Constant)
                            and isinstance(e.value, str)], node.lineno
    return None, 0


def _compared_classes(fn: ast.FunctionDef, class_names: set) -> set:
    """Class-constant names this function compares against."""
    seen: set = set()

    def collect(expr):
        if isinstance(expr, ast.Name) and expr.id in class_names:
            seen.add(expr.id)
        elif isinstance(expr, ast.Attribute) and expr.attr in class_names:
            seen.add(expr.attr)
        elif isinstance(expr, (ast.Tuple, ast.List, ast.Set)):
            for e in expr.elts:
                collect(e)

    for node in ast.walk(fn):
        if isinstance(node, ast.Compare):
            collect(node.left)
            for comp in node.comparators:
                collect(comp)
    return seen


def _has_raise(fn: ast.FunctionDef) -> bool:
    return any(isinstance(n, ast.Raise) for n in ast.walk(fn))


def check_sources(sources: dict[str, str]) -> list[Finding]:
    """``sources`` maps display path -> source text; the declaration is
    looked up across all of them (it lives in containers.py)."""
    findings: list[Finding] = []
    trees = {path: ast.parse(src) for path, src in sources.items()}

    declared = None
    for path, tree in trees.items():
        classes, _line = _declared_classes(tree)
        if classes is not None:
            declared = classes
            break
    if not declared:
        first = next(iter(sources))
        findings.append(Finding(
            "container/missing-declaration", first, 1,
            f"no {DECL_NAME} declaration found", detail=DECL_NAME))
        return findings
    class_names = {c.upper() for c in declared}

    for path, tree in trees.items():
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            compared = _compared_classes(node, class_names)
            if not compared:
                continue
            if compared == class_names or _has_raise(node):
                continue
            missing = ", ".join(sorted(class_names - compared))
            findings.append(Finding(
                "container/missing-class", path, node.lineno,
                f"{node.name} dispatches on container classes "
                f"{sorted(compared)} without covering {missing} or "
                f"raising on the fall-through", detail=node.name))
    return findings


def check_files(paths) -> list[Finding]:
    sources = {}
    for path in paths:
        with open(path) as fh:
            sources[str(path)] = fh.read()
    return check_sources(sources)
