"""Finding records and the suppression-baseline protocol.

Every analyzer rule reports :class:`Finding` rows — file:line, a rule id
(``family/name``), a human message, and a ``detail`` string that survives
line drift (the baseline key deliberately excludes the line number, so a
refactor that shuffles a file does not resurrect suppressed findings).

The committed baseline (``analysis_baseline.json``) maps baseline keys to
counts; CI fails only on findings *beyond* the baselined count per key
(see docs/analysis.md for the workflow).
"""

from __future__ import annotations

import json
from collections import Counter
from dataclasses import dataclass, field


@dataclass(frozen=True)
class Finding:
    """One rule violation: where, which rule, what happened.

    ``detail`` is the stable identity used for baselining (defaults to the
    message); ``line`` is presentation only.
    """

    rule: str
    path: str
    line: int
    message: str
    detail: str = field(default="")

    def key(self) -> str:
        return f"{self.rule}|{self.path}|{self.detail or self.message}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def render_findings(findings) -> list[str]:
    """Stable presentation order: path, then line, then rule."""
    return [f.render() for f in
            sorted(findings, key=lambda f: (f.path, f.line, f.rule))]


def load_baseline(path) -> dict[str, int]:
    """Read a suppression baseline; missing file = empty baseline."""
    try:
        with open(path) as fh:
            raw = json.load(fh)
    except FileNotFoundError:
        return {}
    if not isinstance(raw, dict):
        raise ValueError(f"baseline {path}: expected a JSON object")
    return {str(k): int(v) for k, v in raw.items()}


def save_baseline(path, findings) -> dict[str, int]:
    """Write the current findings as the new baseline (sorted, stable)."""
    counts = Counter(f.key() for f in findings)
    baseline = dict(sorted(counts.items()))
    with open(path, "w") as fh:
        json.dump(baseline, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return baseline


def new_findings(findings, baseline: dict[str, int]) -> list:
    """Findings beyond the baselined count for their key (CI fails on
    these; baselined repeats stay suppressed)."""
    budget = Counter(baseline)
    fresh = []
    for f in findings:
        if budget[f.key()] > 0:
            budget[f.key()] -= 1
        else:
            fresh.append(f)
    return fresh
