"""Pallas kernel ruleset over ``src/repro/kernels/*.py``.

Kernel bodies are identified structurally: any function with a parameter
named ``*_ref`` (the Pallas ref-passing convention).  Rules:

* ``kernel/traced-branch`` — Python ``if``/``while``/ternary on a value
  read from a ref (or derived from ``program_id``).  Tracing would bake
  one branch in; use ``jnp.where`` / ``lax.select`` instead.  Taint is a
  simple forward pass: ref reads and ``program_id`` results taint names,
  assignments propagate.  Keyword-only params are static-by-convention
  (closure-bound Python ints) and never taint.
* ``kernel/host-callback`` — ``print`` / ``debug.print`` /
  ``debug.callback`` / ``io_callback`` / ``pure_callback`` /
  ``host_callback`` inside a kernel body.
* ``kernel/nonstatic-grid`` — ``jnp.``/``jax.`` calls inside a
  ``pallas_call`` ``grid=`` expression or a ``BlockSpec`` shape (grids
  must be Python ints at trace time).  One level of local-variable
  indirection is followed (``grid = (...); pallas_call(..., grid=grid)``).
* ``kernel/ceil-div`` — padding must use the two-step ceil-div form PR 5
  standardized (``rows = -(-n // lanes)`` then ``-(-rows // RT) * RT``),
  not a nested ``-(-(-(-n // lanes)) // RT)`` one-liner; the nested form
  has burned us with sign/precedence edits before and is unreadable in
  review.  Checked module-wide (padding lives in host wrappers).
"""

from __future__ import annotations

import ast

from .findings import Finding

_HOST_CALLS = {"print", "debug_print", "io_callback", "pure_callback",
               "host_callback", "callback"}


def _call_name(node: ast.Call) -> str:
    fn = node.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return ""


def _is_kernel_fn(fn) -> bool:
    args = fn.args
    params = [*args.posonlyargs, *args.args]
    return any(p.arg.endswith("_ref") for p in params)


def _names_in(node) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _jax_calls_in(node) -> list[ast.Call]:
    out = []
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            fn = sub.func
            root = fn
            while isinstance(root, ast.Attribute):
                root = root.value
            if isinstance(root, ast.Name) and root.id in ("jnp", "jax",
                                                          "lax", "pl"):
                out.append(sub)
    return out


def _is_ceil_div(node) -> bool:
    return (isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub)
            and isinstance(node.operand, ast.BinOp)
            and isinstance(node.operand.op, ast.FloorDiv)
            and isinstance(node.operand.left, ast.UnaryOp)
            and isinstance(node.operand.left.op, ast.USub))


class _KernelChecker:
    def __init__(self, path: str, tree: ast.Module):
        self.path = path
        self.tree = tree
        self.findings: list[Finding] = []

    def report(self, rule, line, message, detail=""):
        self.findings.append(Finding(rule, self.path, line, message, detail))

    # -- traced branches + host callbacks (kernel bodies only) ----------

    def _taint(self, fn) -> set[str]:
        args = fn.args
        tainted = {p.arg for p in [*args.posonlyargs, *args.args]
                   if p.arg.endswith("_ref")}

        def expr_tainted(expr) -> bool:
            if _names_in(expr) & tainted:
                return True
            return any(_call_name(c) == "program_id"
                       for c in ast.walk(expr) if isinstance(c, ast.Call))

        for _ in range(2):  # two passes reach a fixpoint for simple chains
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign) and expr_tainted(node.value):
                    for tgt in node.targets:
                        tainted.update(_names_in(tgt))
                elif (isinstance(node, (ast.AnnAssign, ast.AugAssign))
                        and node.value is not None
                        and expr_tainted(node.value)):
                    tainted.update(_names_in(node.target))
        return tainted

    def _check_kernel_fn(self, fn):
        tainted = self._taint(fn)
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                hot = sorted(_names_in(node.test) & tainted)
                if hot:
                    kind = ("ternary" if isinstance(node, ast.IfExp) else
                            "while" if isinstance(node, ast.While) else "if")
                    self.report(
                        "kernel/traced-branch", node.lineno,
                        f"Python {kind} on traced value(s) "
                        f"{', '.join(hot)} in kernel {fn.name!r}; use "
                        f"jnp.where/lax.select",
                        detail=f"{fn.name}:{','.join(hot)}")
            elif isinstance(node, ast.Call):
                name = _call_name(node)
                if name in _HOST_CALLS or (
                        name == "print" and isinstance(node.func, ast.Name)):
                    self.report(
                        "kernel/host-callback", node.lineno,
                        f"host callback {name!r} inside kernel body "
                        f"{fn.name!r}", detail=f"{fn.name}:{name}")

    # -- static grids / BlockSpecs ---------------------------------------

    def _check_grid_exprs(self, fn):
        local_assigns: dict[str, ast.expr] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt = node.targets[0]
                if isinstance(tgt, ast.Name):
                    local_assigns[tgt.id] = node.value

        def resolve(expr):
            if isinstance(expr, ast.Name) and expr.id in local_assigns:
                return local_assigns[expr.id]
            return expr

        def flag_dynamic(expr, what, line):
            for call in _jax_calls_in(resolve(expr)):
                self.report(
                    "kernel/nonstatic-grid", line,
                    f"{what} uses a traced computation "
                    f"({ast.unparse(call.func)}(...)); grids and block "
                    f"shapes must be static Python ints",
                    detail=f"{fn.name}:{what}")

        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node)
            if name == "pallas_call":
                for kw in node.keywords:
                    if kw.arg == "grid":
                        flag_dynamic(kw.value, "pallas_call grid",
                                     kw.value.lineno)
            elif name == "BlockSpec":
                if node.args:
                    flag_dynamic(node.args[0], "BlockSpec shape",
                                 node.args[0].lineno)
                for kw in node.keywords:
                    if kw.arg in ("block_shape", "shape"):
                        flag_dynamic(kw.value, "BlockSpec shape",
                                     kw.value.lineno)

    # -- ceil-div form (module-wide) -------------------------------------

    def _check_ceil_div(self):
        flagged: set[int] = set()
        for node in ast.walk(self.tree):
            if not _is_ceil_div(node):
                continue
            inner = node.operand.left.operand  # the x in -(-x // y)
            for sub in ast.walk(inner):
                if _is_ceil_div(sub):
                    if node.lineno not in flagged:
                        flagged.add(node.lineno)
                        self.report(
                            "kernel/ceil-div", node.lineno,
                            "nested ceil-div one-liner; use the two-step "
                            "form: rows = -(-n // lanes); "
                            "rows_p = -(-rows // RT) * RT",
                            detail=f"line-pattern:{ast.unparse(node)}")
                    break

    def run(self) -> list[Finding]:
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if _is_kernel_fn(node):
                    self._check_kernel_fn(node)
                self._check_grid_exprs(node)
        self._check_ceil_div()
        return self.findings


def check_source(path: str, source: str) -> list[Finding]:
    # nested defs are walked from both the enclosing function and their
    # own FunctionDef node; dedupe identical reports
    seen, out = set(), []
    for f in _KernelChecker(path, ast.parse(source)).run():
        ident = (f.rule, f.line, f.detail)
        if ident not in seen:
            seen.add(ident)
            out.append(f)
    return out


def check_file(path: str) -> list[Finding]:
    with open(path) as fh:
        return check_source(path, fh.read())
