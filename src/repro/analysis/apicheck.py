"""Deprecation / API-hygiene pass.

* ``api/deprecated-shim`` — the bare-kwarg ``search(...)`` and
  ``_backend=`` compatibility shims were removed after their one-release
  deprecation window; any ``DeprecationWarning`` reappearing in ``src/``
  means a shim was resurrected instead of the call sites being fixed.
  Checked via AST (a comment merely *mentioning* the class is fine).
* ``api/unseeded-random`` — tests must not draw from numpy's global
  random state (``np.random.randint`` etc.); use a seeded
  ``np.random.default_rng(seed)`` so failures replay.  This is a *text*
  scan, not an AST scan, because some tests build subprocess scripts as
  string literals (``tests/test_distributed.py``) and the global-state
  call hides inside the string.
"""

from __future__ import annotations

import ast
import re

from .findings import Finding

# legacy global-state draws; the seeded constructors are fine
_UNSEEDED_RE = re.compile(
    r"np\.random\.(?!default_rng\b|seed\b|RandomState\b|Generator\b)"
    r"([A-Za-z_]\w*)\s*\(")
_OK_RE = re.compile(r"analysis-ok\b")


def check_deprecated_shims(path: str, source: str) -> list[Finding]:
    findings = []
    try:
        tree = ast.parse(source)
    except SyntaxError:
        return findings
    for node in ast.walk(tree):
        if isinstance(node, ast.Name) and node.id == "DeprecationWarning":
            findings.append(Finding(
                "api/deprecated-shim", path, node.lineno,
                "DeprecationWarning in src/ — compatibility shims were "
                "removed, do not resurrect them",
                detail="DeprecationWarning"))
    return findings


def check_unseeded_random(path: str, source: str) -> list[Finding]:
    findings = []
    for lineno, line in enumerate(source.splitlines(), start=1):
        if _OK_RE.search(line):
            continue
        for m in _UNSEEDED_RE.finditer(line):
            findings.append(Finding(
                "api/unseeded-random", path, lineno,
                f"np.random.{m.group(1)} draws from the global RNG; use a "
                f"seeded np.random.default_rng",
                detail=f"np.random.{m.group(1)}"))
    return findings
