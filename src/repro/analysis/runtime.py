"""Opt-in runtime sanitizers (``REPRO_SANITIZE=1``).

Two hooks, both free when the env var is unset:

* :func:`maybe_validate` — structural EWAH validation at backend
  ``execute_compressed`` boundaries (delegates to
  :meth:`EwahStream.validate`).
* :func:`make_lock` — lock factory.  Sanitizing returns an
  order-tracking wrapper that records the global acquisition graph and
  raises :class:`LockOrderError` the first time two locks are ever taken
  in both orders (potential deadlock), even if no thread actually
  deadlocks during the run.

The env var is re-read on every call so tests can flip it with
:func:`sanitized` mid-process; ``make_lock`` is the one creation-time
decision (a lock built while sanitizing stays instrumented for life,
which is what tests want).
"""

from __future__ import annotations

import contextlib
import os
import threading


def sanitize_enabled() -> bool:
    return os.environ.get("REPRO_SANITIZE", "") not in ("", "0")


@contextlib.contextmanager
def sanitized(on: bool = True):
    """Temporarily force sanitizer mode on (or off) for a test block."""
    prev = os.environ.get("REPRO_SANITIZE")
    os.environ["REPRO_SANITIZE"] = "1" if on else "0"
    try:
        yield
    finally:
        if prev is None:
            del os.environ["REPRO_SANITIZE"]
        else:
            os.environ["REPRO_SANITIZE"] = prev


def maybe_validate(stream, origin: str = ""):
    """Validate ``stream`` when sanitizing; always returns it unchanged."""
    if sanitize_enabled() and stream is not None:
        stream.validate(origin=origin)
    return stream


class LockOrderError(RuntimeError):
    """Two locks were acquired in both orders across the process."""


class _OrderGraph:
    """Global happened-before graph over named locks.

    Edge a->b means some thread held a while acquiring b.  Adding an edge
    that closes a cycle is an inversion: the opposite order was already
    observed, so two threads interleaving those paths can deadlock.
    """

    def __init__(self):
        self._mutex = threading.Lock()
        self._edges: dict[str, set[str]] = {}
        self._local = threading.local()

    def _held(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _reaches(self, src: str, dst: str) -> bool:
        seen, todo = set(), [src]
        while todo:
            node = todo.pop()
            if node == dst:
                return True
            if node in seen:
                continue
            seen.add(node)
            todo.extend(self._edges.get(node, ()))
        return False

    def acquired(self, name: str):
        stack = self._held()
        with self._mutex:
            for held in stack:
                if held == name:  # reentrant re-acquire adds no ordering
                    continue
                if name not in self._edges.get(held, set()):
                    if self._reaches(name, held):
                        raise LockOrderError(
                            f"lock order inversion: acquiring {name!r} while "
                            f"holding {held!r}, but {name!r} -> {held!r} "
                            f"order was already observed"
                        )
                    self._edges.setdefault(held, set()).add(name)
        stack.append(name)

    def released(self, name: str):
        stack = self._held()
        # release order need not be LIFO; drop the innermost occurrence
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] == name:
                del stack[i]
                break


_GRAPH = _OrderGraph()


def reset_order_graph():
    """Forget all observed orderings (test isolation)."""
    global _GRAPH
    _GRAPH = _OrderGraph()


class _TrackedLock:
    """Context-manager lock wrapper feeding the global order graph."""

    def __init__(self, name: str, reentrant: bool):
        self.name = name
        self._inner = threading.RLock() if reentrant else threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1):
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            try:
                _GRAPH.acquired(self.name)
            except BaseException:
                self._inner.release()
                raise
        return ok

    def release(self):
        self._inner.release()
        _GRAPH.released(self.name)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False


def make_lock(name: str, reentrant: bool = True):
    """A named lock: plain threading lock normally, order-tracked under
    ``REPRO_SANITIZE=1`` (decided at creation time)."""
    if sanitize_enabled():
        return _TrackedLock(name, reentrant)
    return threading.RLock() if reentrant else threading.Lock()
