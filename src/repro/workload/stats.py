"""Workload telemetry: bounded, thread-safe samples of the planner's
per-column predicate flow.

Every executed query batch contributes one sample per predicate event the
planner recorded on its plans (``Plan.workload``, fed by
``query.compile_plan``): ``(column, predicate shape, width, encoding,
merge count, us_per_query)``.  :class:`WorkloadStats` keeps a bounded
recency-weighted tail of these — the training set for
:class:`~repro.workload.cost.CostModel`, which ranks candidate encodings
per column so compaction can re-encode toward the live query mix
(docs/containers.md, "Workload-driven re-encoding").

Mirrors ``query.PlanStats``: same bounding policy (keep the newest half
past ``MAX_SAMPLES``), same save/load persistence contract
(``serve --workload-stats``), same locking discipline.
"""

from __future__ import annotations

import json

from ..analysis.runtime import make_lock


class WorkloadStats:
    """Thread-safe bounded sample buffer of observed predicate costs.

    Samples are ``(column, shape, width, encoding, merges, us)`` tuples:
    ``column`` is the original table position, ``shape`` the predicate
    kind (``"eq"`` / ``"in"`` / ``"range"``), ``width`` its value-domain
    span, ``encoding`` the :class:`~repro.core.encodings.ColumnEncoding`
    kind that compiled it, ``merges`` its :func:`~repro.core.query.
    count_merges` cost, and ``us`` the observed wall time attributed to
    it.  Serving records from worker threads while the background
    compactor reads; ``_mutex`` covers both.
    """

    MAX_SAMPLES = 8192

    def __init__(self):
        self._mutex = make_lock("workload_stats")
        self._samples: list = []  # guarded-by: _mutex
        self.recorded = 0         # guarded-by: _mutex

    def record(self, column, shape, width, encoding, merges, us) -> None:
        sample = (int(column), str(shape), int(width), str(encoding),
                  int(merges), float(us))
        with self._mutex:
            self.recorded += 1
            self._samples.append(sample)
            if len(self._samples) > self.MAX_SAMPLES:
                # keep the newest half: bounded memory, recency-weighted —
                # the model should track the *live* mix, not history
                self._samples = self._samples[self.MAX_SAMPLES // 2:]

    def record_plans(self, plans, us_each) -> None:
        """Record one executed batch: each plan's wall time is attributed
        evenly across its ``Plan.workload`` predicate events."""
        for plan, us in zip(plans, us_each):
            events = getattr(plan, "workload", ())
            if not events:
                continue
            share = float(us) / len(events)
            for col, shape, width, enc_kind, merges in events:
                self.record(col, shape, width, enc_kind, merges, share)

    def samples(self) -> list:
        with self._mutex:
            return list(self._samples)

    def __len__(self) -> int:
        with self._mutex:
            return len(self._samples)

    def clear(self) -> None:
        with self._mutex:
            self._samples = []
            self.recorded = 0

    def stats(self) -> dict:
        with self._mutex:
            return {"recorded": self.recorded,
                    "samples": len(self._samples)}

    def snapshot(self) -> dict:
        """A JSON-serializable copy of the buffer — the cross-host wire
        payload.  Same shape as the :meth:`save` file so the two transports
        (disk and socket) share one format."""
        with self._mutex:
            return {"recorded": self.recorded,
                    "samples": [list(s) for s in self._samples]}

    def drain(self) -> dict:
        """Atomically :meth:`snapshot` and reset — what a serve-plane
        worker ships with each reply so every sample reaches the
        coordinator exactly once."""
        with self._mutex:
            snap = {"recorded": self.recorded,
                    "samples": [list(s) for s in self._samples]}
            self._samples = []
            self.recorded = 0
        return snap

    def merge_snapshot(self, snap: dict) -> int:
        """Fold one host's :meth:`snapshot`/:meth:`drain` payload into this
        buffer; returns the number of samples merged.  Bounding applies, so
        the buffer stays recency-weighted across hosts."""
        samples = [(int(c), str(sh), int(w), str(e), int(m), float(u))
                   for c, sh, w, e, m, u in snap.get("samples", [])]
        extra = int(snap.get("recorded", len(samples))) - len(samples)
        with self._mutex:
            self.recorded += max(0, extra)
        for s in samples:
            self.record(*s)
        return len(samples)

    def save(self, path) -> None:
        with self._mutex:
            payload = {"recorded": self.recorded,
                       "samples": [list(s) for s in self._samples[-2048:]]}
        with open(path, "w") as fh:
            json.dump(payload, fh)

    def load(self, path) -> bool:
        """Restore a persisted sample tail; returns False when the file is
        missing or unreadable — a cold start, not an error."""
        try:
            with open(path) as fh:
                payload = json.load(fh)
        except (OSError, ValueError):
            return False
        samples = [(int(c), str(sh), int(w), str(e), int(m), float(u))
                   for c, sh, w, e, m, u in payload.get("samples", [])]
        with self._mutex:
            self._samples = samples
            self.recorded = int(payload.get("recorded", len(samples)))
        return True


#: Process-wide recorder the query surfaces feed
#: (``BitmapIndex.query*`` / ``SegmentedIndex`` timing wrappers) and
#: ``serve --workload-stats`` persists.
WORKLOAD_STATS = WorkloadStats()


def merge_snapshots(snaps, stats: WorkloadStats | None = None) -> WorkloadStats:
    """Merge per-host :meth:`WorkloadStats.snapshot` payloads into one
    recorder (default: the process-wide :data:`WORKLOAD_STATS`).

    The serve-plane coordinator calls this with every worker reply, so the
    compaction cost model (:func:`repro.workload.cost.make_compaction_chooser`)
    ranks candidate encodings on the *global* query mix rather than any one
    host's slice.  Returns the target recorder.
    """
    target = stats if stats is not None else WORKLOAD_STATS
    for snap in snaps:
        if snap:
            target.merge_snapshot(snap)
    return target


def record_execution(plans, seconds, stats: WorkloadStats | None = None) -> None:
    """Attribute one executed batch's wall clock to its plans' predicate
    events, in microseconds per plan (the ``us_per_query`` the cost model
    fits against)."""
    if not plans:
        return
    us = float(seconds) * 1e6 / len(plans)
    (stats if stats is not None else WORKLOAD_STATS).record_plans(
        plans, [us] * len(plans))
