"""Workload telemetry + cost-model subsystem: the self-tuning loop.

The planner records per-column predicate events (``query.compile_plan``
-> ``Plan.workload``; public counters via ``query.workload_snapshot()``),
the query surfaces time executed batches into :data:`WORKLOAD_STATS`
(:func:`record_execution`), :class:`CostModel` fits per-encoding costs
from those samples, and ``compact()`` / ``BackgroundCompactor`` consult
:func:`make_compaction_chooser` to re-encode merged segments toward the
cheapest representation for the observed mix.  Persisted across restarts
by ``serve --workload-stats``.  See docs/containers.md.
"""

from .cost import (CANDIDATES, CostModel, column_mixes, estimate_merges,
                   make_compaction_chooser)
from .stats import (WORKLOAD_STATS, WorkloadStats, merge_snapshots,
                    record_execution)

__all__ = [
    "CANDIDATES",
    "CostModel",
    "WORKLOAD_STATS",
    "WorkloadStats",
    "column_mixes",
    "estimate_merges",
    "make_compaction_chooser",
    "merge_snapshots",
    "record_execution",
]
