"""Fitted per-encoding cost model over observed workload samples.

The model answers one question for compaction: *given what this column's
queries actually looked like, which encoding would have served them
cheapest?*  It combines

* an **analytic merge estimator** (:func:`estimate_merges`) — how many
  stream merges each candidate encoding would spend compiling the observed
  predicate shapes (mirrors each encoding's ``compile_*`` structure:
  equality/roaring pay O(width) fan-ins with the over-half-domain
  complement trick, bit-sliced pays the O(log card) comparison circuit,
  binned ~sqrt(card) bins); and
* a **fitted per-merge cost** (:class:`CostModel`) — a least-squares line
  ``us ≈ a + b·merges`` per encoding over the recorded ``(merges, us)``
  samples, falling back to a pooled fit (and, when the observed mix is
  degenerate — all samples at one merge count — to a through-origin rate)
  for encodings the workload hasn't exercised yet.

``make_compaction_chooser`` packages both into the ``encoding_chooser``
hook ``compact()`` threads down to ``Segment.seal`` — see
docs/containers.md and docs/lifecycle.md.
"""

from __future__ import annotations

import math

#: Candidate kinds the chooser ranks, in tie-break order (stable sort:
#: earlier wins on equal predicted cost).  ``bitsliced-gray`` is excluded
#: by default — it only differs from ``bitsliced`` in run compression, a
#: size effect this time-based model cannot see.
CANDIDATES = ("roaring", "equality", "bitsliced", "binned")


def estimate_merges(kind: str, shape: str, width: int, card: int,
                    k: int = 1) -> int:
    """Analytic merge count for compiling one predicate under ``kind``.

    ``shape`` is ``"eq"`` / ``"in"`` / ``"range"``, ``width`` the value
    count the predicate spans, ``card`` the column cardinality.  Estimates
    mirror the encodings' compile paths; exactness is not required — the
    fitted slope absorbs constant factors — but the *ordering* in width
    and cardinality must be right.
    """
    card = max(int(card), 2)
    width = max(min(int(width), card), 1)
    k = max(int(k), 1)
    if kind == "equality":
        if shape == "eq":
            return k - 1
        w = width if shape == "in" else min(width, card - width)
        extra = 1 if shape == "range" and 2 * width > card else 0
        return max(w * k - 1, 0) + extra
    if kind in ("bitsliced", "bitsliced-gray"):
        m = max(1, math.ceil(math.log2(card)))
        if shape == "eq":
            return 2 * m - 1       # plane ANDs + zero-bit complements
        if shape == "in":
            return width * 2 * m   # one comparison circuit per value
        return 2 * m               # the O(m) range circuit
    if kind == "binned":
        bins = max(2, min(64, int(round(2 * math.sqrt(card)))))
        if shape in ("eq", "in"):
            return width           # refinement leaf OR per value
        covered = min(width * bins // card + 2, bins)
        return max(covered - 1, 1)
    if kind == "roaring":
        if shape == "eq":
            return 0               # one container fold, no stream merges
        w = width if shape == "in" else min(width, card - width)
        extra = 1 if shape == "range" and 2 * width > card else 0
        return max(w - 1, 0) + extra
    raise ValueError(f"unknown encoding kind {kind!r}")


def _fit_line(points) -> tuple[float, float]:
    """Least squares ``us = a + b*merges`` with b clamped non-negative;
    degenerate inputs (single merge level) fall back to a through-origin
    rate so predicted cost still grows with merges."""
    n = len(points)
    mx = sum(p[0] for p in points) / n
    my = sum(p[1] for p in points) / n
    varx = sum((p[0] - mx) ** 2 for p in points)
    if varx > 0:
        b = sum((p[0] - mx) * (p[1] - my) for p in points) / varx
        if b > 0:
            return (max(my - b * mx, 0.0), b)
    # no usable slope — one merge level, or flat/inverted cost (batched
    # execution attributes uniform us per plan): charge the observed mean
    # cost per merge, so alternatives with fewer merges rank cheaper
    return (0.0, my / max(mx, 1.0))


class CostModel:
    """Per-encoding ``us ≈ a + b·merges`` lines fitted from samples."""

    def __init__(self, coef: dict, default: tuple[float, float]):
        self.coef = coef        # kind -> (a, b)
        self.default = default  # pooled fallback for unseen kinds

    @classmethod
    def fit(cls, samples, min_samples: int = 8) -> "CostModel":
        """``samples`` are WorkloadStats tuples ``(column, shape, width,
        encoding, merges, us)``; kinds with fewer than ``min_samples``
        fall back to the pooled line."""
        by_kind: dict = {}
        pooled = []
        for _col, _shape, _width, kind, merges, us in samples:
            pt = (float(merges), float(us))
            by_kind.setdefault(kind, []).append(pt)
            pooled.append(pt)
        if not pooled:
            raise ValueError("cannot fit a cost model from zero samples")
        default = _fit_line(pooled)
        coef = {kind: _fit_line(pts) for kind, pts in by_kind.items()
                if len(pts) >= min_samples}
        return cls(coef, default)

    def predict(self, kind: str, merges: float) -> float:
        a, b = self.coef.get(kind, self.default)
        return a + b * max(float(merges), 0.0)

    def rank(self, mix, card: int, k: int = 1,
             candidates=CANDIDATES) -> list:
        """Rank candidate encodings for one column against an observed
        predicate mix (``(shape, width, weight)`` triples); returns
        ``[(kind, predicted us), ...]`` cheapest first, ties broken by
        ``candidates`` order."""
        scored = []
        for kind in candidates:
            cost = sum(
                weight * self.predict(
                    kind, estimate_merges(kind, shape, width, card, k))
                for shape, width, weight in mix)
            scored.append((kind, cost))
        scored.sort(key=lambda t: t[1])
        return scored


def column_mixes(samples) -> dict:
    """Aggregate samples into per-column predicate mixes:
    ``{column: [(shape, mean width, count), ...]}``."""
    agg: dict = {}
    for col, shape, width, _kind, _merges, _us in samples:
        cell = agg.setdefault(int(col), {}).setdefault(
            shape, [0, 0])
        cell[0] += 1
        cell[1] += int(width)
    return {col: [(shape, max(ws // max(cnt, 1), 1), cnt)
                  for shape, (cnt, ws) in shapes.items()]
            for col, shapes in agg.items()}


def make_compaction_chooser(stats, min_samples: int = 32,
                            candidates=CANDIDATES):
    """Build the ``encoding_chooser(col, hist, k) -> kind | None`` hook
    compaction threads down to ``Segment.seal``.

    Returns None when ``stats`` holds fewer than ``min_samples`` samples
    — compaction then keeps the spec's static chooser.  The returned
    chooser answers None for columns the workload never touched (same
    static fallback, per column).
    """
    samples = stats.samples()
    if len(samples) < min_samples:
        return None
    model = CostModel.fit(samples)
    mixes = column_mixes(samples)

    def chooser(col, hist, k):
        mix = mixes.get(int(col))
        if not mix:
            return None
        return model.rank(mix, card=len(hist), k=k,
                          candidates=candidates)[0][0]

    return chooser
