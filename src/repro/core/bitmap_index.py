"""Compressed bitmap index over a table (paper §2-§4, Algorithm 1).

Two paths:
  * ``BitmapIndex`` materializes per-bitmap EWAH streams (supports equality
    queries via compressed-domain logical AND) — used at query-benchmark
    scale.
  * ``index_size_report`` computes exact sizes only, in O(nck + L), for the
    multi-million-row size tables.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from . import ewah
from .column_order import order_columns
from .encoding import choose_N, clamp_k, gray_kofn_codes, lex_kofn_codes
from .histogram import column_histogram, value_order
from .index_size import column_bitmap_sizes
from .sorting import order_rows


def assign_codes(
    n_values: int, k: int, code_order: str = "gray", value_policy: str = "alpha",
    hist: np.ndarray | None = None,
) -> tuple[np.ndarray, int, int]:
    """Build the (n_values, k) bitmap-position code table for one column.

    code_order: 'gray' (Gray-Lex / Gray-Frequency) or 'lex' (Alpha-Lex).
    value_policy: 'alpha' or 'freq' — which value gets the rank-i code.
    Returns (codes, N, k_effective).
    """
    k_eff = clamp_k(n_values, k)
    N = choose_N(n_values, k_eff)
    enum = gray_kofn_codes if code_order == "gray" else lex_kofn_codes
    ordered_codes = enum(N, k_eff, n_values)
    if value_policy == "alpha" or hist is None:
        order = np.arange(n_values)
    else:
        order = value_order(hist, value_policy)
    codes = np.empty((n_values, k_eff), dtype=np.int32)
    codes[order] = ordered_codes
    return codes, N, k_eff


@dataclass
class ColumnIndex:
    codes: np.ndarray          # (n_values, k) bitmap positions
    N: int                     # bitmaps in this column
    k: int
    streams: list | None = None    # per-bitmap EWAH uint32 arrays (dense path)
    sizes: np.ndarray | None = None


@dataclass
class BitmapIndex:
    """An EWAH-compressed k-of-N bitmap index over an integer-coded table."""

    n_rows: int
    columns: list = field(default_factory=list)  # ColumnIndex per table column

    # -- construction ------------------------------------------------------

    @staticmethod
    def build(
        table_cols: list,
        k: int = 1,
        row_order: str = "lex",
        code_order: str = "gray",
        value_policy: str | None = None,
        column_order: str | list | None = "heuristic",
        materialize: bool = True,
    ) -> "BitmapIndex":
        """End-to-end Algorithm-1-style construction.

        table_cols: list of (n,) integer value-id arrays (0-based, dense ids).
        row_order: 'unsorted' | 'lex' | 'grayfreq' | 'freqcomp'.
        code_order: 'gray' | 'lex' bitmap-code enumeration order.
        value_policy: which values get low-rank codes; default 'freq' when
          row_order='grayfreq' else 'alpha'.
        column_order: 'heuristic' (paper §4.3 score), None (as given), or an
          explicit permutation of column indices.
        """
        table_cols = [np.asarray(c) for c in table_cols]
        n = len(table_cols[0])
        cards = [int(c.max()) + 1 for c in table_cols]
        if value_policy is None:
            value_policy = "freq" if row_order == "grayfreq" else "alpha"

        if column_order == "heuristic":
            perm_cols = order_columns(cards, k)
        elif column_order is None:
            perm_cols = np.arange(len(table_cols))
        else:
            perm_cols = np.asarray(column_order)
        cols = [table_cols[i] for i in perm_cols]
        cards = [cards[i] for i in perm_cols]

        row_perm = order_rows(cols, row_order)
        cols = [c[row_perm] for c in cols]

        idx = BitmapIndex(n_rows=n)
        for col, card in zip(cols, cards):
            hist = column_histogram(col, card)
            codes, N, k_eff = assign_codes(card, k, code_order, value_policy, hist)
            ci = ColumnIndex(codes=codes, N=N, k=k_eff)
            ci.sizes, _, _ = column_bitmap_sizes(col, codes, N)
            if materialize:
                ci.streams = _materialize_streams(col, codes, N, n)
            idx.columns.append(ci)
        idx._row_perm = row_perm
        idx._col_perm = perm_cols
        return idx

    # -- stats -------------------------------------------------------------

    def size_words(self) -> int:
        return int(sum(int(c.sizes.sum()) for c in self.columns))

    def per_column_words(self) -> list:
        return [int(c.sizes.sum()) for c in self.columns]

    # -- queries -----------------------------------------------------------

    def equality_query(self, col_idx: int, value: int):
        """Rows where column == value: AND of the value's k bitmaps.

        Returns (row_ids, words_scanned).  col_idx refers to the *reordered*
        column position (use .original_column(col_idx) for the mapping).
        """
        ci = self.columns[col_idx]
        assert ci.streams is not None, "index built with materialize=False"
        streams = [ci.streams[b] for b in ci.codes[value]]
        streams = sorted(streams, key=len)
        if len(streams) == 1:
            result, scanned = streams[0], len(streams[0])
        else:
            result, scanned = ewah.logical_many(streams, "and")
        bits = ewah.unpack_bits(ewah.decompress(result), self.n_rows)
        return np.flatnonzero(bits), scanned

    def original_column(self, reordered_idx: int) -> int:
        return int(self._col_perm[reordered_idx])


def _materialize_streams(col, codes, N, n_rows):
    """Per-bitmap compressed streams in O(n*k + sum of stream sizes)."""
    order = np.argsort(col, kind="stable")
    sorted_vals = col[order]
    # row positions per value, grouped
    boundaries = np.flatnonzero(np.diff(sorted_vals)) + 1
    groups = np.split(order, boundaries)
    vals = sorted_vals[np.concatenate(([0], boundaries))] if len(col) else []
    pos_per_value = {int(v): g for v, g in zip(vals, groups)}
    per_bitmap_positions = [[] for _ in range(N)]
    for v, pos in pos_per_value.items():
        for b in codes[v]:
            per_bitmap_positions[int(b)].append(pos)
    streams = []
    for plist in per_bitmap_positions:
        if plist:
            pos = np.sort(np.concatenate(plist))
            words = ewah.positions_to_words(pos, n_rows)
        else:
            words = np.zeros((n_rows + 31) // 32, dtype=np.uint32)
        streams.append(ewah.compress(words))
    return streams


def index_size_report(
    table_cols, k=1, row_order="lex", code_order="gray",
    value_policy=None, column_order="heuristic",
) -> dict:
    """Size-only construction (no bitmap materialization)."""
    idx = BitmapIndex.build(
        table_cols, k=k, row_order=row_order, code_order=code_order,
        value_policy=value_policy, column_order=column_order, materialize=False,
    )
    return {
        "total_words": idx.size_words(),
        "per_column_words": idx.per_column_words(),
        "column_order": [int(i) for i in idx._col_perm],
        "k_effective": [c.k for c in idx.columns],
        "bitmaps": [c.N for c in idx.columns],
    }
