"""Compressed bitmap index over a table (paper §2-§4, Algorithm 1).

Construction is driven by an :class:`~repro.core.strategies.IndexSpec`
resolved through the strategy registry (row order, code enumeration, value
policy, column order); queries go through the predicate algebra + planner in
:mod:`repro.core.query`.

``BitmapIndex.build`` is a *seal-once convenience* over the incremental
lifecycle (:mod:`repro.core.lifecycle`): it appends the whole table to an
:class:`~repro.core.lifecycle.IndexWriter` and closes it into a single
segment.  Streaming ingestion, per-batch sealing, and compaction live on
the writer; see docs/lifecycle.md.

Two paths:
  * ``BitmapIndex`` materializes per-bitmap EWAH streams (supports predicate
    queries via compressed-domain logical ops) — used at query-benchmark
    scale.
  * ``index_size_report`` computes exact sizes only, in O(nck + L), for the
    multi-million-row size tables.

The pre-IndexSpec string kwargs (``BitmapIndex.build(cols, k=2,
row_order=...)``), deprecated since the IndexSpec migration, are **removed**;
``IndexSpec`` is the only entry point (docs/query_api.md has the migration
table).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from time import perf_counter

import numpy as np

from .encodings import (ColumnEncoding, assign_codes,  # noqa: F401 (re-export)
                        build_encoding, _materialize_streams)
from .histogram import column_histogram
from .query import compile_plan, get_backend
from .strategies import IndexSpec


def _observe_workload(plans, seconds: float) -> None:
    """Feed one executed batch into the workload-telemetry subsystem
    (lazy import: the core package must not depend on repro.workload at
    import time)."""
    from ..workload import record_execution

    record_execution(plans, seconds)

_LEGACY_KWARGS = ("k", "row_order", "code_order", "value_policy",
                  "column_order")


def _reject_legacy(kwargs: dict) -> None:
    legacy = sorted(set(kwargs) & set(_LEGACY_KWARGS))
    if legacy:
        raise TypeError(
            f"the string-kwarg build API ({', '.join(legacy)}=...) was "
            "removed; pass an IndexSpec — e.g. "
            "BitmapIndex.build(cols, IndexSpec(k=2, row_order='grayfreq')) "
            "(see docs/query_api.md, 'Migration from the string-kwargs API')")
    if kwargs:
        raise TypeError(f"unexpected keyword arguments: {sorted(kwargs)}")


@dataclass
class ColumnIndex:
    """One indexed column: a :class:`~repro.core.encodings.ColumnEncoding`
    (value bitmaps / slice planes / bins + its predicate compiler) behind
    the attribute surface the rest of the stack reads.

    ``codes`` and ``k`` exist only on the equality encoding (the k-of-N
    code table); other encodings raise AttributeError for them.
    """

    encoding: ColumnEncoding

    @property
    def card(self) -> int:
        return self.encoding.card

    @property
    def N(self) -> int:
        """Bitmap/stream count (value bitmaps, slice planes, or bins)."""
        return self.encoding.n_streams

    @property
    def streams(self):
        return self.encoding.streams

    @property
    def sizes(self) -> np.ndarray:
        return self.encoding.sizes

    @property
    def codes(self) -> np.ndarray:
        return self.encoding.codes  # equality encoding only

    @property
    def k(self) -> int:
        return self.encoding.k      # equality encoding only


@dataclass
class BitmapIndex:
    """An EWAH-compressed k-of-N bitmap index over an integer-coded table.

    ``row_perm`` / ``col_perm`` are public: the row and column permutations
    the build applied (query row ids live in ``row_perm`` space; map back to
    original rows with ``index.row_perm[row_ids]``).

    ``cache_scope`` tags this index's cached query results for scoped
    eviction (:func:`repro.core.query.invalidate_scope`); the segment
    lifecycle sets it to ``("segment", generation)``.
    """

    n_rows: int
    columns: list = field(default_factory=list)  # ColumnIndex per table column
    spec: IndexSpec | None = None
    row_perm: np.ndarray | None = None
    col_perm: np.ndarray | None = None
    cache_scope: tuple | None = None

    # -- construction ------------------------------------------------------

    @staticmethod
    def build(table_cols: list, spec: IndexSpec | None = None, *,
              materialize: bool = True, **removed) -> "BitmapIndex":
        """End-to-end Algorithm-1-style construction: a seal-once
        convenience over :class:`~repro.core.lifecycle.IndexWriter`
        (append everything, close into one segment, return its index).

        table_cols: list of (n,) integer value-id arrays (0-based, dense ids).
        spec: IndexSpec naming the row-order / code-order / value-policy /
          column-order strategies (see repro.core.strategies).
        """
        _reject_legacy(removed)
        if spec is not None and not isinstance(spec, IndexSpec):
            raise TypeError(
                f"second argument must be an IndexSpec, got {spec!r}; the old "
                "positional form build(cols, k) is gone — pass IndexSpec(k=...)")
        from .lifecycle import IndexWriter

        writer = IndexWriter(spec, materialize=materialize)
        writer.append(table_cols)
        seg = writer.close()
        if seg is None:
            raise ValueError("cannot build an index over zero rows")
        return seg.index

    # -- stats -------------------------------------------------------------

    def size_words(self) -> int:
        return int(sum(int(c.sizes.sum()) for c in self.columns))

    def per_column_words(self) -> list:
        return [int(c.sizes.sum()) for c in self.columns]

    # -- queries -----------------------------------------------------------

    def query(self, pred, backend: str = "numpy", names=None, **backend_opts):
        """Run a predicate (Eq/In/Range/And/Or/Not over *original* column
        positions, or names via ``names``) through the planner.

        Returns (row_ids, words_scanned); row ids are positions in the
        reordered row space (``self.row_perm[row_ids]`` maps back).
        """
        plan = compile_plan(self, pred, names=names)
        t0 = perf_counter()
        out = get_backend(backend, **backend_opts).execute(plan)
        _observe_workload([plan], perf_counter() - t0)
        return out

    def query_compressed(self, pred, backend: str = "numpy", names=None,
                         **backend_opts):
        """Compressed-in/compressed-out execution: the result stays an EWAH
        stream (:class:`~repro.core.ewah_stream.EwahStream` — ``.to_rows()``
        materializes, ``.count()`` popcounts without expansion), and
        sub-plan results are memoized in the backend's LRU result cache so
        cascaded predicates reuse shared work."""
        plan = compile_plan(self, pred, names=names)
        t0 = perf_counter()
        out = get_backend(backend, **backend_opts).execute_compressed(plan)
        _observe_workload([plan], perf_counter() - t0)
        return out

    def query_many(self, preds, backend: str = "numpy", names=None,
                   **backend_opts):
        """Batch-execute many predicates; on the jax backend, same-shape
        plans share one padded device dispatch.  Returns a list of
        (row_ids, words_scanned)."""
        plans = [compile_plan(self, p, names=names) for p in preds]
        t0 = perf_counter()
        out = get_backend(backend, **backend_opts).execute_many(plans)
        _observe_workload(plans, perf_counter() - t0)
        return out

    def equality_query(self, col_idx: int, value: int, backend: str = "numpy"):
        """Rows where column == value (planner-compiled AND of the value's
        k bitmaps).

        Returns (row_ids, words_scanned).  col_idx refers to the *reordered*
        column position (use .original_column(col_idx) for the mapping).
        """
        from .query import Eq

        return self.query(Eq(self.original_column(col_idx), value),
                          backend=backend)

    def original_column(self, reordered_idx: int) -> int:
        return int(self.col_perm[reordered_idx])

    def encodings(self) -> tuple:
        """Per-column encoding kinds, in reordered column order (what the
        spec's encoding chooser picked per histogram)."""
        return tuple(c.encoding.kind for c in self.columns)


def _construct(table_cols: list, spec: IndexSpec | None,
               materialize: bool = True,
               encoding_chooser=None) -> "BitmapIndex":
    """The actual Algorithm-1 pipeline over one run of rows.

    This is what :meth:`IndexWriter.seal` runs per segment (and what
    ``BitmapIndex.build`` reaches through its one-segment writer): column
    histograms -> column permutation -> row sort -> per-column encoding
    choice (the spec's ``encoding`` strategy reads each histogram) ->
    per-encoding EWAH streams (k-of-N value bitmaps, bit-slice planes,
    histogram-equalized bins, or Roaring container sets; see
    :mod:`repro.core.encodings`).

    ``encoding_chooser(original_col, hist, k) -> kind | None`` overrides
    the spec's static chooser per column — the workload-driven
    re-encoding hook compaction passes down
    (:func:`repro.workload.make_compaction_chooser`); a None return
    defers that column back to the spec.
    """
    spec = (spec or IndexSpec()).validate()
    strategies = spec.strategies()

    table_cols = [np.asarray(c) for c in table_cols]
    n = len(table_cols[0])
    cards = [int(c.max()) + 1 for c in table_cols]

    if strategies["column_order"] is not None:
        perm_cols = np.asarray(strategies["column_order"](cards, spec.k))
    else:  # explicit permutation carried by the spec
        perm_cols = np.asarray(spec.column_order)
    cols = [table_cols[i] for i in perm_cols]
    cards = [cards[i] for i in perm_cols]

    # histograms are row-permutation invariant: compute once, share with
    # the row-order strategy, the value policy, and the encoding chooser
    hists = [column_histogram(c, card) for c, card in zip(cols, cards)]
    row_perm = strategies["row_order"](cols, hists)
    cols = [c[row_perm] for c in cols]

    idx = BitmapIndex(n_rows=n, spec=spec, row_perm=np.asarray(row_perm),
                      col_perm=perm_cols)
    chooser = strategies["encoding"]
    for pos, col, card, hist in zip(perm_cols, cols, cards, hists):
        kind = None
        if encoding_chooser is not None:
            kind = encoding_chooser(int(pos), hist, spec.k)
        if kind is None:
            kind = chooser(hist, spec.k)
        enc = build_encoding(kind, col, card, hist, spec,
                             materialize=materialize)
        idx.columns.append(ColumnIndex(encoding=enc))
    return idx


def index_size_report(table_cols, spec: IndexSpec | None = None,
                      **removed) -> dict:
    """Size-only construction (no bitmap materialization)."""
    _reject_legacy(removed)
    idx = BitmapIndex.build(table_cols, spec, materialize=False)
    return {
        "total_words": idx.size_words(),
        "per_column_words": idx.per_column_words(),
        "column_order": [int(i) for i in idx.col_perm],
        "encodings": list(idx.encodings()),
        "k_effective": [getattr(c.encoding, "k", None) for c in idx.columns],
        "bitmaps": [c.N for c in idx.columns],
    }
