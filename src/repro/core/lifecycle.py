"""Index lifecycle: the append / delete / seal / compact writer API.

The one-shot ``BitmapIndex.build`` freezes the paper's whole pipeline behind
a single static call — every new batch of rows would force a full re-sort
and re-encode.  :class:`IndexWriter` makes the lifecycle incremental,
LSM-style:

* ``writer.append(rows, ttl=...)`` buffers rows in the **open segment**
  (queryable immediately through the live
  :class:`~repro.core.segment.SegmentedIndex` view — dense evaluation, no
  index build); ``ttl`` stamps per-row absolute expiry deadlines;
* ``writer.delete(pred | row_ids)`` tombstones rows wherever they live:
  sealed segments OR the delete into their compressed tombstone bitmap
  (one merge, no rebuild — every later query ANDs the cached live mask
  into its plan root), buffered rows flip a dense mask;
* ``writer.seal()`` runs the full histogram-aware pipeline (histogram
  refresh, column/value reordering, row sort per the ``IndexSpec``) on the
  word-aligned prefix of the buffer and emits an immutable
  :class:`~repro.core.segment.Segment`; the ``len(buffer) % 32`` tail rows
  carry over into the next open segment, preserving the word-alignment
  contract that lets segment results concatenate in word space.  Buffered
  deletes and TTLs travel into the new segment's tombstones/expiry — an
  all-deleted buffer seals into a valid fully-tombstoned segment;
* ``writer.close()`` seals *everything* left (the final segment may be
  non-word-aligned — it is last, so nothing concatenates after it) and
  rejects further appends (deletes and compaction stay legal: an LSM keeps
  maintaining closed data);
* :func:`compact` merges adjacent segments into one re-sorted segment and
  **purges** tombstoned/expired rows (up to 31 dead rows survive as
  tombstoned fillers so the merged segment stays word-aligned; a
  fully-dead span yields a valid zero-row segment).  The full pipeline
  re-runs, including the spec's per-column encoding chooser over the
  *merged* histograms; the merged segment's ``row_ids`` keep surviving
  ingest ids stable across purges.  ``writer.compact()`` applies the
  size-tiered policy, swaps the merged segment in **atomically** (the
  segment list is a copy-on-write tuple: concurrent queries see the old or
  the new list, never a mix), replays deletes that raced the merge, and
  evicts exactly the retired segments' result-cache entries
  (:func:`repro.core.query.invalidate_scope`);
* :class:`BackgroundCompactor` runs that policy on a scheduler thread —
  compaction leaves the serving path entirely — with exponential backoff
  on transient failures and a drain-on-close that finishes pending tiers.

Thread-safety contract: any number of query threads (and one background
compactor) may run against one writer concurrently with its owner calling
``append``/``delete``/``seal``/``close``; the mutating calls themselves are
serialized by the writer (single-writer discipline, enforced by an RLock).

``BitmapIndex.build`` is now a seal-once convenience over this writer.
See docs/lifecycle.md for semantics and the cache-invalidation contract.
"""

from __future__ import annotations

import threading
import time

import numpy as np

from . import ewah
from ..analysis.runtime import make_lock
from .query import compile_plan, evaluate_mask, get_backend, invalidate_scope
from .segment import Segment, SegmentedIndex
from .strategies import IndexSpec

__all__ = ["BackgroundCompactor", "IndexWriter", "compact",
           "size_tiered_pick"]


class IndexWriter:
    """Incremental builder: append rows, tombstone deletes, seal immutable
    segments, compact (foreground or via :class:`BackgroundCompactor`).

    Parameters
    ----------
    spec:
        The :class:`~repro.core.strategies.IndexSpec` every seal resolves
        (one spec per writer — segments of one index sort consistently).
    names:
        Optional column names, forwarded to the query surface.
    seal_rows:
        Auto-seal threshold: ``append`` seals whenever the open buffer
        reaches this many rows (None = manual sealing only).
    materialize:
        Forwarded to the per-segment index build (False = sizes only).
    clock:
        TTL time source (absolute seconds; default ``time.time``).
        Injectable so tests can expire rows deterministically.
    workload_stats:
        Optional :class:`~repro.workload.WorkloadStats`.  When set,
        every compaction fits a cost model over the recorded query mix
        and re-encodes the merged segment's columns toward the cheapest
        candidate (``repro.workload.make_compaction_chooser``); unset
        keeps the spec's static per-histogram chooser.
    """

    def __init__(self, spec: IndexSpec | None = None, *, names=None,
                 seal_rows: int | None = None, materialize: bool = True,
                 clock=time.time, workload_stats=None):
        self.spec = (spec or IndexSpec()).validate()
        self.names = tuple(names) if names is not None else None
        self.seal_rows = seal_rows
        self.materialize = materialize
        self.clock = clock
        # optional WorkloadStats: compactions consult the fitted cost
        # model and re-encode merged segments toward the observed query
        # mix (repro.workload.make_compaction_chooser)
        self.workload_stats = workload_stats
        self._segments: tuple[Segment, ...] = ()    # guarded-by: _lock
        self._chunks: list[list[np.ndarray]] = []   # guarded-by: _lock
        self._chunk_deleted: list[np.ndarray] = []  # guarded-by: _lock
        self._chunk_expiry: list[np.ndarray] = []   # guarded-by: _lock
        self._buffered = 0                          # guarded-by: _lock
        self._n_cols: int | None = None             # guarded-by: _lock
        self._closed = False                        # guarded-by: _lock
        # _lock serializes mutations and makes (segments, buffer) snapshots
        # atomic; _compact_lock keeps compactions single-file so the
        # background compactor and a foreground compact() can't both retire
        # the same run.  Acquisition order is _compact_lock before _lock,
        # never the reverse (the REPRO_SANITIZE lock-order sanitizer
        # enforces it at runtime).
        self._lock = make_lock("writer._lock")
        self._compact_lock = make_lock("writer._compact_lock",
                                       reentrant=False)

    @classmethod
    def from_parts(cls, spec=None, *, names=None, segments=(),
                   buffer=None, closed=False, seal_rows=None,
                   materialize=True, clock=time.time,
                   workload_stats=None) -> "IndexWriter":
        """Reassemble a writer from previously-sealed parts — the restore
        hook for the sharded serve-plane checkpoints
        (``repro.dist.serve_plane.ServePlane.restore``).

        ``segments`` are already-sealed :class:`Segment` objects covering
        contiguous id spans (typically re-sealed from checkpointed raw
        columns with their recorded encodings); ``buffer`` is the open
        tail as ``(columns, deleted_mask, expiry)`` or None.  The writer
        behaves exactly as if it had ingested those rows itself: appends,
        deletes, seals, and compactions all remain legal (unless
        ``closed``).
        """
        w = cls(spec, names=names, seal_rows=seal_rows,
                materialize=materialize, clock=clock,
                workload_stats=workload_stats)
        segments = tuple(segments)
        with w._lock:
            w._segments = segments
            if buffer is not None:
                cols, deleted, expiry = buffer
                cols = [np.asarray(c) for c in cols]
                n = len(deleted)
                if n:
                    w._chunks = [cols]
                    w._chunk_deleted = [np.asarray(deleted, dtype=bool)]
                    w._chunk_expiry = [np.asarray(expiry,
                                                  dtype=np.float64)]
                    w._buffered = n
                w._n_cols = len(cols)
            elif segments:
                live = next((s for s in segments if s.columns), None)
                if live is not None:
                    w._n_cols = len(live.columns)
            w._closed = bool(closed)
        SegmentedIndex._check(segments, buffer is not None)
        return w

    # -- state -------------------------------------------------------------

    @property
    def closed(self) -> bool:
        return self._closed  # analysis-ok: lock/unguarded-read atomic flag read

    @property
    def buffered_rows(self) -> int:
        return self._buffered  # analysis-ok: lock/unguarded-read atomic int read

    @property
    def n_rows(self) -> int:
        """Ingest ids issued so far (sealed span + buffer); purged rows do
        not shrink this — ids are stable forever."""
        # under _lock: a concurrent seal moves rows from the buffer into a
        # segment, and an unlocked sum could count them twice or miss them
        with self._lock:
            return self.sealed_rows + self._buffered

    @property
    def sealed_rows(self) -> int:
        """End of the sealed ingest-id span (the buffer's first id)."""
        segs = self._segments  # analysis-ok: lock/unguarded-read atomic tuple-reference snapshot
        return segs[-1].row_stop if segs else 0

    @property
    def segments(self) -> list:
        """Snapshot of the sealed segments (copy-on-write: compaction swaps
        the underlying tuple by reference, it never mutates this list)."""
        return list(self._segments)  # analysis-ok: lock/unguarded-read atomic tuple-reference snapshot

    def snapshot(self):
        """Atomic (segments, buffer) view for the query surface; ``buffer``
        is ``(columns, deleted_mask, expiry)`` or None."""
        with self._lock:
            segs = self._segments
            if not self._buffered:
                return segs, None
            cols = [np.concatenate([chunk[c] for chunk in self._chunks])
                    for c in range(self._n_cols)]
            deleted = np.concatenate(self._chunk_deleted)
            expiry = np.concatenate(self._chunk_expiry)
        return segs, (cols, deleted, expiry)

    def buffer_columns(self) -> list:
        """The open buffer as per-column arrays (ingest order); [] when
        nothing is buffered."""
        with self._lock:
            if not self._chunks:
                return []
            return [np.concatenate([chunk[c] for chunk in self._chunks])
                    for c in range(self._n_cols)]

    @property
    def index(self) -> SegmentedIndex:
        """The live query surface: sealed segments + the open buffer."""
        return SegmentedIndex(self._segments, names=self.names,  # analysis-ok: lock/unguarded-read atomic tuple-reference snapshot
                              writer=self)

    def size_words(self) -> int:
        return sum(s.size_words() for s in self._segments)  # analysis-ok: lock/unguarded-read atomic tuple-reference snapshot

    def live_rows(self, now=None) -> int:
        """Rows a whole-domain query would return right now."""
        now = self.clock() if now is None else float(now)
        with self._lock:
            segs = self._segments
            buf_live = 0
            for dmask, emask in zip(self._chunk_deleted, self._chunk_expiry):
                buf_live += int((~dmask & (emask > now)).sum())
        sealed = 0
        for s in segs:
            s.fold_expired(now)
            sealed += s.n_rows - s.deleted_count()
        return sealed + buf_live

    # -- append ------------------------------------------------------------

    def append(self, rows, *, ttl=None) -> None:
        """Buffer a batch of rows in the open segment.

        ``rows`` is a list of per-column integer value-id arrays (the
        ``BitmapIndex.build`` table convention) or, when the writer carries
        ``names``, a dict mapping those names to arrays.  All columns must
        be equal length; column count is fixed by the first append.

        ``ttl`` (seconds; scalar or per-row array) stamps the rows with
        absolute expiry deadlines ``clock() + ttl``; expired rows vanish
        from queries lazily (folded into tombstones at query time) and are
        physically dropped at compaction.
        """
        if self._closed:  # analysis-ok: lock/unguarded-read fast-fail; rechecked under _lock below
            raise ValueError("writer is closed; no further appends")
        if isinstance(rows, dict):
            if self.names is None:
                raise ValueError(
                    "dict appends need a writer built with names=...")
            missing = [c for c in self.names if c not in rows]
            if missing:
                raise ValueError(f"append missing columns: {missing}")
            rows = [rows[c] for c in self.names]
        chunk = [np.asarray(c) for c in rows]
        if not chunk:
            raise ValueError("append needs at least one column")
        n = len(chunk[0])
        if any(len(c) != n for c in chunk):
            raise ValueError("append columns must be equal length")
        expiry = np.full(n, np.inf)
        if ttl is not None:
            t = np.asarray(ttl, dtype=np.float64)
            if t.ndim == 0:
                t = np.full(n, float(t))
            elif len(t) != n:
                raise ValueError(
                    f"ttl has {len(t)} entries for {n} rows")
            expiry = self.clock() + t
        with self._lock:
            # closed/column-count checks belong under the lock: two racing
            # first appends could otherwise both set _n_cols, and a close
            # racing the buffer push could seal without these rows
            if self._closed:
                raise ValueError("writer is closed; no further appends")
            if self._n_cols is None:
                self._n_cols = len(chunk)
            elif len(chunk) != self._n_cols:
                raise ValueError(
                    f"append has {len(chunk)} columns, writer has "
                    f"{self._n_cols}")
            if n == 0:
                return
            self._chunks.append(chunk)
            self._chunk_deleted.append(np.zeros(n, dtype=bool))
            self._chunk_expiry.append(expiry)
            buffered = self._buffered = self._buffered + n
        if self.seal_rows is not None and buffered >= self.seal_rows:
            self.seal()

    # -- delete ------------------------------------------------------------

    def delete(self, pred=None, *, row_ids=None, backend: str = "numpy",
               now=None) -> int:
        """Tombstone rows by predicate or by global ingest id.

        Sealed segments take the delete as a compressed-domain OR into
        their tombstone bitmap (the live-mask complement recomputes once,
        off the query path); buffered rows flip a dense mask that seals
        into the next segment's tombstones.  Ids already dead — or already
        purged by compaction — are ignored.  Legal after ``close()``.
        Returns the count of newly-dead rows.
        """
        if (pred is None) == (row_ids is None):
            raise ValueError("delete needs exactly one of pred= or row_ids=")
        now = self.clock() if now is None else float(now)
        deleted = 0
        # the whole delete holds _lock so it serializes against compaction's
        # late-replay + swap (also under _lock): a delete either lands fully
        # before the swap — its tombstones show up in the replay diff — or
        # starts after and sees the merged segment.  Unlocked, a delete that
        # read the old tuple could tombstone a retired segment after the
        # replay diff ran, and the rows would resurface in the merged
        # generation.  Queries only take _lock for their snapshot, so they
        # are never blocked for long.
        with self._lock:
            if row_ids is not None:
                ids = np.unique(np.asarray(row_ids, dtype=np.int64))
                for seg in self._segments:
                    deleted += seg.delete_ids(ids)
                start = self.sealed_rows
                local = ids[(ids >= start) & (ids < start + self._buffered)]
                deleted += self._mark_buffer_deleted(local - start)
                return deleted
            be = get_backend(backend)
            for seg in self._segments:
                if not seg.n_rows:
                    continue
                seg.fold_expired(now)
                plan = compile_plan(seg.index, pred, names=self.names)
                rows, _ = be.execute(plan)
                deleted += seg.delete_reordered(rows)
            if self._buffered:
                mask = evaluate_mask(pred, self.buffer_columns(),
                                     names=self.names)
                deleted += self._mark_buffer_deleted(np.flatnonzero(mask))
        return deleted

    def _mark_buffer_deleted(self, positions) -> int:  # holds-lock: _lock
        """Flip buffer-local positions dead; returns newly-dead count.
        Caller holds ``_lock``."""
        positions = np.asarray(positions, dtype=np.int64)
        if not len(positions):
            return 0
        newly = 0
        off = 0
        for dmask in self._chunk_deleted:
            n = len(dmask)
            sel = positions[(positions >= off) & (positions < off + n)] - off
            if len(sel):
                newly += int((~dmask[sel]).sum())
                dmask[sel] = True
            off += n
        return newly

    # -- seal --------------------------------------------------------------

    def seal(self) -> Segment | None:
        """Seal the word-aligned prefix of the open buffer into an
        immutable segment; the ``% 32`` tail rows stay buffered (they seal
        with the next segment, or with :meth:`close`).  Returns the new
        :class:`Segment`, or None when fewer than 32 rows are buffered."""
        # the whole seal holds _lock (reentrant with _seal_rows): computing
        # n_seal from an unlocked read lets two concurrent seals both claim
        # the same word-aligned prefix and drive _buffered negative
        with self._lock:
            if self._closed:
                raise ValueError("writer is closed")
            n_seal = (self._buffered // ewah.WORD_BITS) * ewah.WORD_BITS
            return self._seal_rows(n_seal) if n_seal else None

    def close(self) -> Segment | None:
        """Seal everything left in the buffer — the final segment may be
        non-word-aligned because nothing concatenates after it — and close
        the writer for appends.  Deletes and compaction remain legal.
        Returns the final segment (None if nothing buffered)."""
        with self._lock:
            if self._closed:
                raise ValueError("writer is already closed")
            seg = self._seal_rows(self._buffered) if self._buffered else None
            self._closed = True
            return seg

    def _seal_rows(self, n_seal: int) -> Segment:
        with self._lock:
            cols = [np.concatenate([chunk[c] for chunk in self._chunks])
                    for c in range(self._n_cols)]
            deleted = np.concatenate(self._chunk_deleted)
            expiry = np.concatenate(self._chunk_expiry)
            head = [c[:n_seal] for c in cols]
            # an all-deleted buffer still seals physically: the rows are
            # born tombstoned and the next compaction purges them
            seg = Segment.seal(
                head, self.spec, row_start=self.sealed_rows,
                materialize=self.materialize, expiry=expiry[:n_seal],
                tombstone_rows=np.flatnonzero(deleted[:n_seal]))
            remaining = self._buffered - n_seal
            self._segments = self._segments + (seg,)
            self._chunks = [[c[n_seal:] for c in cols]] if remaining else []
            self._chunk_deleted = [deleted[n_seal:]] if remaining else []
            self._chunk_expiry = [expiry[n_seal:]] if remaining else []
            self._buffered = remaining
        return seg

    # -- compaction --------------------------------------------------------

    def compact(self, span: tuple | None = None, *, fanout: int = 4,
                ratio: float = 4.0, now=None) -> Segment | None:
        """Merge a run of adjacent segments into one re-sorted segment,
        purging tombstoned/expired rows.

        ``span=(i, j)`` compacts ``segments[i:j]`` explicitly; without it
        the size-tiered policy (:func:`size_tiered_pick`) picks the first
        run of >= ``fanout`` adjacent segments whose compressed sizes are
        within ``ratio`` of each other (LSM size tiering, restricted to
        adjacent runs because segments must stay contiguous).

        Safe to run from a background thread while queries and appends
        continue: the heavy merge runs off-lock against an immutable
        snapshot, the swap is a single copy-on-write tuple replacement
        (readers see old or new, never a mix), deletes that landed on the
        retired segments during the merge are replayed onto the merged
        segment before it becomes visible, and retired segments' result-
        cache entries are evicted by generation scope — untouched segments
        keep theirs.  Returns the merged segment, or None when no run
        qualifies.
        """
        now = self.clock() if now is None else float(now)
        with self._compact_lock:
            snapshot = self._segments  # analysis-ok: lock/unguarded-read intentional off-_lock snapshot; the swap below re-locates under _lock
            if span is None:
                span = size_tiered_pick(snapshot, fanout=fanout, ratio=ratio)
                if span is None:
                    return None
            i, j = span
            if not 0 <= i < j <= len(snapshot) or j - i < 2:
                raise ValueError(f"compaction span {span} must cover >= 2 "
                                 f"segments of {len(snapshot)}")
            retired = snapshot[i:j]
            # dead-set snapshot: deletes racing the off-lock merge are
            # found by diffing against this and replayed onto the merged
            # segment before the swap publishes it
            pre_dead = [frozenset(s.dead_ids(now).tolist()) for s in retired]
            chooser = None
            if self.workload_stats is not None:
                from ..workload import make_compaction_chooser
                chooser = make_compaction_chooser(self.workload_stats)
            merged = compact(retired, self.spec,
                             materialize=self.materialize, now=now,
                             encoding_chooser=chooser)
            with self._lock:
                cur = self._segments
                # seals only append and compactions are single-file, so the
                # retired run still sits at one spot — locate by identity
                k = next(idx for idx in range(len(cur))
                         if cur[idx] is retired[0])
                late = set()
                now2 = self.clock()
                for s, pre in zip(retired, pre_dead):
                    late.update(set(s.dead_ids(now2).tolist()) - pre)
                if late:
                    merged.delete_ids(np.fromiter(late, dtype=np.int64))
                self._segments = cur[:k] + (merged,) + cur[k + len(retired):]
        for seg in retired:
            invalidate_scope(seg.cache_scope)
        return merged


def compact(segments, spec: IndexSpec | None = None, *,
            materialize: bool = True, now=None,
            encoding_chooser=None) -> Segment:
    """Merge adjacent sealed segments into one re-sorted segment, dropping
    tombstoned rows (and rows expired at ``now``).

    ``encoding_chooser(original_col, hist, k) -> kind | None`` overrides
    the spec's per-column encoding choice for the merged segment — the
    workload-driven re-encoding hook
    (:func:`repro.workload.make_compaction_chooser`); None keeps the
    spec's static chooser for that column.

    Surviving rows concatenate in original ingest order and the full
    pipeline (histogram refresh over the merged distribution, reordering,
    row sort) re-runs across the whole range — the merged segment
    compresses like a monolithic build over those rows, and its ``row_ids``
    keep their global ingest ids so ids stay stable across purges.  Up to
    31 dead rows are retained as *fillers* — still tombstoned, purged by
    the next compaction — whenever that keeps the merged physical row count
    word-aligned (always possible when the retired span was aligned).  A
    fully-dead span returns a valid zero-row segment covering the same id
    span.  Segments must cover contiguous id spans (the writer's
    invariant); violations raise ValueError.
    """
    segments = list(segments)
    if len(segments) < 2:
        raise ValueError("compact needs at least 2 segments")
    for a, b in zip(segments, segments[1:]):
        if a.row_stop != b.row_start:
            raise ValueError(
                f"segments are not adjacent: [{a.row_start}, {a.row_stop}) "
                f"then [{b.row_start}, {b.row_stop})")
    live_segs = [s for s in segments if s.n_rows]
    if any(s.columns is None for s in live_segs):
        raise ValueError(
            "cannot compact segments sealed with keep_columns=False: their "
            "row store was dropped (dist fan-out shards are never compacted)")
    row_start = segments[0].row_start
    span_stop = segments[-1].row_stop
    if not live_segs:
        return Segment.empty(row_start, span_stop)
    n_cols = len(live_segs[0].columns)
    if any(len(s.columns) != n_cols for s in live_segs):
        raise ValueError("segments disagree on column count")
    cat_cols = [np.concatenate([s.columns[c] for s in live_segs])
                for c in range(n_cols)]
    cat_ids = np.concatenate([s.ingest_ids() for s in live_segs])
    cat_exp = np.concatenate(
        [s.expiry if s.expiry is not None
         else np.full(s.n_rows, np.inf) for s in live_segs])
    keep = ~np.concatenate([s.dead_ingest_mask(now) for s in live_segs])
    # retain dead fillers to keep the merged segment word-aligned (mid-
    # sequence segments must stay %32); if the span is too dead-poor to
    # reach alignment it must be the unaligned final segment — leave it
    need = int(-keep.sum() % ewah.WORD_BITS)
    dead_pos = np.flatnonzero(~keep)
    fillers = dead_pos[:need] if need and len(dead_pos) >= need \
        else dead_pos[:0]
    keep[fillers] = True
    kept = np.flatnonzero(keep)
    if not len(kept):
        return Segment.empty(row_start, span_stop)
    return Segment.seal(
        [c[kept] for c in cat_cols], spec, row_start=row_start,
        span_stop=span_stop, row_ids=cat_ids[kept], expiry=cat_exp[kept],
        tombstone_rows=np.searchsorted(kept, fillers),
        materialize=materialize, encoding_chooser=encoding_chooser)


class BackgroundCompactor:
    """Scheduler thread running :func:`size_tiered_pick` compaction off the
    serving path.

    Every ``interval`` seconds it asks the writer for one size-tiered
    compaction (``writer.compact()`` — snapshot, off-lock merge, atomic
    swap).  Transient failures back off exponentially (``backoff`` doubling
    up to ``max_backoff``) and are counted in ``stats`` rather than killing
    the thread; the next success resets the cadence.  ``close()`` drains
    gracefully: it stops the scheduler, joins (an in-flight compaction
    finishes — the swap is never torn), then runs remaining qualifying
    tiers to quiescence.

    Usable as a context manager::

        with BackgroundCompactor(writer, interval=0.01):
            ...ingest/serve...
    """

    def __init__(self, writer: IndexWriter, *, interval: float = 0.05,
                 fanout: int = 4, ratio: float = 4.0,
                 backoff: float = 0.05, max_backoff: float = 2.0,
                 on_error=None):
        self.writer = writer
        self.interval = float(interval)
        self.fanout = fanout
        self.ratio = ratio
        self.backoff = float(backoff)
        self.max_backoff = float(max_backoff)
        self.on_error = on_error
        self._stats_lock = make_lock("compactor._stats_lock",
                                     reentrant=False)
        self._stats = {"cycles": 0,            # guarded-by: _stats_lock
                       "compactions": 0, "failures": 0}
        self._stop = threading.Event()
        self._closed = False
        self._thread = threading.Thread(
            target=self._run, name="index-compactor", daemon=True)
        self._thread.start()

    @property
    def stats(self) -> dict:
        """Point-in-time counter snapshot (the scheduler thread keeps
        mutating the live dict; callers get a consistent copy)."""
        with self._stats_lock:
            return dict(self._stats)

    def _bump(self, key: str) -> None:
        with self._stats_lock:
            self._stats[key] += 1

    def _run(self) -> None:
        delay = self.interval
        while not self._stop.wait(delay):
            self._bump("cycles")
            try:
                merged = self.writer.compact(fanout=self.fanout,
                                             ratio=self.ratio)
            except Exception as exc:  # transient: back off, keep serving
                self._bump("failures")
                if self.on_error is not None:
                    self.on_error(exc)
                delay = min(max(delay * 2, self.backoff), self.max_backoff)
                continue
            if merged is not None:
                self._bump("compactions")
            delay = self.interval

    @property
    def running(self) -> bool:
        return self._thread.is_alive()

    def close(self, drain: bool = True) -> None:
        """Stop the scheduler and join; with ``drain`` (default) finish any
        still-qualifying tiers so the writer closes quiescent.  Idempotent."""
        if self._closed:
            return
        self._stop.set()
        self._thread.join()
        self._closed = True
        if not drain:
            return
        while True:
            try:
                merged = self.writer.compact(fanout=self.fanout,
                                             ratio=self.ratio)
            except Exception as exc:
                self._bump("failures")
                if self.on_error is not None:
                    self.on_error(exc)
                return
            if merged is None:
                return
            self._bump("compactions")

    def __enter__(self) -> "BackgroundCompactor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def size_tiered_pick(segments, fanout: int = 4, ratio: float = 4.0):
    """First run of >= ``fanout`` adjacent segments whose compressed sizes
    are within ``ratio`` of each other; returns ``(i, j)`` or None.

    Classic size tiering buckets segments by size wherever they live; here
    runs must be *adjacent* (segments stay contiguous row ranges), so the
    policy slides a window and fires on the first size-homogeneous run.
    """
    if fanout < 2:
        raise ValueError(f"fanout must be >= 2, got {fanout}")
    sizes = [max(s.size_words(), 1) for s in segments]
    for i in range(len(sizes) - fanout + 1):
        window = sizes[i : i + fanout]
        if max(window) <= ratio * min(window):
            j = i + fanout
            # greedily extend the tier while sizes stay homogeneous
            while j < len(sizes) and \
                    max(max(sizes[i:j + 1]), 1) <= ratio * min(sizes[i:j + 1]):
                j += 1
            return (i, j)
    return None
