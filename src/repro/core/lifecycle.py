"""Index lifecycle: the append / seal / compact writer API.

The one-shot ``BitmapIndex.build`` freezes the paper's whole pipeline behind
a single static call — every new batch of rows would force a full re-sort
and re-encode.  :class:`IndexWriter` makes the lifecycle incremental,
LSM-style:

* ``writer.append(rows)`` buffers rows in the **open segment** (queryable
  immediately through the live :class:`~repro.core.segment.SegmentedIndex`
  view — dense evaluation, no index build);
* ``writer.seal()`` runs the full histogram-aware pipeline (histogram
  refresh, column/value reordering, row sort per the ``IndexSpec``) on the
  word-aligned prefix of the buffer and emits an immutable
  :class:`~repro.core.segment.Segment`; the ``len(buffer) % 32`` tail rows
  carry over into the next open segment, preserving the word-alignment
  contract that lets segment results concatenate in word space;
* ``writer.close()`` seals *everything* left (the final segment may be
  non-word-aligned — it is last, so nothing concatenates after it) and
  rejects further appends;
* :func:`compact` merges adjacent segments into one re-sorted segment
  (rows re-sort globally across the merged range, recovering the
  single-sort compression the per-segment splits gave up); the full
  pipeline re-runs, including the spec's per-column encoding chooser over
  the *merged* histograms — compacting mixed-encoding segments is just a
  re-choice, since per-bitmap/per-plane data never crosses segments;
  ``writer.compact()`` applies the size-tiered policy, swaps the merged
  segment in, and evicts exactly the retired segments' result-cache
  entries (:func:`repro.core.query.invalidate_scope`).

``BitmapIndex.build`` is now a seal-once convenience over this writer.
See docs/lifecycle.md for semantics and the cache-invalidation contract.
"""

from __future__ import annotations

import numpy as np

from . import ewah
from .query import invalidate_scope
from .segment import Segment, SegmentedIndex
from .strategies import IndexSpec

__all__ = ["IndexWriter", "compact", "size_tiered_pick"]


class IndexWriter:
    """Incremental builder: append rows, seal immutable segments, compact.

    Parameters
    ----------
    spec:
        The :class:`~repro.core.strategies.IndexSpec` every seal resolves
        (one spec per writer — segments of one index sort consistently).
    names:
        Optional column names, forwarded to the query surface.
    seal_rows:
        Auto-seal threshold: ``append`` seals whenever the open buffer
        reaches this many rows (None = manual sealing only).
    materialize:
        Forwarded to the per-segment index build (False = sizes only).
    """

    def __init__(self, spec: IndexSpec | None = None, *, names=None,
                 seal_rows: int | None = None, materialize: bool = True):
        self.spec = (spec or IndexSpec()).validate()
        self.names = tuple(names) if names is not None else None
        self.seal_rows = seal_rows
        self.materialize = materialize
        self.segments: list[Segment] = []
        self._chunks: list[list[np.ndarray]] = []   # buffered per-append chunks
        self._buffered = 0
        self._n_cols: int | None = None
        self._closed = False

    # -- state -------------------------------------------------------------

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def buffered_rows(self) -> int:
        return self._buffered

    @property
    def n_rows(self) -> int:
        return self.sealed_rows + self._buffered

    @property
    def sealed_rows(self) -> int:
        return self.segments[-1].row_stop if self.segments else 0

    def buffer_columns(self) -> list:
        """The open buffer as per-column arrays (ingest order); [] when
        nothing is buffered."""
        if not self._chunks:
            return []
        return [np.concatenate([chunk[c] for chunk in self._chunks])
                for c in range(self._n_cols)]

    @property
    def index(self) -> SegmentedIndex:
        """The live query surface: sealed segments + the open buffer."""
        return SegmentedIndex(self.segments, names=self.names, writer=self)

    def size_words(self) -> int:
        return sum(s.size_words() for s in self.segments)

    # -- append ------------------------------------------------------------

    def append(self, rows) -> None:
        """Buffer a batch of rows in the open segment.

        ``rows`` is a list of per-column integer value-id arrays (the
        ``BitmapIndex.build`` table convention) or, when the writer carries
        ``names``, a dict mapping those names to arrays.  All columns must
        be equal length; column count is fixed by the first append.
        """
        if self._closed:
            raise ValueError("writer is closed; no further appends")
        if isinstance(rows, dict):
            if self.names is None:
                raise ValueError(
                    "dict appends need a writer built with names=...")
            missing = [c for c in self.names if c not in rows]
            if missing:
                raise ValueError(f"append missing columns: {missing}")
            rows = [rows[c] for c in self.names]
        chunk = [np.asarray(c) for c in rows]
        if not chunk:
            raise ValueError("append needs at least one column")
        n = len(chunk[0])
        if any(len(c) != n for c in chunk):
            raise ValueError("append columns must be equal length")
        if self._n_cols is None:
            self._n_cols = len(chunk)
        elif len(chunk) != self._n_cols:
            raise ValueError(
                f"append has {len(chunk)} columns, writer has {self._n_cols}")
        if n == 0:
            return
        self._chunks.append(chunk)
        self._buffered += n
        if self.seal_rows is not None and self._buffered >= self.seal_rows:
            self.seal()

    # -- seal --------------------------------------------------------------

    def seal(self) -> Segment | None:
        """Seal the word-aligned prefix of the open buffer into an
        immutable segment; the ``% 32`` tail rows stay buffered (they seal
        with the next segment, or with :meth:`close`).  Returns the new
        :class:`Segment`, or None when fewer than 32 rows are buffered."""
        if self._closed:
            raise ValueError("writer is closed")
        n_seal = (self._buffered // ewah.WORD_BITS) * ewah.WORD_BITS
        return self._seal_rows(n_seal) if n_seal else None

    def close(self) -> Segment | None:
        """Seal everything left in the buffer — the final segment may be
        non-word-aligned because nothing concatenates after it — and close
        the writer.  Returns the final segment (None if nothing buffered)."""
        if self._closed:
            raise ValueError("writer is already closed")
        seg = self._seal_rows(self._buffered) if self._buffered else None
        self._closed = True
        return seg

    def _seal_rows(self, n_seal: int) -> Segment:
        cols = self.buffer_columns()
        head = [c[:n_seal] for c in cols]
        tail = [c[n_seal:] for c in cols]
        seg = Segment.seal(head, self.spec, row_start=self.sealed_rows,
                           materialize=self.materialize)
        self.segments.append(seg)
        remaining = self._buffered - n_seal
        self._chunks = [tail] if remaining else []
        self._buffered = remaining
        return seg

    # -- compaction --------------------------------------------------------

    def compact(self, span: tuple | None = None, *, fanout: int = 4,
                ratio: float = 4.0) -> Segment | None:
        """Merge a run of adjacent segments into one re-sorted segment.

        ``span=(i, j)`` compacts ``segments[i:j]`` explicitly; without it
        the size-tiered policy (:func:`size_tiered_pick`) picks the first
        run of >= ``fanout`` adjacent segments whose compressed sizes are
        within ``ratio`` of each other (LSM size tiering, restricted to
        adjacent runs because segments must stay contiguous).  Retired
        segments' result-cache entries are evicted from every registered
        backend by generation scope; untouched segments keep theirs.
        Returns the merged segment, or None when no run qualifies.
        """
        if span is None:
            span = size_tiered_pick(self.segments, fanout=fanout, ratio=ratio)
            if span is None:
                return None
        i, j = span
        if not 0 <= i < j <= len(self.segments) or j - i < 2:
            raise ValueError(f"compaction span {span} must cover >= 2 "
                             f"segments of {len(self.segments)}")
        retired = self.segments[i:j]
        merged = compact(retired, self.spec, materialize=self.materialize)
        self.segments[i:j] = [merged]
        for seg in retired:
            invalidate_scope(seg.cache_scope)
        return merged


def compact(segments, spec: IndexSpec | None = None, *,
            materialize: bool = True) -> Segment:
    """Merge adjacent sealed segments into one re-sorted segment.

    Rows concatenate in original ingest order and the full pipeline
    (histogram refresh over the merged distribution, reordering, row sort)
    re-runs across the whole range — the merged segment compresses like a
    monolithic build over those rows.  Segments must cover contiguous row
    ranges (the writer's invariant); violations raise ValueError.
    """
    segments = list(segments)
    if len(segments) < 2:
        raise ValueError("compact needs at least 2 segments")
    for a, b in zip(segments, segments[1:]):
        if a.row_stop != b.row_start:
            raise ValueError(
                f"segments are not adjacent: [{a.row_start}, {a.row_stop}) "
                f"then [{b.row_start}, {b.row_stop})")
    if any(s.columns is None for s in segments):
        raise ValueError(
            "cannot compact segments sealed with keep_columns=False: their "
            "row store was dropped (dist fan-out shards are never compacted)")
    n_cols = len(segments[0].columns)
    if any(len(s.columns) != n_cols for s in segments):
        raise ValueError("segments disagree on column count")
    cols = [np.concatenate([s.columns[c] for s in segments])
            for c in range(n_cols)]
    return Segment.seal(cols, spec, row_start=segments[0].row_start,
                        materialize=materialize)


def size_tiered_pick(segments, fanout: int = 4, ratio: float = 4.0):
    """First run of >= ``fanout`` adjacent segments whose compressed sizes
    are within ``ratio`` of each other; returns ``(i, j)`` or None.

    Classic size tiering buckets segments by size wherever they live; here
    runs must be *adjacent* (segments stay contiguous row ranges), so the
    policy slides a window and fires on the first size-homogeneous run.
    """
    if fanout < 2:
        raise ValueError(f"fanout must be >= 2, got {fanout}")
    sizes = [max(s.size_words(), 1) for s in segments]
    for i in range(len(sizes) - fanout + 1):
        window = sizes[i : i + fanout]
        if max(window) <= ratio * min(window):
            j = i + fanout
            # greedily extend the tier while sizes stay homogeneous
            while j < len(sizes) and \
                    max(max(sizes[i:j + 1]), 1) <= ratio * min(sizes[i:j + 1]):
                j += 1
            return (i, j)
    return None
