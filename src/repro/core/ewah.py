"""EWAH (Enhanced Word-Aligned Hybrid) bitmap compression — numpy reference.

Format (paper Fig. 1, 32-bit words):
  * verbatim ("dirty") words: 32 literal bitmap bits;
  * marker words: bit 31 = clean type (0 -> 0x00000000 runs, 1 -> 0xFFFFFFFF
    runs), bits 30..15 = number of clean words (16 bits), bits 14..0 = number
    of verbatim words that follow the marker (15 bits).
  A compressed stream always begins with a marker word.

This module is the *oracle*: simple, obviously-correct numpy/python code that
the JAX implementation (``ewah_jax.py``) and the Pallas kernels are tested
against.  It is also used directly by the paper-table benchmarks, where the
numbers of interest are compressed sizes, not device throughput.
"""

from __future__ import annotations

import numpy as np

WORD_BITS = 32
FULL = np.uint32(0xFFFFFFFF)
MAX_CLEAN = (1 << 16) - 1  # per-marker clean-run capacity
MAX_DIRTY = (1 << 15) - 1  # per-marker verbatim-count capacity


def make_marker(clean_type: int, n_clean: int, n_dirty: int) -> int:
    assert 0 <= n_clean <= MAX_CLEAN and 0 <= n_dirty <= MAX_DIRTY
    return (int(clean_type) << 31) | (int(n_clean) << 15) | int(n_dirty)


def unpack_marker(word: int):
    word = int(word)
    return (word >> 31) & 1, (word >> 15) & 0xFFFF, word & 0x7FFF


def _emit_group(out: list, ctype: int, n_clean: int, dirty: np.ndarray) -> None:
    """Append markers + verbatim words for one (clean-run, dirty-run) group."""
    n_dirty = len(dirty)
    # clean overflow markers (no dirty words attached)
    while n_clean > MAX_CLEAN:
        out.append(make_marker(ctype, MAX_CLEAN, 0))
        n_clean -= MAX_CLEAN
    # first dirty chunk rides on the last clean marker
    chunk = min(n_dirty, MAX_DIRTY)
    out.append(make_marker(ctype, n_clean, chunk))
    out.extend(int(w) for w in dirty[:chunk])
    done = chunk
    while done < n_dirty:
        chunk = min(n_dirty - done, MAX_DIRTY)
        out.append(make_marker(0, 0, chunk))
        out.extend(int(w) for w in dirty[done : done + chunk])
        done += chunk


def compress(words: np.ndarray) -> np.ndarray:
    """Compress an array of uint32 bitmap words into an EWAH stream."""
    words = np.asarray(words, dtype=np.uint32)
    n = len(words)
    out: list[int] = []
    i = 0
    while i < n:
        ctype, n_clean = 0, 0
        if words[i] == 0 or words[i] == FULL:
            ctype = 1 if words[i] == FULL else 0
            pat = FULL if ctype else np.uint32(0)
            j = i
            while j < n and words[j] == pat:
                j += 1
            n_clean = j - i
            i = j
        j = i
        while j < n and words[j] != 0 and words[j] != FULL:
            j += 1
        _emit_group(out, ctype, n_clean, words[i:j])
        i = j
    return np.asarray(out, dtype=np.uint32)


def decompress(stream: np.ndarray, n_words: int | None = None) -> np.ndarray:
    """Expand an EWAH stream back into uint32 bitmap words."""
    stream = np.asarray(stream, dtype=np.uint32)
    out: list[int] = []
    i = 0
    while i < len(stream):
        ctype, n_clean, n_dirty = unpack_marker(stream[i])
        i += 1
        out.extend([0xFFFFFFFF if ctype else 0] * n_clean)
        out.extend(int(w) for w in stream[i : i + n_dirty])
        i += n_dirty
    arr = np.asarray(out, dtype=np.uint32)
    if n_words is not None:
        assert len(arr) == n_words, (len(arr), n_words)
    return arr


def compressed_size(words: np.ndarray) -> int:
    return len(compress(words))


# ---------------------------------------------------------------------------
# Streaming logical operations — now in ewah_stream (the public cursor /
# appender engine).  Lazy re-exports keep ``ewah.logical_op`` etc. working;
# the import is deferred because ewah_stream imports this module's
# primitives (PEP 562 module __getattr__, no cycle at load time).
# ---------------------------------------------------------------------------

_STREAM_COMPAT = {
    "_Cursor": "Cursor",
    "_Appender": "Appender",
    "logical_op": "logical_op",
    "logical_many": "logical_many",
    "logical_not": "logical_not",
    "concat_streams": "concat_streams",
    "EwahStream": "EwahStream",
}


def __getattr__(name: str):
    if name in _STREAM_COMPAT:
        from . import ewah_stream

        return getattr(ewah_stream, _STREAM_COMPAT[name])
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# ---------------------------------------------------------------------------
# Bit/word helpers shared by tests and benchmarks.
# ---------------------------------------------------------------------------


def pack_bits(bits: np.ndarray) -> np.ndarray:
    """Pack a boolean vector (len multiple-of-32 padded) into uint32 words.

    Bit j of word i corresponds to row 32*i + j (little-endian within word).
    """
    bits = np.asarray(bits, dtype=bool)
    n = len(bits)
    n_words = (n + WORD_BITS - 1) // WORD_BITS
    padded = np.zeros(n_words * WORD_BITS, dtype=bool)
    padded[:n] = bits
    m = padded.reshape(n_words, WORD_BITS).astype(np.uint32)
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    return (m << shifts).sum(axis=1, dtype=np.uint32)


def unpack_bits(words: np.ndarray, n: int | None = None) -> np.ndarray:
    words = np.asarray(words, dtype=np.uint32)
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    bits = ((words[:, None] >> shifts) & 1).astype(bool).reshape(-1)
    return bits if n is None else bits[:n]


def positions_to_words(positions: np.ndarray, n_rows: int) -> np.ndarray:
    """Sorted 1-bit row positions -> packed uint32 words (sparse friendly)."""
    n_words = (n_rows + WORD_BITS - 1) // WORD_BITS
    words = np.zeros(n_words, dtype=np.uint32)
    positions = np.asarray(positions, dtype=np.int64)
    np.bitwise_or.at(
        words, positions // WORD_BITS, (np.uint32(1) << (positions % WORD_BITS).astype(np.uint32))
    )
    return words
