"""EWAH (Enhanced Word-Aligned Hybrid) bitmap compression — numpy reference.

Format (paper Fig. 1, 32-bit words):
  * verbatim ("dirty") words: 32 literal bitmap bits;
  * marker words: bit 31 = clean type (0 -> 0x00000000 runs, 1 -> 0xFFFFFFFF
    runs), bits 30..15 = number of clean words (16 bits), bits 14..0 = number
    of verbatim words that follow the marker (15 bits).
  A compressed stream always begins with a marker word.

This module is the *oracle*: simple, obviously-correct numpy/python code that
the JAX implementation (``ewah_jax.py``) and the Pallas kernels are tested
against.  It is also used directly by the paper-table benchmarks, where the
numbers of interest are compressed sizes, not device throughput.
"""

from __future__ import annotations

import numpy as np

WORD_BITS = 32
FULL = np.uint32(0xFFFFFFFF)
MAX_CLEAN = (1 << 16) - 1  # per-marker clean-run capacity
MAX_DIRTY = (1 << 15) - 1  # per-marker verbatim-count capacity


def make_marker(clean_type: int, n_clean: int, n_dirty: int) -> int:
    assert 0 <= n_clean <= MAX_CLEAN and 0 <= n_dirty <= MAX_DIRTY
    return (int(clean_type) << 31) | (int(n_clean) << 15) | int(n_dirty)


def unpack_marker(word: int):
    word = int(word)
    return (word >> 31) & 1, (word >> 15) & 0xFFFF, word & 0x7FFF


def _emit_group(out: list, ctype: int, n_clean: int, dirty: np.ndarray) -> None:
    """Append markers + verbatim words for one (clean-run, dirty-run) group."""
    n_dirty = len(dirty)
    # clean overflow markers (no dirty words attached)
    while n_clean > MAX_CLEAN:
        out.append(make_marker(ctype, MAX_CLEAN, 0))
        n_clean -= MAX_CLEAN
    # first dirty chunk rides on the last clean marker
    chunk = min(n_dirty, MAX_DIRTY)
    out.append(make_marker(ctype, n_clean, chunk))
    out.extend(int(w) for w in dirty[:chunk])
    done = chunk
    while done < n_dirty:
        chunk = min(n_dirty - done, MAX_DIRTY)
        out.append(make_marker(0, 0, chunk))
        out.extend(int(w) for w in dirty[done : done + chunk])
        done += chunk


def compress(words: np.ndarray) -> np.ndarray:
    """Compress an array of uint32 bitmap words into an EWAH stream."""
    words = np.asarray(words, dtype=np.uint32)
    n = len(words)
    out: list[int] = []
    i = 0
    while i < n:
        ctype, n_clean = 0, 0
        if words[i] == 0 or words[i] == FULL:
            ctype = 1 if words[i] == FULL else 0
            pat = FULL if ctype else np.uint32(0)
            j = i
            while j < n and words[j] == pat:
                j += 1
            n_clean = j - i
            i = j
        j = i
        while j < n and words[j] != 0 and words[j] != FULL:
            j += 1
        _emit_group(out, ctype, n_clean, words[i:j])
        i = j
    return np.asarray(out, dtype=np.uint32)


def decompress(stream: np.ndarray, n_words: int | None = None) -> np.ndarray:
    """Expand an EWAH stream back into uint32 bitmap words."""
    stream = np.asarray(stream, dtype=np.uint32)
    out: list[int] = []
    i = 0
    while i < len(stream):
        ctype, n_clean, n_dirty = unpack_marker(stream[i])
        i += 1
        out.extend([0xFFFFFFFF if ctype else 0] * n_clean)
        out.extend(int(w) for w in stream[i : i + n_dirty])
        i += n_dirty
    arr = np.asarray(out, dtype=np.uint32)
    if n_words is not None:
        assert len(arr) == n_words, (len(arr), n_words)
    return arr


def compressed_size(words: np.ndarray) -> int:
    return len(compress(words))


# ---------------------------------------------------------------------------
# Streaming logical operations (compressed domain, O(|A| + |B|)).
# ---------------------------------------------------------------------------


class _Cursor:
    """Iterates a compressed stream as (clean_rem, ctype, dirty_rem) runs."""

    __slots__ = ("s", "i", "clean_rem", "ctype", "dirty_rem", "scanned")

    def __init__(self, stream: np.ndarray):
        self.s = np.asarray(stream, dtype=np.uint32)
        self.i = 0
        self.clean_rem = 0
        self.ctype = 0
        self.dirty_rem = 0
        self.scanned = 0
        self._load()

    def _load(self) -> None:
        while (
            self.clean_rem == 0
            and self.dirty_rem == 0
            and self.i < len(self.s)
        ):
            self.ctype, self.clean_rem, self.dirty_rem = unpack_marker(self.s[self.i])
            self.i += 1
            self.scanned += 1

    def exhausted(self) -> bool:
        return self.clean_rem == 0 and self.dirty_rem == 0 and self.i >= len(self.s)

    def take_clean(self, n: int) -> None:
        self.clean_rem -= n
        self._load()

    def take_dirty(self) -> int:
        w = int(self.s[self.i])
        self.i += 1
        self.scanned += 1
        self.dirty_rem -= 1
        self._load()
        return w

    def skip_dirty(self, n: int) -> None:
        self.i += n
        self.scanned += n
        self.dirty_rem -= n
        self._load()


class _Appender:
    """Re-compresses a stream of words/runs fed to it."""

    def __init__(self):
        self.out: list[int] = []
        self.ctype = 0
        self.n_clean = 0
        self.dirty: list[int] = []

    def _flush(self) -> None:
        if self.n_clean or self.dirty:
            _emit_group(self.out, self.ctype, self.n_clean, np.asarray(self.dirty, dtype=np.uint32))
            self.ctype, self.n_clean, self.dirty = 0, 0, []

    def add_clean(self, ctype: int, n: int) -> None:
        if n == 0:
            return
        if self.dirty or (self.n_clean and self.ctype != ctype):
            self._flush()
        self.ctype = ctype
        self.n_clean += n

    def add_word(self, w: int) -> None:
        if w == 0:
            self.add_clean(0, 1)
        elif w == 0xFFFFFFFF:
            self.add_clean(1, 1)
        else:
            self.dirty.append(w)

    def finish(self) -> np.ndarray:
        self._flush()
        if not self.out:
            self.out.append(make_marker(0, 0, 0))
        return np.asarray(self.out, dtype=np.uint32)


_OPS = {
    "and": lambda a, b: a & b,
    "or": lambda a, b: a | b,
    "xor": lambda a, b: a ^ b,
}
# (op, clean_type) -> clean run dominates (result is clean of known type)
_DOMINATES = {("and", 0): 0, ("or", 1): 1}


def logical_op(a: np.ndarray, b: np.ndarray, op: str = "and"):
    """Streaming merge of two EWAH streams; returns (stream, words_scanned).

    Never decompresses: runs are consumed run-at-a-time so the work is
    O(|a| + |b|) in *compressed* words (the paper's Section 3 claim).
    """
    fn = _OPS[op]
    ca, cb = _Cursor(a), _Cursor(b)
    res = _Appender()
    while not ca.exhausted() and not cb.exhausted():
        if ca.clean_rem and cb.clean_rem:
            n = min(ca.clean_rem, cb.clean_rem)
            ta = fn(ca.ctype, cb.ctype) & 1
            res.add_clean(ta, n)
            ca.take_clean(n)
            cb.take_clean(n)
        elif ca.clean_rem or cb.clean_rem:
            clean, other = (ca, cb) if ca.clean_rem else (cb, ca)
            n = min(clean.clean_rem, other.dirty_rem)
            dom = _DOMINATES.get((op, clean.ctype))
            if dom is not None:
                res.add_clean(dom, n)
                other.skip_dirty(n)
            else:
                pat = 0xFFFFFFFF if clean.ctype else 0
                for _ in range(n):
                    res.add_word(fn(other.take_dirty(), pat) & 0xFFFFFFFF)
            clean.take_clean(n)
        else:  # both dirty
            n = min(ca.dirty_rem, cb.dirty_rem)
            for _ in range(n):
                res.add_word(fn(ca.take_dirty(), cb.take_dirty()) & 0xFFFFFFFF)
    # tail: the paper's bitmaps all have equal (uncompressed) length; if one
    # stream ends early the remainder ops against implicit zeros.
    for tail in (ca, cb):
        while not tail.exhausted():
            if tail.clean_rem:
                n = tail.clean_rem
                t = fn(tail.ctype, 0) & 1
                res.add_clean(t, n)
                tail.take_clean(n)
            else:
                w = tail.take_dirty()
                res.add_word(fn(w, 0) & 0xFFFFFFFF)
    return res.finish(), ca.scanned + cb.scanned


def logical_many(streams, op: str = "and"):
    """Fold ``op`` over many compressed bitmaps; returns (stream, scanned)."""
    assert streams
    acc = streams[0]
    total = 0
    for s in streams[1:]:
        acc, scanned = logical_op(acc, s, op)
        total += scanned
    return acc, total


# ---------------------------------------------------------------------------
# Bit/word helpers shared by tests and benchmarks.
# ---------------------------------------------------------------------------


def pack_bits(bits: np.ndarray) -> np.ndarray:
    """Pack a boolean vector (len multiple-of-32 padded) into uint32 words.

    Bit j of word i corresponds to row 32*i + j (little-endian within word).
    """
    bits = np.asarray(bits, dtype=bool)
    n = len(bits)
    n_words = (n + WORD_BITS - 1) // WORD_BITS
    padded = np.zeros(n_words * WORD_BITS, dtype=bool)
    padded[:n] = bits
    m = padded.reshape(n_words, WORD_BITS).astype(np.uint32)
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    return (m << shifts).sum(axis=1, dtype=np.uint32)


def unpack_bits(words: np.ndarray, n: int | None = None) -> np.ndarray:
    words = np.asarray(words, dtype=np.uint32)
    shifts = np.arange(WORD_BITS, dtype=np.uint32)
    bits = ((words[:, None] >> shifts) & 1).astype(bool).reshape(-1)
    return bits if n is None else bits[:n]


def positions_to_words(positions: np.ndarray, n_rows: int) -> np.ndarray:
    """Sorted 1-bit row positions -> packed uint32 words (sparse friendly)."""
    n_words = (n_rows + WORD_BITS - 1) // WORD_BITS
    words = np.zeros(n_words, dtype=np.uint32)
    positions = np.asarray(positions, dtype=np.int64)
    np.bitwise_or.at(
        words, positions // WORD_BITS, (np.uint32(1) << (positions % WORD_BITS).astype(np.uint32))
    )
    return words
