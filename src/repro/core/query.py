"""Predicate algebra, query planner, and pluggable execution backends.

The one query path from predicate to row ids::

    Eq / In / Range / And / Or / Not          (algebra, column = original id)
        -> compile_plan(index, pred)          (cost-ordered EWAH op tree)
        -> get_backend("numpy" | "jax")       (execution strategy)
        -> (row_ids, words_scanned)

Planner.  A predicate compiles against a materialized ``BitmapIndex`` into a
tree over *leaf* EWAH streams: ``Eq`` on a k-of-N column is an AND fan-in of
its k bitmaps, ``In``/``Range`` are OR fan-ins of those, and nested same-op
nodes are flattened, so ``And(Eq, Eq)`` at k=2 becomes a single 4-stream AND
fan-in.  Fan-in children are ordered smallest-estimated-size-first (leaf cost
= compressed stream length), the paper's smallest-streams-first fold.

Backends (pluggable via :func:`register_backend`):

* ``numpy`` — compressed-domain streaming merges (``ewah_stream``
  cursor/appender engine), never decompressing intermediates;
  ``words_scanned`` counts compressed words the cursors actually visited
  (the paper's machine-independent cost).
* ``jax``  — batched in-graph execution: leaf streams are padded to a
  capacity bucket, decompressed with ``ewah_jax.decompress`` (vmapped over
  queries x leaves), and fan-ins fold in word space through the Pallas
  word-op kernel (``kernels.ops.wordops_fold``), many queries per dispatch.
  ``words_scanned`` is the total compressed leaf words read.

Each backend exposes two result surfaces:

* ``execute(plan) -> (row_ids, words_scanned)`` — the row-id path;
* ``execute_compressed(plan) -> EwahStream`` — compressed in, compressed
  out: the result stays an EWAH stream (``Not`` by marker-type flipping on
  numpy, in-graph recompression through the Pallas classify/run-start
  kernel on jax), backed by an LRU result cache keyed by the canonical
  plan root with content-digested leaves, so cascaded / overlapping
  predicates reuse sub-plan results.

Backends agree bit-for-bit; tests assert it (tests/test_query_plane.py,
tests/test_compressed_engine.py).
"""

from __future__ import annotations

import hashlib
import json
import weakref
from collections import OrderedDict
from dataclasses import dataclass
from functools import lru_cache

import numpy as np

from . import ewah, ewah_stream
from .ewah_stream import EwahStream
from ..analysis.runtime import make_lock, maybe_validate

# ---------------------------------------------------------------------------
# Predicate algebra
# ---------------------------------------------------------------------------


class Predicate:
    """Base class; supports ``&``, ``|``, ``~`` sugar."""

    __slots__ = ()

    def __and__(self, other):
        return And(self, other)

    def __or__(self, other):
        return Or(self, other)

    def __invert__(self):
        return Not(self)


class Eq(Predicate):
    """column == value.  ``col`` is the *original* table column (int
    position or, when the index carries names, a column name)."""

    __slots__ = ("col", "value")

    def __init__(self, col, value):
        self.col = col
        self.value = int(value)

    def __repr__(self):
        return f"Eq({self.col!r}, {self.value})"


class In(Predicate):
    """column in values (OR of equalities)."""

    __slots__ = ("col", "values")

    def __init__(self, col, values):
        self.col = col
        self.values = tuple(int(v) for v in values)

    def __repr__(self):
        return f"In({self.col!r}, {self.values})"


class Range(Predicate):
    """lo <= column <= hi over dense value ids (both ends inclusive)."""

    __slots__ = ("col", "lo", "hi")

    def __init__(self, col, lo, hi):
        self.col = col
        self.lo = int(lo)
        self.hi = int(hi)

    def __repr__(self):
        return f"Range({self.col!r}, {self.lo}, {self.hi})"


class And(Predicate):
    __slots__ = ("children",)

    def __init__(self, *children):
        if not children:
            raise ValueError("And() needs at least one child predicate")
        self.children = tuple(children)

    def __repr__(self):
        return f"And{self.children!r}"


class Or(Predicate):
    __slots__ = ("children",)

    def __init__(self, *children):
        if not children:
            raise ValueError("Or() needs at least one child predicate")
        self.children = tuple(children)

    def __repr__(self):
        return f"Or{self.children!r}"


class Not(Predicate):
    __slots__ = ("child",)

    def __init__(self, child):
        self.child = child

    def __repr__(self):
        return f"Not({self.child!r})"


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------
#
# Node encodings (nested tuples, hashable for jit caching):
#   ("leaf", i)                 -> plan.streams[i]
#   ("not", child)              -> complement (XOR with all-ones)
#   ("and"|"or", (children...)) -> fan-in, children cost-ordered
#   ("fold", ops, (children...))-> sequential left fold with a per-step op
#                                  (ops[i] combines the running result with
#                                  children[i + 1]); child order is
#                                  SEMANTIC — the bit-sliced comparison
#                                  circuit — so it is never cost-reordered
#   ("cfold", ops, (cids...), est) -> left fold over plan.containers[cid]
#                                  Roaring container sets (core/containers);
#                                  est is the estimated compressed word cost.
#                                  Backends replace every cfold with a
#                                  canonical-EWAH leaf via lower_containers()
#                                  BEFORE stream evaluation, so caches,
#                                  tombstone ANDs, fan-out, and sanitizers
#                                  only ever see leaf streams

# The closed set of plan-node kinds.  Every backend must dispatch on all
# of these (repro.analysis enforces it: `backend/missing-kind`), and any
# new kind constructed below must be added here (`backend/undeclared-kind`)
# *and* handled by every backend before it ships.
PLAN_NODE_KINDS = ("leaf", "not", "and", "or", "fold", "cfold")


@dataclass
class Plan:
    """A compiled, cost-ordered op tree over leaf EWAH streams.

    ``scope`` tags every result this plan lands in a backend cache with the
    source index's ``cache_scope`` (segments set ``("segment", generation)``)
    so :func:`invalidate_scope` can evict exactly one retired segment's
    entries; None means unscoped (only content-digest staleness protection).
    """

    streams: list
    root: tuple
    n_rows: int
    scope: tuple | None = None
    # Roaring container sets referenced by ("cfold", ...) nodes; None once
    # lower_containers() has rewritten every cfold into a leaf stream.
    containers: list | None = None
    # per-predicate telemetry events (column, shape, width, encoding,
    # merges) — what WorkloadStats aggregates into cost-model samples
    workload: tuple = ()

    @property
    def n_words(self) -> int:
        return (self.n_rows + ewah.WORD_BITS - 1) // ewah.WORD_BITS

    def leaf_words(self) -> int:
        """Total compressed words across leaves (the jax-backend scan cost)."""
        return int(sum(len(s) for s in self.streams))

    def signature(self) -> tuple:
        """Structural shape (ops + leaf placeholders).  ``compile_plan``
        renumbers leaves in tree-traversal order, so two compiled plans with
        equal signatures have *identical* roots and can batch into one padded
        device dispatch."""
        return _sig(self.root)


def _sig(node):
    kind = node[0]
    if kind == "leaf":
        return ("L",)
    if kind == "not":
        return ("not", _sig(node[1]))
    if kind == "fold":
        return ("fold", node[1], tuple(_sig(c) for c in node[2]))
    if kind == "cfold":
        # container ids are per-plan positions (like leaf numbering), so
        # the structural shape is the op list + fan-in width
        return ("cfold", node[1], len(node[2]))
    return (kind, tuple(_sig(c) for c in node[1]))


def count_merges(node) -> int:
    """Binary stream merges (including ``not`` marker flips) a plan node
    executes — the machine-independent cost the encoding benchmarks and
    the bit-sliced merge-bound acceptance tests gate on.  Walks every node
    kind, so it is the one place to extend when a new kind lands."""
    kind = node[0]
    if kind == "leaf":
        return 0
    if kind == "not":
        return 1 + count_merges(node[1])
    if kind == "fold":
        return len(node[2]) - 1 + sum(count_merges(c) for c in node[2])
    if kind == "cfold":
        # container-wise merges inside the fold, plus nothing per leaf —
        # the lowered EWAH bridge is accounted as part of the fold
        return max(len(node[2]) - 1, 0)
    if kind not in ("and", "or"):
        raise ValueError(f"unknown plan-node kind {kind!r}")
    return len(node[1]) - 1 + sum(count_merges(c) for c in node[1])


# Instruction-tape opcodes, mirrored from kernels/planfuse.py (kept as
# plain ints here so the numpy-only import path never pulls jax;
# tests/test_planfuse.py asserts the two definitions agree).
TAPE_PUSH, TAPE_NOT, TAPE_OP = 0, 1, 2
_TAPE_OP_IDS = {"and": 0, "or": 1, "xor": 2}


def lower_plan(root) -> tuple:
    """Linearize a plan op tree into the static stack-machine tape the
    Pallas megakernel interprets (kernels/planfuse.py); returns
    ``(tape, max_depth)``.

    Instructions are ``(opcode, arg)`` int pairs: ``(TAPE_PUSH, i)``
    pushes leaf plane ``i`` onto the operand stack, ``(TAPE_NOT, 0)``
    complements the top of stack, and ``(TAPE_OP, k)`` pops two operands
    and pushes their combination (k: 0=and, 1=or, 2=xor).  Fan-ins lower
    to left folds and ``fold`` children keep their semantic bit order, so
    the tape visits leaves exactly in the planner's canonical
    tree-traversal numbering and evaluates to the same result as the
    per-stage recursion.  ``max_depth`` is the operand stack's peak — the
    megakernel's live-register high-water mark, which the VMEM fallback
    gate prices (``planfuse.fits_vmem``).
    """
    tape: list = []

    def rec(node):
        kind = node[0]
        if kind == "leaf":
            tape.append((TAPE_PUSH, node[1]))
            return
        if kind == "not":
            rec(node[1])
            tape.append((TAPE_NOT, 0))
            return
        if kind == "fold":
            _, fops, children = node
            rec(children[0])
            for op, child in zip(fops, children[1:]):
                rec(child)
                tape.append((TAPE_OP, _TAPE_OP_IDS[op]))
            return
        if kind == "cfold":
            raise ValueError(
                "container fold nodes cannot lower to the megakernel tape; "
                "lower_containers() must replace them with leaves first")
        if kind not in ("and", "or"):
            raise ValueError(f"unknown plan-node kind {kind!r}")
        children = node[1]
        rec(children[0])
        for child in children[1:]:
            rec(child)
            tape.append((TAPE_OP, _TAPE_OP_IDS[kind]))

    rec(root)
    depth = max_depth = 0
    for opcode, _ in tape:
        if opcode == TAPE_PUSH:
            depth += 1
            max_depth = max(max_depth, depth)
        elif opcode == TAPE_OP:
            depth -= 1
    assert depth == 1, f"tape leaves {depth} operands on the stack"
    return tuple(tape), max_depth


class PlanStats:
    """Observed plan-shape distribution -> autotuned jax capacity buckets.

    The planner feeds it: :func:`compile_plan` records every compiled
    plan's max leaf stream length — the quantity the jax backend pads to
    when batching.  Until :meth:`autotune` derives boundaries (or
    :meth:`load` restores a previous run's), :meth:`capacity_for` falls
    back to power-of-two buckets, so cold processes behave exactly as
    before.  Boundaries are quantiles of the observed distribution
    rounded up to a multiple of 8: buckets hug the live workload instead
    of doubling (less padding per dispatch), while ``max_buckets`` caps
    how many jit variants a shifting query mix can create.
    :meth:`save`/:meth:`load` persist boundaries plus a sample tail, so a
    restarted server warms up with last run's buckets and keeps refining
    them (``serve --plan-stats``).

    Thread-safe: serving records from worker threads while autotune runs
    wherever the operator calls it.
    """

    MAX_SAMPLES = 8192

    def __init__(self):
        self._mutex = make_lock("plan_stats")
        self._max_lens: list = []      # guarded-by: _mutex
        self._boundaries: tuple = ()   # guarded-by: _mutex
        self.recorded = 0              # guarded-by: _mutex

    def record(self, plan) -> None:
        if not plan.streams:
            return
        ml = max(len(s) for s in plan.streams)
        with self._mutex:
            self.recorded += 1
            self._max_lens.append(int(ml))
            if len(self._max_lens) > self.MAX_SAMPLES:
                # keep the newest half: bounded memory, recency-weighted
                self._max_lens = self._max_lens[self.MAX_SAMPLES // 2:]

    def autotune(self, max_buckets: int = 8) -> tuple:
        """Derive bucket boundaries (at most ``max_buckets``) from the
        recorded distribution's quantiles; returns the new boundaries
        (unchanged when nothing was recorded)."""
        with self._mutex:
            lens = sorted(self._max_lens)
            if not lens:
                return self._boundaries
            qs = [lens[min(len(lens) - 1, (i * len(lens)) // max_buckets)]
                  for i in range(1, max_buckets + 1)]
            self._boundaries = tuple(sorted({-(-q // 8) * 8 for q in qs}))
            return self._boundaries

    @property
    def boundaries(self) -> tuple:
        with self._mutex:
            return self._boundaries

    def capacity_for(self, n: int) -> int:
        """Smallest autotuned bucket holding ``n`` stream words; plans
        past the largest boundary use the power-of-two fallback (they are
        the tail the quantiles deliberately don't chase)."""
        with self._mutex:
            bounds = self._boundaries
        for b in bounds:
            if n <= b:
                return b
        return _capacity_bucket(n)

    def stats(self) -> dict:
        with self._mutex:
            return {"recorded": self.recorded,
                    "samples": len(self._max_lens),
                    "boundaries": list(self._boundaries)}

    def save(self, path) -> None:
        with self._mutex:
            payload = {"boundaries": list(self._boundaries),
                       "recorded": self.recorded,
                       "max_lens": self._max_lens[-1024:]}
        with open(path, "w") as fh:
            json.dump(payload, fh)

    def load(self, path) -> bool:
        """Restore persisted boundaries (+ sample tail); returns False
        when the file is missing or unreadable — a cold start, not an
        error."""
        try:
            with open(path) as fh:
                payload = json.load(fh)
        except (OSError, ValueError):
            return False
        with self._mutex:
            self._boundaries = tuple(
                int(b) for b in payload.get("boundaries", []))
            self._max_lens = [int(x) for x in payload.get("max_lens", [])]
        return True


#: Process-wide recorder every ``compile_plan`` feeds and the jax
#: backend's batch grouping reads.  serve --plan-stats persists it.
PLAN_STATS = PlanStats()


@lru_cache(maxsize=32)
def _ones_stream(n_rows: int) -> np.ndarray:
    n_words = (n_rows + ewah.WORD_BITS - 1) // ewah.WORD_BITS
    return ewah.compress(np.full(n_words, ewah.FULL, dtype=np.uint32))


@lru_cache(maxsize=32)
def _zero_stream(n_rows: int) -> np.ndarray:
    n_words = (n_rows + ewah.WORD_BITS - 1) // ewah.WORD_BITS
    return ewah.compress(np.zeros(n_words, dtype=np.uint32))


class PlanContext:
    """What a :class:`~repro.core.encodings.ColumnEncoding` compiles
    against: leaf/container registration plus the constant-result
    streams."""

    __slots__ = ("streams", "n_rows", "containers")

    def __init__(self, n_rows: int):
        self.streams: list = []
        self.containers: list = []
        self.n_rows = n_rows

    def leaf(self, stream) -> tuple:
        self.streams.append(stream)
        return ("leaf", len(self.streams) - 1)

    def container(self, cset) -> int:
        """Register a Roaring container set; returns its cid for a
        ``("cfold", ...)`` node."""
        self.containers.append(cset)
        return len(self.containers) - 1

    def zero(self) -> tuple:
        """Constant-empty leaf (out-of-domain value, empty range)."""
        return self.leaf(_zero_stream(self.n_rows))

    def ones(self) -> tuple:
        """Constant-full leaf (whole-domain range)."""
        return self.leaf(_ones_stream(self.n_rows))


def compile_plan(index, pred: Predicate, names=None) -> Plan:
    """Compile ``pred`` against a materialized ``BitmapIndex``.

    Predicate columns are *original* table positions (pre column-reorder);
    ``names`` optionally maps string column names to those positions.
    Returned row ids live in the index's reordered row space — map back with
    ``index.row_perm[row_ids]``.

    The planner owns the generic steps — name resolution, domain clamping
    (out-of-domain ``Eq``/empty ``Range`` compile to a constant-empty leaf,
    whole-domain to constant-full), fan-in flattening and cost ordering —
    and delegates ``Eq``/``In``/``Range`` on each column to that column's
    :class:`~repro.core.encodings.ColumnEncoding` (equality k-of-N fan-ins,
    bit-sliced comparison folds, or binned coarse-plus-refinement).
    """
    col_perm = np.asarray(index.col_perm)
    inv = np.empty(len(col_perm), dtype=np.int64)
    inv[col_perm] = np.arange(len(col_perm))
    ctx = PlanContext(index.n_rows)

    events: list = []

    def resolve(col):
        if isinstance(col, str):
            if names is None:
                raise ValueError(
                    f"predicate references column {col!r} by name but the "
                    "index has no column names (pass names=...)")
            try:
                col = list(names).index(col)
            except ValueError:
                raise ValueError(
                    f"unknown column {col!r}; known: {', '.join(names)}"
                ) from None
        col = int(col)
        if not 0 <= col < len(col_perm):
            raise ValueError(f"column {col} out of range (0..{len(col_perm) - 1})")
        ci = index.columns[int(inv[col])]
        if ci.streams is None:
            raise ValueError("index built with materialize=False cannot be queried")
        return col, ci.encoding

    def record(col, shape, width, enc, node):
        events.append((col, shape, width, enc.kind, count_merges(node)))
        return node

    def build(p) -> tuple:
        if isinstance(p, Eq):
            col, enc = resolve(p.col)
            if not 0 <= p.value < enc.card:
                return ctx.zero()  # out-of-domain: no rows
            return record(col, "eq", 1, enc, enc.compile_eq(ctx, p.value))
        if isinstance(p, In):
            col, enc = resolve(p.col)
            values = sorted({v for v in p.values if 0 <= v < enc.card})
            if not values:
                return ctx.zero()
            if len(values) == enc.card:
                return ctx.ones()  # every row holds some in-domain value
            return record(col, "in", len(values), enc,
                          enc.compile_in(ctx, values))
        if isinstance(p, Range):
            # clamp to the column domain before any value materializes —
            # Range(col, 0, 10**9) must not iterate a billion values
            col, enc = resolve(p.col)
            lo, hi = max(p.lo, 0), min(p.hi, enc.card - 1)
            if lo > hi:
                return ctx.zero()
            if lo == 0 and hi == enc.card - 1:
                return ctx.ones()
            return record(col, "range", hi - lo + 1, enc,
                          enc.compile_range(ctx, lo, hi))
        if isinstance(p, And):
            return _fanin("and", [build(c) for c in p.children])
        if isinstance(p, Or):
            return _fanin("or", [build(c) for c in p.children])
        if isinstance(p, Not):
            return ("not", build(p.child))
        raise TypeError(f"not a Predicate: {p!r}")

    plan = Plan(streams=ctx.streams, root=build(pred), n_rows=index.n_rows,
                scope=getattr(index, "cache_scope", None),
                containers=ctx.containers or None, workload=tuple(events))
    plan.root = _cost_order(plan.root, plan.streams, plan.n_words)
    _renumber_leaves(plan)
    PLAN_STATS.record(plan)
    _WORKLOAD.record(plan.workload)
    return plan


def evaluate_mask(pred: Predicate, columns, names=None) -> np.ndarray:
    """Evaluate a predicate directly over uncompressed integer columns.

    ``columns`` is the usual per-column array list in **original** order (no
    index, no reordering); returns an (n,) boolean row mask.  This is the
    open-buffer path of :class:`~repro.core.segment.SegmentedIndex` — rows a
    writer has appended but not yet sealed evaluate densely — and doubles
    as the oracle the compressed paths are tested against.
    """
    columns = [np.asarray(c) for c in columns]

    def resolve(col):
        if isinstance(col, str):
            if names is None:
                raise ValueError(
                    f"predicate references column {col!r} by name but no "
                    "names were given")
            try:
                return columns[list(names).index(col)]
            except ValueError:
                raise ValueError(
                    f"unknown column {col!r}; known: {', '.join(names)}"
                ) from None
        col = int(col)
        if not 0 <= col < len(columns):
            raise ValueError(f"column {col} out of range (0..{len(columns) - 1})")
        return columns[col]

    def rec(p) -> np.ndarray:
        if isinstance(p, Eq):
            return resolve(p.col) == p.value
        if isinstance(p, In):
            return np.isin(resolve(p.col), np.asarray(p.values, dtype=np.int64))
        if isinstance(p, Range):
            c = resolve(p.col)
            return (c >= p.lo) & (c <= p.hi)
        if isinstance(p, And):
            m = rec(p.children[0])
            for child in p.children[1:]:
                m = m & rec(child)
            return m
        if isinstance(p, Or):
            m = rec(p.children[0])
            for child in p.children[1:]:
                m = m | rec(child)
            return m
        if isinstance(p, Not):
            return ~rec(p.child)
        raise TypeError(f"not a Predicate: {p!r}")

    return rec(pred)


def _renumber_leaves(plan: Plan) -> None:
    """Renumber leaves in tree-traversal order and permute ``plan.streams``
    to match.  Cost-ordering permutes leaves per-plan, so without this two
    plans of equal structural signature could assign leaf indices to
    different tree positions — and the jax backend, which compiles one
    program per batch group, would evaluate every non-first plan with the
    wrong leaf-to-stream mapping.  After canonicalization, equal signature
    implies an identical root tuple."""
    order: list = []

    def rec(nd):
        if nd[0] == "leaf":
            order.append(nd[1])
            return ("leaf", len(order) - 1)
        if nd[0] == "not":
            return ("not", rec(nd[1]))
        if nd[0] == "fold":
            return ("fold", nd[1], tuple(rec(c) for c in nd[2]))
        if nd[0] == "cfold":
            return nd  # container ids index plan.containers, not streams
        return (nd[0], tuple(rec(c) for c in nd[1]))

    plan.root = rec(plan.root)
    plan.streams = [plan.streams[i] for i in order]


def with_live_mask(plan: Plan, live) -> Plan:
    """AND a segment's live-row stream into a compiled plan root, in place.

    This is the implicit AND-NOT-tombstones rule (docs/query_api.md): a
    segment with tombstones hands the planner the *complement* of its
    tombstone bitmap — computed once at delete time via marker-flip
    ``logical_not``, not per query — so a delete costs exactly **one**
    extra merge per segment at query time (``count_merges`` +1; an
    ``AND(root, NOT(tombstones))`` shape would count two).

    The original root is kept as an interior node (the new AND is *not*
    flattened into an existing root fan-in): backends that memoize interior
    results keep their sub-plan cache hits across deletes, and only the
    final AND recomputes when the tombstone set changes.  Leaves are
    re-canonicalized so equal-signature plans still batch into one padded
    jax dispatch.
    """
    if live is None:
        return plan
    plan.streams.append(np.asarray(live, dtype=np.uint32))
    plan.root = ("and", (plan.root, ("leaf", len(plan.streams) - 1)))
    _renumber_leaves(plan)
    return plan


def lower_containers(plan: Plan, fold, cache=None) -> Plan:
    """Rewrite every ``("cfold", ops, cids, est)`` node into a ``("leaf",
    i)`` over its evaluated canonical EWAH stream, in place.

    ``fold(csets, ops, n_rows) -> np.uint32`` is the backend's container
    evaluator (numpy streaming merges or batched Pallas launches — both
    must produce the same canonical stream).  This is the one bridge out
    of container space: after it runs, the plan holds only the closed
    stream-node set, so result caching, tombstone ANDs, fan-out shipping,
    and the sanitizers are untouched by the container engine.  Lowered
    fold results are memoized in ``cache`` (a :class:`ResultCache`) under
    content digests of the container sets, scoped like any other entry.
    No-op for plans without containers.
    """
    if not plan.containers:
        return plan
    from .containers import digest as _container_digest

    digests: dict = {}

    def cdig(i):
        if i not in digests:
            digests[i] = _container_digest(plan.containers[i])
        return digests[i]

    def rec(nd):
        kind = nd[0]
        if kind == "leaf":
            return nd
        if kind == "cfold":
            _, fops, cids, _est = nd
            key = (plan.n_rows, "cfold", fops,
                   tuple(cdig(i) for i in cids))
            stream = cache.get(key) if cache is not None else None
            if stream is None:
                stream = fold([plan.containers[i] for i in cids], fops,
                              plan.n_rows)
                if cache is not None:
                    cache.put(key, stream, plan.scope)
            plan.streams.append(stream)
            return ("leaf", len(plan.streams) - 1)
        if kind == "not":
            return ("not", rec(nd[1]))
        if kind == "fold":
            return ("fold", nd[1], tuple(rec(c) for c in nd[2]))
        return (kind, tuple(rec(c) for c in nd[1]))

    plan.root = rec(plan.root)
    plan.containers = None
    _renumber_leaves(plan)
    return plan


class _WorkloadCounters:
    """Aggregated per-(column, predicate shape, encoding) planner counters.

    :func:`compile_plan` feeds one event per column predicate it delegates
    to an encoding; the public surface is :func:`workload_snapshot` /
    :func:`workload_reset` — the API benchmarks and
    :mod:`repro.workload`'s cost model read instead of private planner
    state.
    """

    def __init__(self):
        self._mutex = make_lock("query_workload", reentrant=False)
        self._counts: dict = {}  # guarded-by: _mutex

    def record(self, events) -> None:
        if not events:
            return
        with self._mutex:
            for col, shape, width, enc_kind, merges in events:
                cell = self._counts.setdefault(
                    (col, shape, enc_kind),
                    {"count": 0, "merges": 0, "width": 0})
                cell["count"] += 1
                cell["merges"] += merges
                cell["width"] += width

    def snapshot(self) -> dict:
        with self._mutex:
            return {k: dict(v) for k, v in self._counts.items()}

    def reset(self) -> None:
        with self._mutex:
            self._counts.clear()


_WORKLOAD = _WorkloadCounters()


def workload_snapshot() -> dict:
    """Per-column predicate-flow counters accumulated by every
    :func:`compile_plan` call in this process.

    Returns ``{(column, shape, encoding): {"count", "merges", "width"}}``
    where ``column`` is the original table position, ``shape`` is the
    predicate kind (``"eq"`` / ``"in"`` / ``"range"``), ``encoding`` the
    :class:`~repro.core.encodings.ColumnEncoding` kind that compiled it,
    ``count`` how many predicates hit that cell, and ``merges`` / ``width``
    the summed :func:`count_merges` cost and value-domain width.  The
    snapshot is a deep copy — callers may mutate it freely.  See
    docs/query_api.md ("Workload telemetry").
    """
    return _WORKLOAD.snapshot()


def workload_reset() -> None:
    """Clear the process-wide workload counters (test/benchmark hygiene)."""
    _WORKLOAD.reset()


def _fanin(op: str, children: list) -> tuple:
    """n-ary node; same-op children flatten into the parent fan-in."""
    flat: list = []
    for c in children:
        if c[0] == op:
            flat.extend(c[1])
        else:
            flat.append(c)
    return flat[0] if len(flat) == 1 else (op, tuple(flat))


def _cost_order(node, streams, n_words: int):
    """Order every and/or fan-in smallest-estimated-stream-first (stable).

    ``fold`` children are a comparison circuit whose order carries the bit
    position — they are recursed into but never reordered."""

    def est(nd) -> int:
        if nd[0] == "leaf":
            return len(streams[nd[1]])
        if nd[0] == "not":
            # marker-type flipping preserves run structure: the complement
            # has exactly the child's compressed size
            return est(nd[1]) + 1
        if nd[0] == "fold":
            return sum(est(c) for c in nd[2])
        if nd[0] == "cfold":
            return nd[3]  # the encoding's estimated compressed word cost
        return sum(est(c) for c in nd[1])

    def rec(nd):
        if nd[0] == "leaf":
            return nd
        if nd[0] == "not":
            return ("not", rec(nd[1]))
        if nd[0] == "fold":
            return ("fold", nd[1], tuple(rec(c) for c in nd[2]))
        if nd[0] == "cfold":
            return nd
        children = sorted((rec(c) for c in nd[1]), key=est)
        return (nd[0], tuple(children))

    return rec(node)


# ---------------------------------------------------------------------------
# Compressed-result cache
# ---------------------------------------------------------------------------


_DIGEST_MEMO: dict = {}  # id(stream) -> (weakref, digest)


def _leaf_digest(stream) -> bytes:
    """Content digest of a leaf stream, memoized per array object.

    Leaf streams are immutable after ``BitmapIndex.build``, so the digest
    is computed once per stream instead of once per query (a cache *hit*
    must not cost O(leaf bytes)).  The memo key is the object's id with a
    weakref identity check, so a recycled id can never alias a dead array.
    """
    key = id(stream)
    hit = _DIGEST_MEMO.get(key)
    if hit is not None and hit[0]() is stream:
        return hit[1]
    s = np.ascontiguousarray(stream, dtype=np.uint32)
    digest = hashlib.blake2b(s.tobytes(), digest_size=12).digest()
    try:
        # the death callback evicts the entry, so the memo's size is
        # bounded by the number of *live* digested arrays — no sweeps
        ref = weakref.ref(stream,
                          lambda _, k=key: _DIGEST_MEMO.pop(k, None))
    except TypeError:
        return digest  # non-weakref-able input: skip memoization
    _DIGEST_MEMO[key] = (ref, digest)
    return digest


def _node_key(node, digests, n_rows: int):
    """Canonical cache key for a (sub-)plan: the op tree with each leaf
    index replaced by a content digest of its stream.  Equal sub-plans hit
    across plans, indexes, and predicate spellings; rebuilding an index
    changes the digests, so stale entries can never be returned."""

    def rec(nd):
        if nd[0] == "leaf":
            return ("L", digests[nd[1]])
        if nd[0] == "not":
            return ("not", rec(nd[1]))
        if nd[0] == "fold":
            return ("fold", nd[1], tuple(rec(c) for c in nd[2]))
        if nd[0] == "cfold":
            raise ValueError(
                "container fold nodes have no stable content key; "
                "lower_containers() must replace them first")
        return (nd[0], tuple(rec(c) for c in nd[1]))

    return (n_rows, rec(node))


class ResultCache:
    """LRU cache of compressed (sub-)plan results, shared across queries.

    Values are EWAH streams, keys come from :func:`_node_key`.  Capacity
    is **entry-count** based (``maxsize`` results, not a byte budget) —
    each entry holds only a compressed stream, but very large results
    count the same as tiny ones.  ``hits`` / ``misses`` feed the
    cache-hit-rate benchmark and capacity tuning.

    Entries may carry a **scope** tag (a hashable; segments use
    ``("segment", generation)``): :meth:`invalidate` evicts exactly one
    scope's entries, the segmented-index compaction contract — appends
    never touch cached state (open-buffer rows are not cached) and
    compaction evicts only the retired segments' entries.

    Thread-safe: backend instances are shared process-wide through
    ``get_backend``, and the serving path queries from worker threads
    while the background compactor invalidates retired scopes.  ``_mutex``
    is reentrant (``stats`` reads ``hit_rate`` under it)."""

    def __init__(self, maxsize: int = 256):
        self.maxsize = maxsize
        self._mutex = make_lock("result_cache")
        self._data: OrderedDict = OrderedDict()  # guarded-by: _mutex
        self._scope_keys: dict = {}              # guarded-by: _mutex
        self.hits = 0                            # guarded-by: _mutex
        self.misses = 0                          # guarded-by: _mutex
        self.invalidated = 0                     # guarded-by: _mutex

    def get(self, key):
        with self._mutex:
            hit = self._data.get(key)
            if hit is not None:
                self._data.move_to_end(key)
                self.hits += 1
                return hit[0]
            self.misses += 1
            return None

    def put(self, key, value, scope=None) -> None:
        with self._mutex:
            old = self._data.pop(key, None)
            if old is not None:
                self._unscope(key, old[1])
            self._data[key] = (value, scope)
            if scope is not None:
                self._scope_keys.setdefault(scope, set()).add(key)
            while len(self._data) > self.maxsize:
                k, (_, s) = self._data.popitem(last=False)
                self._unscope(k, s)

    def _unscope(self, key, scope) -> None:  # holds-lock: _mutex
        if scope is not None:
            keys = self._scope_keys.get(scope)
            if keys is not None:
                keys.discard(key)
                if not keys:
                    del self._scope_keys[scope]

    def invalidate(self, scope) -> int:
        """Evict every entry tagged with ``scope``; returns the count."""
        with self._mutex:
            keys = self._scope_keys.pop(scope, None)
            if not keys:
                return 0
            for k in keys:
                self._data.pop(k, None)
            self.invalidated += len(keys)
            return len(keys)

    def scopes(self) -> tuple:
        """The scopes with live entries (diagnostics / tests)."""
        with self._mutex:
            return tuple(self._scope_keys)

    def clear(self) -> None:
        with self._mutex:
            self._data.clear()
            self._scope_keys.clear()
            self.hits = 0
            self.misses = 0
            self.invalidated = 0

    def __len__(self) -> int:
        with self._mutex:
            return len(self._data)

    @property
    def hit_rate(self) -> float:
        with self._mutex:
            return self.hits / max(self.hits + self.misses, 1)

    def stats(self) -> dict:
        with self._mutex:
            return {"hits": self.hits, "misses": self.misses,
                    "entries": len(self._data), "hit_rate": self.hit_rate,
                    "invalidated": self.invalidated}


# ---------------------------------------------------------------------------
# Execution backends
# ---------------------------------------------------------------------------

BACKENDS: dict[str, type] = {}


def register_backend(name: str):
    """Decorator: make a backend class available as ``backend=name``."""

    def deco(cls):
        BACKENDS[name] = cls
        cls.name = name
        return cls

    return deco


def backend_names() -> tuple:
    return tuple(sorted(BACKENDS))


_BACKEND_INSTANCES: dict = {}


def get_backend(name: str, **opts):
    """Backend instance for ``name`` (ValueError lists registered names).

    Instances are cached per (name, opts) so state like the jax backend's
    jit cache survives across query calls — without this every
    ``query``/``query_many`` would re-trace identical plans.
    """
    try:
        cls = BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown query backend {name!r}; registered: "
            f"{', '.join(backend_names())}"
        ) from None
    key = (name, tuple(sorted(opts.items())))
    if key not in _BACKEND_INSTANCES:
        _BACKEND_INSTANCES[key] = cls(**opts)
    return _BACKEND_INSTANCES[key]


def invalidate_scope(scope) -> int:
    """Evict one scope's entries from every registered backend instance's
    result cache; returns the total evicted count.

    The segmented-index lifecycle calls this when a segment retires
    (compaction): content-digested keys already guarantee stale results are
    never *returned*, invalidation keeps dead segments' entries from
    squatting in the LRU.  Backends constructed directly (not through
    :func:`get_backend`) manage their own caches —
    ``backend.result_cache.invalidate(scope)``.
    """
    total = 0
    for be in _BACKEND_INSTANCES.values():
        cache = getattr(be, "result_cache", None)
        if cache is not None:
            total += cache.invalidate(scope)
    return total


@register_backend("numpy")
class NumpyBackend:
    """Compressed-domain streaming execution (paper §3, O(|A|+|B|) merges).

    Fan-ins fold through ``ewah_stream.logical_many`` (min-heap on actual
    compressed sizes: cheapest intermediates merge first); ``Not`` is a
    marker-type flip (``ewah_stream.logical_not``), never an XOR against a
    materialized all-ones bitmap.  A bare-leaf root (k=1 equality) costs
    its own stream length — the words a scan touches to materialize the
    answer.

    ``execute`` is the uncached row-id oracle path; ``execute_compressed``
    returns the result as an :class:`EwahStream` and memoizes every
    internal node in ``result_cache``, so cascaded predicates sharing
    sub-plans (the same ``In`` selector AND'd with varying filters, a
    repeated dashboard query) skip the merge entirely.
    """

    def __init__(self, cache_size: int = 256):
        self.result_cache = ResultCache(cache_size)

    def execute(self, plan: Plan):
        plan = lower_containers(plan, self._container_fold)
        stream, scanned = self._eval(plan, plan.root)
        if plan.root[0] == "leaf":
            scanned = len(stream)
        bits = ewah.unpack_bits(ewah.decompress(stream), plan.n_rows)
        return np.flatnonzero(bits), int(scanned)

    def execute_many(self, plans):
        return [self.execute(p) for p in plans]

    def _container_fold(self, csets, fops, n_rows):
        """Streaming container evaluation (core/containers.fold): the
        per-chunk class dispatch raises on unknown container classes."""
        from . import containers
        return containers.fold(csets, fops, n_rows)

    def execute_compressed(self, plan: Plan) -> EwahStream:
        plan = lower_containers(plan, self._container_fold,
                                self.result_cache)
        digests = [_leaf_digest(s) for s in plan.streams]
        stream, scanned = self._eval_cached(plan, plan.root, digests)
        if plan.root[0] == "leaf":
            scanned = len(stream)
        return maybe_validate(
            EwahStream(np.asarray(stream, dtype=np.uint32), plan.n_rows,
                       int(scanned)),
            origin="NumpyBackend.execute_compressed")

    def execute_compressed_many(self, plans):
        return [self.execute_compressed(p) for p in plans]

    def _combine(self, plan: Plan, node, eval_child):
        if node[0] == "cfold":
            raise ValueError(
                "container fold reached the stream evaluator; "
                "lower_containers() must replace it first")
        if node[0] == "not":
            s, scanned = eval_child(node[1])
            r, sc = ewah_stream.logical_not(s, plan.n_words)
            return r, scanned + sc
        if node[0] == "fold":
            # the slice-plane comparison circuit: sequential left fold with
            # a per-step op — child order is the bit order, never reordered
            _, fops, children = node
            parts = [eval_child(c) for c in children]
            scanned = sum(sc for _, sc in parts)
            r = parts[0][0]
            for op, (s, _) in zip(fops, parts[1:]):
                r, sc = ewah_stream.logical_op(r, s, op)
                scanned += sc
            return r, scanned
        op, children = node
        if op not in ("and", "or"):
            raise ValueError(f"unknown plan-node kind {op!r}")
        parts = [eval_child(c) for c in children]
        scanned = sum(sc for _, sc in parts)
        r, sc = ewah_stream.logical_many([s for s, _ in parts], op)
        return r, scanned + sc

    def _eval(self, plan: Plan, node):
        if node[0] == "leaf":
            return plan.streams[node[1]], 0
        return self._combine(plan, node, lambda c: self._eval(plan, c))

    def _eval_cached(self, plan: Plan, node, digests):
        if node[0] == "leaf":
            return plan.streams[node[1]], 0
        key = _node_key(node, digests, plan.n_rows)
        hit = self.result_cache.get(key)
        if hit is not None:
            return hit, 0  # reused: no compressed words visited
        r, scanned = self._combine(
            plan, node, lambda c: self._eval_cached(plan, c, digests))
        self.result_cache.put(key, r, plan.scope)
        return r, scanned


@register_backend("jax")
class JaxBackend:
    """Batched in-graph execution over many queries at once.

    Plans are grouped by (root op tree, capacity bucket): compiled plans
    carry canonically numbered leaves, so structurally equal plans share one
    root tuple and hence one compiled program with a correct leaf mapping.
    Each group's leaf streams pad into one (B, m, C) uint32 batch and
    decompress via a doubly-vmapped ``ewah_jax.decompress``.  With
    ``fuse=True`` (the default) the whole op tree THEN runs as one Pallas
    megakernel launch: the plan root lowers to a static instruction tape
    (:func:`lower_plan`) that ``kernels.ops.plan_fuse`` interprets in
    VMEM — every fold, interior merge, the root op, and the recompress
    classification in a single dispatch, intermediates never leaving the
    chip.  Plans whose tape or operand stack exceeds the VMEM budget
    (``kernels.planfuse.fits_vmem``) fall back automatically to the
    per-stage path (``wordops_fold`` per tree level + ``slice_fold`` per
    comparison + the recompress kernel).  Capacities bucket through
    :data:`PLAN_STATS` (autotuned from the observed plan-size
    distribution; powers of two until boundaries are trained) so jit
    variants stay bounded across query mixes.
    """

    def __init__(self, use_kernel: bool = True, interpret=None,
                 cache_size: int = 256, fuse: bool = True):
        self.use_kernel = use_kernel
        self.interpret = interpret
        self.fuse = fuse
        self._jit_cache: dict = {}
        self._tape_memo: dict = {}
        self.result_cache = ResultCache(cache_size)

    def execute(self, plan: Plan):
        return self.execute_many([plan])[0]

    def execute_many(self, plans):
        import jax.numpy as jnp

        plans = [lower_containers(p, self._container_fold,
                                  self.result_cache) for p in plans]
        out: list = [None] * len(plans)
        for (root, cap, n_rows), idxs in self._group(plans).items():
            batch, lengths = self._pad_group(plans, idxs, cap)
            n_words = (n_rows + ewah.WORD_BITS - 1) // ewah.WORD_BITS
            fn = self._compiled(root, cap, n_words)
            words = np.asarray(fn(jnp.asarray(batch), jnp.asarray(lengths)))
            for b, i in enumerate(idxs):
                bits = ewah.unpack_bits(words[b], n_rows)
                out[i] = (np.flatnonzero(bits), plans[i].leaf_words())
        return out

    def execute_compressed(self, plan: Plan) -> EwahStream:
        return self.execute_compressed_many([plan])[0]

    def execute_compressed_many(self, plans):
        """Batched compressed-in/compressed-out execution: uncached plans
        group exactly like ``execute_many``, but the compiled program ends
        with the in-graph recompression stage (Pallas classify/run-start
        kernel + vmapped scan/scatter emit), so results come back as EWAH
        streams, whole-plan results land in ``result_cache``."""
        import jax.numpy as jnp

        plans = [lower_containers(p, self._container_fold,
                                  self.result_cache) for p in plans]
        out: list = [None] * len(plans)
        keys: list = [None] * len(plans)
        todo = []
        for i, p in enumerate(plans):
            digests = [_leaf_digest(s) for s in p.streams]
            keys[i] = _node_key(p.root, digests, p.n_rows)
            hit = self.result_cache.get(keys[i])
            if hit is not None:
                out[i] = maybe_validate(
                    EwahStream(hit.data, hit.n_rows, 0),  # cache: no scan
                    origin="JaxBackend.execute_compressed_many[cache]")
            else:
                todo.append(i)
        for (root, cap, n_rows), idxs in self._group(plans, todo).items():
            batch, lengths = self._pad_group(plans, idxs, cap)
            n_words = (n_rows + ewah.WORD_BITS - 1) // ewah.WORD_BITS
            if n_words <= ewah.MAX_DIRTY:
                fn = self._compiled(root, cap, n_words, compressed=True)
                streams, lens = fn(jnp.asarray(batch), jnp.asarray(lengths))
                streams, lens = np.asarray(streams), np.asarray(lens)
                enc = [streams[b, : lens[b]] for b in range(len(idxs))]
            else:
                # beyond the single-marker-per-group limit of the vectorized
                # emit (~1M rows) the re-encode happens host-side
                fn = self._compiled(root, cap, n_words)
                words = np.asarray(fn(jnp.asarray(batch), jnp.asarray(lengths)))
                enc = [ewah.compress(words[b]) for b in range(len(idxs))]
            for b, i in enumerate(idxs):
                res = maybe_validate(
                    EwahStream(enc[b], n_rows, plans[i].leaf_words()),
                    origin="JaxBackend.execute_compressed_many")
                self.result_cache.put(keys[i], res, plans[i].scope)
                out[i] = res
        return out

    def _group(self, plans, idxs=None) -> dict:
        groups: dict = {}
        for i in range(len(plans)) if idxs is None else idxs:
            p = plans[i]
            cap = PLAN_STATS.capacity_for(max(len(s) for s in p.streams))
            # key on the full root (leaf indices included), not signature():
            # only plans with an identical leaf-to-stream mapping may share
            # a compiled program
            groups.setdefault((p.root, cap, p.n_rows), []).append(i)
        return groups

    @staticmethod
    def _pad_group(plans, idxs, cap):
        m = len(plans[idxs[0]].streams)
        batch = np.zeros((len(idxs), m, cap), dtype=np.uint32)
        lengths = np.zeros((len(idxs), m), dtype=np.int32)
        for b, i in enumerate(idxs):
            for j, s in enumerate(plans[i].streams):
                batch[b, j, : len(s)] = s
                lengths[b, j] = len(s)
        return batch, lengths

    def _container_fold(self, csets, fops, n_rows):
        """Batched device evaluation of a ``("cfold", ...)`` node.

        Each fold round dispatches its same-chunk container pairs by
        class: array∩bitmap intersections batch into ONE padded galloping
        membership launch (``kernels.ops.container_gallop``), every other
        pair expands to word form and batches into ONE padded
        container-merge launch per round (``kernels.ops.container_pairs``).
        Chunks present on only one side short-circuit by op semantics.
        The accumulated set compresses to the same canonical EWAH stream
        as the numpy streaming path (``containers.fold``) — tests assert
        bit identity.  Unknown container classes raise (``chunk_words`` /
        ``_MERGE_OPS`` dispatch), never fall through.
        """
        from . import containers as C
        from ..kernels import ops as kops

        if not csets:
            return C.fold(csets, fops, n_rows)
        acc = {int(k): (int(c), p) for k, c, p in
               zip(csets[0].keys, csets[0].classes, csets[0].payloads)}
        for op, nxt in zip(fops, csets[1:]):
            if op not in C._MERGE_OPS:
                raise ValueError(f"unknown container merge op {op!r}")
            rhs = {int(k): (int(c), p) for k, c, p in
                   zip(nxt.keys, nxt.classes, nxt.payloads)}
            out = {}
            if op in ("or", "andnot"):
                out.update((k, v) for k, v in acc.items() if k not in rhs)
            if op == "or":
                out.update((k, v) for k, v in rhs.items() if k not in acc)
            gallop, pairs = [], []
            for k in sorted(set(acc) & set(rhs)):
                (ca, pa), (cb, pb) = acc[k], rhs[k]
                if op == "and" and {ca, cb} == {C.ARRAY, C.BITMAP}:
                    gallop.append((k, ca, pa, cb, pb))
                else:
                    pairs.append((k, ca, pa, cb, pb))
            if gallop:
                width = max(len(pa) if ca == C.ARRAY else len(pb)
                            for _, ca, pa, _, pb in gallop)
                pos = np.full((len(gallop), width), -1, dtype=np.int32)
                wrd = np.empty((len(gallop), C.CHUNK_WORDS), dtype=np.uint32)
                for i, (_, ca, pa, cb, pb) in enumerate(gallop):
                    arr = pa if ca == C.ARRAY else pb
                    pos[i, : len(arr)] = arr
                    wrd[i] = pb if cb == C.BITMAP else pa
                hits = np.asarray(kops.container_gallop(
                    pos, wrd, use_kernel=self.use_kernel,
                    interpret=self.interpret))
                for i, (k, ca, pa, cb, pb) in enumerate(gallop):
                    arr = np.asarray(pa if ca == C.ARRAY else pb,
                                     dtype=np.int64)
                    kept = arr[hits[i, : len(arr)].astype(bool)]
                    if len(kept):
                        out[k] = C.make_chunk(kept)
            if pairs:
                lhs = np.stack([C.chunk_words(ca, pa)
                                for _, ca, pa, _, _ in pairs])
                rhs_w = np.stack([C.chunk_words(cb, pb)
                                  for _, _, _, cb, pb in pairs])
                merged = np.asarray(kops.container_pairs(
                    lhs, rhs_w, op, use_kernel=self.use_kernel,
                    interpret=self.interpret))
                for i, (k, *_cls) in enumerate(pairs):
                    if merged[i].any():
                        out[k] = (C.BITMAP, merged[i])
            acc = out
        keys = sorted(acc)
        final = C.ContainerSet(n_rows, keys, [acc[k][0] for k in keys],
                               [acc[k][1] for k in keys])
        return C.to_stream(final)

    def _fused_tape(self, root):
        """The lowered instruction tape for ``root`` when the megakernel
        can run it, else None — the automatic per-stage fallback for
        plans whose tape length or operand-stack depth would blow the
        VMEM budget (``kernels.planfuse``)."""
        if not self.fuse:
            return None
        if root in self._tape_memo:
            return self._tape_memo[root]
        from ..kernels import planfuse

        tape, depth = lower_plan(root)
        m = sum(1 for opcode, _ in tape if opcode == TAPE_PUSH)
        ok = (len(tape) <= planfuse.MAX_TAPE_LEN
              and planfuse.fits_vmem(m, depth))
        self._tape_memo[root] = tape if ok else None
        return self._tape_memo[root]

    def _compiled(self, root, capacity: int, n_words: int,
                  compressed: bool = False):
        tape = self._fused_tape(root)
        key = (root, capacity, n_words, compressed, tape is not None,
               self.use_kernel, self.interpret)
        if key in self._jit_cache:
            return self._jit_cache[key]
        import jax
        import jax.numpy as jnp

        from . import ewah_jax
        from ..kernels import ops as kops

        use_kernel, interpret = self.use_kernel, self.interpret

        def run(batch, lengths):  # (B, m, C), (B, m) -> (B, W)
            dec = jax.vmap(jax.vmap(
                lambda s, l: ewah_jax.decompress(s, l, n_words)))(batch, lengths)

            if tape is not None:
                # fused: the whole op tree + recompress classification in
                # ONE megakernel launch over the flattened batch
                B, m = dec.shape[0], dec.shape[1]
                planes = dec.transpose(1, 0, 2).reshape(m, -1)  # (m, B*W)
                flat, kflat = kops.plan_fuse(
                    planes, tape, use_kernel=use_kernel, interpret=interpret)
                words = flat.reshape(B, n_words)
                if not compressed:
                    return words
                kind = kflat.reshape(B, n_words)
                # per-row run starts from the fused classification: word 0
                # always opens a run (recompress_batch's opposite-class
                # sentinel reduces to exactly this), then any class change
                first = jnp.ones((B, 1), jnp.int32)
                start = jnp.concatenate(
                    [first, (kind[:, 1:] != kind[:, :-1]).astype(jnp.int32)],
                    axis=1)
                return jax.vmap(
                    lambda w, k, s: ewah_jax.compress_from_runs(
                        w, k, s, n_words + 1))(words, kind, start)

            def ev(node):
                if node[0] == "leaf":
                    return dec[:, node[1]]
                if node[0] == "cfold":
                    raise ValueError(
                        "container fold reached the batched evaluator; "
                        "lower_containers() must replace it first")
                if node[0] == "not":
                    return ev(node[1]) ^ jnp.uint32(0xFFFFFFFF)
                if node[0] == "fold":
                    # all planes of a slice comparison dispatch in ONE
                    # padded Pallas call (kernels.ops.slice_fold)
                    _, fops, children = node
                    parts = jnp.stack([ev(c) for c in children])  # (p, B, W)
                    folded = kops.slice_fold(
                        parts.reshape(parts.shape[0], -1), fops,
                        use_kernel=use_kernel, interpret=interpret)
                    return folded.reshape(parts.shape[1:])
                op, children = node
                if op not in ("and", "or"):
                    raise ValueError(f"unknown plan-node kind {op!r}")
                parts = jnp.stack([ev(c) for c in children])  # (p, B, W)
                folded = kops.wordops_fold(
                    parts.reshape(parts.shape[0], -1), op,
                    use_kernel=use_kernel, interpret=interpret)
                return folded.reshape(parts.shape[1:])

            words = ev(root)
            if not compressed:
                return words
            # worst-case EWAH size for n words is n + 1 (all-dirty: one
            # marker + n verbatim words; clean groups only shrink it)
            return kops.recompress_batch(
                words, n_words + 1, use_kernel=use_kernel, interpret=interpret)

        fn = jax.jit(run)
        self._jit_cache[key] = fn
        return fn


def _capacity_bucket(n: int) -> int:
    return max(8, 1 << (int(n) - 1).bit_length())
