"""Immutable index segments and the segmented query surface.

The paper's pipeline (histogram -> column/value reordering -> row sort ->
EWAH) runs *per segment*: a :class:`Segment` is one sealed, immutable run of
rows with its own locally-sorted :class:`~repro.core.bitmap_index.BitmapIndex`
("Sorting improves word-aligned bitmap indexes" shows the sorting benefit
survives partitioning into independently sorted blocks).  A
:class:`SegmentedIndex` stitches many segments — plus the owning writer's
open (not yet sealed) row buffer — into one query surface:

* segments partition the global row space into contiguous *id spans*, every
  physical segment boundary word-aligned (a multiple of 32 rows), exactly
  the ``repro.dist.query_fanout`` shard contract, so per-segment compressed
  results concatenate with :func:`~repro.core.ewah_stream.concat_streams`;
* predicates compile per segment (value domains are segment-local: a value
  a segment never saw compiles to a constant-empty leaf) and execute
  through the existing compressed engine in **one** batched backend call;
* open-buffer rows — the writer's in-flight tail — evaluate directly over
  the uncompressed columns (:func:`~repro.core.query.evaluate_mask`), so
  appends are queryable before any seal;
* row ids come back in **original ingest order** (each segment's local ids
  map through its ``row_perm`` plus its id span) — there is no global
  reordered space across independently sorted segments;
* encodings are **per segment, per column**: each seal re-runs the spec's
  encoding chooser on that segment's own histograms, so an ``'auto'`` spec
  can give the same column different encodings in different segments
  (mixed-encoding segments).  Nothing downstream cares — predicates
  compile per segment against whatever encoding that segment has, and the
  per-plane/per-bitmap representations never cross a segment boundary:
  only *result* streams concatenate.  Compaction concatenates the retired
  segments' raw columns and re-runs the whole pipeline, so the merged
  segment re-chooses its encodings from the merged histograms.

LSM mutability (docs/lifecycle.md):

* **Tombstones.**  Sealed segments stay physically immutable but carry a
  mutable *tombstone* bitmap — an EWAH stream in the segment's reordered
  row space.  A delete ORs into it in the compressed domain and recomputes
  the cached **live mask** (the marker-flip complement,
  :func:`~repro.core.ewah_stream.logical_not`); every compiled plan root
  is then ANDed with the live mask
  (:func:`~repro.core.query.with_live_mask`), so a delete costs one extra
  merge per segment at query time, never a rebuild.
* **TTLs.**  A segment may carry an ingest-order ``expiry`` array (absolute
  deadlines; ``inf`` = never).  Expired rows fold into the tombstones
  *lazily at query time* — the fold memoizes the next-unexpired horizon,
  so the check is O(1) until something actually expires — and are
  physically dropped at compaction.
* **Purged spans.**  Compaction drops dead rows, so a merged segment's id
  span ``[row_start, row_stop)`` can cover more ids than it has physical
  rows; ``row_ids`` then records the surviving ingest ids.  A fully-dead
  span compacts to a valid zero-row segment that keeps the span covered.

Each segment carries a monotonically increasing ``generation``; its index's
``cache_scope`` tags every compressed result the backends cache, so
compaction evicts exactly the retired segments' cache entries
(:func:`repro.core.query.invalidate_scope`) and untouched segments keep
their hits.  See docs/lifecycle.md.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from time import perf_counter

import numpy as np

from . import ewah, ewah_stream
from ..analysis.runtime import maybe_validate
from .bitmap_index import BitmapIndex, _observe_workload
from .ewah_stream import EwahStream, concat_streams
from .query import compile_plan, evaluate_mask, get_backend, with_live_mask

__all__ = ["Segment", "SegmentedIndex"]

_GENERATIONS = itertools.count(1)


def next_generation() -> int:
    """Process-wide monotonic segment generation (cache-invalidation key)."""
    return next(_GENERATIONS)


@dataclass(frozen=True, eq=False)  # identity equality: fields hold ndarrays
class Segment:
    """One sealed run of rows with its own local index.

    ``columns`` keeps the segment's rows in **original ingest order** — the
    row store compaction re-sorts from (a production system would re-read
    them from storage); seal with ``keep_columns=False`` when the segment
    will never compact (the dist fan-out shards do this) and the raw
    arrays are dropped.  ``index`` is the histogram-aware build over the
    rows; ``generation`` is the process-wide monotonic id that scopes the
    segment's entries in backend result caches.

    The physical rows are immutable; the only mutable state is the
    *tombstone* bitmap (deleted rows, reordered row space) and its cached
    complement, the **live mask**.  Both update by whole-array replacement
    (publish-by-reference), so a concurrent reader holding either sees a
    consistent point-in-time mask.

    ``row_start``/``span_stop`` bound the segment's ingest-id span; after a
    purging compaction the span can cover more ids than physical rows, and
    ``row_ids`` records which ids survived (None = the contiguous
    ``arange(row_start, row_start + n_rows)``).  ``expiry`` holds absolute
    per-row deadlines in ingest order (None = no TTLs).
    """

    index: BitmapIndex
    columns: tuple | None = field(repr=False)  # ingest-order arrays, or None
    row_start: int
    generation: int
    span_stop: int | None = None               # id-span end; None = physical
    row_ids: np.ndarray | None = field(default=None, repr=False)
    expiry: np.ndarray | None = field(default=None, repr=False)

    def __post_init__(self):
        object.__setattr__(self, "_tombstone", None)  # deleted, reordered
        object.__setattr__(self, "_live", None)       # cached complement
        object.__setattr__(self, "_inv_perm_cache", None)
        horizon = np.inf
        if self.expiry is not None and len(self.expiry):
            lo = float(self.expiry.min())
            horizon = lo if np.isfinite(lo) else np.inf
        object.__setattr__(self, "_expiry_horizon", horizon)

    @staticmethod
    def seal(table_cols, spec=None, *, row_start: int = 0,
             materialize: bool = True, keep_columns: bool = True,
             span_stop: int | None = None, row_ids=None, expiry=None,
             tombstone_rows=None, encoding_chooser=None) -> "Segment":
        """Run the full per-segment pipeline and freeze the result.

        ``row_ids`` (ascending global ingest ids, one per row) and
        ``span_stop`` describe a purged id span; ``expiry`` carries
        ingest-order absolute deadlines; ``tombstone_rows`` marks
        ingest-local positions dead at birth (buffer deletes surviving a
        seal, compaction's word-alignment filler rows).
        ``encoding_chooser`` is the workload-driven per-column override
        compaction threads down to ``_construct`` (docs/containers.md).
        """
        from .bitmap_index import _construct

        cols = tuple(np.asarray(c) for c in table_cols)
        gen = next_generation()
        index = _construct(list(cols), spec, materialize=materialize,
                           encoding_chooser=encoding_chooser)
        index.cache_scope = ("segment", gen)
        if expiry is not None:
            expiry = np.asarray(expiry, dtype=np.float64)
            if not np.isfinite(expiry).any():
                expiry = None  # all-inf: no TTLs to track
        if row_ids is not None:
            row_ids = np.asarray(row_ids, dtype=np.int64)
            # ascending + first/last contiguous => the whole run is the
            # implicit arange; drop the array
            if len(row_ids) and row_ids[0] == row_start \
                    and row_ids[-1] == row_start + len(row_ids) - 1:
                row_ids = None
        seg = Segment(index=index, columns=cols if keep_columns else None,
                      row_start=int(row_start), generation=gen,
                      span_stop=None if span_stop is None else int(span_stop),
                      row_ids=row_ids, expiry=expiry)
        if tombstone_rows is not None:
            seg.delete_ingest_local(tombstone_rows)
        return seg

    @staticmethod
    def empty(row_start: int, span_stop: int) -> "Segment":
        """A valid zero-row segment covering ``[row_start, span_stop)`` —
        what a fully-tombstoned span compacts to.  It keeps the id span
        contiguous for its neighbours while contributing nothing (and
        costing nothing) to execution."""
        gen = next_generation()
        index = BitmapIndex(n_rows=0, columns=[],
                            row_perm=np.zeros(0, dtype=np.int64),
                            col_perm=np.zeros(0, dtype=np.int64))
        index.cache_scope = ("segment", gen)
        return Segment(index=index, columns=(), row_start=int(row_start),
                       generation=gen, span_stop=int(span_stop))

    # -- shape ---------------------------------------------------------------

    @property
    def n_rows(self) -> int:
        """Physical (surviving) rows."""
        return self.index.n_rows

    @property
    def n_words(self) -> int:
        return (self.n_rows + ewah.WORD_BITS - 1) // ewah.WORD_BITS

    @property
    def row_stop(self) -> int:
        """End of the ingest-id span (>= ``row_start + n_rows`` after a
        purging compaction)."""
        if self.span_stop is not None:
            return self.span_stop
        return self.row_start + self.n_rows

    @property
    def cache_scope(self) -> tuple:
        return ("segment", self.generation)

    def size_words(self) -> int:
        return self.index.size_words()

    def ingest_ids(self) -> np.ndarray:
        """Global ingest ids of the physical rows, ascending ingest order."""
        if self.row_ids is not None:
            return self.row_ids
        return np.arange(self.row_start, self.row_start + self.n_rows,
                         dtype=np.int64)

    def original_rows(self, local_rows: np.ndarray) -> np.ndarray:
        """Map segment-local reordered row ids to original ingest ids."""
        ingest_local = np.asarray(self.index.row_perm)[
            np.asarray(local_rows, dtype=np.int64)]
        if self.row_ids is not None:
            return self.row_ids[ingest_local]
        return self.row_start + ingest_local

    def _inv_perm(self) -> np.ndarray:
        inv = self._inv_perm_cache
        if inv is None:
            perm = np.asarray(self.index.row_perm)
            inv = np.empty(len(perm), dtype=np.int64)
            inv[perm] = np.arange(len(perm))
            object.__setattr__(self, "_inv_perm_cache", inv)
        return inv

    # -- tombstones / TTL ----------------------------------------------------

    @property
    def tombstones(self) -> EwahStream | None:
        """Deleted-row bitmap (reordered row space), or None."""
        t = self._tombstone
        return EwahStream(t, self.n_rows, len(t)) if t is not None else None

    def live_stream(self, now=None):
        """Compressed live-row mask the planner ANDs into every plan root
        (:func:`~repro.core.query.with_live_mask`), or None when every
        physical row is live.  Passing ``now`` folds newly-expired rows in
        first (O(1) when nothing newly expires)."""
        if now is not None:
            self.fold_expired(now)
        return self._live

    def _apply_tombstone(self, stream: np.ndarray) -> None:
        cur = self._tombstone
        if cur is None:
            new = np.asarray(stream, dtype=np.uint32)
        else:
            new, _ = ewah_stream.logical_op(cur, stream, "or")
        live, _ = ewah_stream.logical_not(new, self.n_words)
        # publish complement first: a reader pairing old tombstones with
        # the new live mask would only over-exclude, never resurrect
        object.__setattr__(self, "_live", live)
        object.__setattr__(self, "_tombstone", new)

    def delete_reordered(self, positions) -> int:
        """Tombstone segment-local *reordered* row positions (what a
        compiled plan's execution returns).  Idempotent; returns the count
        of newly-dead rows."""
        positions = np.unique(np.asarray(positions, dtype=np.int64))
        if not len(positions):
            return 0
        before = self.deleted_count()
        words = ewah.positions_to_words(positions, self.n_rows)
        self._apply_tombstone(ewah.compress(words))
        return self.deleted_count() - before

    def delete_ingest_local(self, positions) -> int:
        """Tombstone ingest-local row positions (0..n_rows)."""
        positions = np.asarray(positions, dtype=np.int64)
        if not len(positions):
            return 0
        return self.delete_reordered(self._inv_perm()[positions])

    def delete_ids(self, ids) -> int:
        """Tombstone by global ingest id.  Ids outside the span — or inside
        it but already purged by a compaction — are silently ignored (the
        row is gone either way).  Returns the newly-dead count."""
        ids = np.asarray(ids, dtype=np.int64)
        ids = ids[(ids >= self.row_start) & (ids < self.row_stop)]
        if not len(ids):
            return 0
        mine = self.ingest_ids()
        pos = np.searchsorted(mine, ids)
        hit = pos < len(mine)
        pos = pos[hit]
        present = mine[pos] == ids[hit]
        if not present.any():
            return 0
        return self.delete_ingest_local(pos[present])

    def deleted_count(self) -> int:
        """Tombstoned rows (not counting unexpired TTL rows)."""
        t = self._tombstone
        return EwahStream(t, self.n_rows, 0).count() if t is not None else 0

    def fold_expired(self, now) -> None:
        """Fold rows whose TTL deadline has passed into the tombstones.

        Lazy: memoizes the earliest still-pending deadline, so until the
        clock crosses it this is a single float compare."""
        if self.expiry is None or now < self._expiry_horizon:
            return
        expired = np.flatnonzero(self.expiry <= now)
        pending = self.expiry[self.expiry > now]
        horizon = float(pending.min()) if len(pending) else np.inf
        self.delete_ingest_local(expired)
        object.__setattr__(self, "_expiry_horizon", horizon)

    def dead_ingest_mask(self, now=None) -> np.ndarray:
        """(n_rows,) bool in ingest order: tombstoned, or expired at
        ``now`` (whether or not the expiry has been folded yet)."""
        mask = np.zeros(self.n_rows, dtype=bool)
        t = self._tombstone
        if t is not None:
            reordered = EwahStream(t, self.n_rows, 0).to_rows()
            mask[np.asarray(self.index.row_perm)[reordered]] = True
        if self.expiry is not None and now is not None:
            mask |= self.expiry <= now
        return mask

    def dead_ids(self, now=None) -> np.ndarray:
        """Global ingest ids of dead rows (ascending)."""
        return self.ingest_ids()[self.dead_ingest_mask(now)]


class SegmentedIndex:
    """A query surface over sealed segments plus an optional open buffer.

    Built by :class:`repro.core.lifecycle.IndexWriter` (the live ``.index``
    view) or directly from a list of segments (the dist fan-out path).  The
    contract every execution method checks:

    * segments cover contiguous ingest-id spans in order;
    * every segment but the last covers a multiple of 32 *physical* rows
      (word alignment — what lets compressed results concatenate in word
      space; a purged segment stays aligned via compaction's filler rows,
      and zero-row segments are trivially aligned);
    * the open buffer, when present, sits after the last segment.

    Writer-backed views are **live and snapshot-consistent**: every
    execution reads the writer's segment tuple and buffer once, atomically,
    so a query overlapping a background compaction sees the old or the new
    segment list — never a mix (the writer swaps the tuple by reference).
    """

    def __init__(self, segments, names=None, writer=None, clock=None):
        self._segments = tuple(segments)
        self.names = names
        self._writer = writer
        # writerless views (e.g. fan-out shards) that carry TTL deadlines
        # issued under an injected writer clock must evaluate "now" on that
        # same clock, or every deadline is in the distant past/future
        self._clock = clock

    # -- shape -------------------------------------------------------------

    def _snapshot(self):
        """One consistent (segments, buffer) view.  ``buffer`` is
        ``(columns, deleted_mask, expiry)`` or None."""
        w = self._writer
        if w is None:
            return self._segments, None
        return w.snapshot()

    @property
    def segments(self) -> list:
        return list(self._snapshot()[0])

    @property
    def n_segments(self) -> int:
        return len(self._snapshot()[0])

    def generations(self) -> tuple:
        return tuple(s.generation for s in self._snapshot()[0])

    def encodings(self) -> tuple:
        """Per-segment tuple of per-column encoding kinds (the chooser runs
        on each segment's own histograms, so these may differ — mixed-
        encoding segments are a supported steady state)."""
        return tuple(s.index.encodings() for s in self._snapshot()[0])

    @property
    def n_sealed_rows(self) -> int:
        """End of the sealed ingest-id span (the open buffer's first id)."""
        segs, _ = self._snapshot()
        return segs[-1].row_stop if segs else 0

    @property
    def n_rows(self) -> int:
        """Physical rows: surviving sealed rows plus the open buffer
        (purged rows no longer count)."""
        segs, buf = self._snapshot()
        return (sum(s.n_rows for s in segs)
                + (len(buf[1]) if buf is not None else 0))

    def size_words(self) -> int:
        """Compressed words across sealed segments (buffer rows are not
        compressed until sealed)."""
        return sum(s.size_words() for s in self._snapshot()[0])

    def _now(self, now):
        if now is not None:
            return float(now)
        if self._clock is not None:
            return self._clock()
        w = self._writer
        return w.clock() if w is not None else time.time()

    @staticmethod
    def _check(segments, has_buffer: bool) -> None:
        pos = segments[0].row_start if segments else 0
        last = len(segments) - 1
        for i, seg in enumerate(segments):
            if seg.row_start != pos:
                raise ValueError(
                    f"segment {i} (gen {seg.generation}) starts at "
                    f"{seg.row_start}, expected {pos}: segments must cover "
                    "contiguous id spans")
            if i < last and seg.n_rows % ewah.WORD_BITS:
                raise ValueError(
                    f"segment {i} (gen {seg.generation}) holds {seg.n_rows} "
                    "rows — every segment but the last must be word-aligned "
                    "(a multiple of 32 physical rows)")
            pos = seg.row_stop
        if has_buffer and segments \
                and segments[last].n_rows % ewah.WORD_BITS:
            raise ValueError(
                "open buffer follows a non-word-aligned final segment; "
                "seal order violated the alignment contract")

    # -- deletes (shared by the writer and writerless shard views) ---------

    def delete(self, pred=None, *, row_ids=None, backend: str = "numpy",
               names=None, now=None) -> int:
        """Tombstone sealed rows by predicate or by global ingest id.

        Writer-backed views should prefer
        :meth:`~repro.core.lifecycle.IndexWriter.delete`, which also covers
        the open buffer; this method handles sealed segments only (the
        writerless dist fan-out path).  Returns the newly-dead row count.
        """
        if (pred is None) == (row_ids is None):
            raise ValueError("delete needs exactly one of pred= or row_ids=")
        segs, _ = self._snapshot()
        deleted = 0
        if row_ids is not None:
            ids = np.unique(np.asarray(row_ids, dtype=np.int64))
            for seg in segs:
                deleted += seg.delete_ids(ids)
            return deleted
        names = names if names is not None else self.names
        be = get_backend(backend)
        now = self._now(now)
        for seg in segs:
            if not seg.n_rows:
                continue
            seg.fold_expired(now)
            plan = compile_plan(seg.index, pred, names=names)
            rows, _ = be.execute(plan)
            deleted += seg.delete_reordered(rows)
        return deleted

    # -- execution ---------------------------------------------------------

    def execute_compressed(self, pred, backend: str = "numpy", names=None,
                           now=None, **backend_opts):
        """Per-segment compressed execution; returns
        ``(segment_streams, merged)`` — the merged stream covers sealed
        segments *and* open-buffer rows."""
        return self.execute_compressed_many(
            [pred], backend=backend, names=names, now=now,
            **backend_opts)[0]

    def execute_compressed_many(self, preds, backend: str = "numpy",
                                names=None, now=None, **backend_opts):
        """Batched execution: all predicates' per-segment plans go to the
        backend in one ``execute_compressed_many`` call (same-shape plans
        batch across predicates and segments on the jax backend).  The open
        buffer evaluates densely over its uncompressed columns and its
        result stream concatenates after the sealed segments."""
        _, _, triples = self._execute_many(preds, backend, names,
                                           backend_opts, now)
        return [(per_seg, merged) for per_seg, _, merged in triples]

    def _execute_many(self, preds, backend, names, backend_opts, now=None):
        """-> (segments, buffer, triples): one (per_segment_streams,
        buffer_rows|None, merged) triple per predicate, all against a
        single atomic snapshot; the buffer is evaluated exactly once per
        predicate.  Tombstoned/expired rows are excluded everywhere: each
        sealed plan root is ANDed with its segment's live mask (one extra
        merge), buffer rows mask densely."""
        segs, buf = self._snapshot()
        self._check(segs, buf is not None)
        now = self._now(now)
        names = names if names is not None else self.names
        be = get_backend(backend, **backend_opts)
        live = [s.live_stream(now) if s.n_rows else None for s in segs]
        active = [j for j, s in enumerate(segs) if s.n_rows]
        plans = []
        for p in preds:
            for j in active:
                plan = compile_plan(segs[j].index, p, names=names)
                plans.append(with_live_mask(plan, live[j]))
        t0 = perf_counter()
        if hasattr(be, "execute_compressed_many"):
            results = be.execute_compressed_many(plans)
        else:
            results = [be.execute_compressed(p) for p in plans]
        _observe_workload(plans, perf_counter() - t0)
        total_rows = (sum(s.n_rows for s in segs)
                      + (len(buf[1]) if buf is not None else 0))
        out = []
        k = len(active)
        empty = ewah.compress(np.zeros(0, dtype=np.uint32))
        for i, pred in enumerate(preds):
            got = iter(results[i * k : (i + 1) * k])
            per_seg = [next(got) if s.n_rows else EwahStream(empty, 0, 0)
                       for s in segs]
            parts = [r.data for r in per_seg]
            scanned = sum(r.words_scanned for r in per_seg)
            buf_rows = None
            if buf is not None:
                cols, bdel, bexp = buf
                # dense one-pass evaluation; scan cost is the buffer's
                # dense word count
                mask = evaluate_mask(pred, cols, names=names)
                mask &= ~bdel & (bexp > now)
                buf_rows = np.flatnonzero(mask)
                words = ewah.positions_to_words(buf_rows, len(mask))
                parts.append(ewah.compress(words))
                scanned += len(words)
            merged = (EwahStream(concat_streams(parts), total_rows, scanned)
                      if parts else EwahStream(empty, 0, 0))
            maybe_validate(merged, origin="SegmentedIndex._execute_many")
            out.append((per_seg, buf_rows, merged))
        return segs, buf, out

    def query(self, pred, backend: str = "numpy", names=None, now=None,
              **backend_opts):
        """Returns ``(row_ids, words_scanned)`` with row ids in **original**
        ingest row space, sorted ascending."""
        return self.query_many([pred], backend=backend, names=names,
                               now=now, **backend_opts)[0]

    def query_many(self, preds, backend: str = "numpy", names=None,
                   now=None, **backend_opts):
        """Batched queries; one (row_ids, words_scanned) per predicate."""
        segs, _, triples = self._execute_many(preds, backend, names,
                                              backend_opts, now)
        buf_start = segs[-1].row_stop if segs else 0
        out = []
        for per_seg, buf_rows, merged in triples:
            ids = [seg.original_rows(r.to_rows())
                   for seg, r in zip(segs, per_seg) if seg.n_rows]
            if buf_rows is not None:
                ids.append(buf_start + buf_rows)
            rows = (np.sort(np.concatenate(ids)) if ids
                    else np.asarray([], dtype=np.int64))
            out.append((rows, merged.words_scanned))
        return out

    def count(self, pred, backend: str = "numpy", names=None, now=None,
              **backend_opts) -> int:
        """Matching live-row count without materializing ids (compressed-
        domain popcount of the merged stream; tombstoned and expired rows
        are already ANDed out)."""
        _, merged = self.execute_compressed(pred, backend=backend,
                                            names=names, now=now,
                                            **backend_opts)
        return merged.count()
