"""Immutable index segments and the segmented query surface.

The paper's pipeline (histogram -> column/value reordering -> row sort ->
EWAH) runs *per segment*: a :class:`Segment` is one sealed, immutable run of
rows with its own locally-sorted :class:`~repro.core.bitmap_index.BitmapIndex`
("Sorting improves word-aligned bitmap indexes" shows the sorting benefit
survives partitioning into independently sorted blocks).  A
:class:`SegmentedIndex` stitches many segments — plus the owning writer's
open (not yet sealed) row buffer — into one query surface:

* segments partition the global row space into contiguous ranges, every
  boundary word-aligned (a multiple of 32 rows), exactly the
  ``repro.dist.query_fanout`` shard contract, so per-segment compressed
  results concatenate with :func:`~repro.core.ewah_stream.concat_streams`;
* predicates compile per segment (value domains are segment-local: a value
  a segment never saw compiles to a constant-empty leaf) and execute
  through the existing compressed engine in **one** batched backend call;
* open-buffer rows — the writer's in-flight tail — evaluate directly over
  the uncompressed columns (:func:`~repro.core.query.evaluate_mask`), so
  appends are queryable before any seal;
* row ids come back in **original ingest order** (each segment's local ids
  map through its ``row_perm`` plus row offset) — there is no global
  reordered space across independently sorted segments;
* encodings are **per segment, per column**: each seal re-runs the spec's
  encoding chooser on that segment's own histograms, so an ``'auto'`` spec
  can give the same column different encodings in different segments
  (mixed-encoding segments).  Nothing downstream cares — predicates
  compile per segment against whatever encoding that segment has, and the
  per-plane/per-bitmap representations never cross a segment boundary:
  only *result* streams concatenate.  Compaction concatenates the retired
  segments' raw columns and re-runs the whole pipeline, so the merged
  segment re-chooses its encodings from the merged histograms.

Each segment carries a monotonically increasing ``generation``; its index's
``cache_scope`` tags every compressed result the backends cache, so
compaction evicts exactly the retired segments' cache entries
(:func:`repro.core.query.invalidate_scope`) and untouched segments keep
their hits.  See docs/lifecycle.md.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np

from . import ewah
from .bitmap_index import BitmapIndex
from .ewah_stream import EwahStream, concat_streams
from .query import compile_plan, evaluate_mask, get_backend

__all__ = ["Segment", "SegmentedIndex"]

_GENERATIONS = itertools.count(1)


def next_generation() -> int:
    """Process-wide monotonic segment generation (cache-invalidation key)."""
    return next(_GENERATIONS)


@dataclass(frozen=True, eq=False)  # identity equality: fields hold ndarrays
class Segment:
    """One sealed, immutable run of rows with its own local index.

    ``columns`` keeps the segment's rows in **original ingest order** — the
    row store compaction re-sorts from (a production system would re-read
    them from storage); seal with ``keep_columns=False`` when the segment
    will never compact (the dist fan-out shards do this) and the raw
    arrays are dropped.  ``index`` is the histogram-aware build over the
    rows; ``generation`` is the process-wide monotonic id that scopes the
    segment's entries in backend result caches.
    """

    index: BitmapIndex
    columns: tuple | None = field(repr=False)  # ingest-order arrays, or None
    row_start: int
    generation: int

    @staticmethod
    def seal(table_cols, spec=None, *, row_start: int = 0,
             materialize: bool = True, keep_columns: bool = True) -> "Segment":
        """Run the full per-segment pipeline and freeze the result."""
        from .bitmap_index import _construct

        cols = tuple(np.asarray(c) for c in table_cols)
        gen = next_generation()
        index = _construct(list(cols), spec, materialize=materialize)
        index.cache_scope = ("segment", gen)
        return Segment(index=index, columns=cols if keep_columns else None,
                       row_start=int(row_start), generation=gen)

    @property
    def n_rows(self) -> int:
        return self.index.n_rows

    @property
    def row_stop(self) -> int:
        return self.row_start + self.n_rows

    @property
    def cache_scope(self) -> tuple:
        return ("segment", self.generation)

    def size_words(self) -> int:
        return self.index.size_words()

    def original_rows(self, local_rows: np.ndarray) -> np.ndarray:
        """Map segment-local reordered row ids to original table positions."""
        return self.row_start + self.index.row_perm[np.asarray(local_rows)]


class SegmentedIndex:
    """A query surface over sealed segments plus an optional open buffer.

    Built by :class:`repro.core.lifecycle.IndexWriter` (the live ``.index``
    view) or directly from a list of segments (the dist fan-out path).  The
    contract every execution method checks:

    * segments cover contiguous row ranges in order;
    * every segment but the last covers a multiple of 32 rows (word
      alignment — what lets compressed results concatenate in word space);
    * the open buffer, when present, sits after the last segment.
    """

    def __init__(self, segments: list, names=None, writer=None):
        self._segments = segments
        self.names = names
        self._writer = writer

    # -- shape -------------------------------------------------------------

    @property
    def segments(self) -> list:
        return self._segments

    @property
    def n_segments(self) -> int:
        return len(self._segments)

    def generations(self) -> tuple:
        return tuple(s.generation for s in self._segments)

    def encodings(self) -> tuple:
        """Per-segment tuple of per-column encoding kinds (the chooser runs
        on each segment's own histograms, so these may differ — mixed-
        encoding segments are a supported steady state)."""
        return tuple(s.index.encodings() for s in self._segments)

    def _buffer(self):
        """(columns, row_start, n_rows) of the open buffer, or None."""
        w = self._writer
        if w is None or not w.buffered_rows:
            return None
        cols = w.buffer_columns()
        start = self._segments[-1].row_stop if self._segments else 0
        return cols, start, len(cols[0])

    @property
    def n_sealed_rows(self) -> int:
        return self._segments[-1].row_stop if self._segments else 0

    @property
    def n_rows(self) -> int:
        buf = self._buffer()
        return self.n_sealed_rows + (buf[2] if buf else 0)

    def size_words(self) -> int:
        """Compressed words across sealed segments (buffer rows are not
        compressed until sealed)."""
        return sum(s.size_words() for s in self._segments)

    def _check(self) -> None:
        pos = self._segments[0].row_start if self._segments else 0
        last = len(self._segments) - 1
        for i, seg in enumerate(self._segments):
            if seg.row_start != pos:
                raise ValueError(
                    f"segment {i} (gen {seg.generation}) starts at "
                    f"{seg.row_start}, expected {pos}: segments must cover "
                    "contiguous row ranges")
            if i < last and seg.n_rows % ewah.WORD_BITS:
                raise ValueError(
                    f"segment {i} (gen {seg.generation}) covers {seg.n_rows} "
                    "rows — every segment but the last must be word-aligned "
                    "(a multiple of 32 rows)")
            pos = seg.row_stop
        buf = self._buffer()
        if buf is not None and self._segments and last >= 0 \
                and self._segments[last].n_rows % ewah.WORD_BITS:
            raise ValueError(
                "open buffer follows a non-word-aligned final segment; "
                "seal order violated the alignment contract")

    # -- execution ---------------------------------------------------------

    def execute_compressed(self, pred, backend: str = "numpy", names=None,
                           **backend_opts):
        """Per-segment compressed execution; returns
        ``(segment_streams, merged)`` — the merged stream covers sealed
        segments *and* open-buffer rows."""
        return self.execute_compressed_many(
            [pred], backend=backend, names=names, **backend_opts)[0]

    def execute_compressed_many(self, preds, backend: str = "numpy",
                                names=None, **backend_opts):
        """Batched execution: all predicates' per-segment plans go to the
        backend in one ``execute_compressed_many`` call (same-shape plans
        batch across predicates and segments on the jax backend).  The open
        buffer evaluates densely over its uncompressed columns and its
        result stream concatenates after the sealed segments."""
        return [(per_seg, merged) for per_seg, _, merged in
                self._execute_many(preds, backend, names, backend_opts)]

    def _execute_many(self, preds, backend, names, backend_opts):
        """-> one (per_segment_streams, buffer_rows|None, merged) triple per
        predicate; the buffer is evaluated exactly once per predicate."""
        self._check()
        names = names if names is not None else self.names
        be = get_backend(backend, **backend_opts)
        plans = [compile_plan(seg.index, p, names=names)
                 for p in preds for seg in self._segments]
        if hasattr(be, "execute_compressed_many"):
            results = be.execute_compressed_many(plans)
        else:
            results = [be.execute_compressed(p) for p in plans]
        buf = self._buffer()
        out = []
        n = len(self._segments)
        total_rows = self.n_rows
        for i, pred in enumerate(preds):
            per_seg = list(results[i * n : (i + 1) * n])
            parts = [r.data for r in per_seg]
            scanned = sum(r.words_scanned for r in per_seg)
            buf_rows = None
            if buf is not None:
                cols, _, bn = buf
                # dense one-pass evaluation; scan cost is the buffer's
                # dense word count
                buf_rows = np.flatnonzero(
                    evaluate_mask(pred, cols, names=names))
                words = ewah.positions_to_words(buf_rows, bn)
                parts.append(ewah.compress(words))
                scanned += len(words)
            merged = (EwahStream(concat_streams(parts), total_rows, scanned)
                      if parts else EwahStream(ewah.compress(
                          np.zeros(0, dtype=np.uint32)), 0, 0))
            out.append((per_seg, buf_rows, merged))
        return out

    def query(self, pred, backend: str = "numpy", names=None,
              **backend_opts):
        """Returns ``(row_ids, words_scanned)`` with row ids in **original**
        ingest row space, sorted ascending."""
        return self.query_many([pred], backend=backend, names=names,
                               **backend_opts)[0]

    def query_many(self, preds, backend: str = "numpy", names=None,
                   **backend_opts):
        """Batched queries; one (row_ids, words_scanned) per predicate."""
        buf_start = self.n_sealed_rows
        out = []
        for per_seg, buf_rows, merged in self._execute_many(
                preds, backend, names, backend_opts):
            ids = [seg.original_rows(r.to_rows())
                   for seg, r in zip(self._segments, per_seg)]
            if buf_rows is not None:
                ids.append(buf_start + buf_rows)
            rows = (np.sort(np.concatenate(ids)) if ids
                    else np.asarray([], dtype=np.int64))
            out.append((rows, merged.words_scanned))
        return out

    def count(self, pred, backend: str = "numpy", names=None,
              **backend_opts) -> int:
        """Matching-row count without materializing ids (compressed-domain
        popcount of the merged stream)."""
        _, merged = self.execute_compressed(pred, backend=backend,
                                            names=names, **backend_opts)
        return merged.count()
