"""Attribute-value histograms (the 'histogram-aware' in the paper's title)."""

from __future__ import annotations

import numpy as np


def column_histogram(col: np.ndarray, n_values: int | None = None) -> np.ndarray:
    """Frequency f(v) of each attribute value id in a column."""
    col = np.asarray(col)
    if n_values is None:
        # col.max() raises on zero-length input; an empty column simply has
        # no observed values, i.e. a zero-length histogram
        n_values = int(col.max()) + 1 if col.size else 0
    return np.bincount(col, minlength=n_values)


def value_order(hist: np.ndarray, policy: str = "alpha") -> np.ndarray:
    """Order in which attribute values are assigned bitmap codes.

    'alpha': by value id (Alpha-Lex / Gray-Lex).
    'freq' : by descending frequency, value id tie-break (Gray-Frequency).
    Returns an array ``order`` with order[rank] = value id.
    """
    n = len(hist)
    if policy == "alpha":
        return np.arange(n)
    if policy == "freq":
        return np.lexsort((np.arange(n), -hist.astype(np.int64)))
    raise ValueError(f"unknown value-order policy: {policy}")


def freq_rank_keys(col: np.ndarray, hist: np.ndarray) -> np.ndarray:
    """Per-row sort key for Gray-Frequency: rank of the row's value when
    values are ordered by (descending frequency, value id).  Rows with equal
    keys are exactly rows whose values share a frequency class and id."""
    order = value_order(hist, "freq")
    rank = np.empty(len(hist), dtype=np.int64)
    rank[order] = np.arange(len(hist))
    return rank[np.asarray(col)]
