"""Pluggable per-column bitmap encodings behind one ``ColumnEncoding`` API.

The paper's index hardwires one representation — an EWAH equality bitmap per
attribute value (k-of-N codes).  That makes a ``Range`` spanning w values a
w-wide OR fan-in: range cost scales with cardinality.  The Roaring line of
work (Chambi et al. 2014; Lemire et al. 2016) shows that picking the
representation *per container/column* is what keeps compressed bitmaps
consistently fast, and the attribute-value histogram this repo already
computes for row ordering is exactly the statistic the chooser needs.

Three encodings implement the protocol:

* :class:`EqualityEncoding` — the paper's k-of-N value bitmaps (extracted
  from the old hardwired path, bit-for-bit identical).
* :class:`BitSlicedEncoding` — ``m = ceil(log2(card))`` EWAH *slice planes*
  (plane i = rows whose value has bit i set).  Any range compiles to the
  textbook slice-plane comparison circuit — at most ``2m`` stream merges
  regardless of range width — emitted as sequential ``("fold", ops, ...)``
  plan nodes the backends execute in one pass (one padded Pallas
  ``slice_fold`` launch on jax).  With ``gray=True`` the planes hold the
  Gray code of the value (``encoding.to_gray``, the transform
  ``kernels/gray.py`` implements on-device): adjacent values then differ in
  exactly one plane, which compresses sorted runs better; the comparison
  circuit decodes binary bits in-plan as XOR fan-ins over the Gray planes.
* :class:`BinnedEncoding` — histogram-equalized contiguous value bins (one
  EWAH bitmap per bin, ~equal rows each) plus a lazy candidate-check
  refinement over the per-row value surface (one int32 per row, kept in
  sorted-row order).  A range is the OR of its fully-covered bins' bitmaps
  plus one exact leaf for the partial boundary values — the classic binned
  "coarse plan + refinement", with the refinement resolved densely at
  compile time so both backends execute the result unchanged, and without
  ever reading the segment's raw columns (``keep_columns=False`` safe).

* :class:`RoaringEncoding` — one Roaring-style container set per value
  (:mod:`repro.core.containers`): array / bitmap / run containers per
  aligned 2^16-row chunk, chosen by the classic cardinality/run-count
  thresholds at seal time.  Predicates compile to ``("cfold", ...)`` plan
  nodes evaluated container-wise (galloping intersections, batched Pallas
  word merges) and lowered to one canonical ``EwahStream`` leaf at the
  plan root — see docs/containers.md.

Which encoding a column gets is decided by an ``encoding`` *strategy*
(:mod:`repro.core.strategies`) reading the column histogram — the built-in
``"auto"`` chooser sends high-cardinality columns to bit-sliced, skewed
low-cardinality ones to equality, and mid-cardinality flat ones to binned.
See docs/encodings.md.
"""

from __future__ import annotations

import math

import numpy as np

from . import ewah
from .encoding import choose_N, clamp_k, to_gray
from .index_size import column_bitmap_sizes

__all__ = [
    "ColumnEncoding", "EqualityEncoding", "BitSlicedEncoding",
    "BinnedEncoding", "RoaringEncoding", "assign_codes", "build_encoding",
    "encoding_kinds",
]


def assign_codes(
    n_values: int, k: int, code_order: str = "gray", value_policy: str = "alpha",
    hist: np.ndarray | None = None,
) -> tuple[np.ndarray, int, int]:
    """Build the (n_values, k) bitmap-position code table for one column.

    code_order / value_policy are registry strategy names (built-ins:
    'gray'/'lex' enumeration, 'alpha'/'freq' value policy); unknown names
    raise ValueError listing what is registered.
    Returns (codes, N, k_effective).
    """
    from .strategies import get_strategy

    k_eff = clamp_k(n_values, k)
    N = choose_N(n_values, k_eff)
    enum = get_strategy("code_order", code_order)
    policy = get_strategy("value_policy", value_policy)
    ordered_codes = enum(N, k_eff, n_values)
    order = np.arange(n_values) if hist is None else np.asarray(policy(hist))
    codes = np.empty((n_values, k_eff), dtype=np.int32)
    codes[order] = ordered_codes
    return codes, N, k_eff


def _positions_to_stream(positions: np.ndarray, n_rows: int) -> np.ndarray:
    """Sorted row positions -> compressed EWAH stream over n_rows."""
    if len(positions):
        return ewah.compress(ewah.positions_to_words(positions, n_rows))
    return ewah.compress(np.zeros((n_rows + ewah.WORD_BITS - 1)
                                 // ewah.WORD_BITS, dtype=np.uint32))


def _one_bitmap_size(indicator: np.ndarray, n_rows: int) -> int:
    """Exact EWAH word count of the bitmap set by ``indicator == 1``,
    without emitting the stream (O(n) vectorized run accounting via
    ``column_bitmap_sizes`` over the two-value indicator column)."""
    sizes, _, _ = column_bitmap_sizes(
        indicator, np.asarray([[0], [1]], dtype=np.int64), 2)
    return int(sizes[1])


class ColumnEncoding:
    """One column's bitmap representation + its predicate compiler.

    Concrete encodings expose:

    * ``kind`` — registry name (``"equality"`` / ``"bitsliced"`` /
      ``"bitsliced-gray"`` / ``"binned"``);
    * ``card`` / ``n_rows`` — the column's dense value domain and length;
    * ``streams`` — the per-bitmap EWAH uint32 arrays (None when built with
      ``materialize=False``) and ``sizes`` — their word counts;
    * ``compile_eq / compile_in / compile_range`` — emit plan nodes against
      a :class:`~repro.core.query.PlanContext`.  The planner has already
      clamped inputs to the domain: ``0 <= value < card``, ``values`` is a
      sorted non-empty in-domain tuple, ``0 <= lo <= hi < card``.
    """

    kind = "abstract"

    card: int
    n_rows: int
    streams: list | None
    sizes: np.ndarray

    @property
    def n_streams(self) -> int:
        return len(self.sizes)

    def size_words(self) -> int:
        return int(self.sizes.sum())

    def compile_eq(self, ctx, value: int):
        raise NotImplementedError

    def compile_in(self, ctx, values):
        return _or_node([self.compile_eq(ctx, v) for v in values])

    def compile_range(self, ctx, lo: int, hi: int):
        return self.compile_in(ctx, range(lo, hi + 1))


def _and_node(nodes):
    return nodes[0] if len(nodes) == 1 else ("and", tuple(nodes))


def _or_node(nodes):
    return nodes[0] if len(nodes) == 1 else ("or", tuple(nodes))


class EqualityEncoding(ColumnEncoding):
    """k-of-N value bitmaps (the paper's encoding, extracted).

    ``Eq`` is the AND of the value's k bitmaps, ``In``/``Range`` OR those
    fan-ins; a range wider than half the domain compiles through the
    compressed-domain complement (``Not(In(complement))`` — a marker-type
    flip, no densification) so its fan-in never exceeds card/2.
    """

    kind = "equality"

    def __init__(self, codes, N, k, sizes, streams, card, n_rows):
        self.codes = codes
        self.N = N
        self.k = k
        self.sizes = sizes
        self.streams = streams
        self.card = card
        self.n_rows = n_rows

    @classmethod
    def build(cls, col, card, hist, spec, materialize: bool = True):
        codes, N, k_eff = assign_codes(
            card, spec.k, spec.code_order, spec.resolved_value_policy(), hist)
        sizes, _, _ = column_bitmap_sizes(col, codes, N)
        streams = (_materialize_streams(col, codes, N, len(col))
                   if materialize else None)
        return cls(codes, N, k_eff, sizes, streams, card, len(col))

    def compile_eq(self, ctx, value: int):
        return _and_node([ctx.leaf(self.streams[int(b)])
                          for b in self.codes[value]])

    def compile_range(self, ctx, lo: int, hi: int):
        width = hi - lo + 1
        if width == self.card:
            return ctx.ones()
        # a range spanning more than half the domain compiles through the
        # compressed-domain complement: rows hold exactly one dense value
        # id, so Not(In(complement)) is exact and halves the OR fan-in
        if width > self.card - width:
            return ("not", self.compile_in(
                ctx, [*range(0, lo), *range(hi + 1, self.card)]))
        return self.compile_in(ctx, range(lo, hi + 1))


class RoaringEncoding(ColumnEncoding):
    """Roaring-style chunked containers, one container set per value.

    Each attribute value's row set is a :class:`~repro.core.containers.
    ContainerSet`: per aligned 2^16-row chunk, a sorted-array / bitmap /
    run container chosen by the Roaring cardinality/run-count thresholds
    at seal time (docs/containers.md).  ``Eq`` compiles to a single
    ``("cfold", ...)`` node, ``In``/``Range`` to a container-wise OR fold
    over the member values, and a range wider than half the domain goes
    through the compressed-domain complement exactly like the equality
    encoding.  Backends evaluate the fold container-wise — galloping
    array∩array / array∩bitmap intersection, batched word-space Pallas
    merges — and lower the result to one canonical ``EwahStream`` leaf,
    so everything downstream of the plan root (caching, tombstones,
    fan-out, sanitizers) is unchanged.  Raw columns are not needed at
    query time (``keep_columns=False`` safe).
    """

    kind = "roaring"

    def __init__(self, csets, sizes, card, n_rows):
        self.csets = csets
        self.streams = csets  # non-None marks the column queryable
        self.sizes = sizes
        self.card = card
        self.n_rows = n_rows

    @classmethod
    def build(cls, col, card, hist, spec, materialize: bool = True):
        from . import containers
        order = np.argsort(col, kind="stable")
        sorted_vals = col[order]
        boundaries = np.flatnonzero(np.diff(sorted_vals)) + 1
        groups = np.split(order, boundaries)
        vals = (sorted_vals[np.concatenate(([0], boundaries))]
                if len(col) else [])
        pos_per_value = {int(v): g for v, g in zip(vals, groups)}
        empty = np.empty(0, dtype=np.int64)
        csets = [containers.from_positions(
            np.sort(pos_per_value.get(v, empty)), len(col))
            for v in range(card)]
        sizes = np.asarray([cs.size_words() for cs in csets],
                           dtype=np.int64)
        return cls(csets if materialize else None, sizes, card, len(col))

    def _cfold(self, ctx, csets):
        cids = tuple(ctx.container(cs) for cs in csets)
        est = int(sum(cs.size_words() for cs in csets))
        return ("cfold", ("or",) * (len(cids) - 1), cids, est)

    def compile_eq(self, ctx, value: int):
        return self._cfold(ctx, [self.csets[int(value)]])

    def compile_in(self, ctx, values):
        return self._cfold(ctx, [self.csets[int(v)] for v in values])

    def compile_range(self, ctx, lo: int, hi: int):
        width = hi - lo + 1
        if width == self.card:
            return ctx.ones()
        # same complement trick as EqualityEncoding: over half the domain,
        # fold the complement values and marker-flip the result
        if width > self.card - width:
            return ("not", self.compile_in(
                ctx, [*range(0, lo), *range(hi + 1, self.card)]))
        return self.compile_in(ctx, range(lo, hi + 1))


class BitSlicedEncoding(ColumnEncoding):
    """``m = ceil(log2(card))`` EWAH slice planes; ranges in O(m) merges.

    Plane i holds the rows whose (optionally Gray-coded) value has bit i
    set.  ``x >= c`` is the textbook slice comparison fold, processed
    lsb -> msb::

        G = plane[j]                   # j = lowest set bit of c
        for i in j+1 .. m-1:
            G = (G AND plane[i]) if c_i else (G OR plane[i])

    emitted as one ``("fold", ops, children)`` plan node — ``m - 1`` binary
    merges however wide the range; ``lo <= x <= hi`` is
    ``Geq(lo) AND NOT Geq(hi + 1)`` (<= ``2m`` merges total, vs up to
    card/2 ORs for the equality encoding).  With ``gray=True`` the circuit
    first decodes binary bit i as the XOR suffix of the Gray planes
    (``b_i = g_i ^ g_{i+1} ^ ... ^ g_{m-1}``), again as fold nodes.
    """

    kind = "bitsliced"

    def __init__(self, n_bits, gray, sizes, streams, card, n_rows):
        self.n_bits = n_bits
        self.gray = gray
        self.sizes = sizes
        self.streams = streams
        self.card = card
        self.n_rows = n_rows

    @classmethod
    def build(cls, col, card, hist, spec, materialize: bool = True,
              gray: bool = False):
        col = np.asarray(col)
        m = max(1, int(math.ceil(math.log2(card))) if card > 1 else 1)
        keys = to_gray(col).astype(np.uint64) if gray else \
            col.astype(np.uint64)
        bits = [((keys >> np.uint64(i)) & np.uint64(1)).astype(np.int64)
                for i in range(m)]
        if not materialize:
            # size-only: exact per-plane EWAH sizes without emitting
            # streams (index_size_report's contract) — each plane is the
            # "value 1" bitmap of its bit column
            sizes = np.asarray([_one_bitmap_size(b, len(col))
                                for b in bits], dtype=np.int64)
            return cls(m, gray, sizes, None, card, len(col))
        streams = [_positions_to_stream(np.flatnonzero(b), len(col))
                   for b in bits]
        sizes = np.asarray([len(s) for s in streams], dtype=np.int64)
        return cls(m, gray, sizes, streams, card, len(col))

    def _key(self, value: int) -> int:
        return int(to_gray(np.uint64(value))) if self.gray else int(value)

    def _bit_node(self, ctx, i: int):
        """Plan node for "binary bit i of the row's value is set".

        Gray mode re-emits the full XOR suffix per bit (plans are trees —
        no shared sub-expressions), so a Gray range circuit carries O(m^2)
        leaves vs the binary circuit's m; the numpy cached path dedups the
        suffixes in the result cache, but Gray planes remain the
        size-biased variant ('auto' never picks them, docs/encodings.md).
        """
        if not self.gray or i == self.n_bits - 1:
            return ctx.leaf(self.streams[i])
        children = tuple(ctx.leaf(self.streams[j])
                         for j in range(i, self.n_bits))
        return ("fold", ("xor",) * (len(children) - 1), children)

    def compile_eq(self, ctx, value: int):
        key = self._key(value)
        nodes = []
        for i in range(self.n_bits):
            leaf = ctx.leaf(self.streams[i])
            nodes.append(leaf if (key >> i) & 1 else ("not", leaf))
        return _and_node(nodes)

    def _geq_node(self, ctx, c: int):
        """Node for ``value >= c`` (``None`` = all rows, for c == 0)."""
        if c <= 0:
            return None
        j = (c & -c).bit_length() - 1            # lowest set bit of c
        children = [self._bit_node(ctx, j)]
        ops = []
        for i in range(j + 1, self.n_bits):
            ops.append("and" if (c >> i) & 1 else "or")
            children.append(self._bit_node(ctx, i))
        if not ops:
            return children[0]
        return ("fold", tuple(ops), tuple(children))

    def compile_range(self, ctx, lo: int, hi: int):
        lower = self._geq_node(ctx, lo)
        upper = (None if hi >= self.card - 1
                 else ("not", self._geq_node(ctx, hi + 1)))
        if lower is None and upper is None:
            return ctx.ones()
        if upper is None:
            return lower
        if lower is None:
            return upper
        return ("and", (lower, upper))

    def compile_in(self, ctx, values):
        # contiguous runs compile as O(log card) range circuits, isolated
        # values as plane-AND equalities
        values = list(values)
        nodes, start, prev = [], values[0], values[0]
        for v in values[1:] + [None]:
            if v is not None and v == prev + 1:
                prev = v
                continue
            if prev - start + 1 >= 3:
                nodes.append(self.compile_range(ctx, start, prev))
            else:
                nodes.extend(self.compile_eq(ctx, u)
                             for u in range(start, prev + 1))
            if v is not None:
                start = prev = v
        return _or_node(nodes)


class BitSlicedGrayEncoding(BitSlicedEncoding):
    kind = "bitsliced-gray"

    @classmethod
    def build(cls, col, card, hist, spec, materialize: bool = True):
        return super().build(col, card, hist, spec, materialize=materialize,
                             gray=True)


class BinnedEncoding(ColumnEncoding):
    """Histogram-equalized value bins + lazy candidate-check refinement.

    The value domain partitions into ``n_bins`` contiguous bins holding
    ~equal row counts (boundaries read off the cumulative histogram — the
    histogram-aware part), one EWAH bitmap per bin.  A range is the OR of
    its fully-covered bins plus one *exact* leaf for the partial boundary
    values (the binned literature's candidate check); ``Eq``/``In`` always
    refine — exact results on every backend.

    Refinement is a **lazy post-filter on the row-value surface**: the
    build keeps each row's value in sorted-row order (``_values``, one
    narrow integer per row — the row-id surface an exact candidate check
    needs, since bins merge values and the coarse bitmaps alone cannot
    tell boundary values apart) and each query materializes only its own
    boundary spans from it.  Nothing here reaches back into the segment's
    raw-column row store, so binned columns work unchanged on
    ``Segment.seal(keep_columns=False)`` segments (dist fan-out shards);
    the former value->rows CSR resolved the same spans from 2 int64 words
    per row of retained base data — 4x the memory — and silently pinned
    that base data to supposedly raw-column-free segments.

    ``sizes``/``size_words`` count only the compressed EWAH bin words, so
    binned sizes compare like-for-like against the other encodings'
    compressed footprints; the value surface is *base-data access*, the
    same role as a segment's retained ingest-order columns, and like those
    it is deliberately outside the compressed-size accounting
    (docs/encodings.md lists it as the encoding's extra state).
    """

    kind = "binned"

    def __init__(self, edges, sizes, streams, values, card, n_rows):
        self.edges = edges        # (n_bins + 1,) value boundaries
        self.sizes = sizes
        self.streams = streams
        self._values = values     # per-row values, sorted-row order
        self.card = card
        self.n_rows = n_rows

    @property
    def n_bins(self) -> int:
        return len(self.edges) - 1

    @classmethod
    def build(cls, col, card, hist, spec, materialize: bool = True):
        col = np.asarray(col)
        n_bins = max(2, min(64, int(round(math.sqrt(card)))))
        n_bins = min(n_bins, card)
        hist = np.asarray(hist, dtype=np.int64)
        cum = np.cumsum(hist)
        total = int(cum[-1]) if len(cum) else 0
        # histogram-equalized boundaries: split the cumulative mass evenly
        targets = total * np.arange(1, n_bins) / n_bins
        inner = np.searchsorted(cum, targets, side="left") + 1
        edges = np.unique(np.concatenate(([0], inner, [card])))
        edges = edges[edges <= card].astype(np.int64)
        bin_of = np.searchsorted(edges, np.arange(card), side="right") - 1
        if not materialize:
            # size-only: exact per-bin sizes from the bin-id column (one
            # identity-coded size pass, no streams, no CSR)
            sizes, _, _ = column_bitmap_sizes(
                bin_of[col], np.arange(len(edges) - 1,
                                       dtype=np.int64)[:, None],
                len(edges) - 1)
            return cls(edges, sizes, None, None, card, len(col))
        values = col.astype(np.int32 if card <= np.iinfo(np.int32).max
                            else np.int64)
        streams = []
        for b in range(len(edges) - 1):
            mask = (values >= edges[b]) & (values < edges[b + 1])
            streams.append(_positions_to_stream(np.flatnonzero(mask),
                                                len(col)))
        sizes = np.asarray([len(s) for s in streams], dtype=np.int64)
        return cls(edges, sizes, streams, values, card, len(col))

    def _exact_leaf(self, ctx, mask):
        """One leaf holding exactly the rows whose value-surface ``mask``
        is set — the lazy candidate-check refinement."""
        pos = np.flatnonzero(mask)
        if not len(pos):
            return ctx.zero()
        return ctx.leaf(_positions_to_stream(pos, self.n_rows))

    def compile_eq(self, ctx, value: int):
        return self._exact_leaf(ctx, self._values == value)

    def compile_in(self, ctx, values):
        return self._exact_leaf(
            ctx, np.isin(self._values,
                         np.asarray(values, dtype=self._values.dtype)))

    def compile_range(self, ctx, lo: int, hi: int):
        if lo == 0 and hi == self.card - 1:
            return ctx.ones()
        # fully-covered bins ship their coarse bitmaps as-is
        b_lo = int(np.searchsorted(self.edges, lo, side="right")) - 1
        b_hi = int(np.searchsorted(self.edges, hi, side="right")) - 1
        nodes, refine = [], None
        for b in range(b_lo, b_hi + 1):
            v0, v1 = int(self.edges[b]), int(self.edges[b + 1]) - 1
            if lo <= v0 and v1 <= hi:
                nodes.append(ctx.leaf(self.streams[b]))
            else:  # partial boundary bin -> candidate-check refinement
                s_lo, s_hi = max(lo, v0), min(hi, v1)
                span = (self._values >= s_lo) & (self._values <= s_hi)
                refine = span if refine is None else refine | span
        if refine is not None:
            nodes.append(self._exact_leaf(ctx, refine))
        return _or_node(nodes)


ENCODINGS: dict[str, type] = {
    EqualityEncoding.kind: EqualityEncoding,
    BitSlicedEncoding.kind: BitSlicedEncoding,
    BitSlicedGrayEncoding.kind: BitSlicedGrayEncoding,
    BinnedEncoding.kind: BinnedEncoding,
    RoaringEncoding.kind: RoaringEncoding,
}


def encoding_kinds() -> tuple:
    """The registered concrete encoding kinds (chooser return values)."""
    return tuple(sorted(ENCODINGS))


def build_encoding(kind: str, col, card, hist, spec,
                   materialize: bool = True) -> ColumnEncoding:
    """Construct one column's encoding by kind name (ValueError lists the
    registered kinds on a miss — e.g. an ``encoding`` strategy returning a
    name no encoding class claims)."""
    try:
        cls = ENCODINGS[kind]
    except KeyError:
        raise ValueError(
            f"unknown column encoding {kind!r}; registered: "
            f"{', '.join(encoding_kinds())}") from None
    return cls.build(col, card, hist, spec, materialize=materialize)


def _materialize_streams(col, codes, N, n_rows):
    """Per-bitmap compressed streams in O(n*k + sum of stream sizes)."""
    order = np.argsort(col, kind="stable")
    sorted_vals = col[order]
    # row positions per value, grouped
    boundaries = np.flatnonzero(np.diff(sorted_vals)) + 1
    groups = np.split(order, boundaries)
    vals = sorted_vals[np.concatenate(([0], boundaries))] if len(col) else []
    pos_per_value = {int(v): g for v, g in zip(vals, groups)}
    per_bitmap_positions = [[] for _ in range(N)]
    for v, pos in pos_per_value.items():
        for b in codes[v]:
            per_bitmap_positions[int(b)].append(pos)
    streams = []
    for plist in per_bitmap_positions:
        if plist:
            pos = np.sort(np.concatenate(plist))
            words = ewah.positions_to_words(pos, n_rows)
        else:
            words = np.zeros((n_rows + 31) // 32, dtype=np.uint32)
        streams.append(ewah.compress(words))
    return streams
