"""Core: the paper's contribution — EWAH compression, k-of-N encodings,
histogram-aware row/column reordering, compressed-domain logical ops."""

from . import column_order, encoding, ewah, histogram, index_size, sorting
from .bitmap_index import BitmapIndex, assign_codes, index_size_report

__all__ = [
    "BitmapIndex",
    "assign_codes",
    "index_size_report",
    "column_order",
    "encoding",
    "ewah",
    "histogram",
    "index_size",
    "sorting",
]
