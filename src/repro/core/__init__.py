"""Core: the paper's contribution — EWAH compression, k-of-N encodings,
histogram-aware row/column reordering, compressed-domain logical ops — behind
one composable API: IndexSpec (strategy registry) -> BitmapIndex.build ->
predicate algebra (query.Eq/In/Range/And/Or/Not) -> pluggable backends."""

from . import (column_order, encoding, ewah, ewah_stream, histogram,
               index_size, query, sorting, strategies)
from .bitmap_index import BitmapIndex, assign_codes, index_size_report
from .ewah_stream import EwahStream
from .query import And, Eq, In, Not, Or, Range
from .strategies import IndexSpec

__all__ = [
    "BitmapIndex",
    "EwahStream",
    "IndexSpec",
    "assign_codes",
    "index_size_report",
    "And",
    "Eq",
    "In",
    "Not",
    "Or",
    "Range",
    "column_order",
    "encoding",
    "ewah",
    "ewah_stream",
    "histogram",
    "index_size",
    "query",
    "sorting",
    "strategies",
]
