"""Core: the paper's contribution — EWAH compression, k-of-N encodings,
histogram-aware row/column reordering, compressed-domain logical ops — behind
one composable API: IndexSpec (strategy registry) -> IndexWriter (append /
seal / compact lifecycle) -> Segment / SegmentedIndex -> predicate algebra
(query.Eq/In/Range/And/Or/Not) -> pluggable backends.  BitmapIndex.build is
the seal-once convenience over the writer."""

from . import (column_order, encoding, encodings, ewah, ewah_stream,
               histogram, index_size, query, sorting, strategies)
from .bitmap_index import BitmapIndex, assign_codes, index_size_report
from .ewah_stream import EwahStream
from .lifecycle import (BackgroundCompactor, IndexWriter, compact,
                        size_tiered_pick)
from .query import And, Eq, In, Not, Or, Range, evaluate_mask
from .segment import Segment, SegmentedIndex
from .strategies import IndexSpec

__all__ = [
    "BackgroundCompactor",
    "BitmapIndex",
    "EwahStream",
    "IndexSpec",
    "IndexWriter",
    "Segment",
    "SegmentedIndex",
    "assign_codes",
    "compact",
    "evaluate_mask",
    "index_size_report",
    "size_tiered_pick",
    "And",
    "Eq",
    "In",
    "Not",
    "Or",
    "Range",
    "column_order",
    "encoding",
    "encodings",
    "ewah",
    "ewah_stream",
    "histogram",
    "index_size",
    "query",
    "sorting",
    "strategies",
]

# import-cycle note: segment/lifecycle import bitmap_index at module level;
# bitmap_index reaches lifecycle lazily inside build(), so the order above
# (bitmap_index first) is load-bearing.
