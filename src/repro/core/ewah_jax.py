"""In-graph (jit-able) EWAH: vectorized compress / decompress / size.

TPU adaptation (DESIGN.md §3): the CPU codec is a sequential append loop;
here compression is re-cast as classify -> run-labeling -> exclusive-scan ->
scatter, which is O(n) work at O(log n) depth and maps onto VPU-friendly
primitives.  The *size-only* path (what the sorting heuristics optimize) is a
pure reduction.

Restrictions of the vectorized path (asserted): one marker per (clean,dirty)
group, i.e. clean runs < 2^16 and dirty runs < 2^15 words — always true for
the in-graph uses (MoE dispatch bitmaps over <= 32767-word streams).  The
numpy oracle in ``ewah.py`` has no such restriction.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .ewah import FULL, MAX_CLEAN, MAX_DIRTY  # noqa: F401  (shared constants)

_FULL = jnp.uint32(0xFFFFFFFF)


def classify(words: jax.Array) -> jax.Array:
    """0 = clean-0, 1 = clean-1, 2 = dirty."""
    return jnp.where(words == 0, 0, jnp.where(words == _FULL, 1, 2)).astype(jnp.int32)


def _run_ids(kind: jax.Array):
    start = jnp.concatenate([jnp.ones(1, bool), kind[1:] != kind[:-1]])
    run_id = jnp.cumsum(start) - 1
    return start, run_id


@partial(jax.jit, static_argnames=("capacity",))
def compress(words: jax.Array, capacity: int):
    """EWAH-compress a uint32 word vector. Returns (stream[capacity], length).

    Requires n_words <= MAX_DIRTY (asserted statically) so that every
    (clean run, dirty run) group fits a single marker.
    """
    kind = classify(words)
    start, _ = _run_ids(kind)
    return compress_from_runs(words, kind, start, capacity)


def compress_from_runs(words: jax.Array, kind: jax.Array, start: jax.Array,
                       capacity: int):
    """Scan/scatter epilogue of the vectorized compressor.

    ``kind`` (0/1/2 per word) and ``start`` (run-boundary flags) come either
    from :func:`classify` + ``_run_ids`` (the jnp path in :func:`compress`)
    or from the fused Pallas prefix pass (``kernels.ops.recompress_batch``).
    Vmappable — the jax query backend re-encodes a whole batch of query
    results per dispatch.  Returns (stream[capacity], length).
    """
    n = words.shape[0]
    assert n <= MAX_DIRTY, f"vectorized path supports <= {MAX_DIRTY} words"
    run_id = jnp.cumsum(start.astype(jnp.int32)) - 1
    n_runs = run_id[-1] + 1
    idx = jnp.arange(n)

    run_kind = jax.ops.segment_max(kind, run_id, num_segments=n)
    run_len = jax.ops.segment_sum(jnp.ones(n, jnp.int32), run_id, num_segments=n)
    run_valid = jnp.arange(n) < n_runs

    # groups: every clean run opens a group; a leading dirty run opens one too
    run_is_clean = run_kind < 2
    grp_start = run_is_clean | (jnp.arange(n) == 0)
    grp_of_run = jnp.cumsum(grp_start & run_valid) - 1
    n_groups = jnp.maximum(grp_of_run[jnp.maximum(n_runs - 1, 0)] + 1, 1)

    grp_nclean = jax.ops.segment_sum(
        jnp.where(run_is_clean & run_valid, run_len, 0), grp_of_run, num_segments=n)
    grp_ndirty = jax.ops.segment_sum(
        jnp.where(~run_is_clean & run_valid, run_len, 0), grp_of_run, num_segments=n)
    grp_ctype = jax.ops.segment_max(
        jnp.where(run_is_clean & run_valid, run_kind, 0), grp_of_run, num_segments=n)

    grp_size = jnp.where(jnp.arange(n) < n_groups, 1 + grp_ndirty, 0)
    grp_off = jnp.cumsum(grp_size) - grp_size  # exclusive scan
    total = grp_off[jnp.maximum(n_groups - 1, 0)] + grp_size[jnp.maximum(n_groups - 1, 0)]

    # markers
    marker = (
        (grp_ctype.astype(jnp.uint32) << 31)
        | (grp_nclean.astype(jnp.uint32) << 15)
        | grp_ndirty.astype(jnp.uint32)
    )
    out = jnp.zeros(capacity + 1, jnp.uint32)
    mpos = jnp.where(jnp.arange(n) < n_groups, grp_off, capacity)
    out = out.at[mpos].set(marker, mode="drop")

    # dirty words: word i (dirty) goes to grp_off[g] + 1 + rank-within-dirty-run
    word_run = run_id
    word_grp = grp_of_run[word_run]
    run_start_idx = jax.ops.segment_min(idx, run_id, num_segments=n)
    t = idx - run_start_idx[word_run]
    is_dirty_w = kind == 2
    dpos = jnp.where(is_dirty_w, grp_off[word_grp] + 1 + t, capacity)
    out = out.at[dpos].set(words, mode="drop")
    return out[:capacity], total


@partial(jax.jit, static_argnames=("capacity",))
def compressed_size(words: jax.Array, capacity: int = 0):
    """Compressed size in words (markers + dirty), no materialization.

    Exact for streams within the single-marker-per-group restriction.
    """
    n = words.shape[0]
    kind = classify(words)
    start, run_id = _run_ids(kind)
    n_runs = run_id[-1] + 1
    run_kind = jax.ops.segment_max(kind, run_id, num_segments=n)
    run_valid = jnp.arange(n) < n_runs
    run_is_clean = run_kind < 2
    n_groups = jnp.maximum(
        jnp.sum((run_is_clean & run_valid).astype(jnp.int32))
        + jnp.where(run_kind[0] == 2, 1, 0), 1)
    n_dirty = jnp.sum((kind == 2).astype(jnp.int32))
    return n_groups + n_dirty


@partial(jax.jit, static_argnames=("n_words",))
def decompress(stream: jax.Array, length, n_words: int):
    """Expand an EWAH stream into n_words uint32 words (scan-based)."""
    C = stream.shape[0]

    def step(carry, w):
        i, dirty_rem, out_pos = carry
        active = i < length
        is_dirty = dirty_rem > 0
        ctype = (w >> 31) & 1
        nclean = ((w >> 15) & 0xFFFF).astype(jnp.int32)
        ndirty = (w & 0x7FFF).astype(jnp.int32)
        # dirty word event
        dw_pos = jnp.where(active & is_dirty, out_pos, n_words)
        # marker event: clean run [out_pos, out_pos + nclean)
        mk = active & ~is_dirty
        c_start = jnp.where(mk & (ctype == 1), out_pos, n_words)
        c_len = jnp.where(mk, nclean, 0)
        new_out = out_pos + jnp.where(is_dirty, 1, c_len)
        new_dirty = jnp.where(is_dirty, dirty_rem - 1, jnp.where(mk, ndirty, 0))
        return (i + 1, new_dirty, new_out), (dw_pos, w, c_start, c_len)

    (_, _, final_pos), (dpos, dval, c1s, clen) = jax.lax.scan(
        step, (jnp.int32(0), jnp.int32(0), jnp.int32(0)), stream)
    out = jnp.zeros(n_words + 1, jnp.uint32)
    out = out.at[dpos].set(dval, mode="drop")
    # clean-1 region fill via +1/-1 events and cumsum
    ev = jnp.zeros(n_words + 1, jnp.int32)
    ev = ev.at[c1s].add(1, mode="drop")
    c1e = jnp.where(c1s < n_words, c1s + clen, n_words + 1)
    ev = ev.at[c1e].add(-1, mode="drop")
    infull = jnp.cumsum(ev[:-1]) > 0
    out = jnp.where(infull, _FULL, out[:-1])
    return out


def logical_op(stream_a, len_a, stream_b, len_b, n_words: int, op: str, capacity: int):
    """Compressed op via decompress->op->recompress (vectorized path).

    The O(|A|+|B|) streaming merge lives in the numpy codec and the Pallas
    wordops kernel covers the word-level op; in-graph we trade compressed-
    domain skipping for 128-lane parallelism (DESIGN.md §3).
    """
    a = decompress(stream_a, len_a, n_words)
    b = decompress(stream_b, len_b, n_words)
    fn = {"and": jnp.bitwise_and, "or": jnp.bitwise_or, "xor": jnp.bitwise_xor}[op]
    return compress(fn(a, b), capacity)
