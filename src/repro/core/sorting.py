"""Row-reordering heuristics (paper §4.1, §4.2, §4.4).

All functions return a permutation ``perm`` such that ``col[perm]`` is the
reordered column.  Column order matters: ``columns[0]`` is the primary sort
key (the paper's d_1).
"""

from __future__ import annotations

from functools import cmp_to_key

import numpy as np

from .encoding import gray_less
from .histogram import column_histogram, freq_rank_keys


def order_unsorted(columns) -> np.ndarray:
    return np.arange(len(columns[0]))


def order_lex(columns) -> np.ndarray:
    """Lexicographic row sort; columns[0] is the primary key.

    This is the row order of both Alpha-Lex and Gray-Lex (they differ only
    in how bitmap codes are allocated to attribute values, §4.2).
    """
    # np.lexsort's *last* key is primary
    return np.lexsort(tuple(np.asarray(c) for c in reversed(columns)))


def order_gray_frequency(columns, hists=None) -> np.ndarray:
    """Gray-Frequency (§4.2): lexicographically sort the extended rows
    f(a_1), a_1, f(a_2), a_2, ... — i.e. within each column, cluster values
    of equal frequency, ordering value classes by descending frequency."""
    columns = [np.asarray(c) for c in columns]
    if hists is None:
        hists = [column_histogram(c) for c in columns]
    keys = []
    for col, hist in zip(columns, hists):
        keys.append(freq_rank_keys(col, hist))
    return np.lexsort(tuple(reversed(keys)))


def order_frequent_component(columns, hists=None) -> np.ndarray:
    """Frequent-Component (§4.4): compare rows by their i-th most frequent
    attribute-value frequency, regardless of which column it came from;
    ties broken by the row values themselves."""
    columns = [np.asarray(c) for c in columns]
    if hists is None:
        hists = [column_histogram(c) for c in columns]
    n = len(columns[0])
    freqs = np.stack([h[c] for c, h in zip(columns, hists)], axis=1)  # (n, c)
    freqs = -np.sort(-freqs, axis=1)  # descending per row
    keys = [freqs[:, i] for i in range(freqs.shape[1])]
    # negative so the most-frequent-first rows compare adjacently in
    # descending frequency order, then tie-break on raw values
    keys = [-k for k in keys] + [np.asarray(c) for c in columns]
    return np.lexsort(tuple(reversed(keys)))


def order_gray_code(columns, codes_per_col) -> np.ndarray:
    """True Gray-code row sort over the concatenated k-of-N codes
    (Algorithm 2 comparator).  O(n log n) comparisons, python speed — the
    paper found this 2 orders of magnitude slower than lexicographic sort;
    provided for validation on small inputs."""
    columns = [np.asarray(c) for c in columns]
    n = len(columns[0])
    # build per-row sparse positions of ones across the concatenated bitmaps
    pos_rows = []
    offset = 0
    per_col_pos = []
    for col, codes in zip(columns, codes_per_col):
        per_col_pos.append(np.sort(codes[col], axis=1) + offset)
        offset += int(codes.max()) + 1
    allpos = np.concatenate(per_col_pos, axis=1)
    allpos.sort(axis=1)

    def cmp(i, j):
        if gray_less(allpos[i], allpos[j]):
            return -1
        if gray_less(allpos[j], allpos[i]):
            return 1
        return 0

    return np.asarray(sorted(range(n), key=cmp_to_key(cmp)), dtype=np.int64)


ORDERINGS = {
    "unsorted": order_unsorted,
    "lex": order_lex,
    "grayfreq": order_gray_frequency,
    "freqcomp": order_frequent_component,
}


def order_rows(columns, method: str = "lex", hists=None) -> np.ndarray:
    """Row permutation by strategy name; unknown names raise ValueError
    listing the registered row-order strategies."""
    from .strategies import get_strategy  # function-level: no import cycle

    return get_strategy("row_order", method)(columns, hists)
