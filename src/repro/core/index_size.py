"""Exact EWAH index sizes without materializing bitmaps.

The paper's Algorithm 1 builds a compressed index in O(nck + L) by touching
only dirtied bitmaps per 32-row block.  This module computes the *size* of
that index (markers + verbatim words, per bitmap) with the same complexity,
which lets the benchmarks reproduce the paper's size tables (Tables 3-4,
Figs. 4-5) on multi-million-row tables without allocating n*L bits.

Verified against the dense oracle (``ewah.compress`` of fully materialized
bitmaps) in tests/test_index_size.py.
"""

from __future__ import annotations

import numpy as np

from .ewah import MAX_CLEAN, MAX_DIRTY, WORD_BITS


def _ceil_div(a, b):
    return -(-a // b)


def column_bitmap_sizes(
    col: np.ndarray, codes: np.ndarray, n_bitmaps: int
) -> tuple[np.ndarray, int, int]:
    """Exact per-bitmap EWAH sizes for one table column.

    Args:
      col: (n,) int array of 0-based attribute-value ids, in *table row order*.
      codes: (n_values, k) int array; value v sets bitmaps ``codes[v]``.
      n_bitmaps: number of bitmaps L for this column (the N of k-of-N).

    Returns:
      (sizes, total_markers, total_dirty) where sizes is (n_bitmaps,) int64
      EWAH word counts (markers + verbatim) per bitmap, including trailing
      clean runs so all bitmaps represent exactly n rows (Algorithm 1 does
      the same).
    """
    col = np.asarray(col)
    n = len(col)
    codes = np.asarray(codes, dtype=np.int64)
    k = codes.shape[1]
    n_blocks = _ceil_div(n, WORD_BITS)

    # --- (block, value) occupancy counts ---------------------------------
    block = np.arange(n, dtype=np.int64) // WORD_BITS
    n_vals = codes.shape[0]
    bv_key = block * n_vals + col.astype(np.int64)
    bv_unique, bv_counts = np.unique(bv_key, return_counts=True)
    blk_v = bv_unique // n_vals
    val_v = bv_unique % n_vals

    # --- expand to (block, bitmap) events, merging values sharing bitmaps --
    bmaps = codes[val_v]  # (m, k)
    ev_block = np.repeat(blk_v, k)
    ev_bitmap = bmaps.reshape(-1)
    ev_count = np.repeat(bv_counts, k)
    key = ev_bitmap * n_blocks + ev_block  # sorted-by-(bitmap, block) later
    uniq, inv = np.unique(key, return_inverse=True)
    counts = np.zeros(len(uniq), dtype=np.int64)
    np.add.at(counts, inv, ev_count)
    bm = uniq // n_blocks
    blk = uniq % n_blocks
    # clean-1 word iff all 32 rows of a *full* block set this bitmap
    is_c1 = counts == WORD_BITS
    is_dirty = ~is_c1

    sizes = np.zeros(n_bitmaps, dtype=np.int64)
    total_dirty = int(is_dirty.sum())
    np.add.at(sizes, bm[is_dirty], 1)  # verbatim words

    # --- run structure (events are sorted by bitmap, then block) ----------
    m = len(bm)
    markers = 0
    if m:
        first = np.empty(m, dtype=bool)
        first[0] = True
        first[1:] = bm[1:] != bm[:-1]
        adjacent = np.zeros(m, dtype=bool)
        adjacent[1:] = (~first[1:]) & (blk[1:] == blk[:-1] + 1)
        same_kind = np.zeros(m, dtype=bool)
        same_kind[1:] = is_c1[1:] == is_c1[:-1]
        run_start = ~(adjacent & same_kind)
        starts = np.flatnonzero(run_start)
        run_bm = bm[starts]
        run_kind_c1 = is_c1[starts]
        run_len = np.diff(np.append(starts, m))
        # gap (clean-0 run) before each run
        gap = np.empty(len(starts), dtype=np.int64)
        run_first_of_bitmap = first[starts]
        prev_idx = starts - 1
        gap[:] = blk[starts] - np.where(run_first_of_bitmap, -1, blk[prev_idx]) - 1
        # trailing clean-0 run per bitmap (after its last event)
        bm_ids, last_pos = np.unique(bm[::-1], return_index=True)
        last_blk = blk[m - 1 - last_pos]
        trailing = n_blocks - 1 - last_blk

        # markers from clean runs (c1 runs, c0 gaps, trailing c0)
        c1_markers = _ceil_div(run_len[run_kind_c1], MAX_CLEAN)
        np.add.at(sizes, run_bm[run_kind_c1], c1_markers)
        has_gap = gap > 0
        gap_markers = _ceil_div(gap[has_gap], MAX_CLEAN)
        np.add.at(sizes, run_bm[has_gap], gap_markers)
        has_tr = trailing > 0
        tr_markers = _ceil_div(trailing[has_tr], MAX_CLEAN)
        np.add.at(sizes, bm_ids[has_tr], tr_markers)
        # markers from dirty runs: overflow continuations, plus a marker of
        # its own only when the stream *starts* with a dirty run at block 0
        d = ~run_kind_c1
        d_overflow = np.maximum(0, _ceil_div(run_len[d], MAX_DIRTY) - 1)
        np.add.at(sizes, run_bm[d], d_overflow)
        starts_dirty = d & run_first_of_bitmap & (gap == 0)
        np.add.at(sizes, run_bm[starts_dirty], np.ones(int(starts_dirty.sum()), dtype=np.int64))
        markers = (
            int(c1_markers.sum())
            + int(gap_markers.sum())
            + int(tr_markers.sum())
            + int(d_overflow.sum())
            + int(starts_dirty.sum())
        )
        touched = np.unique(bm)
    else:
        touched = np.empty(0, dtype=np.int64)

    # bitmaps never touched: one pure clean-0 stream covering all blocks
    n_untouched = n_bitmaps - len(touched)
    if n_untouched:
        empty_markers = _ceil_div(n_blocks, MAX_CLEAN) if n_blocks else 1
        mask = np.ones(n_bitmaps, dtype=bool)
        mask[touched] = False
        sizes[mask] += empty_markers
        markers += empty_markers * n_untouched

    return sizes, markers, total_dirty


def table_index_size(
    columns: list[np.ndarray],
    codes_per_col: list[np.ndarray],
    n_bitmaps_per_col: list[int],
) -> dict:
    """Total EWAH index size for a table (one k-of-N encoded index per column)."""
    per_col = []
    total = 0
    markers = 0
    dirty = 0
    for col, codes, L in zip(columns, codes_per_col, n_bitmaps_per_col):
        sizes, mk, dt = column_bitmap_sizes(col, codes, L)
        per_col.append(int(sizes.sum()))
        total += int(sizes.sum())
        markers += mk
        dirty += dt
    return {
        "total_words": total,
        "per_column_words": per_col,
        "markers": markers,
        "dirty_words": dirty,
    }


def storage_cost_bound(n_i: int, k: int) -> float:
    """Proposition 2 bound: sorted column storage cost <= 4*n_i + ceil(k*n_i^(1/k))."""
    return 4.0 * n_i + np.ceil(k * n_i ** (1.0 / k))
