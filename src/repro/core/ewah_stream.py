"""Streaming compressed-domain query ops in JAX (lax.while_loop).

The paper's §3 claim — logical ops in time O(|B1| + |B2|) of the
*compressed* sizes — as an in-graph primitive: a dual-cursor walk over two
EWAH streams that never materializes the n/32 uncompressed words.  Each
iteration consumes at least one compressed word (or one clean-run overlap),
so trip count <= |A| + |B| + #markers.

``and_popcount`` returns the row count of (A AND B) — the equality-query
/ data-curation primitive (count rows matching both predicates).  The
iteration count is returned too, so tests assert the complexity claim.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _unpack(w):
    t = (w >> jnp.uint32(31)) & jnp.uint32(1)
    nc = (w >> jnp.uint32(15)) & jnp.uint32(0xFFFF)
    nd = w & jnp.uint32(0x7FFF)
    return t.astype(jnp.int32), nc.astype(jnp.int32), nd.astype(jnp.int32)


def and_popcount(sa: jax.Array, la, sb: jax.Array, lb):
    """Popcount of (A AND B) over two EWAH streams (uint32 arrays + lengths).

    Returns (count, iterations).  Streams must encode the same number of
    uncompressed words (the index builder guarantees this).
    """
    sa = sa.astype(jnp.uint32)
    sb = sb.astype(jnp.uint32)

    # cursor: (i, clean_rem, clean_type, dirty_rem)
    def load(s, length, cur):
        i, c, t, d = cur
        can = (c == 0) & (d == 0) & (i < length)
        w = s[jnp.minimum(i, s.shape[0] - 1)]
        nt, nc, nd = _unpack(w)
        return (jnp.where(can, i + 1, i),
                jnp.where(can, nc, c),
                jnp.where(can, nt, t),
                jnp.where(can, nd, d))

    def consume_clean(cur, n):
        i, c, t, d = cur
        return (i, c - n, t, d)

    def consume_dirty(cur):
        i, c, t, d = cur
        return (i + 1, c, t, d - 1)

    def cond(st):
        a, b, acc, it = st
        a_more = (a[1] > 0) | (a[3] > 0)
        b_more = (b[1] > 0) | (b[3] > 0)
        return a_more & b_more & (it < sa.shape[0] + sb.shape[0] + 4)

    def body(st):
        a, b, acc, it = st
        ia, ca, ta, da = a
        ib, cb, tb, db = b
        # a marker loads clean AND dirty counts together; the stream is in
        # its clean phase while clean_rem > 0, dirty phase after
        a_cl, b_cl = ca > 0, cb > 0
        a_dt, b_dt = (ca == 0) & (da > 0), (cb == 0) & (db > 0)
        both_clean = a_cl & b_cl
        a_clean_b_dirty = a_cl & b_dt
        a_dirty_b_clean = a_dt & b_cl
        both_dirty = a_dt & b_dt

        # case 1: overlap clean runs
        n = jnp.maximum(jnp.minimum(ca, cb), 1)
        add1 = jnp.where(both_clean & (ta == 1) & (tb == 1), n * 32, 0)

        # case 2/3: clean vs one dirty word (consume one word per step)
        wa = sa[jnp.minimum(ia, sa.shape[0] - 1)]
        wb = sb[jnp.minimum(ib, sb.shape[0] - 1)]
        add2 = jnp.where(a_clean_b_dirty & (ta == 1),
                         jnp.bitwise_count(wb).astype(jnp.int32), 0)
        add3 = jnp.where(a_dirty_b_clean & (tb == 1),
                         jnp.bitwise_count(wa).astype(jnp.int32), 0)
        # case 4: dirty & dirty
        add4 = jnp.where(both_dirty,
                         jnp.bitwise_count(wa & wb).astype(jnp.int32), 0)

        # consume
        a2 = jax.tree.map(
            lambda x, y: jnp.where(both_clean, x, y),
            consume_clean(a, n),
            jax.tree.map(lambda x, y: jnp.where(a_clean_b_dirty, x, y),
                         consume_clean(a, 1),
                         jax.tree.map(lambda x, y: jnp.where(both_dirty | a_dirty_b_clean, x, y),
                                      consume_dirty(a), a)))
        b2 = jax.tree.map(
            lambda x, y: jnp.where(both_clean, x, y),
            consume_clean(b, n),
            jax.tree.map(lambda x, y: jnp.where(a_dirty_b_clean, x, y),
                         consume_clean(b, 1),
                         jax.tree.map(lambda x, y: jnp.where(both_dirty | a_clean_b_dirty, x, y),
                                      consume_dirty(b), b)))
        a2 = load(sa, la, a2)
        b2 = load(sb, lb, b2)
        return (a2, b2, acc + add1 + add2 + add3 + add4, it + 1)

    zero = jnp.int32(0)
    a0 = load(sa, la, (zero, zero, zero, zero))
    b0 = load(sb, lb, (zero, zero, zero, zero))
    a0, b0 = jax.tree.map(jnp.asarray, (a0, b0))
    (_, _, acc, it) = jax.lax.while_loop(cond, body, (a0, b0, zero, zero))
    return acc, it
