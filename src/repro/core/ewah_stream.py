"""The compressed-domain stream engine: one public cursor/appender core.

Every layer that touches EWAH streams — the numpy logical ops, the query
backends' compressed execution path, the dist-shard result merge — runs on
the same two primitives defined here:

  * :class:`Cursor`   — iterates a compressed stream as
    (clean_rem, ctype, dirty_rem) runs without decompressing;
  * :class:`Appender` — re-compresses words/runs fed to it, coalescing
    adjacent clean runs of equal type.

On top of them:

  * :func:`logical_op` / :func:`logical_many` — the paper's §3 streaming
    merges, O(|A| + |B|) in *compressed* words;
  * :func:`logical_not` — compressed-domain complement by *marker-type
    flipping*: clean runs flip their type bit, verbatim words complement in
    place.  One pass over the stream itself; the dense n/32-word complement
    is never materialized (a dirty word's complement is still dirty, so the
    output has exactly the input's run structure);
  * :func:`concat_streams` — bit-concatenation of word-aligned streams with
    clean-run coalescing across the seams (the dist-shard merge protocol);
  * :class:`EwahStream` — the compressed result value object the query
    backends' ``execute_compressed`` returns.

The jax dual-cursor walk (:func:`and_popcount`) lives here too — it is the
in-graph rendition of the same cursor state machine.

``ewah.py`` keeps the codec primitives (compress / decompress / marker
arithmetic) and re-exports the names below for backwards compatibility.
"""

from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass, field

import numpy as np

from .ewah import (FULL, MAX_CLEAN, MAX_DIRTY, WORD_BITS, _emit_group,
                   unpack_marker)

__all__ = [
    "Cursor", "Appender", "EwahStream", "EwahValidationError",
    "logical_op", "logical_many", "logical_not", "concat_streams",
    "and_popcount",
]


class EwahValidationError(ValueError):
    """An EWAH stream violated the structural/canonical-form contract."""


# Wire format (little-endian, 24-byte header + payload):
#   magic   4s   b"EWAH"
#   version u16  1
#   flags   u16  0 (reserved)
#   n_rows  u64  rows the stream covers
#   n_words u32  compressed stream words that follow
#   crc     u32  CRC-32 of the payload bytes
#   payload n_words * 4 bytes of uint32 stream words
_WIRE_MAGIC = b"EWAH"
_WIRE_VERSION = 1
_WIRE_HEADER = struct.Struct("<4sHHQII")


class Cursor:
    """Iterates a compressed stream as (clean_rem, ctype, dirty_rem) runs.

    ``scanned`` counts compressed words visited — the paper's
    machine-independent query cost.
    """

    __slots__ = ("s", "i", "clean_rem", "ctype", "dirty_rem", "scanned")

    def __init__(self, stream: np.ndarray):
        self.s = np.asarray(stream, dtype=np.uint32)
        self.i = 0
        self.clean_rem = 0
        self.ctype = 0
        self.dirty_rem = 0
        self.scanned = 0
        self._load()

    def _load(self) -> None:
        while (
            self.clean_rem == 0
            and self.dirty_rem == 0
            and self.i < len(self.s)
        ):
            self.ctype, self.clean_rem, self.dirty_rem = unpack_marker(self.s[self.i])
            self.i += 1
            self.scanned += 1

    def exhausted(self) -> bool:
        return self.clean_rem == 0 and self.dirty_rem == 0 and self.i >= len(self.s)

    def take_clean(self, n: int) -> None:
        self.clean_rem -= n
        self._load()

    def take_dirty(self) -> int:
        w = int(self.s[self.i])
        self.i += 1
        self.scanned += 1
        self.dirty_rem -= 1
        self._load()
        return w

    def skip_dirty(self, n: int) -> None:
        self.i += n
        self.scanned += n
        self.dirty_rem -= n
        self._load()


class Appender:
    """Re-compresses a stream of words/runs fed to it.

    Adjacent clean runs of equal type merge; words that classify as clean
    (0x0 / 0xFFFFFFFF) join clean runs even when fed through ``add_word`` —
    so feeding one stream's runs through an Appender canonicalizes it.
    """

    def __init__(self):
        self.out: list[int] = []
        self.ctype = 0
        self.n_clean = 0
        self.dirty: list[int] = []
        self.n_words = 0  # uncompressed words represented so far

    def _flush(self) -> None:
        if self.n_clean or self.dirty:
            _emit_group(self.out, self.ctype, self.n_clean,
                        np.asarray(self.dirty, dtype=np.uint32))
            self.ctype, self.n_clean, self.dirty = 0, 0, []

    def add_clean(self, ctype: int, n: int) -> None:
        if n == 0:
            return
        if self.dirty or (self.n_clean and self.ctype != ctype):
            self._flush()
        self.ctype = ctype
        self.n_clean += n
        self.n_words += n

    def add_word(self, w: int) -> None:
        if w == 0:
            self.add_clean(0, 1)
        elif w == 0xFFFFFFFF:
            self.add_clean(1, 1)
        else:
            self.dirty.append(w)
            self.n_words += 1

    def add_cursor(self, cur: Cursor) -> None:
        """Drain a cursor into this appender run-at-a-time (coalescing)."""
        while not cur.exhausted():
            if cur.clean_rem:
                n = cur.clean_rem
                self.add_clean(cur.ctype, n)
                cur.take_clean(n)
            else:
                self.add_word(cur.take_dirty())

    def finish(self) -> np.ndarray:
        self._flush()
        if not self.out:
            self.out.append(0)  # make_marker(0, 0, 0)
        return np.asarray(self.out, dtype=np.uint32)


@dataclass(frozen=True, eq=False)
class EwahStream:
    """A compressed query result: EWAH words + the row count they cover.

    The value object ``execute_compressed`` returns and the dist fan-out
    ships between shards.  ``data`` encodes exactly
    ``ceil(n_rows / 32)`` uncompressed words; bits at positions >= n_rows
    (the final word's padding) are unspecified and truncated by the
    row-materializing accessors.

    Equality/hash are by content (stream words + row count;
    ``words_scanned`` is a measurement, not identity) — the generated
    dataclass comparison would choke on the ndarray field.
    """

    data: np.ndarray
    n_rows: int
    words_scanned: int = field(default=0, compare=False)

    def __eq__(self, other):
        if not isinstance(other, EwahStream):
            return NotImplemented
        return (self.n_rows == other.n_rows
                and np.array_equal(self.data, other.data))

    def __hash__(self):
        return hash((self.n_rows,
                     np.asarray(self.data, dtype=np.uint32).tobytes()))

    @property
    def n_words(self) -> int:
        return (self.n_rows + WORD_BITS - 1) // WORD_BITS

    def __len__(self) -> int:
        return len(self.data)

    def to_words(self) -> np.ndarray:
        from . import ewah

        return ewah.decompress(self.data, self.n_words)

    def to_bits(self) -> np.ndarray:
        from . import ewah

        return ewah.unpack_bits(self.to_words(), self.n_rows)

    def to_rows(self) -> np.ndarray:
        return np.flatnonzero(self.to_bits())

    def validate(self, *, dense_check: bool = True, origin: str = ""):
        """Assert the stream is well-formed *canonical* EWAH; returns self.

        Structural: begins with a marker, every marker's verbatim words
        are present, decoded word count equals ``ceil(n_rows / 32)`` (the
        word-alignment contract).  Canonical form: verbatim words are
        never 0x0/0xFFFFFFFF, adjacent same-type clean runs are coalesced
        (the ``concat_streams`` seam contract), dirty runs are split
        across markers only at MAX_DIRTY, clean runs only at MAX_CLEAN,
        and the empty marker appears only as the sole word of a zero-row
        stream.  With ``dense_check`` the compressed-domain :meth:`count`
        must agree with the dense popcount.

        The ``REPRO_SANITIZE=1`` backends call this on every
        ``execute_compressed`` result; raises
        :class:`EwahValidationError`.
        """

        def fail(i, msg):
            where = f"{origin}: " if origin else ""
            raise EwahValidationError(
                f"{where}word {i}: {msg} "
                f"(n_rows={self.n_rows}, {len(self.data)} stream words)")

        data = np.asarray(self.data)
        if data.ndim != 1 or data.dtype != np.uint32:
            fail(0, f"stream must be 1-D uint32, got "
                    f"{data.dtype} ndim={data.ndim}")
        n_words = self.n_words
        if len(data) == 0:
            if n_words:
                fail(0, "empty stream for a non-empty bitmap")
            return self

        total = 0
        i = 0
        prev = None  # (ctype, n_clean, n_dirty) of the previous marker
        while i < len(data):
            ctype, n_clean, n_dirty = unpack_marker(data[i])
            if n_clean == 0 and n_dirty == 0:
                if len(data) > 1 or n_words or int(data[i]) != 0:
                    fail(i, "empty marker inside a stream (legal only as "
                            "the sole word of a zero-row stream)")
            if prev is not None:
                p_type, p_clean, p_dirty = prev
                if p_dirty == 0 and p_clean < MAX_CLEAN:
                    if n_clean > 0 and p_clean > 0 and ctype == p_type:
                        fail(i, f"uncoalesced clean runs (type {ctype}: "
                                f"{p_clean} then {n_clean})")
                    if n_clean == 0 and n_dirty > 0:
                        fail(i, "dirty run split from a marker with spare "
                                "capacity")
                elif 0 < p_dirty < MAX_DIRTY and n_clean == 0 and n_dirty:
                    fail(i, f"dirty continuation after a non-full dirty "
                            f"run ({p_dirty} < {MAX_DIRTY})")
            if i + 1 + n_dirty > len(data):
                fail(i, f"marker claims {n_dirty} verbatim words, only "
                        f"{len(data) - i - 1} remain")
            seg = data[i + 1 : i + 1 + n_dirty]
            if n_dirty and bool(((seg == 0) | (seg == FULL)).any()):
                j = int(np.flatnonzero((seg == 0) | (seg == FULL))[0])
                fail(i + 1 + j, "verbatim word is 0x0/0xFFFFFFFF (must be "
                                "encoded as a clean run)")
            total += n_clean + n_dirty
            prev = (ctype, n_clean, n_dirty)
            i += 1 + n_dirty
        if total != n_words:
            fail(len(data) - 1,
                 f"stream decodes {total} words, bitmap needs {n_words}")
        if dense_check and self.n_rows:
            dense = int(self.to_bits().sum())
            got = self.count()
            if dense != got:
                fail(0, f"compressed popcount {got} != dense popcount "
                        f"{dense}")
        return self

    def count(self) -> int:
        """Popcount of the valid bits (rows matching), compressed-domain:
        clean-1 runs count 32*n without expansion; only dirty words and the
        final padded word are inspected."""
        total = 0
        pos = 0  # uncompressed word position
        cur = Cursor(self.data)
        last = self.n_words - 1
        tail_bits = self.n_rows - last * WORD_BITS
        tail_mask = (1 << tail_bits) - 1 if self.n_rows else 0
        while not cur.exhausted():
            if cur.clean_rem:
                n = cur.clean_rem
                if cur.ctype:
                    total += n * WORD_BITS
                    if pos + n - 1 == last:
                        total -= WORD_BITS - tail_bits
                pos += n
                cur.take_clean(n)
            else:
                w = cur.take_dirty()
                if pos == last:
                    w &= tail_mask
                total += bin(w).count("1")
                pos += 1
        return total

    def to_bytes(self) -> bytes:
        """Serialize for the wire: versioned little-endian header + CRC +
        the compressed stream words, never the dense bitmap.  The inverse
        of :meth:`from_bytes`."""
        payload = np.ascontiguousarray(
            np.asarray(self.data, dtype=np.uint32)).astype(
                "<u4", copy=False).tobytes()
        header = _WIRE_HEADER.pack(
            _WIRE_MAGIC, _WIRE_VERSION, 0, self.n_rows,
            len(self.data), zlib.crc32(payload))
        return header + payload

    @classmethod
    def from_bytes(cls, buf: bytes) -> "EwahStream":
        """Deserialize a :meth:`to_bytes` buffer.

        Always checks magic/version/length/CRC; under ``REPRO_SANITIZE=1``
        additionally runs the full canonical-form :meth:`validate` walk on
        the decoded stream.  Raises :class:`EwahValidationError` on any
        mismatch.
        """
        if len(buf) < _WIRE_HEADER.size:
            raise EwahValidationError(
                f"wire buffer truncated: {len(buf)} bytes < "
                f"{_WIRE_HEADER.size}-byte header")
        magic, version, _flags, n_rows, n_words, crc = _WIRE_HEADER.unpack_from(buf)
        if magic != _WIRE_MAGIC:
            raise EwahValidationError(f"bad wire magic {magic!r}")
        if version != _WIRE_VERSION:
            raise EwahValidationError(
                f"unsupported wire version {version} (expected "
                f"{_WIRE_VERSION})")
        payload = buf[_WIRE_HEADER.size:]
        if len(payload) != n_words * 4:
            raise EwahValidationError(
                f"wire payload is {len(payload)} bytes, header claims "
                f"{n_words} words ({n_words * 4} bytes)")
        if zlib.crc32(payload) != crc:
            raise EwahValidationError(
                f"wire CRC mismatch (header {crc:#010x}, payload "
                f"{zlib.crc32(payload):#010x})")
        data = np.frombuffer(payload, dtype="<u4").astype(np.uint32,
                                                          copy=False)
        stream = cls(data=data, n_rows=n_rows)
        from ..analysis.runtime import sanitize_enabled

        if sanitize_enabled():
            stream.validate(origin="EwahStream.from_bytes")
        return stream


# ---------------------------------------------------------------------------
# Streaming logical operations (compressed domain, O(|A| + |B|)).
# ---------------------------------------------------------------------------

_OPS = {
    "and": lambda a, b: a & b,
    "or": lambda a, b: a | b,
    "xor": lambda a, b: a ^ b,
}
# (op, clean_type) -> clean run dominates (result is clean of known type)
_DOMINATES = {("and", 0): 0, ("or", 1): 1}


def logical_op(a: np.ndarray, b: np.ndarray, op: str = "and"):
    """Streaming merge of two EWAH streams; returns (stream, words_scanned).

    Never decompresses: runs are consumed run-at-a-time so the work is
    O(|a| + |b|) in *compressed* words (the paper's Section 3 claim).
    """
    fn = _OPS[op]
    ca, cb = Cursor(a), Cursor(b)
    res = Appender()
    while not ca.exhausted() and not cb.exhausted():
        if ca.clean_rem and cb.clean_rem:
            n = min(ca.clean_rem, cb.clean_rem)
            ta = fn(ca.ctype, cb.ctype) & 1
            res.add_clean(ta, n)
            ca.take_clean(n)
            cb.take_clean(n)
        elif ca.clean_rem or cb.clean_rem:
            clean, other = (ca, cb) if ca.clean_rem else (cb, ca)
            n = min(clean.clean_rem, other.dirty_rem)
            dom = _DOMINATES.get((op, clean.ctype))
            if dom is not None:
                res.add_clean(dom, n)
                other.skip_dirty(n)
            else:
                pat = 0xFFFFFFFF if clean.ctype else 0
                for _ in range(n):
                    res.add_word(fn(other.take_dirty(), pat) & 0xFFFFFFFF)
            clean.take_clean(n)
        else:  # both dirty
            n = min(ca.dirty_rem, cb.dirty_rem)
            for _ in range(n):
                res.add_word(fn(ca.take_dirty(), cb.take_dirty()) & 0xFFFFFFFF)
    # tail: the paper's bitmaps all have equal (uncompressed) length; if one
    # stream ends early the remainder ops against implicit zeros.
    for tail in (ca, cb):
        while not tail.exhausted():
            if tail.clean_rem:
                n = tail.clean_rem
                t = fn(tail.ctype, 0) & 1
                res.add_clean(t, n)
                tail.take_clean(n)
            else:
                w = tail.take_dirty()
                res.add_word(fn(w, 0) & 0xFFFFFFFF)
    return res.finish(), ca.scanned + cb.scanned


def logical_many(streams, op: str = "and"):
    """Fold ``op`` over many compressed bitmaps; returns (stream, scanned).

    ``and``/``or`` fold smallest-pair-first through a min-heap on actual
    compressed sizes (the paper's smallest-streams-first cost model);
    ``xor`` — associative and commutative but size-agnostic (a xor can grow
    past both inputs) — folds the same way, which keeps one code path for
    all three ops instead of the former binary-only left fold.
    """
    import heapq

    assert streams
    if len(streams) == 1:
        return np.asarray(streams[0], dtype=np.uint32), 0
    if op not in _OPS:
        raise ValueError(f"unknown op {op!r}; supported: {', '.join(_OPS)}")
    heap = [(len(s), i, s) for i, s in enumerate(streams)]
    heapq.heapify(heap)
    tiebreak = len(heap)
    total = 0
    while len(heap) > 1:
        _, _, a = heapq.heappop(heap)
        _, _, b = heapq.heappop(heap)
        r, scanned = logical_op(a, b, op)
        total += scanned
        heapq.heappush(heap, (len(r), tiebreak, r))
        tiebreak += 1
    return heap[0][2], total


def logical_not(stream: np.ndarray, n_words: int | None = None):
    """Compressed-domain complement; returns (stream, words_scanned).

    Marker-type flipping: every clean run re-emits with its type bit
    flipped, every verbatim word complements in place (a dirty word's
    complement is neither 0x0 nor 0xFFFFFFFF, so it stays dirty).  One pass
    over the compressed words — the dense complement is never materialized
    and the output has exactly the input's run structure (same size).

    ``n_words`` pads a short stream's implicit zero tail to clean-1s so the
    complement covers the full bitmap length.
    """
    cur = Cursor(stream)
    res = Appender()
    while not cur.exhausted():
        if cur.clean_rem:
            n = cur.clean_rem
            res.add_clean(1 - cur.ctype, n)
            cur.take_clean(n)
        else:
            res.add_word(~cur.take_dirty() & 0xFFFFFFFF)
    if n_words is not None and res.n_words < n_words:
        res.add_clean(1, n_words - res.n_words)
    return res.finish(), cur.scanned


def concat_streams(parts) -> np.ndarray:
    """Bit-concatenate compressed streams with clean-run coalescing.

    ``parts`` is an iterable of EWAH uint32 arrays.  Every part except the
    last must cover a multiple-of-32 rows (word alignment — the dist
    fan-out's shard splitter guarantees it), so concatenating in word space
    is concatenating in row space.  Runs feed through one shared
    :class:`Appender`, so a clean run ending one shard and starting the next
    merges into a single marker ("concatenation with clean-run coalescing",
    the shard merge protocol).
    """
    res = Appender()
    for s in parts:
        res.add_cursor(Cursor(s))
    if not res.n_words:
        # canonical empty: byte-identical to ewah.compress of zero words,
        # so concatenating any all-empty partition equals the whole
        return np.zeros(0, dtype=np.uint32)
    return res.finish()


# ---------------------------------------------------------------------------
# In-graph dual-cursor walk (jax).
# ---------------------------------------------------------------------------


def and_popcount(sa, la, sb, lb):
    """Popcount of (A AND B) over two EWAH streams (uint32 arrays + lengths).

    The lax.while_loop rendition of the dual-cursor state machine above:
    each iteration consumes at least one compressed word (or one clean-run
    overlap), so trip count <= |A| + |B| + #markers — the paper's §3
    O(|B1| + |B2|) claim as an in-graph primitive.  Returns
    (count, iterations); tests assert the complexity claim on the
    iteration count.  Streams must encode the same number of uncompressed
    words (the index builder guarantees this).
    """
    import jax
    import jax.numpy as jnp

    sa = sa.astype(jnp.uint32)
    sb = sb.astype(jnp.uint32)

    def _unpack(w):
        t = (w >> jnp.uint32(31)) & jnp.uint32(1)
        nc = (w >> jnp.uint32(15)) & jnp.uint32(0xFFFF)
        nd = w & jnp.uint32(0x7FFF)
        return t.astype(jnp.int32), nc.astype(jnp.int32), nd.astype(jnp.int32)

    # cursor: (i, clean_rem, clean_type, dirty_rem)
    def load(s, length, cur):
        i, c, t, d = cur
        can = (c == 0) & (d == 0) & (i < length)
        w = s[jnp.minimum(i, s.shape[0] - 1)]
        nt, nc, nd = _unpack(w)
        return (jnp.where(can, i + 1, i),
                jnp.where(can, nc, c),
                jnp.where(can, nt, t),
                jnp.where(can, nd, d))

    def consume_clean(cur, n):
        i, c, t, d = cur
        return (i, c - n, t, d)

    def consume_dirty(cur):
        i, c, t, d = cur
        return (i + 1, c, t, d - 1)

    def cond(st):
        a, b, acc, it = st
        a_more = (a[1] > 0) | (a[3] > 0)
        b_more = (b[1] > 0) | (b[3] > 0)
        return a_more & b_more & (it < sa.shape[0] + sb.shape[0] + 4)

    def body(st):
        a, b, acc, it = st
        ia, ca, ta, da = a
        ib, cb, tb, db = b
        # a marker loads clean AND dirty counts together; the stream is in
        # its clean phase while clean_rem > 0, dirty phase after
        a_cl, b_cl = ca > 0, cb > 0
        a_dt, b_dt = (ca == 0) & (da > 0), (cb == 0) & (db > 0)
        both_clean = a_cl & b_cl
        a_clean_b_dirty = a_cl & b_dt
        a_dirty_b_clean = a_dt & b_cl
        both_dirty = a_dt & b_dt

        # case 1: overlap clean runs
        n = jnp.maximum(jnp.minimum(ca, cb), 1)
        add1 = jnp.where(both_clean & (ta == 1) & (tb == 1), n * 32, 0)

        # case 2/3: clean vs one dirty word (consume one word per step)
        wa = sa[jnp.minimum(ia, sa.shape[0] - 1)]
        wb = sb[jnp.minimum(ib, sb.shape[0] - 1)]
        add2 = jnp.where(a_clean_b_dirty & (ta == 1),
                         jnp.bitwise_count(wb).astype(jnp.int32), 0)
        add3 = jnp.where(a_dirty_b_clean & (tb == 1),
                         jnp.bitwise_count(wa).astype(jnp.int32), 0)
        # case 4: dirty & dirty
        add4 = jnp.where(both_dirty,
                         jnp.bitwise_count(wa & wb).astype(jnp.int32), 0)

        # consume
        a2 = jax.tree.map(
            lambda x, y: jnp.where(both_clean, x, y),
            consume_clean(a, n),
            jax.tree.map(lambda x, y: jnp.where(a_clean_b_dirty, x, y),
                         consume_clean(a, 1),
                         jax.tree.map(lambda x, y: jnp.where(both_dirty | a_dirty_b_clean, x, y),
                                      consume_dirty(a), a)))
        b2 = jax.tree.map(
            lambda x, y: jnp.where(both_clean, x, y),
            consume_clean(b, n),
            jax.tree.map(lambda x, y: jnp.where(a_dirty_b_clean, x, y),
                         consume_clean(b, 1),
                         jax.tree.map(lambda x, y: jnp.where(both_dirty | a_clean_b_dirty, x, y),
                                      consume_dirty(b), b)))
        a2 = load(sa, la, a2)
        b2 = load(sb, lb, b2)
        return (a2, b2, acc + add1 + add2 + add3 + add4, it + 1)

    zero = jnp.int32(0)
    a0 = load(sa, la, (zero, zero, zero, zero))
    b0 = load(sb, lb, (zero, zero, zero, zero))
    a0, b0 = jax.tree.map(jnp.asarray, (a0, b0))
    (_, _, acc, it) = jax.lax.while_loop(cond, body, (a0, b0, zero, zero))
    return acc, it
