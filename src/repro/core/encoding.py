"""k-of-N bitmap encodings and Gray-code enumeration (paper §2, §4.2, Prop. 1)."""

from __future__ import annotations

import math

import numpy as np


def choose_N(n_values: int, k: int) -> int:
    """Smallest N with C(N, k) >= n_values (paper: 'choose N as small as
    possible'), via the sufficient bound N = ceil(k * n^(1/k)) then refined."""
    if k == 1:
        return max(1, n_values)
    N = max(k, int(math.ceil(k * n_values ** (1.0 / k))))
    while math.comb(N - 1, k) >= n_values and N - 1 >= k:
        N -= 1
    while math.comb(N, k) < n_values:
        N += 1
    return N


def clamp_k(n_values: int, k: int) -> int:
    """Paper §2 heuristic: small columns cap k.

    <5 distinct values -> unary only (k=1); <21 -> k<=2; <85 -> k<=3.
    """
    if n_values < 5:
        return 1
    if n_values < 21:
        return min(k, 2)
    if n_values < 85:
        return min(k, 3)
    return k


def gray_kofn_codes(N: int, k: int, count: int | None = None) -> np.ndarray:
    """Enumerate k-of-N codes in Gray-code order (Proposition 1).

    Returns an int32 array (count, k) of 0-based positions of the k set bits.
    Nested loops with alternating direction: a_1 ascends, a_2 descends,
    a_3 ascends, ... Successive codes have Hamming distance exactly 2.
    """
    total = math.comb(N, k)
    count = total if count is None else min(count, total)
    out = np.empty((count, k), dtype=np.int32)
    a = [0] * k  # 1-based values per the paper, stored 1-based internally
    idx = 0

    def rec(level: int, prev: int):
        nonlocal idx
        if idx >= count:
            return
        hi = N - k + level  # max value of a_level (1-based)
        lo = prev + 1
        rng = range(lo, hi + 1) if level % 2 == 1 else range(hi, lo - 1, -1)
        for v in rng:
            if idx >= count:
                return
            a[level - 1] = v
            if level == k:
                out[idx] = [x - 1 for x in a]
                idx += 1
            else:
                rec(level + 1, v)

    rec(1, 0)
    assert idx == count, (idx, count)
    return out


def lex_kofn_codes(N: int, k: int, count: int | None = None) -> np.ndarray:
    """k-of-N codes in lexicographic order of the *bitmap code* (1100, 1010,
    1001, 0110, ... -- i.e. descending positions treated as most significant)."""
    total = math.comb(N, k)
    count = total if count is None else min(count, total)
    out = np.empty((count, k), dtype=np.int32)
    idx = 0

    def rec(level: int, prev: int, acc: list):
        nonlocal idx
        if idx >= count:
            return
        if level == k + 1:
            out[idx] = acc
            idx += 1
            return
        for v in range(prev + 1, N - k + level + 1):
            rec(level + 1, v, acc + [v - 1])

    rec(1, 0, [])
    assert idx == count
    return out


def codes_to_bits(codes: np.ndarray, N: int) -> np.ndarray:
    """(count, k) position codes -> (count, N) boolean code matrix."""
    count = codes.shape[0]
    bits = np.zeros((count, N), dtype=bool)
    rows = np.repeat(np.arange(count), codes.shape[1])
    bits[rows, codes.reshape(-1)] = True
    return bits


def hamming_between_successive(codes: np.ndarray, N: int) -> np.ndarray:
    bits = codes_to_bits(codes, N)
    return (bits[1:] != bits[:-1]).sum(axis=1)


# --- binary (full-space) Gray codes, used for sort keys -------------------


def to_gray(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, dtype=np.uint64)
    return x ^ (x >> np.uint64(1))


def from_gray(g: np.ndarray) -> np.ndarray:
    g = np.asarray(g, dtype=np.uint64).copy()
    shift = 1
    while shift < 64:
        g ^= g >> np.uint64(shift)
        shift *= 2
    return g


def gray_less(a_pos, b_pos) -> bool:
    """Algorithm 2: Gray-code '<' over sparse bit vectors given 1-positions."""
    f = True
    m = min(len(a_pos), len(b_pos))
    for p in range(m):
        if a_pos[p] > b_pos[p]:
            return f
        if a_pos[p] < b_pos[p]:
            return not f
        f = not f
    if len(a_pos) > len(b_pos):
        return not f
    if len(b_pos) > len(a_pos):
        return f
    return False
