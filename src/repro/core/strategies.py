"""Strategy registry + ``IndexSpec``: the configuration plane of the index.

The paper's contribution is that *configuration choices* — row order (§4.1,
§4.2, §4.4), code enumeration (§4.2), value-to-code policy, column order
(§4.3) — drive compressed size and query speed.  Here each choice is a named,
introspectable strategy in a registry; an :class:`IndexSpec` bundles one name
per axis into a serializable value object that ``BitmapIndex.build`` resolves.

New heuristics plug in without touching the builder::

    from repro.core.strategies import register_row_order

    @register_row_order("reverse-lex")
    def _reverse_lex(columns, hists=None):
        return order_lex(columns)[::-1]

    BitmapIndex.build(cols, IndexSpec(row_order="reverse-lex"))

Canonical strategy signatures (what the builder calls):

========== ============================================== =====================
kind        signature                                      returns
========== ============================================== =====================
row_order   fn(columns, hists=None)                        (n,) row permutation
code_order  fn(N, k, count)                                (count, k) bit codes
value_policy fn(hist)                                      order[rank] = value
column_order fn(cardinalities, k)                          column permutation
encoding    fn(hist, k)                                    encoding kind name
========== ============================================== =====================

The ``encoding`` axis is the *chooser*: called once per column with that
column's attribute-value histogram, it returns the name of a concrete
:mod:`repro.core.encodings` kind ('equality', 'bitsliced',
'bitsliced-gray', 'binned').  The built-in choosers are the four constant
functions plus ``'auto'``, the histogram-aware policy (high cardinality ->
bit-sliced, skewed low-cardinality -> equality, mid -> binned); because the
choice is per column (and, under the segment lifecycle, per segment), one
index can mix encodings.
"""

from __future__ import annotations

from dataclasses import dataclass, fields

import numpy as np

from . import column_order as _column_order
from . import encoding as _encoding
from . import histogram as _histogram
from . import sorting as _sorting

KINDS = ("row_order", "code_order", "value_policy", "column_order",
         "encoding")

_REGISTRY: dict[str, dict[str, object]] = {kind: {} for kind in KINDS}


def register_strategy(kind: str, name: str):
    """Decorator: register ``fn`` as the ``kind`` strategy called ``name``."""
    if kind not in KINDS:
        raise ValueError(f"unknown strategy kind {kind!r}; kinds: {', '.join(KINDS)}")

    def deco(fn):
        _REGISTRY[kind][name] = fn
        return fn

    return deco


def register_row_order(name: str):
    return register_strategy("row_order", name)


def register_code_order(name: str):
    return register_strategy("code_order", name)


def register_value_policy(name: str):
    return register_strategy("value_policy", name)


def register_column_order(name: str):
    return register_strategy("column_order", name)


def register_encoding(name: str):
    return register_strategy("encoding", name)


def unregister_strategy(kind: str, name: str) -> None:
    """Remove a registered strategy (plugin teardown / tests)."""
    _REGISTRY[kind].pop(name, None)


def strategy_names(kind: str) -> tuple:
    """Sorted names registered under ``kind``."""
    return tuple(sorted(_REGISTRY[kind]))


def get_strategy(kind: str, name: str):
    """Look up a strategy; unknown names list what *is* registered."""
    try:
        return _REGISTRY[kind][name]
    except KeyError:
        raise ValueError(
            f"unknown {kind} strategy {name!r}; registered: "
            f"{', '.join(strategy_names(kind))}"
        ) from None


# ---------------------------------------------------------------------------
# Built-in strategies (the paper's heuristics).
# ---------------------------------------------------------------------------


@register_row_order("unsorted")
def _row_unsorted(columns, hists=None):
    return _sorting.order_unsorted(columns)


@register_row_order("lex")
def _row_lex(columns, hists=None):
    return _sorting.order_lex(columns)


@register_row_order("grayfreq")
def _row_grayfreq(columns, hists=None):
    return _sorting.order_gray_frequency(columns, hists)


@register_row_order("freqcomp")
def _row_freqcomp(columns, hists=None):
    return _sorting.order_frequent_component(columns, hists)


register_code_order("gray")(_encoding.gray_kofn_codes)
register_code_order("lex")(_encoding.lex_kofn_codes)


@register_value_policy("alpha")
def _value_alpha(hist):
    return np.arange(len(hist))


@register_value_policy("freq")
def _value_freq(hist):
    return _histogram.value_order(hist, "freq")


@register_column_order("heuristic")
def _cols_heuristic(cardinalities, k):
    return _column_order.order_columns(cardinalities, k)


@register_column_order("given")
def _cols_given(cardinalities, k):
    return np.arange(len(cardinalities))


# -- encoding choosers (see repro.core.encodings) ---------------------------

for _kind in ("equality", "bitsliced", "bitsliced-gray", "binned",
              "roaring"):
    register_strategy("encoding", _kind)(
        lambda hist, k, _kind=_kind: _kind)


@register_encoding("auto")
def _encoding_auto(hist, k):
    """Histogram-aware per-column encoding choice.

    * high cardinality (>= 256 values): bit-sliced — any range costs
      O(log card) merges where equality pays O(card) ORs;
    * skewed columns (top value holds >= half the rows) and small domains
      (< 32 values): equality — few bitmaps, each long-run compressible,
      and narrow fan-ins stay cheap;
    * mid-cardinality, flat-ish distributions: binned — histogram-equalized
      bins keep range fan-ins ~sqrt(card) with an exact refinement leaf.
    """
    hist = np.asarray(hist, dtype=np.int64)
    card = len(hist)
    n = int(hist.sum())
    if card >= 256:
        return "bitsliced"
    if card < 32 or (n and int(hist.max()) * 2 >= n):
        return "equality"
    return "binned"


# ---------------------------------------------------------------------------
# IndexSpec
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class IndexSpec:
    """Serializable index configuration, resolved through the registry.

    value_policy=None means *auto*: 'freq' when row_order='grayfreq' (the
    paper's Gray-Frequency couples the two), else 'alpha'.

    column_order may be a strategy name ('heuristic', 'given') or an explicit
    permutation of column indices (stored as a tuple).  ``None`` normalizes
    to 'given' (legacy spelling for "index columns in table order").

    encoding names the per-column encoding *chooser* ('equality' — the
    paper's k-of-N value bitmaps and the default — 'bitsliced',
    'bitsliced-gray', 'binned', or 'auto', the histogram-aware policy); the
    chooser runs once per column with that column's histogram, so 'auto'
    specs can mix encodings within one index.
    """

    k: int = 1
    row_order: str = "lex"
    code_order: str = "gray"
    value_policy: str | None = None
    column_order: str | tuple | None = "heuristic"
    encoding: str = "equality"

    def __post_init__(self):
        co = self.column_order
        if co is None:
            co = "given"
        elif not isinstance(co, str):
            co = tuple(int(i) for i in np.asarray(co).reshape(-1))
        object.__setattr__(self, "column_order", co)
        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")

    # -- resolution --------------------------------------------------------

    def resolved_value_policy(self) -> str:
        if self.value_policy is not None:
            return self.value_policy
        return "freq" if self.row_order == "grayfreq" else "alpha"

    def strategies(self) -> dict:
        """Resolve every axis against the registry (raises ValueError with
        the registered names on an unknown strategy).  The 'column_order'
        entry is None when the spec carries an explicit permutation."""
        return {
            "row_order": get_strategy("row_order", self.row_order),
            "code_order": get_strategy("code_order", self.code_order),
            "value_policy": get_strategy("value_policy", self.resolved_value_policy()),
            "column_order": (
                get_strategy("column_order", self.column_order)
                if isinstance(self.column_order, str)
                else None
            ),
            "encoding": get_strategy("encoding", self.encoding),
        }

    def validate(self) -> "IndexSpec":
        self.strategies()
        return self

    # -- serialization -----------------------------------------------------

    def to_dict(self) -> dict:
        d = {f.name: getattr(self, f.name) for f in fields(self)}
        if isinstance(d["column_order"], tuple):
            d["column_order"] = list(d["column_order"])
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "IndexSpec":
        return cls(**d)
