"""Column-ordering gain model and heuristic (paper §4.3, Figs. 3-4)."""

from __future__ import annotations

import numpy as np

from .ewah import WORD_BITS


def expected_dirty_words(r: float, L: float, n: float, w: int = WORD_BITS) -> float:
    """delta(r, L, n): expected dirty words of L bitmaps x n rows holding r
    randomly scattered 1-bits (paper §4.3)."""
    return (1.0 - (1.0 - r / (L * n)) ** w) * (L * n) / w


def sorted_column_cost(n_i: int, k: int) -> float:
    """Storage cost of a sorted column (Prop. 2 bound): 4*n_i + ceil(k*n_i^(1/k))."""
    return 4.0 * n_i + np.ceil(k * n_i ** (1.0 / k))


def shuffled_column_cost(n: int, n_i: int, k: int, w: int = WORD_BITS) -> float:
    """Approximate storage cost of a randomly shuffled column: 2*delta + L."""
    L = np.ceil(k * n_i ** (1.0 / k))
    return 2.0 * expected_dirty_words(k * n, L, n, w) + L


def column_gain(n: int, n_i: int, k: int, w: int = WORD_BITS) -> float:
    """Expected words saved by sorting one column (Fig. 3):
    2*delta(kn, ceil(k*n_i^(1/k)), n) - 4*n_i."""
    L = np.ceil(k * n_i ** (1.0 / k))
    return 2.0 * expected_dirty_words(k * n, L, n, w) - 4.0 * n_i


def heuristic_score(n_i: int, k: int, w: int = WORD_BITS) -> float:
    """Paper §4.3 ordering score: min(n_i^(-1/k), (1 - n_i^(-1/k)) / (4w - 1)).

    Maximal at density n_i^(-1/k) = 1/(4w); decays to 0 as density -> 1
    (too dense: sorting can't help) and as density -> 0 (too sparse: the
    column is almost all clean anyway)."""
    d = float(n_i) ** (-1.0 / k)
    return min(d, (1.0 - d) / (4.0 * w - 1.0))


def order_columns(cardinalities, k: int, w: int = WORD_BITS) -> np.ndarray:
    """Column order: decreasing heuristic score (first column = primary key)."""
    scores = np.asarray([heuristic_score(c, k, w) for c in cardinalities])
    return np.argsort(-scores, kind="stable")
