"""Roaring-style chunked containers: array / bitmap / run per 2^16-row chunk.

The paper's EWAH bitmaps pick one representation for a whole column.  The
Roaring line of work (Chambi et al. 2014, "Better bitmap performance with
Roaring bitmaps"; Lemire et al. 2016, "Consistently faster and smaller
compressed bitmaps with Roaring") shows the consistent win comes from
choosing the representation **per aligned 2^16-row chunk**:

* ``array``   — sorted uint16 local positions; chosen for sparse chunks
  (at most :data:`ARRAY_MAX` = 4096 set rows, the classic boundary where a
  position list stops being smaller than a dense bitmap).
* ``bitmap``  — 2048 dense uint32 words (65536 bits); chosen for dense
  scattered chunks.
* ``run``     — sorted ``(start, end)`` inclusive intervals; chosen when
  ``2*runs + 1 < min(n, ARRAY_MAX)`` (the Roaring run-container rule), so
  long contiguous stretches — exactly what the paper's histogram-aware row
  ordering produces — coalesce to a handful of intervals.

A :class:`ContainerSet` is one compressed row set: parallel arrays of chunk
keys, container classes, and payloads.  Classes are re-chosen after every
merge, so ORing two adjacent run containers re-coalesces rather than
degrading to arrays.  The numpy merge path here is the streaming oracle the
jax backend must match bit-for-bit; its batched Pallas counterpart lives in
``repro.kernels.containers``.  Container sets convert to the canonical
:class:`~repro.core.ewah_stream.EwahStream` word format via
:func:`to_stream` at plan roots, so caching, tombstone ANDs, fan-out
shipping, and the ``REPRO_SANITIZE=1`` validators never see a container.

Container-class dispatch is exhaustiveness-checked by
``repro.analysis.containercheck``: every function that branches on a class
constant must either cover all of :data:`CONTAINER_CLASSES` or end in a
``raise`` — an unknown class is a hard error, never a silent fall-through.
"""

from __future__ import annotations

import hashlib

import numpy as np

from . import ewah

CHUNK_BITS = 16
CHUNK_ROWS = 1 << CHUNK_BITS          # rows per aligned container chunk
CHUNK_WORDS = CHUNK_ROWS // ewah.WORD_BITS  # 2048 uint32 words per chunk
ARRAY_MAX = 4096                      # array/bitmap cardinality boundary

# Declared container classes — repro.analysis.containercheck requires every
# dispatch site to cover all of them (or raise).  Index into this tuple IS
# the class id stored in ContainerSet.classes.
CONTAINER_CLASSES = ("array", "bitmap", "run")
ARRAY, BITMAP, RUN = range(len(CONTAINER_CLASSES))

_MERGE_OPS = ("and", "or", "andnot")


class ContainerSet:
    """One compressed row set over ``n_rows`` rows as per-chunk containers.

    ``keys[i]`` is the aligned chunk index (``row >> 16``), ``classes[i]``
    the container class id, ``payloads[i]`` the class-specific numpy
    payload.  Chunks with no set rows are absent.  Instances are immutable
    by convention — every operation returns a new set.
    """

    __slots__ = ("n_rows", "keys", "classes", "payloads")

    def __init__(self, n_rows, keys, classes, payloads):
        self.n_rows = int(n_rows)
        self.keys = np.asarray(keys, dtype=np.int64)
        self.classes = np.asarray(classes, dtype=np.uint8)
        self.payloads = list(payloads)

    def __len__(self):
        return len(self.keys)

    def n_set(self) -> int:
        """Total number of set rows across all chunks."""
        return sum(int(chunk_cardinality(c, p))
                   for c, p in zip(self.classes, self.payloads))

    def size_words(self) -> int:
        """Serialized footprint in uint32 words (1 header word per chunk +
        the per-class payload cost in packed uint16 units)."""
        total = 0
        for c, p in zip(self.classes, self.payloads):
            total += 1 + (_chunk_cost_u16(int(c), p) + 1) // 2
        return total


def _chunk_cost_u16(cls: int, payload) -> int:
    """Payload cost in uint16 units (the Roaring accounting unit)."""
    if cls == ARRAY:
        return len(payload)
    if cls == BITMAP:
        return 2 * CHUNK_WORDS
    if cls == RUN:
        return 2 * len(payload) + 1
    raise ValueError(f"unknown container class {cls!r}")


def chunk_cardinality(cls: int, payload) -> int:
    """Number of set rows in one container."""
    if cls == ARRAY:
        return len(payload)
    if cls == BITMAP:
        return int(np.sum(np.unpackbits(payload.view(np.uint8))))
    if cls == RUN:
        return int(np.sum(payload[:, 1].astype(np.int64)
                          - payload[:, 0].astype(np.int64) + 1))
    raise ValueError(f"unknown container class {cls!r}")


def make_chunk(pos16: np.ndarray):
    """Choose the cheapest container class for sorted local positions.

    Implements the Roaring selection rule: run when ``2r + 1`` uint16 units
    undercut both alternatives, else array up to :data:`ARRAY_MAX`
    positions, else bitmap.  Returns ``(class_id, payload)``.
    """
    pos = np.asarray(pos16, dtype=np.int64)
    n = len(pos)
    if n == 0:
        raise ValueError("empty chunks are dropped, not stored")
    breaks = np.nonzero(np.diff(pos) > 1)[0]
    r = len(breaks) + 1
    if 2 * r + 1 < min(n, ARRAY_MAX):
        starts = pos[np.concatenate(([0], breaks + 1))]
        ends = pos[np.concatenate((breaks, [n - 1]))]
        return RUN, np.stack([starts, ends], axis=1).astype(np.uint16)
    if n <= ARRAY_MAX:
        return ARRAY, pos.astype(np.uint16)
    return BITMAP, ewah.positions_to_words(pos, CHUNK_ROWS)


def chunk_positions(cls: int, payload) -> np.ndarray:
    """Expand one container to sorted local int64 positions."""
    if cls == ARRAY:
        return payload.astype(np.int64)
    if cls == BITMAP:
        bits = ewah.unpack_bits(payload, CHUNK_ROWS)
        return np.nonzero(bits)[0].astype(np.int64)
    if cls == RUN:
        starts = payload[:, 0].astype(np.int64)
        ends = payload[:, 1].astype(np.int64)
        return np.concatenate(
            [np.arange(s, e + 1, dtype=np.int64)
             for s, e in zip(starts, ends)]) if len(payload) else \
            np.empty(0, dtype=np.int64)
    raise ValueError(f"unknown container class {cls!r}")


def chunk_words(cls: int, payload) -> np.ndarray:
    """Expand one container to its dense 2048-word uint32 form."""
    if cls == BITMAP:
        return payload
    if cls == ARRAY or cls == RUN:
        return ewah.positions_to_words(chunk_positions(cls, payload),
                                       CHUNK_ROWS)
    raise ValueError(f"unknown container class {cls!r}")


def from_positions(positions: np.ndarray, n_rows: int) -> ContainerSet:
    """Build a :class:`ContainerSet` from sorted global row positions."""
    pos = np.asarray(positions, dtype=np.int64)
    if len(pos) and (pos[0] < 0 or pos[-1] >= n_rows):
        raise ValueError("positions out of range")
    keys, classes, payloads = [], [], []
    if len(pos):
        chunk_ids = pos >> CHUNK_BITS
        bounds = np.nonzero(np.diff(chunk_ids))[0] + 1
        for local in np.split(pos, bounds):
            keys.append(int(local[0]) >> CHUNK_BITS)
            cls, payload = make_chunk(local & (CHUNK_ROWS - 1))
            classes.append(cls)
            payloads.append(payload)
    return ContainerSet(n_rows, keys, classes, payloads)


def to_positions(cs: ContainerSet) -> np.ndarray:
    """Expand a container set to sorted global int64 row positions."""
    parts = [chunk_positions(int(c), p) + (int(k) << CHUNK_BITS)
             for k, c, p in zip(cs.keys, cs.classes, cs.payloads)]
    return (np.concatenate(parts) if parts
            else np.empty(0, dtype=np.int64))


def to_words(cs: ContainerSet) -> np.ndarray:
    """Expand a container set to the dense uint32 word array covering
    ``n_rows`` rows (the EWAH pre-compression form)."""
    n_words = (cs.n_rows + ewah.WORD_BITS - 1) // ewah.WORD_BITS
    words = np.zeros(n_words, dtype=np.uint32)
    for k, c, p in zip(cs.keys, cs.classes, cs.payloads):
        off = int(k) * CHUNK_WORDS
        cw = chunk_words(int(c), p)
        words[off:off + CHUNK_WORDS] = cw[:max(0, n_words - off)]
    return words


def to_stream(cs: ContainerSet) -> np.ndarray:
    """Canonical EWAH stream of the container set (the plan-root bridge:
    everything downstream — caches, tombstone ANDs, fan-out, sanitizers —
    sees only this)."""
    return ewah.compress(to_words(cs))


def digest(cs: ContainerSet) -> bytes:
    """Stable content digest (cache key for lowered container folds)."""
    h = hashlib.blake2b(digest_size=12)
    h.update(np.int64(cs.n_rows).tobytes())
    h.update(cs.keys.tobytes())
    h.update(cs.classes.tobytes())
    for p in cs.payloads:
        h.update(np.ascontiguousarray(p).tobytes())
    return h.digest()


def gallop_intersect(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Intersect two sorted position arrays by galloping the smaller one
    into the larger (each probe is an exponential/binary search — O(n log
    m) instead of the O(n + m) linear merge, the Roaring array∩array
    kernel)."""
    a = np.asarray(a, dtype=np.int64)
    b = np.asarray(b, dtype=np.int64)
    if len(a) > len(b):
        a, b = b, a
    if not len(a) or not len(b):
        return np.empty(0, dtype=np.int64)
    idx = np.searchsorted(b, a)
    hit = idx < len(b)
    hit[hit] = b[idx[hit]] == a[hit]
    return a[hit]


def array_bitmap_intersect(pos: np.ndarray, words: np.ndarray) -> np.ndarray:
    """Galloping array∩bitmap: each position jumps straight to its word
    (``pos >> 5``) and tests one bit — no scan of the dense side."""
    pos = np.asarray(pos, dtype=np.int64)
    looked = words[pos >> 5]
    hit = (looked >> (pos & 31).astype(np.uint32)) & np.uint32(1)
    return pos[hit.astype(bool)]


def _merge_chunk(cls_a: int, pa, cls_b: int, pb, op: str):
    """Merge two same-chunk containers; returns ``(class, payload)`` with
    the class re-chosen, or ``None`` for an empty result."""
    if op == "and" and cls_a == ARRAY and cls_b == BITMAP:
        out = array_bitmap_intersect(chunk_positions(cls_a, pa), pb)
    elif op == "and" and cls_a == BITMAP and cls_b == ARRAY:
        out = array_bitmap_intersect(chunk_positions(cls_b, pb), pa)
    elif op == "and" and cls_a == ARRAY and cls_b == ARRAY:
        out = gallop_intersect(pa, pb)
    elif cls_a == BITMAP and cls_b == BITMAP:
        if op == "and":
            wa = pa & pb
        elif op == "or":
            wa = pa | pb
        elif op == "andnot":
            wa = pa & ~pb
        else:
            raise ValueError(f"unknown container merge op {op!r}")
        bits = ewah.unpack_bits(wa, CHUNK_ROWS)
        out = np.nonzero(bits)[0].astype(np.int64)
    else:
        # Mixed/run general path: expand both sides to positions.
        a = chunk_positions(cls_a, pa)
        b = chunk_positions(cls_b, pb)
        if op == "and":
            out = gallop_intersect(a, b)
        elif op == "or":
            out = np.union1d(a, b)
        elif op == "andnot":
            out = np.setdiff1d(a, b, assume_unique=True)
        else:
            raise ValueError(f"unknown container merge op {op!r}")
    if not len(out):
        return None
    return make_chunk(out)


def merge(a: ContainerSet, b: ContainerSet, op: str) -> ContainerSet:
    """Container-wise logical merge (``"and"``, ``"or"``, ``"andnot"``).

    Chunks present on only one side short-circuit by op semantics; chunk
    pairs dispatch per container class (galloping for array∩array and
    array∩bitmap, word ops for bitmap∩bitmap, positional expansion
    otherwise) and the result class is re-chosen per chunk.
    """
    if op not in _MERGE_OPS:
        raise ValueError(f"unknown container merge op {op!r}")
    if a.n_rows != b.n_rows:
        raise ValueError("container sets cover different row spans")
    keys, classes, payloads = [], [], []
    ia = ib = 0
    while ia < len(a) or ib < len(b):
        ka = int(a.keys[ia]) if ia < len(a) else None
        kb = int(b.keys[ib]) if ib < len(b) else None
        if kb is None or (ka is not None and ka < kb):
            if op in ("or", "andnot"):  # right side absent: keep left
                keys.append(ka)
                classes.append(int(a.classes[ia]))
                payloads.append(a.payloads[ia])
            ia += 1
        elif ka is None or kb < ka:
            if op == "or":  # left side absent: keep right
                keys.append(kb)
                classes.append(int(b.classes[ib]))
                payloads.append(b.payloads[ib])
            ib += 1
        else:
            merged = _merge_chunk(int(a.classes[ia]), a.payloads[ia],
                                  int(b.classes[ib]), b.payloads[ib], op)
            if merged is not None:
                keys.append(ka)
                classes.append(merged[0])
                payloads.append(merged[1])
            ia += 1
            ib += 1
    return ContainerSet(a.n_rows, keys, classes, payloads)


def fold(csets, ops, n_rows: int) -> np.ndarray:
    """Left-fold container sets through ``ops`` and return the canonical
    EWAH stream — the numpy streaming evaluator for ``("cfold", ...)``
    plan nodes (the jax backend's batched counterpart must match this
    bit-for-bit)."""
    if not csets:
        return ewah.compress(
            np.zeros((n_rows + ewah.WORD_BITS - 1) // ewah.WORD_BITS,
                     dtype=np.uint32))
    acc = csets[0]
    for op, nxt in zip(ops, csets[1:]):
        acc = merge(acc, nxt, op)
    return to_stream(acc)
