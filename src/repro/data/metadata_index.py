"""EWAH bitmap index over training-data metadata — the paper's original use
case, hosted in the training data plane.

Every training sequence carries categorical metadata (source, domain,
quality bin, length bin).  A data-mixing / curation query like
``domain = 3 AND quality_bin >= 8`` is exactly the paper's predicate
workload; the index is built with histogram-aware column ordering and
Gray-Frequency row sorting (the paper's best heuristics) and queried through
the predicate planner (repro.core.query), on either the numpy streaming
backend or the batched jax backend.

With ``query_fanout > 1`` the index shards over word-aligned row ranges
(``repro.dist.query_fanout``) and every query fans out, each shard
executing in the compressed domain and shipping its compressed result
stream.  Fan-out queries return row ids in **original** (ingest) row order
— there is no global reordered space across independently sorted shards —
whereas the single-index path keeps the historical reordered-space ids
(map back with ``index.row_perm[row_ids]``).
"""

from __future__ import annotations

import numpy as np

from ..core import And, BitmapIndex, Eq, IndexSpec


class MetadataIndex:
    COLS = ("source", "domain", "quality_bin", "length_bin")

    def __init__(self, k: int = 1, row_order: str = "grayfreq",
                 spec: IndexSpec | None = None, query_fanout: int = 0):
        self.spec = spec or IndexSpec(k=k, row_order=row_order,
                                      column_order="heuristic")
        self.k = self.spec.k
        self.row_order = self.spec.row_order
        self.query_fanout = query_fanout
        self._rows = {c: [] for c in self.COLS}
        self._index: BitmapIndex | None = None
        self._sharded = None

    def add_batch(self, meta: dict):
        for c in self.COLS:
            self._rows[c].append(np.asarray(meta[c]))
        self._index = None
        self._sharded = None

    def _cols(self):
        return [np.concatenate(self._rows[c]) for c in self.COLS]

    def build(self):
        if self.query_fanout > 1:
            return self.sharded
        self._index = BitmapIndex.build(self._cols(), self.spec)
        return self._index

    @property
    def index(self) -> BitmapIndex:
        if self.query_fanout > 1:
            # a silently-built second full index would double memory and
            # answer in a different row space than the fan-out path
            raise ValueError(
                "MetadataIndex was built with query_fanout="
                f"{self.query_fanout}; use .sharded (row ids from queries "
                "are original ingest positions, not reordered space)")
        if self._index is None:
            self._index = BitmapIndex.build(self._cols(), self.spec)
        return self._index

    @property
    def sharded(self):
        if self._sharded is None:
            from ..dist.query_fanout import ShardedIndex

            self._sharded = ShardedIndex.build(
                self._cols(), self.spec, n_shards=self.query_fanout,
                names=self.COLS)
        return self._sharded

    def query_pred(self, pred, backend: str = "numpy"):
        """Run any predicate (columns by name, e.g. ``Eq("domain", 3)`` or
        ``In("quality_bin", range(8, 16))``) through the planner.
        Returns (row_ids, compressed_words_scanned); with fan-out active,
        row ids are original ingest positions (see module docstring)."""
        if self.query_fanout > 1:
            return self.sharded.query(pred, backend=backend, names=self.COLS)
        return self.index.query(pred, backend=backend, names=self.COLS)

    def query(self, _backend: str = "numpy", **conditions):
        """Equality query: rows matching all column=value conditions
        (compiled to one And(Eq, ...) plan — a single smallest-streams-first
        AND fan-in).  Returns (row_ids, compressed_words_scanned)."""
        if not conditions:
            return np.asarray([], dtype=np.int64), 0
        pred = And(*[Eq(col, int(v)) for col, v in conditions.items()])
        return self.query_pred(pred, backend=_backend)

    def size_words(self) -> int:
        if self.query_fanout > 1:
            return self.sharded.size_words()
        return self.index.size_words()
