"""EWAH bitmap index over training-data metadata — the paper's original use
case, hosted in the training data plane.

Every training sequence carries categorical metadata (source, domain,
quality bin, length bin).  A data-mixing / curation query like
``domain = 3 AND quality_bin >= 8`` is exactly the paper's predicate
workload; the index is built with histogram-aware column ordering and
Gray-Frequency row sorting (the paper's best heuristics) and queried through
the predicate planner (repro.core.query), on either the numpy streaming
backend or the batched jax backend.

Ingestion is **incremental** (repro.core.lifecycle): every ``add_batch``
appends to an :class:`~repro.core.lifecycle.IndexWriter` and seals the
word-aligned prefix into an immutable segment — no monolithic rebuild per
batch.  Queries run through the live
:class:`~repro.core.segment.SegmentedIndex` view (sealed segments through
the compressed engine, the open tail densely) and return row ids in
**original ingest order**.  The index is a full LSM surface: ``delete``
tombstones rows (curation removals — e.g. a contaminated source — cost one
compressed merge, not a rebuild), ``add_batch(..., ttl=)`` expires rows
lazily (rolling data-freshness windows), and ``compact()`` — or the
:class:`~repro.core.lifecycle.BackgroundCompactor` behind
``start_compactor()`` — purges dead rows off the serving path while
re-sorting with the histogram-aware pipeline.

With ``query_fanout > 1`` the index instead shards over word-aligned row
ranges (``repro.dist.query_fanout``) and every query fans out, each shard
executing in the compressed domain and shipping its compressed result
stream; fan-out row ids are original ingest positions too (stable across
deletes and purges — the shards carry the surviving ids), so the two modes
answer identically.

With ``hosts >= 2`` queries serve through a multi-process
:class:`~repro.dist.serve_plane.ServePlane` instead: each worker process
owns a word-aligned run of sealed segments (re-homed after compaction)
and ships only compressed result streams back to the coordinator, which
stitches them into the same original-ingest-order answers
(docs/dist.md).  Ingest, deletes, TTLs, and compaction all keep working —
the plane syncs worker ownership lazily before every query batch.
"""

from __future__ import annotations

import numpy as np

from ..core import And, Eq, IndexSpec, IndexWriter
from ..core.lifecycle import BackgroundCompactor


class MetadataIndex:
    COLS = ("source", "domain", "quality_bin", "length_bin")

    def __init__(self, k: int = 1, row_order: str = "grayfreq",
                 spec: IndexSpec | None = None, query_fanout: int = 0,
                 encoding: str = "equality", hosts: int = 0):
        self.spec = spec or IndexSpec(k=k, row_order=row_order,
                                      column_order="heuristic",
                                      encoding=encoding)
        if hosts >= 2 and query_fanout > 1:
            raise ValueError(
                "hosts and query_fanout are separate serving topologies "
                "(multi-process plane vs in-process shard view); pick one")
        self.k = self.spec.k
        self.row_order = self.spec.row_order
        self.query_fanout = query_fanout
        self.hosts = hosts
        self.writer = IndexWriter(self.spec, names=self.COLS)
        self._sharded = None
        self._compactor = None
        self._plane = None

    def add_batch(self, meta: dict, ttl=None):
        """Append one metadata batch and seal its word-aligned prefix into
        an immutable segment (the ``len % 32`` tail rides in the open
        buffer and is still queryable).  ``ttl`` (seconds, scalar or
        per-row) expires the rows lazily — rolling freshness windows for
        curation data.  In fan-out mode rows only buffer — queries run
        through ``.sharded``, so per-batch segment indexes would be wasted
        work."""
        self.writer.append({c: np.asarray(meta[c]) for c in self.COLS},
                           ttl=ttl)
        if self.query_fanout <= 1:
            self.writer.seal()
        self._sharded = None

    def delete(self, where: dict | None = None, *, pred=None, row_ids=None,
               backend: str = "numpy") -> int:
        """Tombstone rows by equality conditions (``where={column: value}``,
        compiled to one And(Eq, ...) plan), an arbitrary predicate, or
        global ingest ids.  Sealed segments absorb the delete as one
        compressed-domain merge; every later query ANDs the live mask in.
        Returns the newly-dead row count."""
        given = [x is not None for x in (where, pred, row_ids)]
        if sum(given) != 1:
            raise ValueError(
                "delete needs exactly one of where=, pred=, or row_ids=")
        if where is not None:
            unknown = sorted(set(where) - set(self.COLS))
            if unknown:
                raise ValueError(f"unknown columns {unknown}; known: "
                                 f"{', '.join(self.COLS)}")
            pred = And(*[Eq(col, int(v)) for col, v in where.items()])
        if self._plane is not None:
            # the plane broadcasts tombstones to segment-owning workers
            # (shipped segments keep their generation across a tombstone)
            n = self._plane.delete(pred, row_ids=row_ids, backend=backend)
        else:
            n = self.writer.delete(pred, row_ids=row_ids, backend=backend)
        self._sharded = None
        return n

    def compact(self, **kwargs):
        """Size-tiered compaction of accumulated small segments (see
        ``IndexWriter.compact``): merges re-sort with the histogram-aware
        pipeline, tombstoned/expired rows are physically purged, and
        retired segments' cached query results are evicted by generation
        scope."""
        merged = self.writer.compact(**kwargs)
        if merged is not None:
            self._sharded = None
        return merged

    def start_compactor(self, **kwargs) -> BackgroundCompactor:
        """Run the size-tiered policy on a scheduler thread
        (:class:`~repro.core.lifecycle.BackgroundCompactor`): ingest never
        pauses for maintenance.  ``close()`` drains it."""
        if self._compactor is not None and self._compactor.running:
            raise ValueError("a background compactor is already running")
        self._compactor = BackgroundCompactor(self.writer, **kwargs)
        return self._compactor

    def close(self) -> None:
        """Drain and stop the background compactor, if one is running,
        and shut down the serve-plane worker fleet (hosts mode)."""
        if self._compactor is not None:
            self._compactor.close()
            self._compactor = None
        if self._plane is not None:
            self._plane.close()
            self._plane = None

    @property
    def n_rows(self) -> int:
        return self.writer.n_rows

    def _live_cols(self):
        """(columns, ids, expiry) of the currently-live rows, ingest order
        — what the fan-out view is (re)built from.  Ids are global ingest
        positions, so fan-out results stay comparable across deletes and
        purges; expiry travels so rows TTL-ing out after the build still
        vanish lazily."""
        now = self.writer.clock()
        segs, buf = self.writer.snapshot()
        col_parts, id_parts, exp_parts = [], [], []
        for s in segs:
            keep = ~s.dead_ingest_mask(now)
            col_parts.append([c[keep] for c in s.columns])
            id_parts.append(s.ingest_ids()[keep])
            exp_parts.append(
                (s.expiry if s.expiry is not None
                 else np.full(s.n_rows, np.inf))[keep])
        if buf is not None:
            bcols, bdel, bexp = buf
            keep = ~bdel & (bexp > now)
            start = segs[-1].row_stop if segs else 0
            col_parts.append([c[keep] for c in bcols])
            id_parts.append(start + np.flatnonzero(keep))
            exp_parts.append(bexp[keep])
        n_cols = len(self.COLS)
        cols = [np.concatenate([p[c] for p in col_parts])
                if col_parts else np.zeros(0, dtype=np.int64)
                for c in range(n_cols)]
        ids = (np.concatenate(id_parts) if id_parts
               else np.zeros(0, dtype=np.int64))
        exp = np.concatenate(exp_parts) if exp_parts else np.zeros(0)
        return cols, ids, exp

    @property
    def index(self):
        """The live :class:`~repro.core.segment.SegmentedIndex` view
        (sealed segments + open buffer).  Row ids from queries are original
        ingest positions."""
        if self.query_fanout > 1:
            # a second full query surface would double memory and confuse
            # cache scoping; fan-out mode queries through .sharded
            raise ValueError(
                "MetadataIndex was built with query_fanout="
                f"{self.query_fanout}; use .sharded")
        return self.writer.index

    @property
    def plane(self):
        """The multi-process :class:`~repro.dist.serve_plane.ServePlane`
        (``hosts >= 2`` mode), spawned lazily on first use so indexes that
        never query don't pay the worker-fleet startup."""
        if self.hosts < 2:
            raise ValueError(
                f"MetadataIndex was built with hosts={self.hosts}; the "
                "serve plane needs hosts >= 2")
        if self._plane is None:
            from ..dist.serve_plane import ServePlane

            self._plane = ServePlane(self.writer, n_hosts=self.hosts)
        return self._plane

    @property
    def sharded(self):
        if self._sharded is None:
            from ..dist.query_fanout import ShardedIndex

            cols, ids, exp = self._live_cols()
            self._sharded = ShardedIndex.build(
                cols, self.spec, n_shards=self.query_fanout,
                names=self.COLS, row_ids=ids,
                expiry=exp if np.isfinite(exp).any() else None,
                clock=self.writer.clock)
        return self._sharded

    def query_pred(self, pred, backend: str = "numpy"):
        """Run any predicate (columns by name, e.g. ``Eq("domain", 3)`` or
        ``In("quality_bin", range(8, 16))``) through the planner.
        Returns (row_ids, compressed_words_scanned); row ids are original
        ingest positions in all three serving modes (segmented, fan-out,
        multi-process plane)."""
        if self.hosts >= 2:
            return self.plane.query(pred, backend=backend)
        if self.query_fanout > 1:
            return self.sharded.query(pred, backend=backend, names=self.COLS)
        return self.index.query(pred, backend=backend)

    def query(self, where: dict | None = None, *, backend: str = "numpy"):
        """Equality query: rows matching all ``where={column: value}``
        conditions (compiled to one And(Eq, ...) plan — a single
        smallest-streams-first AND fan-in).  Returns
        (row_ids, compressed_words_scanned).

        ``backend`` is a normal keyword-only option; conditions travel in
        the explicit ``where=`` dict so column names can never collide with
        option names.  The PR-4 one-release shims (conditions as bare
        kwargs, the backend as ``_backend=``) are **removed** — those
        spellings now raise TypeError.
        """
        if not where:
            return np.asarray([], dtype=np.int64), 0
        unknown = sorted(set(where) - set(self.COLS))
        if unknown:
            raise ValueError(
                f"unknown columns {unknown}; known: {', '.join(self.COLS)}")
        pred = And(*[Eq(col, int(v)) for col, v in where.items()])
        return self.query_pred(pred, backend=backend)

    def size_words(self) -> int:
        if self.query_fanout > 1:
            return self.sharded.size_words()
        return self.writer.size_words()
