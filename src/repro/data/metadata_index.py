"""EWAH bitmap index over training-data metadata — the paper's original use
case, hosted in the training data plane.

Every training sequence carries categorical metadata (source, domain,
quality bin, length bin).  A data-mixing / curation query like
``domain = 3 AND quality_bin >= 8`` is exactly the paper's predicate
workload; the index is built with histogram-aware column ordering and
Gray-Frequency row sorting (the paper's best heuristics) and queried through
the predicate planner (repro.core.query), on either the numpy streaming
backend or the batched jax backend.
"""

from __future__ import annotations

import numpy as np

from ..core import And, BitmapIndex, Eq, IndexSpec


class MetadataIndex:
    COLS = ("source", "domain", "quality_bin", "length_bin")

    def __init__(self, k: int = 1, row_order: str = "grayfreq",
                 spec: IndexSpec | None = None):
        self.spec = spec or IndexSpec(k=k, row_order=row_order,
                                      column_order="heuristic")
        self.k = self.spec.k
        self.row_order = self.spec.row_order
        self._rows = {c: [] for c in self.COLS}
        self._index: BitmapIndex | None = None

    def add_batch(self, meta: dict):
        for c in self.COLS:
            self._rows[c].append(np.asarray(meta[c]))
        self._index = None

    def build(self):
        cols = [np.concatenate(self._rows[c]) for c in self.COLS]
        self._index = BitmapIndex.build(cols, self.spec)
        return self._index

    @property
    def index(self) -> BitmapIndex:
        if self._index is None:
            self.build()
        return self._index

    def query_pred(self, pred, backend: str = "numpy"):
        """Run any predicate (columns by name, e.g. ``Eq("domain", 3)`` or
        ``In("quality_bin", range(8, 16))``) through the planner.
        Returns (row_ids, compressed_words_scanned)."""
        return self.index.query(pred, backend=backend, names=self.COLS)

    def query(self, _backend: str = "numpy", **conditions):
        """Equality query: rows matching all column=value conditions
        (compiled to one And(Eq, ...) plan — a single smallest-streams-first
        AND fan-in).  Returns (row_ids, compressed_words_scanned)."""
        if not conditions:
            return np.asarray([], dtype=np.int64), 0
        pred = And(*[Eq(col, int(v)) for col, v in conditions.items()])
        return self.query_pred(pred, backend=_backend)

    def size_words(self) -> int:
        return self.index.size_words()
