"""EWAH bitmap index over training-data metadata — the paper's original use
case, hosted in the training data plane.

Every training sequence carries categorical metadata (source, domain,
quality bin, length bin).  A data-mixing / curation query like
``domain = 3 AND quality_bin >= 8`` is exactly the paper's equality-query
workload; the index is built with histogram-aware column ordering and
Gray-Frequency row sorting (the paper's best heuristics).
"""

from __future__ import annotations

import numpy as np

from ..core import BitmapIndex, ewah


class MetadataIndex:
    COLS = ("source", "domain", "quality_bin", "length_bin")

    def __init__(self, k: int = 1, row_order: str = "grayfreq"):
        self.k = k
        self.row_order = row_order
        self._rows = {c: [] for c in self.COLS}
        self._index: BitmapIndex | None = None

    def add_batch(self, meta: dict):
        for c in self.COLS:
            self._rows[c].append(np.asarray(meta[c]))
        self._index = None

    def build(self):
        cols = [np.concatenate(self._rows[c]) for c in self.COLS]
        self._index = BitmapIndex.build(
            cols, k=self.k, row_order=self.row_order,
            column_order="heuristic")
        return self._index

    @property
    def index(self) -> BitmapIndex:
        if self._index is None:
            self.build()
        return self._index

    def query(self, **conditions):
        """Equality query: rows matching all column=value conditions.
        Returns (row_ids, compressed_words_scanned)."""
        idx = self.index
        col_pos = {self.COLS[idx.original_column(i)]: i
                   for i in range(len(self.COLS))}
        streams = []
        scanned = 0
        result = None
        for col, value in conditions.items():
            rows, sc = idx.equality_query(col_pos[col], int(value))
            scanned += sc
            rows = set(rows.tolist())
            result = rows if result is None else (result & rows)
        return np.asarray(sorted(result or [])), scanned

    def size_words(self) -> int:
        return self.index.size_words()
