"""EWAH bitmap index over training-data metadata — the paper's original use
case, hosted in the training data plane.

Every training sequence carries categorical metadata (source, domain,
quality bin, length bin).  A data-mixing / curation query like
``domain = 3 AND quality_bin >= 8`` is exactly the paper's predicate
workload; the index is built with histogram-aware column ordering and
Gray-Frequency row sorting (the paper's best heuristics) and queried through
the predicate planner (repro.core.query), on either the numpy streaming
backend or the batched jax backend.

Ingestion is **incremental** (repro.core.lifecycle): every ``add_batch``
appends to an :class:`~repro.core.lifecycle.IndexWriter` and seals the
word-aligned prefix into an immutable segment — no monolithic rebuild per
batch.  Queries run through the live
:class:`~repro.core.segment.SegmentedIndex` view (sealed segments through
the compressed engine, the open tail densely) and return row ids in
**original ingest order**.  ``compact()`` applies the size-tiered policy
when many small batches have accumulated.

With ``query_fanout > 1`` the index instead shards over word-aligned row
ranges (``repro.dist.query_fanout``) and every query fans out, each shard
executing in the compressed domain and shipping its compressed result
stream; fan-out row ids are original ingest positions too, so the two modes
answer identically.
"""

from __future__ import annotations

import numpy as np

from ..core import And, Eq, IndexSpec, IndexWriter


class MetadataIndex:
    COLS = ("source", "domain", "quality_bin", "length_bin")

    def __init__(self, k: int = 1, row_order: str = "grayfreq",
                 spec: IndexSpec | None = None, query_fanout: int = 0,
                 encoding: str = "equality"):
        self.spec = spec or IndexSpec(k=k, row_order=row_order,
                                      column_order="heuristic",
                                      encoding=encoding)
        self.k = self.spec.k
        self.row_order = self.spec.row_order
        self.query_fanout = query_fanout
        self.writer = IndexWriter(self.spec, names=self.COLS)
        self._sharded = None

    def add_batch(self, meta: dict):
        """Append one metadata batch and seal its word-aligned prefix into
        an immutable segment (the ``len % 32`` tail rides in the open
        buffer and is still queryable).  In fan-out mode rows only buffer —
        queries run through ``.sharded``, so per-batch segment indexes
        would be wasted work."""
        self.writer.append({c: np.asarray(meta[c]) for c in self.COLS})
        if self.query_fanout <= 1:
            self.writer.seal()
        self._sharded = None

    def compact(self, **kwargs):
        """Size-tiered compaction of accumulated small segments (see
        ``IndexWriter.compact``); retired segments' cached query results
        are evicted by generation scope."""
        return self.writer.compact(**kwargs)

    @property
    def n_rows(self) -> int:
        return self.writer.n_rows

    def _cols(self):
        segs = [s.columns for s in self.writer.segments]
        buf = self.writer.buffer_columns()
        parts = [[s[c] for s in segs] + ([buf[c]] if buf else [])
                 for c in range(len(self.COLS))]
        return [np.concatenate(p) for p in parts]

    @property
    def index(self):
        """The live :class:`~repro.core.segment.SegmentedIndex` view
        (sealed segments + open buffer).  Row ids from queries are original
        ingest positions."""
        if self.query_fanout > 1:
            # a second full query surface would double memory and confuse
            # cache scoping; fan-out mode queries through .sharded
            raise ValueError(
                "MetadataIndex was built with query_fanout="
                f"{self.query_fanout}; use .sharded")
        return self.writer.index

    @property
    def sharded(self):
        if self._sharded is None:
            from ..dist.query_fanout import ShardedIndex

            self._sharded = ShardedIndex.build(
                self._cols(), self.spec, n_shards=self.query_fanout,
                names=self.COLS)
        return self._sharded

    def query_pred(self, pred, backend: str = "numpy"):
        """Run any predicate (columns by name, e.g. ``Eq("domain", 3)`` or
        ``In("quality_bin", range(8, 16))``) through the planner.
        Returns (row_ids, compressed_words_scanned); row ids are original
        ingest positions in both the segmented and fan-out modes."""
        if self.query_fanout > 1:
            return self.sharded.query(pred, backend=backend, names=self.COLS)
        return self.index.query(pred, backend=backend)

    def query(self, where: dict | None = None, *, backend: str = "numpy"):
        """Equality query: rows matching all ``where={column: value}``
        conditions (compiled to one And(Eq, ...) plan — a single
        smallest-streams-first AND fan-in).  Returns
        (row_ids, compressed_words_scanned).

        ``backend`` is a normal keyword-only option; conditions travel in
        the explicit ``where=`` dict so column names can never collide with
        option names.  The PR-4 one-release shims (conditions as bare
        kwargs, the backend as ``_backend=``) are **removed** — those
        spellings now raise TypeError.
        """
        if not where:
            return np.asarray([], dtype=np.int64), 0
        unknown = sorted(set(where) - set(self.COLS))
        if unknown:
            raise ValueError(
                f"unknown columns {unknown}; known: {', '.join(self.COLS)}")
        pred = And(*[Eq(col, int(v)) for col, v in where.items()])
        return self.query_pred(pred, backend=backend)

    def size_words(self) -> int:
        if self.query_fanout > 1:
            return self.sharded.size_words()
        return self.writer.size_words()
