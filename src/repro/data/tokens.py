"""Deterministic synthetic LM token pipeline.

Shardable (each data-parallel host reads its own offset range), resumable
(the stream position is a pure function of (seed, step), saved with the
checkpoint), and metadata-aware: every sequence carries categorical
metadata (source, domain, quality bin, length bin) which the EWAH bitmap
index in data/metadata_index.py indexes — the paper's use case embedded in
the training data plane.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class TokenPipelineState:
    seed: int
    step: int
    host_id: int
    n_hosts: int


class TokenPipeline:
    """Markov-ish synthetic tokens with enough structure for loss to drop."""

    N_SOURCES = 8
    N_DOMAINS = 32
    N_QBINS = 10
    N_LBINS = 8

    def __init__(self, vocab_size: int, batch: int, seq: int, seed: int = 0,
                 host_id: int = 0, n_hosts: int = 1):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq
        self.state = TokenPipelineState(seed, 0, host_id, n_hosts)
        r = np.random.default_rng(seed)
        # fixed bigram structure so the LM has something learnable
        self._next = r.integers(0, vocab_size, size=(min(vocab_size, 4096),))

    def _rng_for(self, step):
        s = self.state
        return np.random.default_rng(
            (s.seed * 1_000_003 + step) * 64 + s.host_id)

    def next_batch(self):
        step = self.state.step
        r = self._rng_for(step)
        b, s, v = self.batch, self.seq, self.vocab
        start = r.integers(0, min(v, 4096), size=(b, 1))
        noise = r.integers(0, v, size=(b, s))
        take_chain = r.random((b, s)) < 0.7
        toks = np.empty((b, s), dtype=np.int32)
        cur = start[:, 0]
        for t in range(s):  # cheap python chain; CPU-scale batches only
            cur = np.where(take_chain[:, t],
                           self._next[cur % len(self._next)], noise[:, t])
            toks[:, t] = cur
        labels = np.roll(toks, -1, axis=1)
        meta = {
            "source": r.integers(0, self.N_SOURCES, size=b),
            "domain": r.integers(0, self.N_DOMAINS, size=b),
            "quality_bin": r.integers(0, self.N_QBINS, size=b),
            "length_bin": r.integers(0, self.N_LBINS, size=b),
        }
        self.state.step += 1
        return {"inputs": toks, "labels": labels}, meta

    # --- fault tolerance ---------------------------------------------------

    def snapshot(self) -> dict:
        return {"seed": self.state.seed, "step": self.state.step,
                "host_id": self.state.host_id, "n_hosts": self.state.n_hosts}

    def restore(self, snap: dict):
        self.state = TokenPipelineState(**snap)
