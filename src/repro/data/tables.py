"""Synthetic tables mirroring the paper's data sets (Table 2 profiles).

The four originals (Census-Income, DBGEN, Netflix, KJV-4grams) are not
redistributable offline; these generators match their published shape
statistics — row counts (scaled), column cardinalities and skew — so the
paper's qualitative claims can be validated (DESIGN.md §8).
"""

from __future__ import annotations

import numpy as np


def zipf_column(n: int, card: int, skew: float, rng) -> np.ndarray:
    """Zipf-distributed value ids (0-based, dense)."""
    ranks = np.arange(1, card + 1, dtype=np.float64)
    probs = ranks ** -skew
    probs /= probs.sum()
    return rng.choice(card, size=n, p=probs).astype(np.int64)


def uniform_column(n: int, card: int, rng) -> np.ndarray:
    return rng.integers(0, card, size=n).astype(np.int64)


def make_uniform_table(n: int, cards, seed=0):
    rng = np.random.default_rng(seed)
    return [uniform_column(n, c, rng) for c in cards]


def make_zipf_table(n: int, cards, skews, seed=0):
    rng = np.random.default_rng(seed)
    return [zipf_column(n, c, s, rng) for c, s in zip(cards, skews)]


def make_census_like(n: int = 199_523, seed=0):
    """Census-Income 4-d projection: cardinalities 91, 1240, 1478, 99800;
    real census columns are moderately skewed."""
    rng = np.random.default_rng(seed)
    cards = [91, 1240, 1478, min(99_800, n // 2)]
    skews = [1.0, 1.1, 1.3, 0.4]
    return [zipf_column(n, c, s, rng) for c, s in zip(cards, skews)]


def make_dbgen_like(n: int = 1_000_000, seed=1):
    """DBGEN 4-d projection: cardinalities 7, 11, 2526, 400000 (scaled);
    TPC-H columns are near-uniform."""
    rng = np.random.default_rng(seed)
    cards = [7, 11, 2526, min(400_000, max(1000, n // 35))]
    return [uniform_column(n, c, rng) for c in cards]


def make_netflix_like(n: int = 2_000_000, seed=2):
    """Netflix: Rating(5), MovieID(17770), Date(2182), UserID(480189 scaled).

    Ratings and movie popularity are skewed; user activity long-tailed."""
    rng = np.random.default_rng(seed)
    cards = [5, 2182, 17_770, min(480_189, max(10_000, n // 20))]
    skews = [0.7, 0.9, 1.1, 0.8]
    return [zipf_column(n, c, s, rng) for c, s in zip(cards, skews)]


def make_kjv4grams_like(n: int = 4_000_000, seed=3, pool: int = 200_000):
    """KJV-4grams: 4 word columns (~8k stems each) with HEAVY row
    duplication — rows drawn from a zipf-weighted pool of distinct 4-tuples
    (the bible text repeats n-grams), which is what makes sorting pay off
    ~9x on this data set."""
    rng = np.random.default_rng(seed)
    cols = 4
    card = 8_000
    pool_rows = np.stack(
        [zipf_column(pool, card, 1.1, rng) for _ in range(cols)], axis=1)
    pick = zipf_column(n, pool, 1.05, rng)
    rows = pool_rows[pick]
    return [rows[:, j].copy() for j in range(cols)]


DATASETS = {
    "census": make_census_like,
    "dbgen": make_dbgen_like,
    "netflix": make_netflix_like,
    "kjv4grams": make_kjv4grams_like,
}
