"""Pallas TPU kernel: sequential slice-plane fold with a per-step op.

The bit-sliced range circuit (core/encodings.py) is a *left fold* over the
column's slice planes — ``R = ((P_j op_1 P_{j+1}) op_2 P_{j+2}) ...`` with
each step's op fixed by a bit of the comparison constant (AND where the bit
is 1, OR where 0, XOR for Gray-plane decode).  Unlike ``wordops_fold`` the
op varies per level and the order is semantic, so a tree reduction does not
apply; instead all m planes stream through one kernel launch: each grid
tile loads its (m, ROW_TILE, LANE_TILE) plane block once and runs the whole
statically-unrolled fold in registers — one VMEM round trip for the entire
comparison instead of m - 1 separate two-operand launches.

  in : x (m, N, 128) uint32 — the m word-aligned slice planes
  out: r (N, 128) uint32    — the folded result
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 64
LANE_TILE = 128

_OPS = {"and": 0, "or": 1, "xor": 2}


def _kernel(x_ref, o_ref, *, ops: tuple):
    r = x_ref[0]
    for step, op in enumerate(ops):
        p = x_ref[step + 1]
        if op == 0:
            r = r & p
        elif op == 1:
            r = r | p
        else:
            r = r ^ p
    o_ref[...] = r


def slicefold_kernel(x: jax.Array, ops: tuple, *, interpret: bool = True):
    """x (m, N, C) uint32, ops — m-1 names from {'and','or','xor'}."""
    m, N, C = x.shape
    assert len(ops) == m - 1, (len(ops), m)
    assert N % ROW_TILE == 0 and C % LANE_TILE == 0
    op_ids = tuple(_OPS[o] for o in ops)
    grid = (N // ROW_TILE, C // LANE_TILE)
    in_spec = pl.BlockSpec((m, ROW_TILE, LANE_TILE), lambda i, j: (0, i, j))
    out_spec = pl.BlockSpec((ROW_TILE, LANE_TILE), lambda i, j: (i, j))
    return pl.pallas_call(
        partial(_kernel, ops=op_ids),
        grid=grid,
        in_specs=[in_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((N, C), jnp.uint32),
        interpret=interpret,
    )(x)
