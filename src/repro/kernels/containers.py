"""Pallas kernels for batched Roaring-container merges.

Two kernels back ``JaxBackend._container_fold`` (core/query.py):

* ``containerops_kernel`` — elementwise AND / OR / AND-NOT over a batch of
  same-chunk container pairs expanded to word form, shape (P, 2048)
  uint32: every chunk pair of a fold round runs in ONE padded launch, the
  op baked in statically (no traced branches).
* ``member_kernel`` — the vectorized half of the galloping array∩bitmap
  intersection: each array position has already jumped to its word
  (``jnp.take_along_axis`` of ``pos >> 5`` at the wrapper level — per-lane
  dynamic gathers don't belong inside a TPU kernel); the kernel tests the
  single bit ``(word >> (pos & 31)) & 1`` for the whole padded batch at
  once.

Wrappers with padding, jnp fallbacks, and CPU interpret-mode defaults live
in ``kernels.ops`` (``container_pairs`` / ``container_gallop``), following
the conventions in docs/fusion.md.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 8     # chunk pairs per tile (min sublane count for 32-bit)
LANE_TILE = 128  # words / positions per tile

_OPS = {"and": 0, "or": 1, "andnot": 2}


def _pair_kernel(a_ref, b_ref, o_ref, *, op: int):
    a = a_ref[...]
    b = b_ref[...]
    if op == 0:
        o_ref[...] = a & b
    elif op == 1:
        o_ref[...] = a | b
    else:
        o_ref[...] = a & ~b


def containerops_kernel(a, b, op: str, *, interpret=True):
    """Batched container merge in word space: (P, W) uint32 pairs -> (P, W)
    with ``op`` in {"and", "or", "andnot"}.  P and W must already be tile
    multiples (kernels.ops.container_pairs pads)."""
    if op not in _OPS:
        raise ValueError(f"unknown container merge op {op!r}")
    P, W = a.shape
    grid = (P // ROW_TILE, W // LANE_TILE)
    spec = pl.BlockSpec((ROW_TILE, LANE_TILE), lambda i, j: (i, j))
    return pl.pallas_call(
        partial(_pair_kernel, op=_OPS[op]),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((P, W), jnp.uint32),
        interpret=interpret,
    )(a, b)


def _member_kernel(w_ref, p_ref, o_ref):
    w = w_ref[...]
    shift = (p_ref[...] & 31).astype(jnp.uint32)
    o_ref[...] = (w >> shift) & jnp.uint32(1)


def member_kernel(gathered, pos, *, interpret=True):
    """Bit-test stage of the galloping array∩bitmap intersection:
    ``gathered[i, j]`` is the bitmap word holding position ``pos[i, j]``;
    returns (P, L) uint32 membership flags."""
    P, L = pos.shape
    grid = (P // ROW_TILE, L // LANE_TILE)
    spec = pl.BlockSpec((ROW_TILE, LANE_TILE), lambda i, j: (i, j))
    return pl.pallas_call(
        _member_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((P, L), jnp.uint32),
        interpret=interpret,
    )(gathered, pos)
