"""jit'd public wrappers around the Pallas kernels.

Handle padding to tile boundaries, choose interpret mode automatically
(True off-TPU so the kernels validate on CPU), and expose a ``use_kernel``
switch falling back to the jnp reference implementation.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core import ewah_jax
from . import ref
from .bitpack import LANE_TILE, ROW_TILE, bitpack_kernel
from .gray import gray_kernel
from .histmm import TOK_TILE, VAL_TILE, histmm_kernel
from .moe_route import moe_route_kernel
from .planfuse import planfuse_kernel
from .recompress import recompress_kernel
from .slicefold import slicefold_kernel
from .wordops import wordops_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, mult, axis, value=0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def bitpack(bits, use_kernel=True, interpret=None):
    """(R, C) bool -> (ceil(R/32), C) uint32."""
    R, C = bits.shape
    if not use_kernel:
        return ref.bitpack(_pad_to(bits, 32, 0))[: -(-R // 32)]
    interpret = not _on_tpu() if interpret is None else interpret
    x = _pad_to(_pad_to(bits, ROW_TILE, 0), LANE_TILE, 1)
    out = bitpack_kernel(x, interpret=interpret)
    return out[: -(-R // 32), :C]


@partial(jax.jit, static_argnames=("op", "use_kernel", "interpret"))
def wordops(a, b, op="and", use_kernel=True, interpret=None):
    """1-D compressed-word vectors -> (result words, classification)."""
    n = a.shape[0]
    if not use_kernel:
        return ref.wordops(a, b, op)
    interpret = not _on_tpu() if interpret is None else interpret
    lanes = 128
    rows = -(-n // lanes)
    from .wordops import ROW_TILE as RT
    rows_p = -(-rows // RT) * RT
    a2 = jnp.zeros((rows_p * lanes,), jnp.uint32).at[:n].set(a).reshape(rows_p, lanes)
    b2 = jnp.zeros((rows_p * lanes,), jnp.uint32).at[:n].set(b).reshape(rows_p, lanes)
    r, cls = wordops_kernel(a2, b2, op, interpret=interpret)
    return r.reshape(-1)[:n], cls.reshape(-1)[:n]


@partial(jax.jit, static_argnames=("op", "use_kernel", "interpret"))
def wordops_fold(stacked, op="and", use_kernel=True, interpret=None):
    """Fold ``op`` across axis 0 of (m, n) word vectors -> (n,).

    Tree reduction: each level combines *all* of its pairs in one flattened
    ``wordops`` launch, so a whole batch of queries (n = B * words-per-query)
    folds in ceil(log2 m) kernel dispatches — the query plane's batched
    jax-backend primitive.
    """
    m, n = stacked.shape
    while m > 1:
        even = (m // 2) * 2
        a = stacked[0:even:2].reshape(-1)
        b = stacked[1:even:2].reshape(-1)
        r, _ = wordops(a, b, op, use_kernel=use_kernel, interpret=interpret)
        merged = r.reshape(even // 2, n)
        if m % 2:
            merged = jnp.concatenate([merged, stacked[-1:]], axis=0)
        stacked = merged
        m = stacked.shape[0]
    return stacked[0]


@partial(jax.jit, static_argnames=("op", "use_kernel", "interpret"))
def container_pairs(a, b, op="and", use_kernel=True, interpret=None):
    """Batched Roaring-container merge in word space: (P, W) uint32 pairs
    -> (P, W), one padded Pallas launch for a whole fold round's chunk
    pairs (W = containers.CHUNK_WORDS in the backend)."""
    if op not in ("and", "or", "andnot"):
        raise ValueError(f"unknown container merge op {op!r}")
    a = jnp.asarray(a, jnp.uint32)
    b = jnp.asarray(b, jnp.uint32)
    if not use_kernel:
        if op == "and":
            return a & b
        if op == "or":
            return a | b
        return a & ~b
    interpret = not _on_tpu() if interpret is None else interpret
    from .containers import LANE_TILE as LT
    from .containers import ROW_TILE as RT
    from .containers import containerops_kernel
    P, W = a.shape
    a2 = _pad_to(_pad_to(a, RT, 0), LT, 1)
    b2 = _pad_to(_pad_to(b, RT, 0), LT, 1)
    r = containerops_kernel(a2, b2, op, interpret=interpret)
    return r[:P, :W]


@partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def container_gallop(positions, words, use_kernel=True, interpret=None):
    """Galloping array∩bitmap membership for a batch of chunk pairs.

    ``positions``: (P, L) int32 local chunk positions, right-padded with
    -1.  ``words``: (P, containers.CHUNK_WORDS) uint32 bitmap payloads.
    Each position gallops straight to its word (``pos >> 5`` — the gather
    happens here at the jnp level, not inside the kernel) and the Pallas
    bit-test kernel checks the whole padded batch in one launch.  Returns
    (P, L) uint32 flags: 1 where the bitmap holds the position, 0 for
    misses and padding.
    """
    pos = jnp.asarray(positions, jnp.int32)
    w = jnp.asarray(words, jnp.uint32)
    safe = jnp.maximum(pos, 0)
    gathered = jnp.take_along_axis(w, safe >> 5, axis=1)
    if not use_kernel:
        hits = (gathered >> (safe & 31).astype(jnp.uint32)) & jnp.uint32(1)
    else:
        interpret = not _on_tpu() if interpret is None else interpret
        from .containers import LANE_TILE as LT
        from .containers import ROW_TILE as RT
        from .containers import member_kernel
        P, L = pos.shape
        g2 = _pad_to(_pad_to(gathered, RT, 0), LT, 1)
        p2 = _pad_to(_pad_to(safe, RT, 0), LT, 1)
        hits = member_kernel(g2, p2, interpret=interpret)[:P, :L]
    return jnp.where(pos >= 0, hits, jnp.uint32(0))


@partial(jax.jit, static_argnames=("ops", "use_kernel", "interpret"))
def slice_fold(stacked, ops, use_kernel=True, interpret=None):
    """Left-fold (m, n) word vectors with a per-step op -> (n,).

    The batched slice-fold entry point of the bit-sliced encoding: ``ops``
    is a static tuple of m-1 names from {'and', 'or', 'xor'}, applied
    sequentially (``r = (stacked[0] ops[0] stacked[1]) ops[1] ...``) —
    the slice-plane comparison circuit, where the op sequence encodes the
    comparison constant's bits.  The jax query backend flattens a whole
    batch of queries into n = B * words-per-query, so all planes of every
    comparison in the batch dispatch in ONE padded Pallas call
    (``kernels.slicefold``) instead of m - 1 two-operand launches.
    """
    m, n = stacked.shape
    if len(ops) != m - 1:
        raise ValueError(f"slice_fold got {m} planes but {len(ops)} ops "
                         "(need exactly m - 1)")
    if m == 1:
        return stacked[0]
    if not use_kernel:
        fns = {"and": jnp.bitwise_and, "or": jnp.bitwise_or,
               "xor": jnp.bitwise_xor}
        r = stacked[0]
        for i, op in enumerate(ops):
            r = fns[op](r, stacked[i + 1])
        return r
    interpret = not _on_tpu() if interpret is None else interpret
    lanes = 128
    from .slicefold import ROW_TILE as RT
    rows = -(-n // lanes)
    rows_p = -(-rows // RT) * RT
    x = (jnp.zeros((m, rows_p * lanes), jnp.uint32)
         .at[:, :n].set(stacked).reshape(m, rows_p, lanes))
    out = slicefold_kernel(x, tuple(ops), interpret=interpret)
    return out.reshape(-1)[:n]


@partial(jax.jit, static_argnames=("tape", "use_kernel", "interpret"))
def plan_fuse(stacked, tape, use_kernel=True, interpret=None):
    """Evaluate a lowered plan tape over (m, n) word planes in ONE Pallas
    launch -> (result (n,), kind (n,)).

    ``tape`` is the static stack-machine program from
    ``core.query.lower_plan`` (``(opcode, arg)`` int pairs — PUSH leaf /
    NOT / binary OP); the jax backend flattens a whole batch of queries
    into n = B * words-per-query, so every fold, interior merge, the root
    op, AND the recompress classification of the entire plan dispatch in
    one padded megakernel call (``kernels.planfuse``) instead of one
    launch per stage.  ``kind`` is the per-word EWAH class of the result
    (0 = clean-0, 1 = clean-1, 2 = dirty) — the run-start/scan emit stages
    of recompression consume it directly.
    """
    from .planfuse import ROW_TILE as RT
    from .planfuse import NOT, OP_AND, OP_OR, PUSH

    m, n = stacked.shape
    if not use_kernel:
        full = jnp.uint32(0xFFFFFFFF)
        stack = []
        for opcode, arg in tape:
            if opcode == PUSH:
                stack.append(stacked[arg])
            elif opcode == NOT:
                stack.append(stack.pop() ^ full)
            else:
                b = stack.pop()
                a = stack.pop()
                fn = (jnp.bitwise_and if arg == OP_AND else
                      jnp.bitwise_or if arg == OP_OR else jnp.bitwise_xor)
                stack.append(fn(a, b))
        r = stack.pop()
        return r, ewah_jax.classify(r)
    interpret = not _on_tpu() if interpret is None else interpret
    lanes = 128
    rows = -(-n // lanes)
    rows_p = -(-rows // RT) * RT
    x = (jnp.zeros((m, rows_p * lanes), jnp.uint32)
         .at[:, :n].set(stacked).reshape(m, rows_p, lanes))
    r, kind = planfuse_kernel(x, tape, interpret=interpret)
    return r.reshape(-1)[:n], kind.reshape(-1)[:n]


@partial(jax.jit, static_argnames=("capacity", "use_kernel", "interpret"))
def recompress_batch(words, capacity, use_kernel=True, interpret=None):
    """(B, W) dense uint32 word rows -> (streams (B, capacity), lengths (B,)).

    In-graph EWAH re-encode of a batch of query results (the compressed-
    domain closure of the jax backend: ``wordops_fold`` output goes back to
    EWAH without leaving the graph).  One Pallas launch computes per-word
    classification + run-start flags for the *whole* batch — rows get an
    opposite-class sentinel as word 0's predecessor, so runs never bleed
    across queries — then the scan/scatter epilogue
    (``ewah_jax.compress_from_runs``) vmaps over rows.

    Requires W <= 2**15 - 1 (one marker per group, asserted statically).
    """
    B, W = words.shape
    words = words.astype(jnp.uint32)
    sent = jnp.where(words[:, :1] == 0, jnp.uint32(0xFFFFFFFF), jnp.uint32(0))
    prev = jnp.concatenate([sent, words[:, :-1]], axis=1)
    if use_kernel:
        interpret = not _on_tpu() if interpret is None else interpret
        lanes = 128
        from .recompress import ROW_TILE as RT
        n = B * W
        rows = -(-n // lanes)
        rows_p = -(-rows // RT) * RT
        w2 = (jnp.zeros((rows_p * lanes,), jnp.uint32)
              .at[:n].set(words.reshape(-1)).reshape(rows_p, lanes))
        p2 = (jnp.zeros((rows_p * lanes,), jnp.uint32)
              .at[:n].set(prev.reshape(-1)).reshape(rows_p, lanes))
        kind, start = recompress_kernel(w2, p2, interpret=interpret)
        kind = kind.reshape(-1)[:n].reshape(B, W)
        start = start.reshape(-1)[:n].reshape(B, W)
    else:
        kind = ewah_jax.classify(words)
        start = (kind != ewah_jax.classify(prev)).astype(jnp.int32)
    return jax.vmap(
        lambda w, k, s: ewah_jax.compress_from_runs(w, k, s, capacity)
    )(words, kind, start)


@partial(jax.jit, static_argnames=("capacity", "use_kernel", "interpret"))
def recompress(words, capacity, use_kernel=True, interpret=None):
    """(W,) dense uint32 words -> (stream[capacity], length), in-graph."""
    streams, lengths = recompress_batch(
        words[None, :], capacity, use_kernel=use_kernel, interpret=interpret)
    return streams[0], lengths[0]


@partial(jax.jit, static_argnames=("inverse", "use_kernel", "interpret"))
def gray(x, inverse=False, use_kernel=True, interpret=None):
    """uint32 vector -> Gray code (or inverse)."""
    n = x.shape[0]
    if not use_kernel:
        return ref.gray(x, inverse)
    interpret = not _on_tpu() if interpret is None else interpret
    lanes = 128
    from .gray import ROW_TILE as RT
    rows = -(-n // lanes)
    rows_p = -(-rows // RT) * RT
    x2 = jnp.zeros((rows_p * lanes,), jnp.uint32).at[:n].set(x).reshape(rows_p, lanes)
    out = gray_kernel(x2, inverse, interpret=interpret)
    return out.reshape(-1)[:n]


@partial(jax.jit, static_argnames=("n_values", "use_kernel", "interpret"))
def histogram(vals, n_values, use_kernel=True, interpret=None):
    """int32 values -> (n_values,) float32 counts."""
    if not use_kernel:
        return ref.histmm(vals, n_values)
    interpret = not _on_tpu() if interpret is None else interpret
    n = vals.shape[0]
    v_pad = -(-n_values // VAL_TILE) * VAL_TILE
    # pad tokens with an out-of-range value -> lands in a padded count slot
    pad_val = n_values if v_pad > n_values else None
    t_pad = (-n) % TOK_TILE
    if t_pad and pad_val is None:
        v_pad += VAL_TILE
        pad_val = n_values
    x = jnp.concatenate([vals, jnp.full((t_pad,), pad_val or 0, vals.dtype)]) \
        if t_pad else vals
    out = histmm_kernel(x, v_pad, interpret=interpret)
    return out[:n_values]


@partial(jax.jit, static_argnames=("n_experts", "use_kernel", "interpret"))
def moe_route_bitmap(eids, n_experts, use_kernel=True, interpret=None):
    """(T, k) top-k expert ids -> (ceil(T/32), E) uint32 dispatch words."""
    T, k = eids.shape
    if not use_kernel:
        return ref.moe_route(eids, n_experts)
    interpret = not _on_tpu() if interpret is None else interpret
    from .moe_route import LANE_TILE as LT, ROW_TILE as RT
    e_pad = -(-n_experts // LT) * LT
    t_pad = (-T) % RT
    x = jnp.concatenate(
        [eids, jnp.full((t_pad, k), -1, eids.dtype)]) if t_pad else eids
    out = moe_route_kernel(x, e_pad, interpret=interpret)
    return out[: -(-T // 32), :n_experts]
