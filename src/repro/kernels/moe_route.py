"""Pallas TPU kernel: top-k routing decisions -> packed k-of-E bitmap words.

The MoE integration (DESIGN.md §4): the (tokens x experts) dispatch matrix
is the paper's k-of-N bitmap index.  This kernel fuses the one-hot
expansion of top-k expert ids with the 32-row word packing of Algorithm 1,
yielding the EWAH-ready uint32 word matrix in one VMEM pass.

  in : eids (T, k) int32      T % 256 == 0
  out: words (T/32, E) uint32 E % 128 == 0 (ops.py pads)
       bit j of words[w, e] == 1  iff  expert e in eids[32*w + j]
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 256
LANE_TILE = 128


def _kernel(eids_ref, words_ref, *, k: int):
    e0 = pl.program_id(1) * LANE_TILE
    eids = eids_ref[...]  # (ROW_TILE, k)
    ecol = jax.lax.broadcasted_iota(jnp.int32, (ROW_TILE, LANE_TILE), 1) + e0
    hit = jnp.zeros((ROW_TILE, LANE_TILE), jnp.uint32)
    for i in range(k):  # k is small and static (4 or 8)
        hit |= (eids[:, i : i + 1] == ecol).astype(jnp.uint32)
    h = hit.reshape(ROW_TILE // 32, 32, LANE_TILE)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 32, 1), 1)
    words_ref[...] = (h << shifts).sum(axis=1, dtype=jnp.uint32)


def moe_route_kernel(eids: jax.Array, n_experts: int, *, interpret: bool = True):
    T, k = eids.shape
    assert T % ROW_TILE == 0 and n_experts % LANE_TILE == 0
    return pl.pallas_call(
        partial(_kernel, k=k),
        grid=(T // ROW_TILE, n_experts // LANE_TILE),
        in_specs=[pl.BlockSpec((ROW_TILE, k), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((ROW_TILE // 32, LANE_TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((T // 32, n_experts), jnp.uint32),
        interpret=interpret,
    )(eids)
