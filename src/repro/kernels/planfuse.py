"""Pallas TPU megakernel: a whole compiled plan in ONE launch.

The jax backend's per-stage path dispatches one Pallas call per fold/merge
stage (``wordops_fold`` per tree level, ``slice_fold`` per comparison,
``recompress`` at the root), bouncing every intermediate through HBM.
This kernel instead *interprets a static instruction tape* — the
stack-machine linearization of the plan DAG produced by
``core.query.lower_plan`` — so the entire op tree plus the recompress
classification evaluates in VMEM in a single launch: each grid tile loads
its (m, ROW_TILE, LANE_TILE) block of decompressed leaf planes once,
unrolls the tape over a Python-list operand stack (every tape entry is a
static int pair, so the unrolled trace contains straight-line bitwise ops
only — no traced branches), and writes the root result together with its
EWAH word classification (0 = clean-0, 1 = clean-1, 2 = dirty), the first
half of the recompress stage fused in.

Tape instructions (``(opcode, arg)`` int pairs):

  (0, i)  PUSH   leaf plane i onto the operand stack
  (1, 0)  NOT    complement the top of stack (x ^ 0xFFFFFFFF)
  (2, k)  OP     pop b, pop a, push ``a <op_k> b``; k: 0=and, 1=or, 2=xor

  in : x (m, N, 128) uint32 — the m decompressed leaf planes
  out: r (N, 128) uint32    — the root result words
       kind (N, 128) int32  — per-word EWAH class of r

VMEM model.  A tile holds the m-plane input block, the live operand stack
(``max_depth`` registers at the peak), and the two output tiles; anything
past the budget falls back to the per-stage path (``fits_vmem`` is the
backend's gate, sized to half a TPU core's ~16 MiB so double-buffering
and compiler temporaries keep headroom).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 64
LANE_TILE = 128

# half of a v5e core's ~16 MiB VMEM: leave room for pipelining/temporaries
VMEM_BUDGET_BYTES = 8 * 2**20
# unrolled-trace backstop: past this the kernel would compile, but trace
# and compile time grow linearly and the per-stage path stops being the
# bottleneck anyway
MAX_TAPE_LEN = 512

PUSH, NOT, OP = 0, 1, 2
OP_AND, OP_OR, OP_XOR = 0, 1, 2


def tape_vmem_bytes(m: int, max_depth: int) -> int:
    """Worst-case VMEM bytes one grid tile needs: the m-plane input block,
    the operand stack at its peak, and the two output tiles."""
    tiles = m + max_depth + 2
    return tiles * ROW_TILE * LANE_TILE * 4


def fits_vmem(m: int, max_depth: int,
              budget: int = VMEM_BUDGET_BYTES) -> bool:
    return tape_vmem_bytes(m, max_depth) <= budget


def _kernel(x_ref, r_ref, kind_ref, *, tape: tuple):
    full = jnp.uint32(0xFFFFFFFF)
    stack = []
    for opcode, arg in tape:
        if opcode == PUSH:
            stack.append(x_ref[arg])
        elif opcode == NOT:
            stack.append(stack.pop() ^ full)
        elif arg == OP_AND:
            b = stack.pop()
            stack.append(stack.pop() & b)
        elif arg == OP_OR:
            b = stack.pop()
            stack.append(stack.pop() | b)
        else:
            b = stack.pop()
            stack.append(stack.pop() ^ b)
    r = stack.pop()
    r_ref[...] = r
    kind_ref[...] = jnp.where(r == 0, 0, jnp.where(r == full, 1, 2)
                              ).astype(jnp.int32)


def planfuse_kernel(x: jax.Array, tape: tuple, *, interpret: bool = True):
    """x (m, N, C) uint32, tape — static ``(opcode, arg)`` pairs from
    ``core.query.lower_plan``; returns (result (N, C), kind (N, C))."""
    m, N, C = x.shape
    assert N % ROW_TILE == 0 and C % LANE_TILE == 0
    n_push = sum(1 for opcode, _ in tape if opcode == PUSH)
    assert n_push <= m, (n_push, m)
    grid = (N // ROW_TILE, C // LANE_TILE)
    in_spec = pl.BlockSpec((m, ROW_TILE, LANE_TILE), lambda i, j: (0, i, j))
    out_spec = pl.BlockSpec((ROW_TILE, LANE_TILE), lambda i, j: (i, j))
    return pl.pallas_call(
        partial(_kernel, tape=tuple(tape)),
        grid=grid,
        in_specs=[in_spec],
        out_specs=(out_spec, out_spec),
        out_shape=(jax.ShapeDtypeStruct((N, C), jnp.uint32),
                   jax.ShapeDtypeStruct((N, C), jnp.int32)),
        interpret=interpret,
    )(x)
