"""Pallas TPU kernel: histogram via one-hot matmul (MXU-native).

Scatter-add histograms serialize on TPU; the systolic-array-native form is
``ones(1,T) @ onehot(T,V)`` — the paper's attribute-value histograms
(the 'histogram' in histogram-aware) computed at MXU rate.

  in : vals (T,) int32 in [0, V)
  out: counts (V,) float32   (f32 accumulation; exact for counts < 2^24)

Grid: (V/128, T/512); the token dim is the reduction dim, accumulated
across grid steps into the same output block (revisiting-output pattern).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TOK_TILE = 512
VAL_TILE = 128


def _kernel(vals_ref, out_ref):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    v0 = pl.program_id(0) * VAL_TILE
    vals = vals_ref[...]  # (1, TOK_TILE)
    vcol = jax.lax.broadcasted_iota(jnp.int32, (TOK_TILE, VAL_TILE), 1) + v0
    onehot = (vals.reshape(TOK_TILE, 1) == vcol).astype(jnp.float32)
    ones = jnp.ones((1, TOK_TILE), jnp.float32)
    out_ref[...] += jnp.dot(ones, onehot,
                            preferred_element_type=jnp.float32)


def histmm_kernel(vals: jax.Array, n_values: int, *, interpret: bool = True):
    (T,) = vals.shape
    assert T % TOK_TILE == 0 and n_values % VAL_TILE == 0
    vals2 = vals.reshape(1, T)
    out = pl.pallas_call(
        _kernel,
        grid=(n_values // VAL_TILE, T // TOK_TILE),
        in_specs=[pl.BlockSpec((1, TOK_TILE), lambda v, t: (0, t))],
        out_specs=pl.BlockSpec((1, VAL_TILE), lambda v, t: (0, v)),
        out_shape=jax.ShapeDtypeStruct((1, n_values), jnp.float32),
        interpret=interpret,
    )(vals2)
    return out[0]
