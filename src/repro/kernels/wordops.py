"""Pallas TPU kernel: word-aligned bitwise ops + clean-word classification.

The throughput path for EWAH logical operations on TPU (DESIGN.md §3):
tiles of packed words are combined with the VPU bitwise op while the same
pass classifies each result word (clean-0 / clean-1 / dirty), producing the
statistics the re-compression / size accounting needs — one VMEM round trip
for both jobs.

  in : a, b (N, 128) uint32
  out: r    (N, 128) uint32 = a OP b
       cls  (N, 128) int32 in {0,1,2}  (0x00, 0xFF.., dirty)
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 64
LANE_TILE = 128
FULL = jnp.uint32(0xFFFFFFFF)

_OPS = {"and": 0, "or": 1, "xor": 2}


def _kernel(a_ref, b_ref, r_ref, cls_ref, *, op: int):
    a = a_ref[...]
    b = b_ref[...]
    if op == 0:
        r = a & b
    elif op == 1:
        r = a | b
    else:
        r = a ^ b
    r_ref[...] = r
    full = jnp.bitwise_not(jnp.zeros_like(r))  # 0xFFFFFFFF without capture
    cls_ref[...] = jnp.where(r == 0, 0, jnp.where(r == full, 1, 2)).astype(jnp.int32)


def wordops_kernel(a: jax.Array, b: jax.Array, op: str = "and",
                   *, interpret: bool = True):
    N, C = a.shape
    assert a.shape == b.shape and N % ROW_TILE == 0 and C % LANE_TILE == 0
    grid = (N // ROW_TILE, C // LANE_TILE)
    spec = pl.BlockSpec((ROW_TILE, LANE_TILE), lambda i, j: (i, j))
    return pl.pallas_call(
        partial(_kernel, op=_OPS[op]),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=(spec, spec),
        out_shape=(jax.ShapeDtypeStruct((N, C), jnp.uint32),
                   jax.ShapeDtypeStruct((N, C), jnp.int32)),
        interpret=interpret,
    )(a, b)
