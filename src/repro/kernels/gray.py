"""Pallas TPU kernel: binary <-> Gray-code conversion for sort keys.

Gray-Lex / Gray-Frequency orderings need Gray ranks of attribute values as
sort keys (DESIGN.md §3).  to-Gray is one xor-shift; from-Gray is the
log-cascade prefix xor — both pure VPU element-wise chains on (8,128) tiles.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 64
LANE_TILE = 128


def _kernel(x_ref, o_ref, *, inverse: bool):
    x = x_ref[...]
    if not inverse:
        o_ref[...] = x ^ (x >> jnp.uint32(1))
    else:
        for s in (1, 2, 4, 8, 16):
            x = x ^ (x >> jnp.uint32(s))
        o_ref[...] = x


def gray_kernel(x: jax.Array, inverse: bool = False, *, interpret: bool = True):
    N, C = x.shape
    assert N % ROW_TILE == 0 and C % LANE_TILE == 0
    spec = pl.BlockSpec((ROW_TILE, LANE_TILE), lambda i, j: (i, j))
    return pl.pallas_call(
        partial(_kernel, inverse=inverse),
        grid=(N // ROW_TILE, C // LANE_TILE),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((N, C), jnp.uint32),
        interpret=interpret,
    )(x)
