"""Pure-jnp oracles for every Pallas kernel (tested via assert_allclose)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

FULL = jnp.uint32(0xFFFFFFFF)


def bitpack(bits: jax.Array) -> jax.Array:
    """(R, C) bool/int -> (ceil(R/32), C) uint32 (zero-padded rows)."""
    R, C = bits.shape
    pad = (-R) % 32
    if pad:
        bits = jnp.pad(bits, ((0, pad), (0, 0)))
    b = bits.astype(jnp.uint32).reshape(-1, 32, C)
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :, None]
    return (b << shifts).sum(axis=1, dtype=jnp.uint32)


def wordops(a, b, op="and"):
    fn = {"and": jnp.bitwise_and, "or": jnp.bitwise_or,
          "xor": jnp.bitwise_xor}[op]
    r = fn(a, b)
    cls = jnp.where(r == 0, 0, jnp.where(r == FULL, 1, 2)).astype(jnp.int32)
    return r, cls


def gray(x, inverse=False):
    x = x.astype(jnp.uint32)
    if not inverse:
        return x ^ (x >> jnp.uint32(1))
    for s in (1, 2, 4, 8, 16):
        x = x ^ (x >> jnp.uint32(s))
    return x


def histmm(vals, n_values):
    return jnp.zeros(n_values, jnp.float32).at[vals].add(1.0)


def moe_route(eids, n_experts):
    T, k = eids.shape
    onehot = jax.nn.one_hot(eids, n_experts, dtype=jnp.uint32).sum(1)
    onehot = jnp.minimum(onehot, 1)
    return bitpack(onehot)
