"""Pallas TPU kernel: fused classify + run-boundary pass for in-graph EWAH
recompression.

The segmented run-length emit that re-encodes a query result's dense words
back to an EWAH stream without leaving the graph (DESIGN.md §3: the jax
backend's word-space fold output must stay compressed for result caching /
shard shipping) splits into

  1. a VPU-friendly prefix pass — classify every word
     (clean-0 / clean-1 / dirty) and flag run starts by comparing each
     word's class against its predecessor's — this kernel, one VMEM round
     trip for both jobs over 128-lane tiles;
  2. a scan/scatter epilogue (exclusive scan of group sizes, marker and
     dirty-word scatter) in jnp — ``ewah_jax.compress_from_runs``.

The caller supplies the predecessor array (a flat shift by one word, with a
sentinel of *opposite* class at each row's word 0), so batches of many
query-result rows flatten into a single launch without runs bleeding across
rows.

  in : w     (N, 128) uint32  words
       p     (N, 128) uint32  predecessor words
  out: kind  (N, 128) int32 in {0,1,2}  (0x0, 0xFF.., dirty)
       start (N, 128) int32 in {0,1}    (class(w) != class(p))
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 64
LANE_TILE = 128


def _kernel(w_ref, p_ref, kind_ref, start_ref):
    w = w_ref[...]
    p = p_ref[...]
    full = jnp.bitwise_not(jnp.zeros_like(w))  # 0xFFFFFFFF without capture
    kw = jnp.where(w == 0, 0, jnp.where(w == full, 1, 2)).astype(jnp.int32)
    kp = jnp.where(p == 0, 0, jnp.where(p == full, 1, 2)).astype(jnp.int32)
    kind_ref[...] = kw
    start_ref[...] = (kw != kp).astype(jnp.int32)


def recompress_kernel(w: jax.Array, p: jax.Array, *, interpret: bool = True):
    N, C = w.shape
    assert w.shape == p.shape and N % ROW_TILE == 0 and C % LANE_TILE == 0
    grid = (N // ROW_TILE, C // LANE_TILE)
    spec = pl.BlockSpec((ROW_TILE, LANE_TILE), lambda i, j: (i, j))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=(spec, spec),
        out_shape=(jax.ShapeDtypeStruct((N, C), jnp.int32),
                   jax.ShapeDtypeStruct((N, C), jnp.int32)),
        interpret=interpret,
    )(w, p)
