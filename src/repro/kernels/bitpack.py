"""Pallas TPU kernel: pack boolean bitmap columns into 32-bit words.

The paper's Algorithm 1 "wordizes" 32 table rows at a time on a CPU; the
TPU-native form packs a (rows x bitmaps) boolean tile resident in VMEM into
uint32 words with VPU shift/or reductions — 128 bitmaps per lane-dim tile,
256 rows (-> 8 output sublanes) per row-dim tile, so in/out tiles are the
native (8,128)x4B register tiling.

  in : bits  (R, C) int8/bool   R % 256 == 0, C % 128 == 0 (ops.py pads)
  out: words (R/32, C) uint32   bit j of word w = bits[32*w + j]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 256  # 8 words of 32 rows
LANE_TILE = 128


def _kernel(bits_ref, words_ref):
    bits = bits_ref[...].astype(jnp.uint32)  # (ROW_TILE, LANE_TILE)
    b = bits.reshape(ROW_TILE // 32, 32, LANE_TILE)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 32, 1), 1)
    words_ref[...] = (b << shifts).sum(axis=1, dtype=jnp.uint32)


def bitpack_kernel(bits: jax.Array, *, interpret: bool = True) -> jax.Array:
    """bits: (R, C) -> (R//32, C) uint32.  Shapes must be tile-aligned."""
    R, C = bits.shape
    assert R % ROW_TILE == 0 and C % LANE_TILE == 0, (R, C)
    grid = (R // ROW_TILE, C // LANE_TILE)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROW_TILE, LANE_TILE), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((ROW_TILE // 32, LANE_TILE), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R // 32, C), jnp.uint32),
        interpret=interpret,
    )(bits)
