"""Training and serving step functions (pjit-able)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..models import transformer
from ..models.common import lshard
from ..optim.adamw import OptConfig, apply_updates, init_opt_state


def cross_entropy(logits, labels, mask=None):
    """Token-level CE. logits (b, s, V) any float dtype; labels (b, s) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(params, cfg, batch, aux_weight=0.01):
    inputs = batch["inputs"]
    logits, aux = transformer.forward(
        params, cfg, inputs,
        positions=batch.get("positions"),
        mrope_positions=batch.get("mrope_positions"),
        patches=batch.get("patches"))
    loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss + aux_weight * aux, (loss, aux)


def train_step(params, opt_state, batch, *, cfg, opt_cfg: OptConfig,
               microbatches: int = 1, grad_shardings=None,
               accum: str = "scan"):
    """One optimizer step; optionally accumulates over microbatches.

    accum="unroll" (§Perf iteration 6): python-unrolled accumulation — the
    per-microbatch gradient all-reduces feed a tree of adds, which XLA's
    AllReduceReassociate merges into ONE data-parallel sync per step.
    accum="scan" folds the microbatch dim into lax.scan (O(1) HLO size)
    but the eager all-reduce inside the loop body executes once per
    microbatch: measured 16x more DP sync volume at microbatches=16.

    grad_shardings: pytree of NamedSharding matching params — constrains
    grads to the ZeRO moment shardings (reduce-scatter dataflow)."""

    if microbatches == 1:
        grads, (loss, aux) = jax.grad(loss_fn, has_aux=True)(params, cfg, batch)
    elif accum == "unroll":
        B = batch["inputs"].shape[0]

        def mb_slice(x, i):
            if x.shape[0] == B:
                m = B // microbatches
                return x[i * m : (i + 1) * m]
            m = x.shape[1] // microbatches
            return x[:, i * m : (i + 1) * m]

        grads = None
        loss = aux = 0.0
        for i in range(microbatches):
            mbatch = jax.tree.map(lambda x: mb_slice(x, i), batch)
            g, (l, a) = jax.grad(loss_fn, has_aux=True)(params, cfg, mbatch)
            grads = g if grads is None else jax.tree.map(jnp.add, grads, g)
            loss, aux = loss + l, aux + a
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        loss, aux = loss / microbatches, aux / microbatches
    else:
        B = batch["inputs"].shape[0]

        def split(x):
            if x.shape[0] == B:
                y = x.reshape(microbatches, B // microbatches, *x.shape[1:])
                axes = (None, "batch") + (None,) * (y.ndim - 2)
            else:
                # leading non-batch dim, e.g. mrope_positions (3, B, S)
                y = x.reshape(x.shape[0], microbatches,
                              B // microbatches, *x.shape[2:])
                y = jnp.moveaxis(y, 1, 0)  # (mb, 3, b, ...)
                axes = (None, None, "batch") + (None,) * (y.ndim - 3)
            # keep the data-parallel shard on the (new) batch dim
            return lshard(y, *axes)

        mb = jax.tree.map(split, batch)

        def _constrain(g):
            if grad_shardings is None:
                return g
            return jax.tree.map(
                lambda x, sh: jax.lax.with_sharding_constraint(x, sh),
                g, grad_shardings)

        def acc_step(carry, mbatch):
            g_acc, l_acc, a_acc = carry
            g, (l, a) = jax.grad(loss_fn, has_aux=True)(params, cfg, mbatch)
            g = _constrain(g)  # reduce-scatter per microbatch (ZeRO accum)
            return (jax.tree.map(jnp.add, g_acc, g), l_acc + l, a_acc + a), None

        g0 = _constrain(
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (grads, loss, aux), _ = jax.lax.scan(
            acc_step, (g0, jnp.float32(0), jnp.float32(0)), mb)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        loss, aux = loss / microbatches, aux / microbatches

    if grad_shardings is not None:
        grads = jax.tree.map(
            lambda g, sh: jax.lax.with_sharding_constraint(g, sh),
            grads, grad_shardings)
    if "ef" in opt_state:
        # int8 error-feedback compression of the cross-pod gradient sync
        # (optim/compress.py); opt_state must come from
        # init_opt_state(params, error_feedback=True)
        from ..optim.compress import compress_grads as _cg
        grads, new_ef = _cg(grads, opt_state["ef"])
        opt_state = dict(opt_state, ef=new_ef)
    new_params, new_opt, metrics = apply_updates(opt_cfg, params, grads, opt_state)
    metrics.update({"loss": loss, "aux_loss": aux})
    return new_params, new_opt, metrics


def eval_step(params, batch, *, cfg):
    loss, (ce, aux) = loss_fn(params, cfg, batch)
    return {"loss": loss, "ce": ce, "aux": aux}


def serve_step(params, tokens, cache, cache_len, *, cfg, temperature=0.0, rng=None):
    """One batched decode step: logits -> next token ids.

    tokens: (b, 1) int32 (or (b, 1, d) embeddings for vlm/audio stubs).
    Greedy when temperature == 0.
    """
    logits, new_cache = transformer.decode_step(params, cfg, tokens, cache, cache_len)
    if temperature > 0.0 and rng is not None:
        next_tok = jax.random.categorical(rng, logits / temperature, axis=-1)
    else:
        next_tok = jnp.argmax(logits, axis=-1)
    return next_tok.astype(jnp.int32)[:, None], new_cache


def prefill_step(params, batch, *, cfg):
    """Prefill: forward over the prompt, returning logits for sampling the
    first generated token (cache-filling fused variant is future work —
    dry-run measures the forward cost, which dominates)."""
    logits, _ = transformer.forward(
        params, cfg, batch["inputs"],
        positions=batch.get("positions"),
        mrope_positions=batch.get("mrope_positions"),
        patches=batch.get("patches"))
    return logits[:, -1]
