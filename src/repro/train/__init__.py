from .step import (cross_entropy, eval_step, loss_fn, prefill_step,
                   serve_step, train_step)
