"""qwen2-vl-7b: qwen2-7b backbone + M-RoPE; patch frontend is a stub
(input_specs provides precomputed patch embeddings) [arXiv:2409.12191]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064, head_dim=128,
    qkv_bias=True, rope_theta=1e6,
    frontend="patch", mrope_sections=(16, 24, 24),
)
