"""qwen2-moe-a2.7b: 4 shared + 60 routed experts, top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B].  Routed expert ff=1408; the 4 shared experts
are fused into one 5632-wide FFN.  Dispatch bitmaps are 4-of-60 codes —
the paper's k-of-N encoding (DESIGN.md §4)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=151936, head_dim=128, rope_theta=1e6,
    n_experts=60, n_shared_experts=4, top_k=4,
    moe_d_ff=1408, shared_d_ff=5632,
)
