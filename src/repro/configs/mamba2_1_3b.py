"""mamba2-1.3b: attention-free SSD [arXiv:2405.21060].
48 mamba2 layers, d_state=128, tied embeddings, sub-quadratic."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280, rope=False,
    ssm_state=128, ssm_heads=64, ssm_groups=1, ssm_expand=2, ssm_chunk=128,
    tie_embeddings=True, subquadratic=True,
)
