"""Model configuration dataclass + registry for the assigned architectures."""

from __future__ import annotations

import importlib
from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                 # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope: bool = True
    rope_theta: float = 1e6
    sliding_window: int = 0      # 0 = full attention
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    shared_d_ff: int = 0
    route_sort: str = "none"     # "none" | "expert" | "grayfreq"
    moe_dispatch: str = "gather" # "gather" (optimized) | "scatter" (baseline)
    moe_capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_groups: int = 1
    ssm_expand: int = 2
    ssm_chunk: int = 128
    attn_every: int = 0          # hybrid: shared attention block period
    # multimodal stubs
    frontend: str = "none"       # none | patch (vlm) | frames (audio)
    mrope_sections: tuple = (16, 24, 24)
    # numerics / impl
    dtype: str = "bfloat16"
    attn_impl: str = "blockwise"
    remat: bool = True
    remat_policy: str = "dots"   # "dots" (save matmul outs) | "full" (save nothing)
    # which input shapes this arch supports for the long-context cell
    subquadratic: bool = False   # True -> can run long_500k

    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 16 so vocab-sharding divides the
        production model axis (standard embedding padding)."""
        return -(-self.vocab_size // 16) * 16

    def smoke(self) -> "ModelConfig":
        """Reduced config of the same family for CPU smoke tests."""
        kw = dict(
            n_layers=2, d_model=128, vocab_size=256,
            d_ff=256 if self.d_ff else 0,
        )
        if self.n_heads:
            kw.update(n_heads=4, n_kv_heads=max(1, min(self.n_kv_heads, 2)), head_dim=32)
        if self.frontend == "patch":
            kw.update(mrope_sections=(4, 6, 6))  # sums to head_dim/2 = 16
        if self.n_experts:
            kw.update(n_experts=8, top_k=min(self.top_k, 2), moe_d_ff=64,
                      shared_d_ff=128 if self.n_shared_experts else 0)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_heads=4, ssm_chunk=16)
        if self.attn_every:
            kw.update(attn_every=2)
        return replace(self, **kw)


_REGISTRY = [
    "qwen2_7b", "tinyllama_1_1b", "phi3_medium_14b", "qwen2_5_14b",
    "qwen2_vl_7b", "zamba2_1_2b", "qwen2_moe_a2_7b", "olmoe_1b_7b",
    "musicgen_medium", "mamba2_1_3b",
]

ARCH_IDS = [m.replace("_", "-").replace("qwen2-5", "qwen2.5")
            .replace("tinyllama-1-1b", "tinyllama-1.1b")
            .replace("phi3-medium-14b", "phi3-medium-14b")
            .replace("zamba2-1-2b", "zamba2-1.2b")
            .replace("qwen2-moe-a2-7b", "qwen2-moe-a2.7b")
            .replace("olmoe-1b-7b", "olmoe-1b-7b")
            .replace("mamba2-1-3b", "mamba2-1.3b")
            for m in _REGISTRY]


def get_config(arch: str) -> ModelConfig:
    """Look up an architecture by its public id (e.g. 'qwen2-7b')."""
    module_name = (
        arch.replace(".", "_").replace("-", "_")
    )
    mod = importlib.import_module(f"repro.configs.{module_name}")
    return mod.CONFIG


def list_archs():
    return list(ARCH_IDS)
