"""olmoe-1b-7b: 64 experts, top-8 [arXiv:2409.02060].
Dispatch bitmaps are 8-of-64 codes (paper k-of-N)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304, head_dim=128, rope_theta=1e4,
    n_experts=64, n_shared_experts=0, top_k=8, moe_d_ff=1024,
)
