"""zamba2-1.2b: Mamba2 backbone + ONE shared (attn+MLP) block applied every
6 mamba layers (weight-tied) [arXiv:2411.15242].  d_ff is the shared block's
MLP width.  Long-context: shared attention uses a 4096 sliding window at
500k (DESIGN.md §Arch-applicability)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64, rope_theta=1e4,
    ssm_state=64, ssm_heads=64, ssm_groups=1, ssm_expand=2, ssm_chunk=128,
    attn_every=6, subquadratic=True,
)
