"""Fused prefill: one forward pass that also populates the decode cache.

Serving a request = prefill_with_cache(prompt) -> serve_step loop.  The
per-layer K/V projections are captured as scan outputs and written into
the (layers, b, max_len, kvh, hd) cache; SSM/hybrid archs capture the
final recurrent state and conv tail instead.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import ssm as ssm_mod
from ..models import transformer
from ..models.attention import attention
from ..models.common import lshard, rms_norm, swiglu
from ..models.moe import moe_ffn
from ..models.ssm import CONV_K, mamba2_block


def prefill_with_cache(params, cfg, tokens, max_len: int,
                       mrope_positions=None, patches=None):
    """tokens: (b, s) ids. Returns (next_token_logits (b, V), cache)."""
    b, s = tokens.shape[:2]
    assert s <= max_len
    if tokens.ndim == 2:
        x = params["embed"][tokens]
    else:
        x = tokens
    if patches is not None:
        x = jax.lax.dynamic_update_slice(x, patches.astype(x.dtype), (0, 0, 0))
    x = lshard(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if not cfg.rope and cfg.family not in ("ssm", "hybrid"):
        x = x + transformer._sinusoid(positions, cfg.d_model).astype(x.dtype)

    cache = transformer.init_decode_cache(cfg, b, max_len)

    if cfg.family in ("ssm", "hybrid"):
        convs, states, ks, vs = [], [], [], []
        slot = 0
        for start, ln, shared_after in transformer._segments(cfg):
            sl = jax.tree.map(lambda a: a[start : start + ln], params["layers"])

            def body(x, lp):
                h = rms_norm(x, lp["ln1"])
                # recompute final state via the chunked scan
                mix = mamba2_block(lp["mixer"], cfg, h)
                return x + mix, _ssm_tail_state(lp["mixer"], cfg, h)

            x, (conv_t, state_t) = jax.lax.scan(body, x, sl)
            convs.append(conv_t)
            states.append(state_t)
            if shared_after:
                sp = params["shared_attn"]
                h = rms_norm(x, sp["ln1"])
                o, k, v = attention(sp["attn"], cfg, h, positions,
                                    impl=cfg.attn_impl, return_kv=True)
                x = x + o
                h = rms_norm(x, sp["ln2"])
                x = x + swiglu(h, sp["ffn"]["w_gate"], sp["ffn"]["w_up"],
                               sp["ffn"]["w_down"])
                ks.append(k)
                vs.append(v)
                slot += 1
        cache["conv"] = jnp.concatenate(convs)
        cache["state"] = jnp.concatenate(states)
        if cfg.family == "hybrid" and ks:
            pad = max_len - s
            cache["k"] = jnp.pad(jnp.stack(ks), ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))).astype(cache["k"].dtype)
            cache["v"] = jnp.pad(jnp.stack(vs), ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))).astype(cache["v"].dtype)
    else:
        def body(x, lp):
            h = rms_norm(x, lp["ln1"])
            o, k, v = attention(lp["mixer"], cfg, h, positions,
                                mrope_positions, impl=cfg.attn_impl,
                                return_kv=True)
            x = x + o
            h = rms_norm(x, lp["ln2"])
            if cfg.family == "moe":
                y, _ = moe_ffn(lp["ffn"], cfg, h, route_sort="none",
                               dispatch=cfg.moe_dispatch)
            else:
                y = swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                           lp["ffn"]["w_down"])
            return x + y, (k, v)

        x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
        pad = max_len - s
        cache["k"] = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))).astype(cache["k"].dtype)
        cache["v"] = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))).astype(cache["v"].dtype)
        cache["k"] = lshard(cache["k"], None, "batch", "kv_seq", "kv_heads", "head_dim")
        cache["v"] = lshard(cache["v"], None, "batch", "kv_seq", "kv_heads", "head_dim")

    x = rms_norm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x[:, -1] @ head)
    return logits, cache


def _ssm_tail_state(p, cfg, h):
    """Final (conv tail, ssm state) of a mamba2 layer over prompt h."""
    b, s, d = h.shape
    d_in = cfg.ssm_expand * d
    ng, N, nh = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    hp = d_in // nh
    zxbcdt = h @ p["w_in"]
    xBC = zxbcdt[..., d_in : d_in + d_in + 2 * ng * N]
    # conv tail: last K-1 pre-activation inputs
    tail = xBC[:, -(CONV_K - 1):]
    if s < CONV_K - 1:
        tail = jnp.pad(xBC, ((0, 0), (CONV_K - 1 - s, 0), (0, 0)))
    from ..models.ssm import _causal_conv, ssd_chunked
    xBC1 = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xs = xBC1[..., :d_in].reshape(b, s, nh, hp)
    B = xBC1[..., d_in : d_in + ng * N].reshape(b, s, ng, N)
    C = xBC1[..., d_in + ng * N :].reshape(b, s, ng, N)
    dt = jax.nn.softplus(zxbcdt[..., -nh:].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    pad = (-s) % cfg.ssm_chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    _, S = ssd_chunked(xs.astype(jnp.float32), dt, A, B.astype(jnp.float32),
                       C.astype(jnp.float32), p["D"], cfg.ssm_chunk)
    return tail, S
