"""Distributed placement over the ("data", "model") mesh.

Translates the models' *logical* axis annotations (models/common.py
DEFAULT_RULES) into concrete ``jax.sharding.NamedSharding`` trees that the
launchers hand to ``jax.jit`` as in/out shardings:

  * ``param_shardings`` — tensor parallelism: FFN ("ff"), attention heads
    ("heads"), vocab/embedding ("vocab") and expert ("experts") dims land on
    the "model" axis; everything else is replicated.
  * ``opt_shardings``   — ZeRO-1: AdamW moments are stored **1-D flattened
    and zero-padded** to a multiple of the "data"-axis size
    (``init_opt_state(params, zero_pad=zero_pad_for(mesh))``) and sharded
    over that axis, so *every* leaf shards regardless of its dimension
    divisibility and optimizer memory scales down with data parallelism.
    ``grad_shardings_zero`` keeps the old param-shaped dim-based placement
    for gradient constraints (grads stay param-shaped; the constraint
    drives the reduce-scatter dataflow).
  * ``batch_shardings`` — train / prefill / decode batches split on the
    data axes (("pod", "data") when a pod axis exists).
  * ``cache_shardings`` — decode KV cache / SSM state placement per
    ``transformer.cache_axes``.

All functions are pure metadata: no device allocation happens here, so they
are safe to call under ``jax.eval_shape`` and inside an already-active
``ShardingCtx`` (the context is re-entrant).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..models import transformer
from ..models.common import DEFAULT_RULES, ShardingCtx, logical_to_spec


def replicated(mesh) -> NamedSharding:
    """Fully-replicated placement (scalars, small broadcast state)."""
    return NamedSharding(mesh, P())


def _shardings_from_axes(mesh, axes_tree, rules=None):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    with ShardingCtx(mesh, rules):
        return jax.tree.map(
            lambda ax: NamedSharding(mesh, logical_to_spec(ax)),
            axes_tree, is_leaf=lambda x: isinstance(x, tuple))


def param_shardings(mesh, cfg, rules=None):
    """NamedSharding tree mirroring ``transformer.init_params(key, cfg)``."""
    return _shardings_from_axes(mesh, transformer.params_axes(cfg), rules)


def _mesh_axes_size(mesh, axis) -> int:
    axes = axis if isinstance(axis, tuple) else (axis,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def _zero_axis(mesh, rules):
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    zero = merged.get("opt_zero")
    if isinstance(zero, tuple):
        zero = tuple(a for a in zero if a in mesh.axis_names) or None
    elif zero is not None and zero not in mesh.axis_names:
        zero = None
    return zero


def _zero1_sharding(sharding, shape, mesh, zero):
    """Extend a param sharding with the ZeRO axis on the first replicated
    dimension it divides (the legacy dim-based placement; leaves with no
    divisible replicated dim stay unsharded — still used for *gradient*
    constraints, which must keep the parameter shape)."""
    spec = list(sharding.spec) + [None] * (len(shape) - len(sharding.spec))
    dsize = _mesh_axes_size(mesh, zero)
    if dsize > 1:
        for i, dim in enumerate(shape):
            if spec[i] is None and dim % dsize == 0:
                spec[i] = zero
                break
    return NamedSharding(mesh, P(*spec))


def zero_pad_for(mesh, rules=None) -> int:
    """The ZeRO-1 flatten multiple: size of the mesh's ZeRO axis (1 when
    the mesh has no such axis — moments then keep the parameter shape).
    Pass this as ``init_opt_state(params, zero_pad=...)`` so the stored
    moment shapes match :func:`opt_shardings`."""
    zero = _zero_axis(mesh, rules)
    return _mesh_axes_size(mesh, zero) if zero is not None else 1


def opt_shardings(mesh, cfg, rules=None):
    """NamedSharding tree mirroring
    ``init_opt_state(params, zero_pad=zero_pad_for(mesh))``: ZeRO-1
    moments ("m"/"v"), replicated step counter.

    Moments are stored 1-D flattened, zero-padded to a multiple of the
    ZeRO-axis size, and sharded ``P(zero)`` — flatten + pad + reshape means
    every leaf shards evenly whatever its dimensions (a (4097, 3) leaf on
    an 8-way data axis shards as 8 x 1537 flat words), where the old
    dim-based placement left any leaf with no divisible replicated dim
    fully replicated."""
    p_sh = param_shardings(mesh, cfg, rules)
    zero = _zero_axis(mesh, rules)
    if zero is None or _mesh_axes_size(mesh, zero) <= 1:
        m_sh = p_sh
    else:
        flat = NamedSharding(mesh, P(zero))
        m_sh = jax.tree.map(lambda _: flat, p_sh)
    return {"m": m_sh, "v": m_sh, "step": replicated(mesh)}


def grad_shardings_zero(mesh, cfg, rules=None):
    """Param-shaped ZeRO placements for *gradient* sharding constraints
    (``train_step(grad_shardings=...)``): grads must keep the parameter
    shape, so this is the dim-based placement — the ZeRO axis lands on the
    first replicated dimension it divides, and non-divisible leaves stay
    replicated (their moment storage still shards via the flat path)."""
    p_sh = param_shardings(mesh, cfg, rules)
    zero = _zero_axis(mesh, rules)
    if zero is None:
        return p_sh
    shapes = jax.eval_shape(
        lambda k: transformer.init_params(k, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    return jax.tree.map(
        lambda sh, s: _zero1_sharding(sh, s.shape, mesh, zero),
        p_sh, shapes)


def batch_shardings(mesh, cfg, kind: str, rules=None):
    """Input-batch placements for one step kind.

    kind: "train" (inputs+labels), "prefill" (inputs only), or
    "decode"/"serve" (single-token ids).  Optional modality keys
    (patches / mrope_positions) appear exactly when the config uses them;
    callers with plainer batches pop what they don't feed.
    """
    with ShardingCtx(mesh, rules):
        def ns(*axes):
            return NamedSharding(mesh, logical_to_spec(axes))

        if kind in ("train", "prefill"):
            sh = {"inputs": ns("batch", "seq")}
            if kind == "train":
                sh["labels"] = ns("batch", "seq")
            if cfg.frontend != "none":
                sh["patches"] = ns("batch", None, "embed")
            if cfg.family == "vlm":
                sh["mrope_positions"] = ns(None, "batch", "seq")
            return sh
        if kind in ("decode", "serve"):
            return {"tokens": ns("batch", None)}
        raise ValueError(f"unknown batch kind: {kind!r}")


def cache_shardings(mesh, cfg, rules=None):
    """NamedSharding tree mirroring ``transformer.init_decode_cache``."""
    return _shardings_from_axes(mesh, transformer.cache_axes(cfg), rules)
