"""Multi-host segment-parallel serving: the cross-process serve plane.

:class:`ServePlane` turns the single-process segmented engine into a
coordinator + N worker processes, one segment-subset per worker:

* **Placement.**  The coordinator owns the authoritative
  :class:`~repro.core.lifecycle.IndexWriter` (appends, deletes, seals,
  compactions all land there first).  Every query first *syncs*: it
  snapshots the writer's segment list, computes the ownership map with
  :func:`~repro.dist.query_fanout.assign_segments` (word-aligned carving
  of the cumulative compressed word space — the same splitter the
  in-process fan-out uses), and ships any new or reassigned segment to
  its owner.  Compaction changes the generation list, so ownership
  rebalances automatically at the next sync.

* **Shipping.**  A segment crosses the wire as its *reconstruction
  state* (:func:`segment_state`): ingest-order raw columns, id-span
  bounds, ``row_ids``/``expiry``, the ingest-local tombstoned positions,
  and the per-original-column encoding kinds the seal chose.  The worker
  re-runs the deterministic seal pipeline (:func:`seal_from_state`) with
  those kinds pinned, producing a bit-identical local index — per-plane
  bitmaps never cross the wire in either direction.

* **Execution.**  The coordinator fans a query batch out to every owner
  (all sends first, then all receives — workers compute in parallel),
  each worker executes its segments' plans through the existing backends
  (numpy, or jax with megakernel fusion) and replies with **compressed**
  :meth:`~repro.core.ewah_stream.EwahStream.to_bytes` result streams —
  results are never densified for transport.  The coordinator evaluates
  the open buffer densely (it owns those rows), stitches per-segment
  streams with :func:`~repro.core.ewah_stream.concat_streams`, and
  returns original-ingest-order row ids — bit-identical to
  :class:`~repro.core.segment.SegmentedIndex` over the same writer.

* **Checkpointing.**  :meth:`ServePlane.save_checkpoint` runs the
  two-phase commit barrier from :mod:`repro.dist.checkpoint`: phase 1,
  every worker writes the segment directories it owns (the coordinator
  writes zero-row segments and the writer-level buffer state) and acks
  per-file CRCs; phase 2, the coordinator verifies every ack, fsyncs the
  manifest, atomically flips ``LATEST``, and only then prunes old steps.
  :meth:`ServePlane.restore` reassembles a writer from the manifest and
  re-shards ownership over the *current* world size, so a host missing
  since the save is tolerated by design.

Transport is length-prefixed CRC-framed pickle over a loopback TCP
socket pair per worker (workers are subprocesses this coordinator
spawned — a trusted, same-user transport; the framing is for integrity
and the EWAH payloads additionally carry their own versioned header +
CRC via ``EwahStream.to_bytes``).  Worker processes import only the
numpy core (~no jax) until a query names ``backend="jax"``.

See docs/dist.md for the ownership map, wire framing, and the commit
barrier state diagram.
"""

from __future__ import annotations

import argparse
import os
import pickle
import socket
import struct
import subprocess
import sys
import time
import zlib
from time import perf_counter

import numpy as np

from ..analysis.runtime import make_lock, maybe_validate
from ..core import ewah
from ..core.bitmap_index import _observe_workload
from ..core.ewah_stream import EwahStream, concat_streams
from ..core.lifecycle import IndexWriter
from ..core.query import compile_plan, evaluate_mask, get_backend, \
    with_live_mask
from ..core.segment import Segment
from ..core.strategies import IndexSpec
from . import checkpoint as ckpt
from .query_fanout import assign_segments

__all__ = ["ServePlane", "seal_from_state", "segment_state", "worker_main"]


# ---------------------------------------------------------------------------
# Wire framing: <magic 4s> <version u8> <kind u8> <flags u16> <len u64>
# <crc u32>, then `len` payload bytes (pickle of an (op, payload) pair).
# ---------------------------------------------------------------------------

_FRAME = struct.Struct("<4sBBHQI")
_FRAME_MAGIC = b"SPLN"
_FRAME_VERSION = 1


class WireError(RuntimeError):
    """A frame failed validation (bad magic/version/CRC) or the peer hung
    up mid-message."""


def send_msg(sock, op: str, payload) -> int:
    """Frame and send one message; returns the bytes put on the wire."""
    body = pickle.dumps((op, payload), protocol=pickle.HIGHEST_PROTOCOL)
    frame = _FRAME.pack(_FRAME_MAGIC, _FRAME_VERSION, 0, 0, len(body),
                        zlib.crc32(body))
    sock.sendall(frame + body)
    return len(frame) + len(body)


def _recv_exact(sock, n: int) -> bytes:
    chunks = []
    while n:
        got = sock.recv(min(n, 1 << 20))
        if not got:
            raise WireError("peer closed the connection mid-message")
        chunks.append(got)
        n -= len(got)
    return b"".join(chunks)


def recv_msg(sock):
    """Receive one framed message; returns ``(op, payload, wire_bytes)``."""
    header = _recv_exact(sock, _FRAME.size)
    magic, version, _kind, _flags, length, crc = _FRAME.unpack(header)
    if magic != _FRAME_MAGIC:
        raise WireError(f"bad frame magic {magic!r}")
    if version != _FRAME_VERSION:
        raise WireError(f"unsupported frame version {version}")
    body = _recv_exact(sock, length)
    if zlib.crc32(body) != crc:
        raise WireError("frame payload CRC mismatch")
    op, payload = pickle.loads(body)
    return op, payload, _FRAME.size + length


# ---------------------------------------------------------------------------
# Segment <-> state dict: what crosses the wire and what checkpoints hold.
# ---------------------------------------------------------------------------


def segment_state(seg: Segment) -> dict:
    """A segment's reconstruction state: everything a peer needs to
    re-seal a bit-identical copy (and everything a checkpoint persists).

    ``dead`` captures the tombstone set at snapshot time as ingest-local
    positions (TTL deadlines travel separately in ``expiry`` and re-fold
    against the query-time clock on the receiving side — folding is
    idempotent, so a fold that already happened here never double-counts
    there).  ``encodings`` pins the per-original-column kinds this seal
    chose, so the receiver reproduces them even when they came from a
    workload-driven compaction chooser rather than the spec."""
    if seg.columns is None:
        raise ValueError(
            f"segment gen {seg.generation} was sealed with "
            "keep_columns=False; its row store is gone and it cannot be "
            "shipped or checkpointed")
    idx = seg.index
    return {
        "gen": int(seg.generation),
        "row_start": int(seg.row_start),
        "span_stop": None if seg.span_stop is None else int(seg.span_stop),
        "n_rows": int(seg.n_rows),
        "columns": [np.asarray(c) for c in seg.columns],
        "row_ids": seg.row_ids,
        "expiry": seg.expiry,
        "dead": np.flatnonzero(seg.dead_ingest_mask(None)),
        "encodings": {int(idx.col_perm[i]): idx.columns[i].encoding.kind
                      for i in range(len(idx.columns))},
    }


def seal_from_state(state: dict, spec: IndexSpec | None, *,
                    materialize: bool = True,
                    keep_columns: bool = True) -> Segment:
    """Re-run the deterministic seal pipeline on a :func:`segment_state`
    dict.  The recorded encoding kinds are pinned through the chooser
    hook, so the rebuilt index is bit-identical to the original
    regardless of what chooser produced those kinds."""
    row_start = int(state["row_start"])
    span_stop = state.get("span_stop")
    if not int(state["n_rows"]):
        return Segment.empty(row_start,
                             row_start if span_stop is None
                             else int(span_stop))
    kinds = {int(k): v for k, v in (state.get("encodings") or {}).items()}
    dead = state.get("dead")
    return Segment.seal(
        state["columns"], spec, row_start=row_start,
        span_stop=None if span_stop is None else int(span_stop),
        row_ids=state.get("row_ids"), expiry=state.get("expiry"),
        tombstone_rows=None if dead is None else np.asarray(dead,
                                                            dtype=np.int64),
        materialize=materialize, keep_columns=keep_columns,
        encoding_chooser=lambda col, hist, k: kinds.get(int(col)))


def _empty_stream() -> EwahStream:
    return EwahStream(ewah.compress(np.zeros(0, dtype=np.uint32)), 0, 0)


# ---------------------------------------------------------------------------
# Coordinator
# ---------------------------------------------------------------------------


class ServePlane:
    """Coordinator for a fleet of segment-owning worker processes.

    Wraps (or creates) an :class:`~repro.core.lifecycle.IndexWriter`;
    ingest mutations go straight to the writer and propagate to workers
    lazily at the next sync.  Query surfaces (`query`, `query_many`,
    `count`, `count_many`) match ``SegmentedIndex`` bit-for-bit.

    Lock order: ``_lock`` (reentrant) before the writer's ``_lock``,
    never the reverse — the plane never runs inside writer callbacks.
    Counters: ``result_bytes_compressed`` / ``result_bytes_dense`` track
    what result shipping cost versus what dense (1 bit/row) shipping
    would have cost; ``ship_bytes`` counts segment-state shipping.
    """

    def __init__(self, writer: IndexWriter | None = None, *,
                 n_hosts: int = 2, spec: IndexSpec | None = None,
                 names=None, seal_rows: int | None = None,
                 clock=time.time, workload_stats=None,
                 connect_timeout: float = 60.0):
        if n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
        self.writer = writer if writer is not None else IndexWriter(
            spec, names=names, seal_rows=seal_rows, clock=clock,
            workload_stats=workload_stats)
        self.n_hosts = int(n_hosts)
        self._lock = make_lock("serve_plane._lock")
        self._procs: list = []        # guarded-by: _lock
        self._socks: list = []        # guarded-by: _lock
        self._owner_of: dict = {}     # guarded-by: _lock  gen -> rank
        self._closed = False          # guarded-by: _lock
        self.ship_bytes = 0                 # guarded-by: _lock
        self.result_bytes_compressed = 0    # guarded-by: _lock
        self.result_bytes_dense = 0         # guarded-by: _lock
        self.restored_step: int | None = None
        with self._lock:
            self._spawn(connect_timeout)

    # -- process management ------------------------------------------------

    def _spawn(self, connect_timeout: float) -> None:  # holds-lock: _lock
        import repro

        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.bind(("127.0.0.1", 0))
        listener.listen(self.n_hosts)
        listener.settimeout(connect_timeout)
        host, port = listener.getsockname()
        # repro is a namespace package (__file__ is None) — its __path__
        # entry is the package dir, whose parent must be importable
        src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
        env = dict(os.environ)
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        env.setdefault("JAX_PLATFORMS", "cpu")
        try:
            for rank in range(self.n_hosts):
                self._procs.append(subprocess.Popen(
                    [sys.executable, "-m", "repro.dist.serve_plane",
                     "--worker", "--connect", f"{host}:{port}",
                     "--rank", str(rank)],
                    env=env))
            by_rank: dict = {}
            while len(by_rank) < self.n_hosts:
                conn, _ = listener.accept()
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                op, payload, _ = recv_msg(conn)
                if op != "hello":
                    raise WireError(f"expected hello, got {op!r}")
                by_rank[int(payload["rank"])] = conn
            self._socks = [by_rank[r] for r in range(self.n_hosts)]
            cfg = {"spec": self.writer.spec.to_dict(),
                   "names": (None if self.writer.names is None
                             else list(self.writer.names))}
            for sock in self._socks:
                send_msg(sock, "config", cfg)
            for rank in range(self.n_hosts):
                self._reply(rank)
        except BaseException:
            self._kill_workers()
            raise
        finally:
            listener.close()

    def _reply(self, rank: int):  # holds-lock: _lock
        op, payload, n = recv_msg(self._socks[rank])
        if op == "error":
            raise RuntimeError(
                f"worker {rank} failed:\n{payload['traceback']}")
        if op != "ok":
            raise WireError(f"worker {rank}: unexpected reply {op!r}")
        return payload, n

    def _kill_workers(self) -> None:  # holds-lock: _lock
        for sock in self._socks:
            try:
                sock.close()
            except OSError:
                pass
        for proc in self._procs:
            if proc.poll() is None:
                proc.kill()
        for proc in self._procs:
            try:
                proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                pass
        self._socks, self._procs = [], []

    @property
    def world_size(self) -> int:
        return len(self._socks)  # analysis-ok: lock/unguarded-read atomic list-reference snapshot

    def __enter__(self) -> "ServePlane":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Shut the worker fleet down (the writer stays usable)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            for rank, sock in enumerate(self._socks):
                try:
                    send_msg(sock, "shutdown", {})
                    self._reply(rank)
                except (OSError, WireError, RuntimeError):
                    pass
            self._kill_workers()

    # -- ingest passthrough ------------------------------------------------

    def append(self, rows, *, ttl=None) -> None:
        self.writer.append(rows, ttl=ttl)

    def seal(self):
        return self.writer.seal()

    def writer_close(self):
        """Seal the final segment and close the writer for appends (the
        plane keeps serving; :meth:`close` shuts the fleet down)."""
        return self.writer.close()

    def compact(self, span=None, **kw):
        return self.writer.compact(span, **kw)

    def delete(self, pred=None, *, row_ids=None, backend: str = "numpy",
               now=None) -> int:
        """Tombstone rows everywhere: the authoritative writer first, then
        a broadcast to every worker (each ignores ids outside its owned
        spans).  Predicate deletes resolve to ids through a plane query at
        a single ``now`` so both sides tombstone the identical row set."""
        if (pred is None) == (row_ids is None):
            raise ValueError("delete needs exactly one of pred= or row_ids=")
        with self._lock:
            now = self.writer.clock() if now is None else float(now)
            if row_ids is None:
                ids, _ = self.query(pred, backend=backend, now=now)
            else:
                ids = np.unique(np.asarray(row_ids, dtype=np.int64))
            deleted = self.writer.delete(row_ids=ids, now=now)
            for sock in self._socks:
                send_msg(sock, "delete_ids", {"ids": ids})
            for rank in range(len(self._socks)):
                self._reply(rank)
        return deleted

    # -- sync: ship the ownership map's deltas -----------------------------

    def _sync_locked(self):  # holds-lock: _lock
        """Snapshot the writer and bring every worker's owned set up to
        date; returns ``(segments, buffer, owner_of)`` for that snapshot.
        Ownership is recomputed from scratch each time — compaction or
        growth changes the generation list and segments re-home to keep
        the compressed-word load balanced."""
        segs, buf = self.writer.snapshot()
        owners = assign_segments(segs, len(self._socks))
        new_owner = {}
        ship: list = [[] for _ in self._socks]
        for seg, owner in zip(segs, owners):
            if not seg.n_rows:
                continue  # zero-row spans never ship; stitched locally
            new_owner[seg.generation] = owner
            if self._owner_of.get(seg.generation) != owner:
                ship[owner].append(seg)
        drop: list = [[] for _ in self._socks]
        for gen, owner in self._owner_of.items():
            if new_owner.get(gen) != owner:
                drop[owner].append(gen)
        pending = []
        for rank, sock in enumerate(self._socks):
            if ship[rank] or drop[rank]:
                states = [segment_state(s) for s in ship[rank]]
                self.ship_bytes += send_msg(
                    sock, "ship", {"segments": states, "drop": drop[rank]})
                pending.append(rank)
        for rank in pending:
            self._reply(rank)
        self._owner_of = new_owner
        return segs, buf, new_owner

    # -- execution ---------------------------------------------------------

    def _now(self, now):
        return self.writer.clock() if now is None else float(now)

    def _execute_many(self, preds, backend, now, backend_opts):
        """Mirror of ``SegmentedIndex._execute_many`` with the per-segment
        execution fanned out across worker processes; returns
        ``(segments, buffer, triples)`` against one synced snapshot."""
        preds = list(preds)
        with self._lock:
            if self._closed:
                raise ValueError("serve plane is closed")
            now = self._now(now)
            segs, buf, owner_of = self._sync_locked()
            names = self.writer.names
            # owned[rank] = ordered indices into segs (the reply's stream
            # order is this order, per predicate)
            owned: list = [[] for _ in self._socks]
            for i, seg in enumerate(segs):
                if seg.n_rows:
                    owned[owner_of[seg.generation]].append(i)
            active = [r for r in range(len(self._socks)) if owned[r]]
            for r in active:  # all sends first: workers compute in parallel
                send_msg(self._socks[r], "query", {
                    "preds": preds, "now": now, "backend": backend,
                    "opts": backend_opts,
                    "gens": [segs[i].generation for i in owned[r]]})
            replies = {}
            for r in active:
                payload, _wire_n = self._reply(r)
                replies[r] = payload
                from ..workload import merge_snapshots
                merge_snapshots([payload.get("workload")],
                                stats=self.writer.workload_stats)
            # where does segment i's stream sit in its owner's reply?
            slot = {}
            for r in active:
                for j, i in enumerate(owned[r]):
                    slot[i] = j
            total_rows = (sum(s.n_rows for s in segs)
                          + (len(buf[1]) if buf is not None else 0))
            out = []
            for p_i, pred in enumerate(preds):
                per_seg, scanned = [], 0
                for i, seg in enumerate(segs):
                    if not seg.n_rows:
                        per_seg.append(_empty_stream())
                        continue
                    r = owner_of[seg.generation]
                    blob = replies[r]["streams"][p_i][slot[i]]
                    got = EwahStream.from_bytes(blob)
                    words_scanned = replies[r]["scanned"][p_i][slot[i]]
                    per_seg.append(EwahStream(got.data, got.n_rows,
                                              words_scanned))
                    # what shipping this result cost vs a dense 1-bit/row
                    # bitmap of the same segment
                    self.result_bytes_compressed += len(blob)
                    self.result_bytes_dense += 4 * (
                        (seg.n_rows + ewah.WORD_BITS - 1) // ewah.WORD_BITS)
                parts = [s.data for s in per_seg]
                scanned = sum(s.words_scanned for s in per_seg)
                buf_rows = None
                if buf is not None:
                    cols, bdel, bexp = buf
                    mask = evaluate_mask(pred, cols, names=names)
                    mask &= ~bdel & (bexp > now)
                    buf_rows = np.flatnonzero(mask)
                    words = ewah.positions_to_words(buf_rows, len(mask))
                    parts.append(ewah.compress(words))
                    scanned += len(words)
                merged = (EwahStream(concat_streams(parts), total_rows,
                                     scanned)
                          if parts else _empty_stream())
                maybe_validate(merged, origin="ServePlane._execute_many")
                out.append((per_seg, buf_rows, merged))
        return segs, buf, out

    def execute_compressed_many(self, preds, backend: str = "numpy",
                                now=None, **backend_opts):
        _, _, triples = self._execute_many(preds, backend, now,
                                           backend_opts)
        return [(per_seg, merged) for per_seg, _, merged in triples]

    def query_many(self, preds, backend: str = "numpy", now=None,
                   **backend_opts):
        """Batched queries; one ``(row_ids, words_scanned)`` per
        predicate, row ids in original ingest order, sorted ascending —
        the ``SegmentedIndex.query_many`` contract."""
        segs, _, triples = self._execute_many(preds, backend, now,
                                              backend_opts)
        buf_start = segs[-1].row_stop if segs else 0
        out = []
        for per_seg, buf_rows, merged in triples:
            ids = [seg.original_rows(r.to_rows())
                   for seg, r in zip(segs, per_seg) if seg.n_rows]
            if buf_rows is not None:
                ids.append(buf_start + buf_rows)
            rows = (np.sort(np.concatenate(ids)) if ids
                    else np.asarray([], dtype=np.int64))
            out.append((rows, merged.words_scanned))
        return out

    def query(self, pred, backend: str = "numpy", now=None,
              **backend_opts):
        return self.query_many([pred], backend=backend, now=now,
                               **backend_opts)[0]

    def count_many(self, preds, backend: str = "numpy", now=None,
                   **backend_opts):
        """Matching live-row counts, popcounted in the compressed domain
        — nothing densifies anywhere on this path."""
        _, _, triples = self._execute_many(preds, backend, now,
                                           backend_opts)
        return [merged.count() for _, _, merged in triples]

    def count(self, pred, backend: str = "numpy", now=None,
              **backend_opts) -> int:
        return self.count_many([pred], backend=backend, now=now,
                               **backend_opts)[0]

    def stats(self) -> dict:
        with self._lock:
            return {"world_size": len(self._socks),
                    "ship_bytes": self.ship_bytes,
                    "result_bytes_compressed": self.result_bytes_compressed,
                    "result_bytes_dense": self.result_bytes_dense}

    # -- sharded two-phase checkpoint --------------------------------------

    def save_checkpoint(self, directory: str, step: int, *,
                        keep: int | None = None) -> None:
        """Two-phase sharded commit (docs/dist.md): every worker writes
        only the segment directories it owns and acks CRCs; the
        coordinator writes zero-row segments and the writer-level state,
        then — only once every ack is in — fsyncs the manifest, flips the
        ``LATEST`` pointer, and prunes old steps."""
        with self._lock:
            segs, buf, owner_of = self._sync_locked()
            step_path = ckpt._step_dir(directory, step)
            os.makedirs(step_path, exist_ok=True)
            seg_acks: list = [None] * len(segs)
            owners: list = []
            per_rank: list = [{} for _ in self._socks]
            for i, seg in enumerate(segs):
                if seg.n_rows:
                    rank = owner_of[seg.generation]
                    per_rank[rank][seg.generation] = i
                    owners.append(rank)
                else:
                    # zero-row spans live nowhere; the coordinator persists
                    # them so the id span stays covered on restore
                    seg_acks[i] = ckpt.write_segment_dir(
                        step_path, i, segment_state(seg))
                    owners.append(-1)
            active = [r for r in range(len(self._socks)) if per_rank[r]]
            for r in active:  # phase 1: fan the writes out
                send_msg(self._socks[r], "ckpt",
                         {"step_path": step_path, "ordinals": per_rank[r]})
            wl = self.writer.workload_stats
            coord_ack = ckpt.write_coordinator_state(step_path, {
                "spec": self.writer.spec.to_dict(),
                "names": (None if self.writer.names is None
                          else list(self.writer.names)),
                "closed": self.writer.closed,
                "seal_rows": self.writer.seal_rows,
                "buffer": buf,
                "workload": wl.snapshot() if wl is not None else None})
            for r in active:
                payload, _ = self._reply(r)
                for ordinal, ack in payload["acks"].items():
                    seg_acks[int(ordinal)] = ack
            missing = [i for i, a in enumerate(seg_acks) if a is None]
            if missing:
                raise RuntimeError(
                    f"checkpoint step {step}: segments {missing} never "
                    "acked; refusing to commit a torn step")
            # phase 2: manifest fsync -> LATEST flip -> prune
            ckpt.commit_sharded_step(directory, step, owners, seg_acks,
                                     coord_ack, keep=keep)

    @classmethod
    def restore(cls, directory: str, *, n_hosts: int = 2,
                seal_rows: int | None = None, clock=time.time,
                workload_stats=None, materialize: bool = True,
                connect_timeout: float = 60.0) -> "ServePlane":
        """Reassemble a plane from the newest committed sharded step.

        Segments re-seal from their checkpointed raw columns with their
        recorded encodings (bit-identical indexes), the writer rebuilds
        via :meth:`IndexWriter.from_parts`, and ownership re-shards over
        the *current* ``n_hosts`` at the first sync — a host that died
        since the save simply isn't part of the new map."""
        coord, seg_states, step, _manifest = ckpt.load_sharded_step(
            directory)
        spec = IndexSpec.from_dict(coord["spec"])
        segments = [seal_from_state(st, spec, materialize=materialize)
                    for st in seg_states]
        if workload_stats is not None and coord.get("workload"):
            workload_stats.merge_snapshot(coord["workload"])
        writer = IndexWriter.from_parts(
            spec, names=coord.get("names"), segments=segments,
            buffer=coord.get("buffer"), closed=coord.get("closed", False),
            seal_rows=(seal_rows if seal_rows is not None
                       else coord.get("seal_rows")),
            clock=clock, workload_stats=workload_stats)
        plane = cls(writer, n_hosts=n_hosts,
                    connect_timeout=connect_timeout)
        plane.restored_step = step
        return plane


# ---------------------------------------------------------------------------
# Worker
# ---------------------------------------------------------------------------


def _handle(op: str, payload, state: dict):
    """One worker request -> reply payload.  ``state`` holds the worker's
    config plus its owned segments (gen -> Segment)."""
    if op == "config":
        state["spec"] = IndexSpec.from_dict(payload["spec"])
        state["names"] = payload["names"]
        return {"rank": state["rank"]}
    if op == "ship":
        for st in payload["segments"]:
            # keep_columns=True: checkpoint writes need the row store
            state["segments"][int(st["gen"])] = seal_from_state(
                st, state["spec"])
        for gen in payload["drop"]:
            state["segments"].pop(int(gen), None)
        return {"owned": sorted(state["segments"])}
    if op == "query":
        segs = [state["segments"][int(g)] for g in payload["gens"]]
        now = payload["now"]
        be = get_backend(payload["backend"], **payload.get("opts", {}))
        live = [s.live_stream(now) for s in segs]
        plans = []
        for pred in payload["preds"]:
            for seg, lv in zip(segs, live):
                plan = compile_plan(seg.index, pred, names=state["names"])
                plans.append(with_live_mask(plan, lv))
        t0 = perf_counter()
        if hasattr(be, "execute_compressed_many"):
            results = be.execute_compressed_many(plans)
        else:
            results = [be.execute_compressed(p) for p in plans]
        _observe_workload(plans, perf_counter() - t0)
        from ..workload import WORKLOAD_STATS

        k = len(segs)
        return {
            "streams": [[results[i * k + j].to_bytes() for j in range(k)]
                        for i in range(len(payload["preds"]))],
            "scanned": [[int(results[i * k + j].words_scanned)
                         for j in range(k)]
                        for i in range(len(payload["preds"]))],
            "workload": WORKLOAD_STATS.drain(),
        }
    if op == "delete_ids":
        ids = np.asarray(payload["ids"], dtype=np.int64)
        deleted = sum(seg.delete_ids(ids)
                      for seg in state["segments"].values())
        return {"deleted": int(deleted)}
    if op == "ckpt":
        acks = {}
        for gen, ordinal in payload["ordinals"].items():
            seg = state["segments"][int(gen)]
            acks[int(ordinal)] = ckpt.write_segment_dir(
                payload["step_path"], int(ordinal), segment_state(seg))
        return {"acks": acks}
    raise ValueError(f"unknown op {op!r}")


def worker_main(connect: str, rank: int) -> None:
    """Worker process entry: connect back to the coordinator and serve
    requests until ``shutdown``.  Single-threaded by design — requests on
    one segment subset are serialized; parallelism comes from the fleet.
    """
    host, _, port = connect.rpartition(":")
    sock = socket.create_connection((host, int(port)))
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    state = {"rank": int(rank), "spec": None, "names": None,
             "segments": {}}
    send_msg(sock, "hello", {"rank": int(rank), "pid": os.getpid()})
    try:
        while True:
            op, payload, _ = recv_msg(sock)
            if op == "shutdown":
                send_msg(sock, "ok", {})
                return
            try:
                reply = _handle(op, payload, state)
            except Exception:
                import traceback

                send_msg(sock, "error",
                         {"traceback": traceback.format_exc()})
                continue
            send_msg(sock, "ok", reply)
    finally:
        sock.close()


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(
        prog="python -m repro.dist.serve_plane",
        description="serve-plane worker process (spawned by ServePlane)")
    parser.add_argument("--worker", action="store_true", required=True)
    parser.add_argument("--connect", required=True,
                        help="coordinator host:port to dial back")
    parser.add_argument("--rank", type=int, required=True)
    args = parser.parse_args(argv)
    worker_main(args.connect, args.rank)


if __name__ == "__main__":
    main()
