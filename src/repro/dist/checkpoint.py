"""Atomic, fault-tolerant checkpointing: pytrees and sharded segments.

Pytree layout — one directory per step, made visible atomically:

    <dir>/step_00000042/
        metadata.json        {"step", "extra", "leaves": [{dtype, shape, crc}]}
        leaf_00000.npy       flattened-pytree leaves, save order = jax.tree
        leaf_00001.npy       flatten order of the saved tree
        ...
    <dir>/LATEST             committed-step pointer, flipped atomically

Saves write into a ``tmp.*`` sibling directory and ``os.replace`` it into
place, so readers never observe a partial step.  Every leaf carries a CRC32
plus shape/dtype in the metadata; ``restore`` walks steps newest-first and
falls back to the next older step when validation fails, so a write torn by
a crash (or bit rot on one leaf) costs one checkpoint, not the run.

**Retention is pointer-gated** (crash-safe under concurrent writers): old
step directories are retired only *after* the new step's ``LATEST`` pointer
flip is fsynced, and never at or above the pointer's target.  A crash
between the step write and the flip leaves every previously-committed step
intact — the half-committed step is merely unreferenced, and the next
restore still has the pointer's target to fall back to.

The **sharded serve-plane checkpoints** (``repro.dist.serve_plane``) reuse
the same step/pointer scheme but write *per segment*: each host writes only
``segment_<ordinal>/`` directories it owns, the coordinator writes the
writer-level state, and commit is a two-phase barrier — all hosts write and
ack with CRCs, then the coordinator fsyncs ``manifest.json`` and atomically
flips ``LATEST`` (levanter/TensorStore-style).  Restore trusts only steps
whose manifest validates, so a torn multi-host write costs one checkpoint.

bfloat16 (which numpy cannot serialize natively) round-trips via a uint16
raw view with the true dtype recorded in the metadata.  jax imports
lazily: the segment-checkpoint half of this module is numpy-only, so
serve-plane worker processes never pay the jax import.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import zlib

import numpy as np

_STEP_PREFIX = "step_"
_META = "metadata.json"
_LATEST = "LATEST"
_MANIFEST = "manifest.json"

# dtypes numpy can't serialize natively: name -> storage dtype (the restore
# view resolves through jax lazily so worker processes stay jax-free)
_RAW = {"bfloat16": np.uint16}


def _raw_view(name: str):
    import jax.numpy as jnp

    return {"bfloat16": jnp.bfloat16}[name]


class CorruptCheckpoint(RuntimeError):
    """A step directory failed validation (missing/truncated/bad leaves)."""


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"{_STEP_PREFIX}{step:08d}")


def available_steps(directory: str) -> list[int]:
    """Sorted step numbers present under ``directory`` ([] if none)."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if not name.startswith(_STEP_PREFIX):
            continue
        try:
            step = int(name[len(_STEP_PREFIX):])
        except ValueError:
            continue
        if os.path.isdir(os.path.join(directory, name)):
            steps.append(step)
    return sorted(steps)


def _fsync_dir(path: str) -> None:
    """Flush a directory entry to disk (best-effort: some filesystems
    refuse to open directories)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def latest_step(directory: str) -> int | None:
    """The committed step the ``LATEST`` pointer names, or None when no
    pointer exists (pre-pointer checkpoints, or nothing committed yet)."""
    try:
        with open(os.path.join(directory, _LATEST)) as f:
            return int(f.read().strip())
    except (OSError, ValueError):
        return None


def flip_latest(directory: str, step: int) -> None:
    """Atomically commit ``step`` as the newest checkpoint: write the
    pointer to a temp file, fsync it, ``os.replace`` over ``LATEST``, fsync
    the directory entry.  A stale concurrent writer (an async save of an
    older step finishing late) never moves the pointer backwards."""
    cur = latest_step(directory)
    if cur is not None and cur > step:
        return
    fd, tmp = tempfile.mkstemp(prefix="tmp.latest.", dir=directory)
    try:
        with os.fdopen(fd, "w") as f:
            f.write(f"{int(step)}\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(directory, _LATEST))
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    _fsync_dir(directory)


def _prune(directory: str, keep: int) -> None:
    """Retire old step directories.  Runs only after a pointer flip is
    fsynced, and never removes the pointer's target or anything newer —
    so a crash anywhere in a save never costs a committed checkpoint."""
    committed = latest_step(directory)
    steps = available_steps(directory)
    if committed is not None:
        steps = [s for s in steps if s < committed]
        keep = keep - 1  # the committed step occupies one retention slot
    for s in steps[: max(0, len(steps) - max(keep, 0))]:
        shutil.rmtree(_step_dir(directory, s), ignore_errors=True)


def _snapshot(tree) -> list[np.ndarray]:
    """Copy leaves to host memory NOW (callers may donate the device
    buffers to the next step immediately after)."""
    import jax

    return [np.asarray(jax.device_get(x)) for x in jax.tree.leaves(tree)]


def _write(directory: str, step: int, leaves, extra, keep) -> None:
    os.makedirs(directory, exist_ok=True)
    final = _step_dir(directory, step)
    tmp = tempfile.mkdtemp(prefix="tmp.", dir=directory)
    try:
        meta = {"step": int(step), "extra": extra if extra is not None else {},
                "leaves": []}
        for i, x in enumerate(leaves):
            name = np.dtype(x.dtype).name
            stored = x.view(_RAW[name]) if name in _RAW else x
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), stored,
                    allow_pickle=False)
            meta["leaves"].append({
                "dtype": name,
                "shape": list(x.shape),
                "crc": zlib.crc32(stored.tobytes()),
            })
        with open(os.path.join(tmp, _META), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # commit order is load-bearing: the step becomes the pointer's target
    # (fsynced) BEFORE any retention runs, so a crash in between leaves
    # every committed step on disk — see test_checkpoint.py's injected
    # crash between write and flip
    flip_latest(directory, step)
    if keep is not None:
        _prune(directory, keep)


def save(directory: str, step: int, tree, extra=None, keep: int | None = None):
    """Synchronous atomic save; ``extra`` is a small JSON-able dict (data
    pipeline position, RNG state, ...); ``keep`` retains only the N newest
    steps after a successful write."""
    _write(directory, step, _snapshot(tree), extra, keep)


_pending: list[threading.Thread] = []  # guarded-by: _pending_lock
_pending_lock = threading.Lock()


def save_async(directory: str, step: int, tree, extra=None,
               keep: int | None = None) -> threading.Thread:
    """Snapshot to host synchronously, write in a background thread.

    The device-to-host copy happens before this returns, so the caller may
    donate the tree's buffers to the next train step.  Returns the writer
    thread (already started); ``wait_pending()`` joins all outstanding ones.
    """
    leaves = _snapshot(tree)
    t = threading.Thread(target=_write, args=(directory, step, leaves, extra, keep),
                         name=f"ckpt-save-{step}", daemon=True)
    with _pending_lock:
        _pending.append(t)
    t.start()
    return t


def wait_pending() -> None:
    """Block until every save_async writer has finished."""
    with _pending_lock:
        threads, _pending[:] = list(_pending), []
    for t in threads:
        t.join()


def _load_step(path: str, n_leaves: int):
    meta_path = os.path.join(path, _META)
    if not os.path.exists(meta_path):
        raise CorruptCheckpoint(f"{path}: missing {_META}")
    try:
        with open(meta_path) as f:
            meta = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CorruptCheckpoint(f"{path}: unreadable metadata ({e})")
    if len(meta.get("leaves", [])) != n_leaves:
        raise CorruptCheckpoint(
            f"{path}: {len(meta.get('leaves', []))} leaves on disk, "
            f"restore target has {n_leaves}")
    leaves = []
    try:  # valid JSON with missing/mangled keys is corruption too
        for i, rec in enumerate(meta["leaves"]):
            fp = os.path.join(path, f"leaf_{i:05d}.npy")
            try:
                stored = np.load(fp, allow_pickle=False)
            except Exception as e:  # noqa: BLE001 — any unreadable leaf is corruption
                raise CorruptCheckpoint(f"{fp}: {e}")
            name = rec["dtype"]
            want = np.dtype(_RAW[name] if name in _RAW else name)
            if stored.dtype != want or list(stored.shape) != list(rec["shape"]):
                raise CorruptCheckpoint(
                    f"{fp}: got {stored.dtype}{stored.shape}, "
                    f"recorded {name}{tuple(rec['shape'])}")
            if zlib.crc32(stored.tobytes()) != rec["crc"]:
                raise CorruptCheckpoint(f"{fp}: CRC mismatch")
            leaves.append(stored.view(_raw_view(name)) if name in _RAW
                          else stored)
        return leaves, int(meta["step"]), meta.get("extra", {})
    except (KeyError, TypeError, ValueError) as e:
        raise CorruptCheckpoint(f"{path}: malformed metadata ({e!r})")


def restore(directory: str, tree_like, shardings=None):
    """Load the newest valid checkpoint.

    ``tree_like`` supplies the pytree structure and the expected leaf
    *shapes* (leaf values are ignored, but a saved leaf whose shape
    disagrees with its ``tree_like`` counterpart is rejected with a clear
    error — e.g. a checkpoint written before a state-layout change, like
    the param-shaped-moments era before flat ZeRO-1, must not be silently
    placed under the new shardings).  ``shardings`` is an optional
    matching pytree of ``NamedSharding`` used to place each restored leaf.
    Returns ``(tree, step, extra)``; raises FileNotFoundError when no
    step exists or none validates.
    """
    import jax

    steps = available_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory!r}")
    leaves_like, treedef = jax.tree.flatten(tree_like)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves_like))
    failures = []
    for step in reversed(steps):
        try:
            raw, saved_step, extra = _load_step(
                _step_dir(directory, step), len(leaves_like))
        except CorruptCheckpoint as e:
            failures.append(str(e))
            continue
        for i, (x, like) in enumerate(zip(raw, leaves_like)):
            x_shape = tuple(np.asarray(x).shape)
            want = tuple(getattr(like, "shape", np.asarray(like).shape))
            if x_shape != want:
                raise ValueError(
                    f"checkpoint step {saved_step} leaf {i} has shape "
                    f"{x_shape} but the current state expects {want} — "
                    "the saved state layout predates the running code "
                    "(e.g. param-shaped optimizer moments from before "
                    "flat ZeRO-1); restart fresh or migrate the "
                    "checkpoint")
        leaves = [jax.device_put(x) if sh is None else jax.device_put(x, sh)
                  for x, sh in zip(raw, shard_leaves)]
        return jax.tree.unflatten(treedef, leaves), saved_step, extra
    raise FileNotFoundError(
        f"all checkpoints under {directory!r} failed validation: "
        + "; ".join(failures))


# ---------------------------------------------------------------------------
# Sharded serve-plane checkpoints: per-segment directories, two-phase commit.
#
# Numpy-only — worker processes call write_segment_dir/read_segment_dir
# without ever importing jax.  The coordinator drives the barrier:
#
#   phase 1   every host writes the segment dirs it owns (plus the
#             coordinator's writer-level state) under <dir>/step_N/ and
#             acks with per-file CRCs;
#   phase 2   the coordinator verifies all acks, fsyncs manifest.json
#             (ownership map + CRCs), atomically flips LATEST, and only
#             then prunes old steps.
#
# A crash before the flip leaves the previous LATEST target untouched (the
# half-written step is unreferenced); load_sharded_step trusts only steps
# whose manifest validates.
# ---------------------------------------------------------------------------


def _npz_payload(arrays: dict) -> tuple[bytes, int]:
    """Serialize named arrays to npz bytes + CRC32 (one file per segment —
    a single CRC covers every column)."""
    import io

    buf = io.BytesIO()
    np.savez(buf, **arrays)
    payload = buf.getvalue()
    return payload, zlib.crc32(payload)


def write_segment_dir(step_path: str, ordinal: int, state: dict) -> dict:
    """Write one segment's reconstruction state under
    ``<step_path>/segment_<ordinal>/``; returns its CRC manifest entry.

    ``state`` is the serve plane's wire/state dict: ``columns`` (ingest
    order), ``row_start``/``span_stop``, optional ``row_ids``/``expiry``,
    ``dead`` (ingest-local tombstoned positions), and ``encodings`` (the
    per-original-column kinds the seal chose, so a restore re-seals to the
    bit-identical index even when the kinds came from a workload-driven
    compaction chooser).
    """
    d = os.path.join(step_path, f"segment_{ordinal:05d}")
    os.makedirs(d, exist_ok=True)
    arrays = {f"col_{c:05d}": np.asarray(col)
              for c, col in enumerate(state.get("columns") or [])}
    for key in ("row_ids", "expiry", "dead"):
        if state.get(key) is not None:
            arrays[key] = np.asarray(state[key])
    payload, crc = _npz_payload(arrays)
    with open(os.path.join(d, "state.npz"), "wb") as f:
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    meta = {"row_start": int(state["row_start"]),
            "span_stop": (None if state.get("span_stop") is None
                          else int(state["span_stop"])),
            "n_rows": int(state["n_rows"]),
            "n_cols": len(state.get("columns") or []),
            "encodings": {str(k): v
                          for k, v in (state.get("encodings") or {}).items()},
            "crc": crc}
    with open(os.path.join(d, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    return {"crc": crc}


def read_segment_dir(step_path: str, ordinal: int) -> dict:
    """Load one segment's state dict back; validates the CRC.  The inverse
    of :func:`write_segment_dir`."""
    d = os.path.join(step_path, f"segment_{ordinal:05d}")
    try:
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CorruptCheckpoint(f"{d}: unreadable meta.json ({e})")
    try:
        with open(os.path.join(d, "state.npz"), "rb") as f:
            payload = f.read()
    except OSError as e:
        raise CorruptCheckpoint(f"{d}: unreadable state.npz ({e})")
    if zlib.crc32(payload) != meta.get("crc"):
        raise CorruptCheckpoint(f"{d}: state.npz CRC mismatch")
    import io

    with np.load(io.BytesIO(payload), allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files}
    n_cols = int(meta.get("n_cols", 0))
    return {
        "row_start": int(meta["row_start"]),
        "span_stop": meta.get("span_stop"),
        "n_rows": int(meta["n_rows"]),
        "columns": [arrays[f"col_{c:05d}"] for c in range(n_cols)],
        "row_ids": arrays.get("row_ids"),
        "expiry": arrays.get("expiry"),
        "dead": arrays.get("dead"),
        "encodings": {int(k): v
                      for k, v in meta.get("encodings", {}).items()},
    }


def write_coordinator_state(step_path: str, state: dict) -> dict:
    """Write the writer-level (non-segment) state the coordinator owns:
    spec/names/closed plus the open buffer's rows.  Returns the CRC
    manifest entry."""
    os.makedirs(step_path, exist_ok=True)
    arrays = {}
    buf = state.get("buffer")
    if buf is not None:
        cols, deleted, expiry = buf
        arrays = {f"buf_col_{c:05d}": np.asarray(col)
                  for c, col in enumerate(cols)}
        arrays["buf_deleted"] = np.asarray(deleted)
        arrays["buf_expiry"] = np.asarray(expiry)
    payload, crc = _npz_payload(arrays)
    with open(os.path.join(step_path, "buffer.npz"), "wb") as f:
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    meta = {"spec": state["spec"], "names": state.get("names"),
            "closed": bool(state.get("closed", False)),
            "seal_rows": state.get("seal_rows"),
            "n_buf_cols": len(buf[0]) if buf is not None else 0,
            "has_buffer": buf is not None,
            "workload": state.get("workload"),
            "crc": crc}
    with open(os.path.join(step_path, "writer.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    return {"crc": crc}


def read_coordinator_state(step_path: str) -> dict:
    """Inverse of :func:`write_coordinator_state` (CRC-validated)."""
    try:
        with open(os.path.join(step_path, "writer.json")) as f:
            meta = json.load(f)
        with open(os.path.join(step_path, "buffer.npz"), "rb") as f:
            payload = f.read()
    except (OSError, json.JSONDecodeError) as e:
        raise CorruptCheckpoint(f"{step_path}: unreadable writer state ({e})")
    if zlib.crc32(payload) != meta.get("crc"):
        raise CorruptCheckpoint(f"{step_path}: buffer.npz CRC mismatch")
    buf = None
    if meta.get("has_buffer"):
        import io

        with np.load(io.BytesIO(payload), allow_pickle=False) as z:
            cols = [z[f"buf_col_{c:05d}"]
                    for c in range(int(meta.get("n_buf_cols", 0)))]
            buf = (cols, z["buf_deleted"], z["buf_expiry"])
    return {"spec": meta["spec"], "names": meta.get("names"),
            "closed": bool(meta.get("closed", False)),
            "seal_rows": meta.get("seal_rows"),
            "workload": meta.get("workload"),
            "buffer": buf}


def commit_sharded_step(directory: str, step: int, owners: list,
                        seg_acks: list, coord_ack: dict,
                        keep: int | None = None) -> None:
    """Phase 2 of the serve-plane commit barrier: all hosts have written
    and acked — persist the manifest (ownership map + CRCs), fsync it,
    atomically flip ``LATEST``, then (and only then) prune old steps."""
    step_path = _step_dir(directory, step)
    manifest = {"step": int(step),
                "n_segments": len(seg_acks),
                "owners": [int(h) for h in owners],
                "segments": seg_acks,
                "coordinator": coord_ack}
    with open(os.path.join(step_path, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(step_path)
    flip_latest(directory, step)
    if keep is not None:
        _prune(directory, keep)


def load_sharded_step(directory: str):
    """Load the newest committed sharded checkpoint.

    Tries the ``LATEST`` pointer's target first, then every other step
    newest-first; a step counts only if its manifest exists and every
    segment + the coordinator state validate their CRCs.  Returns
    ``(writer_state, [segment_state, ...], step, manifest)``; the caller
    (``ServePlane.restore``) re-shards ownership across the *current*
    world size, so a host missing since the save is tolerated by design.
    """
    steps = available_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory!r}")
    order = list(reversed(steps))
    pointed = latest_step(directory)
    if pointed in order:
        order.remove(pointed)
        order.insert(0, pointed)
    failures = []
    for step in order:
        step_path = _step_dir(directory, step)
        try:
            with open(os.path.join(step_path, _MANIFEST)) as f:
                manifest = json.load(f)
            coord = read_coordinator_state(step_path)
            seg_states = []
            for i in range(int(manifest["n_segments"])):
                state = read_segment_dir(step_path, i)
                want = manifest["segments"][i]["crc"]
                got = zlib.crc32(
                    open(os.path.join(step_path, f"segment_{i:05d}",
                                      "state.npz"), "rb").read())
                if got != want:
                    raise CorruptCheckpoint(
                        f"segment {i}: manifest CRC {want}, on disk {got}")
                seg_states.append(state)
            return coord, seg_states, step, manifest
        except (OSError, json.JSONDecodeError, KeyError, IndexError,
                CorruptCheckpoint) as e:
            failures.append(f"step {step}: {e}")
    raise FileNotFoundError(
        f"no committed sharded checkpoint under {directory!r}: "
        + "; ".join(failures))
