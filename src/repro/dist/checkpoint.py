"""Atomic, fault-tolerant checkpointing for (sharded) pytrees.

Layout — one directory per step, made visible atomically:

    <dir>/step_00000042/
        metadata.json        {"step", "extra", "leaves": [{dtype, shape, crc}]}
        leaf_00000.npy       flattened-pytree leaves, save order = jax.tree
        leaf_00001.npy       flatten order of the saved tree
        ...

Saves write into a ``tmp.*`` sibling directory and ``os.replace`` it into
place, so readers never observe a partial step.  Every leaf carries a CRC32
plus shape/dtype in the metadata; ``restore`` walks steps newest-first and
falls back to the next older step when validation fails, so a write torn by
a crash (or bit rot on one leaf) costs one checkpoint, not the run.

bfloat16 (which numpy cannot serialize natively) round-trips via a uint16
raw view with the true dtype recorded in the metadata.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import zlib

import jax
import jax.numpy as jnp
import numpy as np

_STEP_PREFIX = "step_"
_META = "metadata.json"

# dtypes numpy can't serialize natively: name -> (storage dtype, restore view)
_RAW = {"bfloat16": (np.uint16, jnp.bfloat16)}


class CorruptCheckpoint(RuntimeError):
    """A step directory failed validation (missing/truncated/bad leaves)."""


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"{_STEP_PREFIX}{step:08d}")


def available_steps(directory: str) -> list[int]:
    """Sorted step numbers present under ``directory`` ([] if none)."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if not name.startswith(_STEP_PREFIX):
            continue
        try:
            step = int(name[len(_STEP_PREFIX):])
        except ValueError:
            continue
        if os.path.isdir(os.path.join(directory, name)):
            steps.append(step)
    return sorted(steps)


def _snapshot(tree) -> list[np.ndarray]:
    """Copy leaves to host memory NOW (callers may donate the device
    buffers to the next step immediately after)."""
    return [np.asarray(jax.device_get(x)) for x in jax.tree.leaves(tree)]


def _write(directory: str, step: int, leaves, extra, keep) -> None:
    os.makedirs(directory, exist_ok=True)
    final = _step_dir(directory, step)
    tmp = tempfile.mkdtemp(prefix="tmp.", dir=directory)
    try:
        meta = {"step": int(step), "extra": extra if extra is not None else {},
                "leaves": []}
        for i, x in enumerate(leaves):
            name = np.dtype(x.dtype).name
            stored = x.view(_RAW[name][0]) if name in _RAW else x
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), stored,
                    allow_pickle=False)
            meta["leaves"].append({
                "dtype": name,
                "shape": list(x.shape),
                "crc": zlib.crc32(stored.tobytes()),
            })
        with open(os.path.join(tmp, _META), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    if keep is not None:
        for s in available_steps(directory)[:-keep]:
            shutil.rmtree(_step_dir(directory, s), ignore_errors=True)


def save(directory: str, step: int, tree, extra=None, keep: int | None = None):
    """Synchronous atomic save; ``extra`` is a small JSON-able dict (data
    pipeline position, RNG state, ...); ``keep`` retains only the N newest
    steps after a successful write."""
    _write(directory, step, _snapshot(tree), extra, keep)


_pending: list[threading.Thread] = []  # guarded-by: _pending_lock
_pending_lock = threading.Lock()


def save_async(directory: str, step: int, tree, extra=None,
               keep: int | None = None) -> threading.Thread:
    """Snapshot to host synchronously, write in a background thread.

    The device-to-host copy happens before this returns, so the caller may
    donate the tree's buffers to the next train step.  Returns the writer
    thread (already started); ``wait_pending()`` joins all outstanding ones.
    """
    leaves = _snapshot(tree)
    t = threading.Thread(target=_write, args=(directory, step, leaves, extra, keep),
                         name=f"ckpt-save-{step}", daemon=True)
    with _pending_lock:
        _pending.append(t)
    t.start()
    return t


def wait_pending() -> None:
    """Block until every save_async writer has finished."""
    with _pending_lock:
        threads, _pending[:] = list(_pending), []
    for t in threads:
        t.join()


def _load_step(path: str, n_leaves: int):
    meta_path = os.path.join(path, _META)
    if not os.path.exists(meta_path):
        raise CorruptCheckpoint(f"{path}: missing {_META}")
    try:
        with open(meta_path) as f:
            meta = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CorruptCheckpoint(f"{path}: unreadable metadata ({e})")
    if len(meta.get("leaves", [])) != n_leaves:
        raise CorruptCheckpoint(
            f"{path}: {len(meta.get('leaves', []))} leaves on disk, "
            f"restore target has {n_leaves}")
    leaves = []
    try:  # valid JSON with missing/mangled keys is corruption too
        for i, rec in enumerate(meta["leaves"]):
            fp = os.path.join(path, f"leaf_{i:05d}.npy")
            try:
                stored = np.load(fp, allow_pickle=False)
            except Exception as e:  # noqa: BLE001 — any unreadable leaf is corruption
                raise CorruptCheckpoint(f"{fp}: {e}")
            name = rec["dtype"]
            want = np.dtype(_RAW[name][0] if name in _RAW else name)
            if stored.dtype != want or list(stored.shape) != list(rec["shape"]):
                raise CorruptCheckpoint(
                    f"{fp}: got {stored.dtype}{stored.shape}, "
                    f"recorded {name}{tuple(rec['shape'])}")
            if zlib.crc32(stored.tobytes()) != rec["crc"]:
                raise CorruptCheckpoint(f"{fp}: CRC mismatch")
            leaves.append(stored.view(_RAW[name][1]) if name in _RAW else stored)
        return leaves, int(meta["step"]), meta.get("extra", {})
    except (KeyError, TypeError, ValueError) as e:
        raise CorruptCheckpoint(f"{path}: malformed metadata ({e!r})")


def restore(directory: str, tree_like, shardings=None):
    """Load the newest valid checkpoint.

    ``tree_like`` supplies the pytree structure and the expected leaf
    *shapes* (leaf values are ignored, but a saved leaf whose shape
    disagrees with its ``tree_like`` counterpart is rejected with a clear
    error — e.g. a checkpoint written before a state-layout change, like
    the param-shaped-moments era before flat ZeRO-1, must not be silently
    placed under the new shardings).  ``shardings`` is an optional
    matching pytree of ``NamedSharding`` used to place each restored leaf.
    Returns ``(tree, step, extra)``; raises FileNotFoundError when no
    step exists or none validates.
    """
    steps = available_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory!r}")
    leaves_like, treedef = jax.tree.flatten(tree_like)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves_like))
    failures = []
    for step in reversed(steps):
        try:
            raw, saved_step, extra = _load_step(
                _step_dir(directory, step), len(leaves_like))
        except CorruptCheckpoint as e:
            failures.append(str(e))
            continue
        for i, (x, like) in enumerate(zip(raw, leaves_like)):
            x_shape = tuple(np.asarray(x).shape)
            want = tuple(getattr(like, "shape", np.asarray(like).shape))
            if x_shape != want:
                raise ValueError(
                    f"checkpoint step {saved_step} leaf {i} has shape "
                    f"{x_shape} but the current state expects {want} — "
                    "the saved state layout predates the running code "
                    "(e.g. param-shaped optimizer moments from before "
                    "flat ZeRO-1); restart fresh or migrate the "
                    "checkpoint")
        leaves = [jax.device_put(x) if sh is None else jax.device_put(x, sh)
                  for x, sh in zip(raw, shard_leaves)]
        return jax.tree.unflatten(treedef, leaves), saved_step, extra
    raise FileNotFoundError(
        f"all checkpoints under {directory!r} failed validation: "
        + "; ".join(failures))
