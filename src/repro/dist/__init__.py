"""Distributed layer: placement (sharding), fault tolerance (checkpoint),
query fan-out over row-range index shards (query_fanout), and the
cross-process serve plane (serve_plane).

Submodules resolve lazily (PEP 562): serve-plane *worker* processes run
``python -m repro.dist.serve_plane`` through this package and must not
pay the jax import that ``sharding`` drags in — a worker imports only
the numpy core until a query actually names ``backend="jax"``.
"""

_SUBMODULES = ("checkpoint", "query_fanout", "serve_plane", "sharding")

_LAZY = {
    # query_fanout: placement + in-process fan-out (numpy-only)
    "IndexShard": "query_fanout",
    "ShardedIndex": "query_fanout",
    "assign_segments": "query_fanout",
    "shard_ranges": "query_fanout",
    # serve_plane: cross-process coordinator/worker (numpy-only)
    "ServePlane": "serve_plane",
    "seal_from_state": "serve_plane",
    "segment_state": "serve_plane",
    # sharding: jax mesh placement (imports jax)
    "batch_shardings": "sharding",
    "cache_shardings": "sharding",
    "grad_shardings_zero": "sharding",
    "opt_shardings": "sharding",
    "param_shardings": "sharding",
    "replicated": "sharding",
    "zero_pad_for": "sharding",
}

__all__ = sorted([*_SUBMODULES, *_LAZY])


def __getattr__(name):
    from importlib import import_module

    if name in _SUBMODULES:
        return import_module(f".{name}", __name__)
    if name in _LAZY:
        return getattr(import_module(f".{_LAZY[name]}", __name__), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return __all__
