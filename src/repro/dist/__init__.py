"""Distributed layer: placement (sharding) and fault tolerance (checkpoint)."""

from . import checkpoint, sharding
from .sharding import (batch_shardings, cache_shardings, opt_shardings,
                       param_shardings, replicated)

__all__ = [
    "checkpoint", "sharding",
    "batch_shardings", "cache_shardings", "opt_shardings",
    "param_shardings", "replicated",
]
