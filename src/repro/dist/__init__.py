"""Distributed layer: placement (sharding), fault tolerance (checkpoint),
and query fan-out over row-range index shards (query_fanout)."""

from . import checkpoint, query_fanout, sharding
from .query_fanout import IndexShard, ShardedIndex, shard_ranges
from .sharding import (batch_shardings, cache_shardings, grad_shardings_zero,
                       opt_shardings, param_shardings, replicated,
                       zero_pad_for)

__all__ = [
    "checkpoint", "query_fanout", "sharding",
    "IndexShard", "ShardedIndex", "shard_ranges",
    "batch_shardings", "cache_shardings", "grad_shardings_zero",
    "opt_shardings", "param_shardings", "replicated", "zero_pad_for",
]
