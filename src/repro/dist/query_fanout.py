"""Query fan-out across row-range shards of a bitmap index.

Sharding-for-serving counterpart of the placement/checkpoint modules: a
table splits into contiguous *word-aligned* row ranges (every boundary a
multiple of 32 rows, so shard result bitmaps concatenate in word space),
each shard builds its own locally-sorted :class:`BitmapIndex`, and a query
fans out as

  1. the predicate compiles *per shard* against that shard's index (value
     domains are shard-local: a value a shard never saw compiles to a
     constant-empty leaf, and ``Not`` complements only the shard's row
     range);
  2. every shard executes the plan through ``execute_compressed`` — the
     result that crosses the (logical) wire is the compressed EWAH stream,
     not row ids, typically orders of magnitude smaller;
  3. the coordinator merges by **concatenation with clean-run coalescing**
     (:func:`~repro.core.ewah_stream.concat_streams`): a clean run ending
     one shard and opening the next collapses into a single marker, so the
     merged stream is exactly what a single-shard execution over the
     concatenated row space would produce.

Shards are independent — the per-shard step parallelizes across processes
or hosts without coordination; this module keeps the execution loop local
and the *protocol* (word alignment, compressed shipping, coalescing merge)
is what `docs/dist.md` specifies for a multi-host deployment.

Row-id semantics: each shard's local ids live in its own reordered row
space; :meth:`ShardedIndex.query` maps them through the shard's
``row_perm`` and row offset, so fan-out queries return **original** table
row positions (unlike ``BitmapIndex.query``, whose ids live in reordered
space — there is no global reordered space across independently sorted
shards).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core import BitmapIndex
from ..core.ewah import WORD_BITS
from ..core.ewah_stream import EwahStream, concat_streams
from ..core.query import compile_plan, get_backend


def shard_ranges(n_rows: int, n_shards: int) -> list:
    """Split ``n_rows`` into up to ``n_shards`` contiguous [start, stop)
    ranges with every internal boundary word-aligned (multiple of 32 rows).
    Ranges cover the table exactly; empty ranges are dropped (tiny tables
    yield fewer shards than requested)."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    words = (n_rows + WORD_BITS - 1) // WORD_BITS
    bounds = [min((words * i // n_shards) * WORD_BITS, n_rows)
              for i in range(n_shards)] + [n_rows]
    return [(bounds[i], bounds[i + 1]) for i in range(n_shards)
            if bounds[i + 1] > bounds[i]]


@dataclass
class IndexShard:
    """One shard: a locally-built index over rows [row_start, row_stop)."""

    index: BitmapIndex
    row_start: int
    row_stop: int

    @property
    def n_rows(self) -> int:
        return self.row_stop - self.row_start

    def original_rows(self, local_rows: np.ndarray) -> np.ndarray:
        """Map shard-local reordered row ids to original table positions."""
        return self.row_start + self.index.row_perm[np.asarray(local_rows)]


class ShardedIndex:
    """A bitmap index fanned out over word-aligned row-range shards."""

    def __init__(self, shards: list, names=None):
        if not shards:
            raise ValueError("ShardedIndex needs at least one shard")
        self.shards = shards
        self.names = names

    @staticmethod
    def build(table_cols, spec=None, n_shards: int = 4,
              names=None) -> "ShardedIndex":
        """Build one :class:`BitmapIndex` per word-aligned row range.

        Each shard sorts its own rows (the paper's reordering applies per
        shard — sorted runs never span shard boundaries, which is also what
        keeps shard builds embarrassingly parallel)."""
        table_cols = [np.asarray(c) for c in table_cols]
        n_rows = len(table_cols[0])
        shards = [
            IndexShard(
                index=BitmapIndex.build([c[start:stop] for c in table_cols],
                                        spec),
                row_start=start, row_stop=stop)
            for start, stop in shard_ranges(n_rows, n_shards)
        ]
        return ShardedIndex(shards, names=names)

    @property
    def n_rows(self) -> int:
        return self.shards[-1].row_stop

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def size_words(self) -> int:
        return sum(sh.index.size_words() for sh in self.shards)

    # -- execution ---------------------------------------------------------

    def execute_compressed(self, pred, backend: str = "numpy", names=None,
                           **backend_opts):
        """Fan the predicate out; returns (shard_results, merged).

        ``shard_results`` is the per-shard list of
        :class:`~repro.core.ewah_stream.EwahStream` (what each shard ships);
        ``merged`` is their concatenation with clean-run coalescing — one
        compressed stream over the full row space, bit-identical to a
        single-index execution over the same (per-shard reordered) rows.
        """
        return self.execute_compressed_many(
            [pred], backend=backend, names=names, **backend_opts)[0]

    def execute_compressed_many(self, preds, backend: str = "numpy",
                                names=None, **backend_opts):
        """Batched fan-out: all predicates' per-shard plans go to the
        backend in **one** ``execute_compressed_many`` call, so the jax
        backend's same-shape grouping batches across predicates *and*
        shards (one padded dispatch per plan shape, not one per
        predicate x shard).  Returns a (shard_results, merged) pair per
        predicate."""
        names = names if names is not None else self.names
        be = get_backend(backend, **backend_opts)
        plans = [compile_plan(sh.index, p, names=names)
                 for p in preds for sh in self.shards]
        if hasattr(be, "execute_compressed_many"):
            results = be.execute_compressed_many(plans)
        else:
            results = [be.execute_compressed(p) for p in plans]
        out = []
        n = len(self.shards)
        for i in range(len(preds)):
            per_shard = results[i * n : (i + 1) * n]
            merged = EwahStream(
                concat_streams([r.data for r in per_shard]), self.n_rows,
                sum(r.words_scanned for r in per_shard))
            out.append((per_shard, merged))
        return out

    def query(self, pred, backend: str = "numpy", names=None,
              **backend_opts):
        """Fan-out query; returns (row_ids, words_scanned) with row ids in
        **original** table row space, sorted ascending (each shard's local
        ids map through its ``row_perm`` + row offset)."""
        return self.query_many([pred], backend=backend, names=names,
                               **backend_opts)[0]

    def query_many(self, preds, backend: str = "numpy", names=None,
                   **backend_opts):
        """Batched fan-out queries; one (row_ids, words_scanned) per
        predicate, row ids in original table row space."""
        out = []
        for per_shard, merged in self.execute_compressed_many(
                preds, backend=backend, names=names, **backend_opts):
            ids = [sh.original_rows(r.to_rows())
                   for sh, r in zip(self.shards, per_shard)]
            out.append((np.sort(np.concatenate(ids)), merged.words_scanned))
        return out
