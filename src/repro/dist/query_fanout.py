"""Query fan-out across row-range shards of a bitmap index.

Sharding-for-serving counterpart of the placement/checkpoint modules — and,
since the segmented-lifecycle redesign, a **thin view over segments**: a
shard IS a :class:`~repro.core.segment.Segment` (word-aligned contiguous
row range + locally-sorted index + generation), and :class:`ShardedIndex`
delegates execution to :class:`~repro.core.segment.SegmentedIndex`.  What
this module adds is the *placement* policy (``shard_ranges``: split a table
into up to N equal word-aligned ranges) and the fan-out framing:

  1. the predicate compiles *per shard* against that shard's index (value
     domains are shard-local: a value a shard never saw compiles to a
     constant-empty leaf, and ``Not`` complements only the shard's row
     range); the spec's per-column *encoding* choice travels with the spec
     too — under ``encoding='auto'`` each shard's chooser reads its own
     histograms, so shards of one fan-out may answer the same ``Range``
     through different encodings and still merge bit-identically (only
     result streams cross the wire, never slice planes or bins);
  2. every shard executes the plan through ``execute_compressed`` — the
     result that crosses the (logical) wire is the compressed EWAH stream,
     not row ids, typically orders of magnitude smaller;
  3. the coordinator merges by **concatenation with clean-run coalescing**
     (:func:`~repro.core.ewah_stream.concat_streams`): a clean run ending
     one shard and opening the next collapses into a single marker, so the
     merged stream is exactly what a single-shard execution over the
     concatenated row space would produce.

Shards are independent — the per-shard step parallelizes across processes
or hosts without coordination.  This module keeps the execution loop local
and owns the **placement policy** shared with the cross-process serve
plane (:mod:`repro.dist.serve_plane`): :func:`shard_ranges` splits a row
space into word-aligned ranges, and :func:`assign_segments` maps sealed
segments onto host ranks by carving the *cumulative compressed word
space* with the same word-aligned splitter — so ownership rebalances
whenever compaction changes the segment list, exactly as `docs/dist.md`
specifies for a multi-host deployment.

Row-id semantics: fan-out queries return **original** table row positions
(each shard's local ids map through its ``row_perm`` and row offset) —
the same contract as every segmented surface; ``BitmapIndex.query`` ids
live in reordered space (map with ``index.row_perm``).
"""

from __future__ import annotations

from ..core.ewah import WORD_BITS
from ..core.segment import Segment, SegmentedIndex

# a shard is a segment; the old name stays importable
IndexShard = Segment


def assign_segments(segments, n_hosts: int) -> list:
    """Ownership map for the serve plane: one owner rank per segment.

    Carves the *cumulative compressed word space* (each segment weighted
    by its ``size_words``, floor 1 so zero-cost segments still land
    somewhere) into up to ``n_hosts`` contiguous ranges using the same
    word-aligned splitter queries shard rows with, then homes each
    segment on the range containing its midpoint.  Contiguity means a
    host owns a contiguous run of segments — compaction spans and
    ownership spans nest — and recomputing after a compaction re-homes
    only segments near the changed run.
    """
    if n_hosts < 1:
        raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
    sizes = [max(s.size_words(), 1) for s in segments]
    if not sizes:
        return []
    ranges = shard_ranges(sum(sizes) * WORD_BITS, n_hosts)
    starts = [start for start, _ in ranges]
    owners, pos = [], 0
    for words in sizes:
        mid = (pos + words / 2.0) * WORD_BITS
        rank = len(starts) - 1
        while rank > 0 and starts[rank] > mid:
            rank -= 1
        owners.append(rank)
        pos += words
    # densify: ranks number 0..k-1 in first-appearance order, so a tiny
    # fleet-of-one workload homes on rank 0, not wherever the word-aligned
    # splitter happened to drop its midpoint
    remap: dict = {}
    return [remap.setdefault(r, len(remap)) for r in owners]


def shard_ranges(n_rows: int, n_shards: int) -> list:
    """Split ``n_rows`` into up to ``n_shards`` contiguous [start, stop)
    ranges with every internal boundary word-aligned (multiple of 32 rows).
    Ranges cover the table exactly; empty ranges are dropped (tiny tables
    yield fewer shards than requested)."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    words = (n_rows + WORD_BITS - 1) // WORD_BITS
    bounds = [min((words * i // n_shards) * WORD_BITS, n_rows)
              for i in range(n_shards)] + [n_rows]
    return [(bounds[i], bounds[i + 1]) for i in range(n_shards)
            if bounds[i + 1] > bounds[i]]


class ShardedIndex:
    """A bitmap index fanned out over word-aligned row-range shards.

    A thin view: ``shards`` are :class:`~repro.core.segment.Segment`s and
    every execution method delegates to the shared
    :class:`~repro.core.segment.SegmentedIndex` engine.
    """

    def __init__(self, shards: list, names=None, clock=None):
        if not shards:
            raise ValueError("ShardedIndex needs at least one shard")
        self.shards = shards
        self.names = names
        self._segmented = SegmentedIndex(shards, names=names, clock=clock)

    @staticmethod
    def build(table_cols, spec=None, n_shards: int = 4, names=None,
              row_ids=None, expiry=None, clock=None) -> "ShardedIndex":
        """Seal one :class:`Segment` per word-aligned row range.

        Each shard sorts its own rows (the paper's reordering applies per
        shard — sorted runs never span shard boundaries, which is also what
        keeps shard builds embarrassingly parallel).

        ``row_ids`` (ascending global ingest ids, one per row) builds the
        fan-out over a *purged* row set — rows dropped by deletes/TTLs
        before the fan-out was built keep every surviving id stable, and
        the shard id-spans stay contiguous around the gaps.  ``expiry``
        carries per-row absolute TTL deadlines into the shards (expired
        rows fold into shard tombstones lazily at query time); pass the
        ``clock`` those deadlines were issued against (e.g. the feeding
        writer's) so lazy expiry evaluates "now" consistently."""
        import numpy as np

        table_cols = [np.asarray(c) for c in table_cols]
        n_rows = len(table_cols[0])
        ranges = shard_ranges(n_rows, n_shards)
        if row_ids is not None:
            row_ids = np.asarray(row_ids, dtype=np.int64)
            # span boundaries sit on the first id of each shard, so spans
            # tile [first_id, last_id + 1) contiguously around purge gaps
            bounds = [int(row_ids[start]) for start, _ in ranges]
            bounds.append(int(row_ids[-1]) + 1 if len(row_ids) else 0)
        else:
            bounds = [start for start, _ in ranges]
            bounds.append(ranges[-1][1] if ranges else 0)
        shards = [
            # shards are never compacted: drop the raw-column row store
            Segment.seal(
                [c[start:stop] for c in table_cols], spec,
                row_start=bounds[i], span_stop=bounds[i + 1],
                keep_columns=False,
                row_ids=None if row_ids is None else row_ids[start:stop],
                expiry=None if expiry is None else expiry[start:stop])
            for i, (start, stop) in enumerate(ranges)
        ]
        return ShardedIndex(shards, names=names, clock=clock)

    @property
    def n_rows(self) -> int:
        return self.shards[-1].row_stop

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def size_words(self) -> int:
        return self._segmented.size_words()

    # -- deletes -----------------------------------------------------------

    def delete(self, pred=None, *, row_ids=None, backend: str = "numpy",
               now=None) -> int:
        """Tombstone rows across the fan-out (delegated to the segmented
        engine): each shard ORs its share of the delete into its compressed
        tombstone bitmap and recomputes its live mask; every later fan-out
        query ANDs that mask into the shard's plan root — one extra merge
        per shard, no rebuild, and only result streams still cross the
        wire.  Returns the newly-dead row count."""
        return self._segmented.delete(pred, row_ids=row_ids,
                                      backend=backend, names=self.names,
                                      now=now)

    # -- execution (delegated to the segmented engine) ---------------------

    def execute_compressed(self, pred, backend: str = "numpy", names=None,
                           **backend_opts):
        """Fan the predicate out; returns (shard_results, merged).

        ``shard_results`` is the per-shard list of
        :class:`~repro.core.ewah_stream.EwahStream` (what each shard ships);
        ``merged`` is their concatenation with clean-run coalescing — one
        compressed stream over the full row space, bit-identical to a
        single-index execution over the same (per-shard reordered) rows.
        """
        return self._segmented.execute_compressed(
            pred, backend=backend, names=names, **backend_opts)

    def execute_compressed_many(self, preds, backend: str = "numpy",
                                names=None, **backend_opts):
        """Batched fan-out: all predicates' per-shard plans go to the
        backend in **one** ``execute_compressed_many`` call, so the jax
        backend's same-shape grouping batches across predicates *and*
        shards (one padded dispatch per plan shape, not one per
        predicate x shard).  Returns a (shard_results, merged) pair per
        predicate."""
        return self._segmented.execute_compressed_many(
            preds, backend=backend, names=names, **backend_opts)

    def query(self, pred, backend: str = "numpy", names=None,
              **backend_opts):
        """Fan-out query; returns (row_ids, words_scanned) with row ids in
        **original** table row space, sorted ascending."""
        return self._segmented.query(pred, backend=backend, names=names,
                                     **backend_opts)

    def query_many(self, preds, backend: str = "numpy", names=None,
                   **backend_opts):
        """Batched fan-out queries; one (row_ids, words_scanned) per
        predicate, row ids in original table row space."""
        return self._segmented.query_many(preds, backend=backend,
                                          names=names, **backend_opts)
