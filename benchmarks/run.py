"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows plus PASS/FAIL validation of
the paper's claims.  ``--quick`` shrinks row counts (used by CI/tests).

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig2,table4]
"""

from __future__ import annotations

import argparse
import json
import os
import time


def dist_smoke() -> None:
    """Tiny multi-process serve-plane check for CI: spawns a real worker
    fleet, gates only on the noise-immune claims (bit-identity with the
    in-process engine, compressed-shipped < 0.2 of dense) — the
    throughput race gates in the full bench where the trend machinery
    can absorb runner noise."""
    from repro.data.tables import make_census_like

    from . import bench_fig6

    # 24k rows -> 3k-row segments: big enough that the 24-byte wire
    # header stops dominating the per-segment compressed payload
    rows = bench_fig6.run_distributed(make_census_like(24_000), queries=8,
                                      hosts=(2,))
    failed = False
    for r in rows:
        if r["hosts"] < 2:
            continue
        ok = r["agrees_with_local"] and r["compressed_to_dense"] < 0.2
        failed |= not ok
        print(f"dist-smoke hosts={r['hosts']}: "
              f"bit-identical={r['agrees_with_local']} "
              f"compressed/dense={r['compressed_to_dense']:.3f} "
              f"speedup={r['speedup_vs_one']:.2f}x "
              f"({r['cpus']:.0f} cpus): {'PASS' if ok else 'FAIL'}")
    raise SystemExit(1 if failed else 0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="results/benchmarks.json")
    ap.add_argument("--dist-smoke", action="store_true",
                    help="run only the multi-process serve-plane smoke "
                         "(bit-identity + wire-compression gates) and exit")
    args, _ = ap.parse_known_args()

    if args.dist_smoke:
        dist_smoke()

    from . import (bench_fig2, bench_fig3, bench_fig4, bench_fig6,
                   bench_moe_dispatch, bench_scaling, bench_table3,
                   bench_table4, bench_workload)

    suites = {
        "fig2_dirty_probability": bench_fig2,
        "fig3_column_gain": bench_fig3,
        "fig4_column_orderings": bench_fig4,
        "table3_percolumn_sort": bench_table3,
        "table4_index_sizes": bench_table4,
        "fig6_query_cost": bench_fig6,
        "scaling_prefix_growth": bench_scaling,
        "moe_dispatch_bitmaps": bench_moe_dispatch,
        "workload_replay": bench_workload,
    }
    if args.only:
        keys = [k for k in suites if any(s in k for s in args.only.split(","))]
        suites = {k: suites[k] for k in keys}

    all_results = {}
    all_checks = []
    print("name,us_per_call,derived")
    for name, mod in suites.items():
        t0 = time.perf_counter()
        rows = mod.run(quick=args.quick)
        dt = (time.perf_counter() - t0) * 1e6
        checks = mod.validate(rows)
        all_results[name] = {"rows": rows, "checks": checks}
        all_checks.extend(checks)
        derived = f"{len(rows)}rows/{sum('PASS' in c for c in checks)}pass"
        print(f"{name},{dt:.0f},{derived}")
        for c in checks:
            print(f"#   {c}")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(all_results, f, indent=1, default=str)
    n_fail = sum("FAIL" in c for c in all_checks)
    print(f"# total: {len(all_checks)} checks, {n_fail} failures")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
