"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows plus PASS/FAIL validation of
the paper's claims.  ``--quick`` shrinks row counts (used by CI/tests).

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig2,table4]
"""

from __future__ import annotations

import argparse
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="results/benchmarks.json")
    args, _ = ap.parse_known_args()

    from . import (bench_fig2, bench_fig3, bench_fig4, bench_fig6,
                   bench_moe_dispatch, bench_scaling, bench_table3,
                   bench_table4, bench_workload)

    suites = {
        "fig2_dirty_probability": bench_fig2,
        "fig3_column_gain": bench_fig3,
        "fig4_column_orderings": bench_fig4,
        "table3_percolumn_sort": bench_table3,
        "table4_index_sizes": bench_table4,
        "fig6_query_cost": bench_fig6,
        "scaling_prefix_growth": bench_scaling,
        "moe_dispatch_bitmaps": bench_moe_dispatch,
        "workload_replay": bench_workload,
    }
    if args.only:
        keys = [k for k in suites if any(s in k for s in args.only.split(","))]
        suites = {k: suites[k] for k in keys}

    all_results = {}
    all_checks = []
    print("name,us_per_call,derived")
    for name, mod in suites.items():
        t0 = time.perf_counter()
        rows = mod.run(quick=args.quick)
        dt = (time.perf_counter() - t0) * 1e6
        checks = mod.validate(rows)
        all_results[name] = {"rows": rows, "checks": checks}
        all_checks.extend(checks)
        derived = f"{len(rows)}rows/{sum('PASS' in c for c in checks)}pass"
        print(f"{name},{dt:.0f},{derived}")
        for c in checks:
            print(f"#   {c}")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(all_results, f, indent=1, default=str)
    n_fail = sum("FAIL" in c for c in all_checks)
    print(f"# total: {len(all_checks)} checks, {n_fail} failures")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
