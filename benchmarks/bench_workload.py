"""Workload-replay smoke: the cost model's re-encoding choice must track
the recorded query mix.

Untimed (no ``us_per_query`` rows — ``trend.py`` ignores the suite): two
synthetic workloads are replayed into a fresh ``WorkloadStats`` through
the real telemetry path (queries against a live ``SegmentedIndex``), and
``make_compaction_chooser`` must flip the column's encoding when the mix
flips from point lookups to wide ranges — roaring for the point mix
(Eq = one container fold, zero stream merges), a range-friendly encoding
(bit-sliced at this cardinality) for the range mix.  The timed version of
the same loop is ``bench_fig6``'s adaptive scenario; this suite is the
fast deterministic gate on the *decision*, not the wall clock.
"""

from __future__ import annotations

import numpy as np

from repro.core import Eq, IndexSpec, IndexWriter, Range
from repro.workload import WORKLOAD_STATS, make_compaction_chooser


def _replay(col, preds, queries_needed=48):
    """Build a two-segment writer over ``col``, replay ``preds`` against
    the live view until the global stats (the sink the telemetry wrappers
    feed) have enough samples, compact, and probe the chooser the
    compaction consulted.  Returns a result row sans the mix label."""
    spec = IndexSpec(k=1, row_order="lex", column_order="given",
                     encoding="auto")
    w = IndexWriter(spec, workload_stats=WORKLOAD_STATS)
    half = len(col) // 2
    w.append([col[:half]])
    w.seal()
    w.append([col[half:]])
    w.seal()
    view = w.index
    WORKLOAD_STATS.clear()
    while len(WORKLOAD_STATS) < queries_needed:
        view.query_many(preds, backend="numpy")
    merged = w.compact(span=(0, 2))
    chooser = make_compaction_chooser(WORKLOAD_STATS)
    row = {"chosen": merged.index.encodings()[0],
           "samples": len(WORKLOAD_STATS),
           "chooser_fitted": chooser is not None,
           "untracked_column_untouched":
               chooser is not None and chooser(5, None, 1) is None}
    WORKLOAD_STATS.clear()
    return row


def run(quick=False):
    n = 4_000 if quick else 12_000
    rng = np.random.default_rng(31)
    card = 300
    col = np.minimum((rng.random(n) ** 2.5 * card).astype(np.int64),
                     card - 1)
    card = int(col.max()) + 1
    width = max(2, int(card * 0.85))
    mixes = {
        "point": [Eq(0, int(v))
                  for v in rng.integers(0, card, size=16)],
        "range": [Range(0, int(lo), int(lo) + width - 1)
                  for lo in rng.integers(0, card - width + 1, size=16)],
    }
    out = []
    for mix, preds in mixes.items():
        out.append({"scenario": "workload-replay", "mix": mix,
                    **_replay(col, preds)})
    return out


def validate(rows):
    by_mix = {r["mix"]: r for r in rows}
    pt, rg = by_mix["point"], by_mix["range"]
    checks = [
        f"workload-replay: point mix re-encodes to roaring "
        f"(got {pt['chosen']}, {pt['samples']} samples): "
        f"{'PASS' if pt['chosen'] == 'roaring' else 'FAIL'}",
        f"workload-replay: chosen encoding flips when the mix flips "
        f"point->range ({pt['chosen']} -> {rg['chosen']}): "
        f"{'PASS' if rg['chosen'] != pt['chosen'] else 'FAIL'}",
        f"workload-replay: range mix picks a range-friendly encoding "
        f"(got {rg['chosen']}): "
        f"{'PASS' if rg['chosen'] in ('bitsliced', 'binned') else 'FAIL'}",
        f"workload-replay: chooser leaves untracked columns to the "
        f"static per-column choice: "
        f"{'PASS' if all(r['untracked_column_untouched'] for r in rows) else 'FAIL'}",
    ]
    return checks
