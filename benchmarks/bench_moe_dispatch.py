"""Integration benchmark: the paper's technique on MoE routing bitmaps.

Top-k routing over E experts = k-of-E bitmap encoding (DESIGN.md §4).
Measures EWAH-compressed size of the (tokens x experts) dispatch bitmap
index under three row orders — unsorted, expert-sorted (Alpha-Lex) and
Gray-Frequency — for the two assigned MoE architectures, plus the fused
Pallas moe_route kernel wall-clock (interpret mode on CPU).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ewah
from repro.kernels import ops
from repro.models.moe import grayfreq_token_order


def routed_assignments(T, E, k, skew=1.2, seed=0):
    """Realistic skewed routing: expert popularity ~ zipf + per-token noise."""
    rng = np.random.default_rng(seed)
    pop = (np.arange(1, E + 1) ** -skew)
    pop /= pop.sum()
    eids = np.stack(
        [rng.choice(E, size=k, replace=False, p=pop) for _ in range(T)])
    return eids.astype(np.int32)


def compressed_dispatch_size(eids, E, order=None):
    T, k = eids.shape
    if order is not None:
        eids = eids[order]
    words = np.asarray(ops.moe_route_bitmap(jnp.asarray(eids), E))  # (W, E)
    total = 0
    for e in range(E):
        total += len(ewah.compress(words[:, e]))
    return total


def run(quick=False):
    T = 4096 if quick else 16384
    out = []
    for name, E, k in (("qwen2-moe-a2.7b", 60, 4), ("olmoe-1b-7b", 64, 8)):
        eids = routed_assignments(T, E, k)
        je = jnp.asarray(eids)
        orders = {
            "unsorted": None,
            "expert_sorted": np.argsort(eids[:, 0], kind="stable"),
            "grayfreq": np.asarray(grayfreq_token_order(je, E)),
        }
        row = {"arch": name, "T": T, "E": E, "k": k}
        for oname, order in orders.items():
            row[f"words_{oname}"] = compressed_dispatch_size(eids, E, order)
        row["uncompressed_words"] = ((T + 31) // 32) * E
        # kernel timing (interpret mode — functional, not TPU wall-clock)
        t0 = time.perf_counter()
        ops.moe_route_bitmap(je, E).block_until_ready()
        row["kernel_us"] = (time.perf_counter() - t0) * 1e6
        out.append(row)
    return out


def validate(rows):
    checks = []
    for r in rows:
        ok = r["words_grayfreq"] < r["words_unsorted"]
        checks.append(
            f"{r['arch']}: Gray-Freq shrinks dispatch bitmaps "
            f"({r['words_grayfreq']} vs unsorted {r['words_unsorted']}): "
            f"{'PASS' if ok else 'FAIL'}")
        ok = r["words_grayfreq"] <= r["words_expert_sorted"]
        checks.append(
            f"{r['arch']}: Gray-Freq <= expert-sort "
            f"({r['words_grayfreq']} vs {r['words_expert_sorted']}): "
            f"{'PASS' if ok else 'FAIL'}")
    return checks
