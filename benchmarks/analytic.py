"""Analytic FLOP / byte models per (arch x shape) — roofline inputs.

XLA's cost_analysis counts while/scan bodies ONCE (verified in
EXPERIMENTS.md §Dry-run), so compiled-HLO flops understate layer-stacked
models by ~n_layers.  The matmul flop counts below use the same 2*m*n*k
convention as XLA's flop counter and are exact for the architectures we
define (we wrote every einsum); they are cross-checked against HLO flops
on a 1-layer config in tests/test_roofline.py.

Hardware constants (TPU v5e, per spec): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

from dataclasses import dataclass

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

# effective on-wire multiplier per collective (ring algorithms)
COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def _attn_flops(cfg, T, S_ctx, causal=True):
    """Per-token projections + score/value matmuls for T query tokens
    attending to S_ctx context (full materialized length)."""
    h, kv, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    proj = 2 * T * d * (h * hd) * 2          # q and o projections
    proj += 2 * T * d * (kv * hd) * 2        # k and v projections
    ctx = S_ctx / 2 if causal and T == S_ctx else S_ctx
    if cfg.sliding_window:
        ctx = min(ctx, cfg.sliding_window)
    sc = 2 * T * ctx * h * hd * 2            # QK^T and PV
    return proj + sc


def _mlp_flops(cfg, T):
    return 3 * 2 * T * cfg.d_model * cfg.d_ff


def _moe_flops(cfg, T):
    routed = 3 * 2 * T * cfg.top_k * cfg.d_model * cfg.moe_d_ff
    shared = 3 * 2 * T * cfg.d_model * cfg.shared_d_ff if cfg.n_shared_experts else 0
    router = 2 * T * cfg.d_model * cfg.n_experts
    return routed + shared + router


def _mamba_flops(cfg, T):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    g, N, nh = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    p = d_in // nh
    Q = cfg.ssm_chunk
    proj = 2 * T * d * (2 * d_in + 2 * g * N + nh) + 2 * T * d_in * d
    # SSD per chunk: scores (Q^2 g N) + y_diag (Q^2 h p) + 2 state matmuls
    nc = max(T // Q, 1)
    intra = 2 * nc * Q * Q * (g * N + nh * p)
    inter = 2 * 2 * nc * Q * nh * p * N
    return proj + intra + inter


def _layer_flops(cfg, T, S_ctx, kind):
    causal = kind != "decode"
    if cfg.family in ("ssm", "hybrid"):
        f = _mamba_flops(cfg, T)
        return f
    f = _attn_flops(cfg, T, S_ctx, causal)
    if cfg.family == "moe":
        f += _moe_flops(cfg, T)
    else:
        f += _mlp_flops(cfg, T)
    return f


def _shared_block_flops(cfg, T, S_ctx, kind):
    return _attn_flops(cfg, T, S_ctx, kind != "decode") + _mlp_flops(cfg, T)


def forward_flops(cfg, batch, seq, kind):
    """Whole-model forward FLOPs for the global batch."""
    T = batch * (1 if kind == "decode" else seq)
    S_ctx = seq
    f = cfg.n_layers * _layer_flops(cfg, T, S_ctx, kind)
    if cfg.family == "hybrid" and cfg.attn_every:
        f += (cfg.n_layers // cfg.attn_every) * _shared_block_flops(cfg, T, S_ctx, kind)
    f += 2 * T * cfg.d_model * cfg.padded_vocab  # lm head
    return f


def step_flops(cfg, batch, seq, kind, remat_policy="dots"):
    """Total executed FLOPs for the step (train = fwd + 2x bwd [+ remat])."""
    f = forward_flops(cfg, batch, seq, kind)
    if kind == "train":
        mult = 3.0 if remat_policy == "dots" else 4.0  # full remat refwd
        return f * mult
    return f


def model_flops(cfg, batch, seq, kind, n_params, n_active=None):
    """The spec's MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE)."""
    T = batch * (1 if kind == "decode" else seq)
    n = n_active if n_active is not None else n_params
    if kind == "train":
        return 6.0 * n * T
    return 2.0 * n * T


def active_params(cfg, n_params):
    """MoE: subtract the inactive routed-expert share."""
    if not cfg.n_experts:
        return n_params
    from repro.models.moe import padded_experts
    ep = padded_experts(cfg.n_experts)
    per_layer_routed = 3 * cfg.d_model * cfg.moe_d_ff
    routed_total = cfg.n_layers * ep * per_layer_routed
    active_routed = cfg.n_layers * cfg.top_k * per_layer_routed
    return n_params - routed_total + active_routed


def hbm_bytes(cfg, batch, seq, kind, n_params, n_chips, microbatches=1,
              tp=16):
    """Per-chip HBM traffic model for one step (napkin, documented).

    train : param read+write (2B each, TP+DP sharded) + Adam moments
            (f32 m,v read+write = 16B) + activation traffic: forward save
            + backward read of layer inputs (~6B/elem incl. recompute),
            activations sharded batch->DP and d_model->TP.
    decode: params once (2B, the classic decode bound) + KV cache r/w.
    prefill: params + one activation pass.
    """
    P = n_params / n_chips  # params are sharded over TP and ZeRO over DP
    dp = n_chips / tp
    T_dp = batch * (1 if kind == "decode" else seq) / dp
    act_layer_bytes = 6 * cfg.d_model / tp  # d_model split across TP
    if kind == "train":
        opt = 20 * P
        acts = 2 * T_dp * cfg.n_layers * act_layer_bytes
        return opt + acts
    if kind == "prefill":
        return 2 * P + T_dp * cfg.n_layers * act_layer_bytes
    # decode: params + cache traffic
    kvb = 0.0
    if cfg.n_kv_heads:
        slots = cfg.n_layers if cfg.family != "hybrid" else max(
            cfg.n_layers // max(cfg.attn_every, 1), 1)
        ctx = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
        # cache read per step (seq-sharded over TP), small write
        kvb = slots * 2 * (batch / dp) * ctx * cfg.n_kv_heads * cfg.head_dim * 2 / tp
    if cfg.ssm_state:
        hp = cfg.ssm_expand * cfg.d_model // cfg.ssm_heads
        kvb += 2 * cfg.n_layers * (batch / dp) * cfg.ssm_heads * hp * cfg.ssm_state * 4
    return 2 * P + kvb


def roofline_terms(cfg, batch, seq, kind, n_params, coll_bytes_by_op,
                   n_chips=256, remat_policy="dots", microbatches=1):
    """The three terms (seconds) from the spec, per step."""
    f = step_flops(cfg, batch, seq, kind, remat_policy)
    compute_s = f / (n_chips * PEAK_FLOPS)
    mem_s = hbm_bytes(cfg, batch, seq, kind, n_params, n_chips,
                      microbatches) / HBM_BW
    coll_bytes = sum(COLL_FACTOR.get(k, 1.0) * v
                     for k, v in coll_bytes_by_op.items())
    coll_s = coll_bytes / LINK_BW  # HLO bytes are already per-device shards
    return {"compute_s": compute_s, "memory_s": mem_s, "collective_s": coll_s}
