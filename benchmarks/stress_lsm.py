"""LSM ingest stress: sustained SegmentedAdmission traffic under a live
background compactor, failing on any dropped or duplicated row id.

The nightly job runs this for a couple of wall-clock minutes: request
waves stream through :class:`repro.launch.serve.SegmentedAdmission`
(append -> auto-seal) while the :class:`~repro.core.lifecycle
.BackgroundCompactor` merges and purges off-thread and a rolling
``retire()`` tombstones a slice of already-served requests.  After every
wave — racing the compactor on purpose — the full queue re-packs and the
emitted row ids are checked against the ground-truth live set: every
admitted-and-not-retired id exactly once, no ghosts, no duplicates, no
resurrections.  Query results racing a generation swap must come from the
old or the new segment list, never a mix; this is the end-to-end check of
that contract under real scheduling jitter.

  PYTHONPATH=src python -m benchmarks.stress_lsm [--seconds 120] [--seed 0]

``--workload`` runs the adaptive-re-encoding phase instead: an
``IndexWriter`` carrying ``workload_stats`` ingests skewed waves while a
background compactor merges — and *re-encodes* — segments toward the
recorded point-heavy query mix (docs/containers.md), racing live
``query_many`` traffic and rolling deletes.  Every wave diffs a census
query and sampled predicates against a dense numpy oracle: no dropped,
duplicated, or resurrected ids, no drift, even when a query lands mid
re-encode; at the end the converged column must have left the static
chooser's bit-sliced pick for a point-cheap encoding (``roaring``, or its
analytic-model tie ``equality`` at k=1).

Exit status 0 = clean; 1 = an id was dropped/duplicated (details printed).
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np


def check_pack(queue, batch_size, live_ids, wave):
    """Re-pack the whole queue and diff the emitted ids against the
    ground-truth live set.  Returns a list of problem strings."""
    batches = queue.pack(batch_size)
    got = (np.concatenate(batches) if batches
           else np.zeros(0, dtype=np.int64))
    problems = []
    uniq, counts = np.unique(got, return_counts=True)
    dups = uniq[counts > 1]
    if len(dups):
        problems.append(f"wave {wave}: {len(dups)} duplicated row ids "
                        f"(first: {dups[:5].tolist()})")
    want = np.asarray(sorted(live_ids), dtype=np.int64)
    missing = np.setdiff1d(want, uniq)
    if len(missing):
        problems.append(f"wave {wave}: {len(missing)} dropped row ids "
                        f"(first: {missing[:5].tolist()})")
    ghosts = np.setdiff1d(uniq, want)
    if len(ghosts):
        problems.append(f"wave {wave}: {len(ghosts)} retired/unknown ids "
                        f"resurfaced (first: {ghosts[:5].tolist()})")
    return problems


def run(seconds=120.0, seed=0, batch_size=16, wave_rows=96):
    # imported here so --sanitize can set REPRO_SANITIZE before the
    # admission queue's locks are created (instrumentation is decided at
    # lock construction)
    from repro.launch.serve import SegmentedAdmission

    rng = np.random.default_rng(seed)
    queue = SegmentedAdmission(seal_rows=64, compactor=True,
                               compact_interval=0.005)
    live: set = set()
    admitted = 0
    problems = []
    waves = 0
    deadline = time.time() + seconds
    try:
        while time.time() < deadline and not problems:
            waves += 1
            n = int(rng.integers(1, wave_rows))
            queue.admit(rng.integers(8, 96, size=n))
            live.update(range(admitted, admitted + n))
            admitted += n
            # retire a random slice of what's still live (served requests)
            if live and rng.integers(0, 2):
                victims = rng.choice(np.fromiter(live, dtype=np.int64),
                                     size=min(len(live), 24), replace=False)
                queue.retire(victims)
                live.difference_update(victims.tolist())
            problems = check_pack(queue, batch_size, live, waves)
    finally:
        # keep the live dict: close() drains remaining tiers into it
        compactor_stats = queue._compactor.stats if queue._compactor else {}
        queue.close()
    # post-drain: the compactor has merged everything it can; the queue
    # must still answer exactly
    problems += check_pack(queue, batch_size, live, "post-drain")
    stats = {"waves": waves, "admitted": admitted, "live": len(live),
             "segments": queue.n_segments, **compactor_stats}
    return problems, stats


def run_workload(seconds=60.0, seed=0, wave_rows=96):
    """The adaptive phase: a workload-stats-carrying writer under a live
    background compactor whose merges re-encode toward the observed mix,
    racing queries and deletes.  Returns ``(problems, stats)`` like
    :func:`run`."""
    # deferred like run(): --sanitize must set REPRO_SANITIZE first
    from repro.core import (BackgroundCompactor, Eq, IndexSpec, IndexWriter,
                            Range, evaluate_mask)
    from repro.workload import WORKLOAD_STATS

    rng = np.random.default_rng(seed)
    card = 300
    spec = IndexSpec(k=1, row_order="lex", column_order="given",
                     encoding="auto")
    w = IndexWriter(spec, seal_rows=64, workload_stats=WORKLOAD_STATS)
    WORKLOAD_STATS.clear()
    values = np.zeros(0, dtype=np.int64)   # every admitted row, ingest order
    alive = np.zeros(0, dtype=bool)
    problems = []
    waves = 0
    queries = 0
    deadline = time.time() + seconds
    with BackgroundCompactor(w, interval=0.005):
        while time.time() < deadline and not problems:
            waves += 1
            n = int(rng.integers(16, wave_rows))
            batch = np.minimum(
                (rng.random(n) ** 2.5 * card).astype(np.int64), card - 1)
            w.append([batch])
            values = np.concatenate([values, batch])
            alive = np.concatenate([alive, np.ones(n, dtype=bool)])
            if alive.any() and rng.integers(0, 2):
                live_ids = np.flatnonzero(alive)
                victims = rng.choice(live_ids,
                                     size=min(len(live_ids), 24),
                                     replace=False)
                w.delete(row_ids=victims)
                alive[victims] = False
            # point-heavy mix (so the chooser should converge on roaring)
            # with occasional ranges, racing the compactor on purpose
            preds = [Eq(0, int(v)) for v in rng.integers(0, card, size=6)]
            if waves % 4 == 0:
                lo = int(rng.integers(0, card // 2))
                preds.append(Range(0, lo, lo + card // 3))
            preds.append(Range(0, 0, card - 1))   # the full id census
            results = w.index.query_many(preds)
            queries += len(preds)
            for p, (got, _) in zip(preds, results):
                want = np.flatnonzero(evaluate_mask(p, [values]) & alive)
                if not np.array_equal(np.sort(got), want):
                    dup = len(got) - len(np.unique(got))
                    problems.append(
                        f"wave {waves}: {p!r} drifted from the dense "
                        f"oracle ({len(got)} rows vs {len(want)}, "
                        f"{dup} duplicated)")
    # converged: one explicit full-span compaction under the recorded mix
    # must land on a point-cheap encoding — the static auto rule picks
    # bitsliced at this cardinality, so leaving it proves the workload
    # model (not the histogram) chose.  roaring and equality tie on the
    # analytic model at k=1 (both answer Eq in zero stream merges), so
    # either proves the re-encode; the fitted lines break the tie.
    segs = w.segments
    merged = (w.compact(span=(0, len(segs))) if len(segs) >= 2
              else segs[0] if segs else None)
    encoding = merged.index.encodings()[0] if merged is not None else None
    samples = len(WORKLOAD_STATS)
    if not problems and samples >= 32 and encoding not in ("roaring",
                                                           "equality"):
        problems.append(
            f"workload: point-heavy mix ({samples} samples) compacted to "
            f"{encoding!r}, expected a point-cheap re-encode "
            f"(roaring/equality) instead of the static bitsliced choice")
    WORKLOAD_STATS.clear()
    stats = {"waves": waves, "admitted": len(values),
             "live": int(alive.sum()), "segments": len(w.segments),
             "queries": queries, "workload_samples": samples,
             "final_encoding": encoding}
    return problems, stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seconds", type=float, default=120.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--sanitize", action="store_true",
                    help="run with REPRO_SANITIZE=1: every pack result is "
                         "structurally validated and lock acquisition "
                         "order is checked for inversions")
    ap.add_argument("--workload", action="store_true",
                    help="run the adaptive-re-encoding phase: the "
                         "background compactor re-encodes segments toward "
                         "the live query mix while queries and deletes "
                         "race it")
    args = ap.parse_args(argv)
    if args.sanitize:
        os.environ["REPRO_SANITIZE"] = "1"
    if args.workload:
        problems, stats = run_workload(seconds=args.seconds, seed=args.seed)
    else:
        problems, stats = run(seconds=args.seconds, seed=args.seed,
                              batch_size=args.batch)
    print(f"stress_lsm: {stats}")
    if problems:
        for p in problems:
            print(f"FAIL {p}", file=sys.stderr)
        return 1
    print(f"PASS {stats['waves']} waves, {stats['admitted']} rows admitted, "
          f"{stats['live']} live, no dropped/duplicated ids")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
