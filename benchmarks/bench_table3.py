"""Table 3: per-column EWAH words after lexicographic sort, ordering the
10 columns by ascending (d1..d10) vs descending (d10..d1) cardinality."""

from __future__ import annotations

import numpy as np

from repro.core import IndexSpec
from repro.core.bitmap_index import index_size_report
from repro.data.tables import uniform_column, zipf_column


def make_10d(n=199_523, seed=0, kind="census"):
    rng = np.random.default_rng(seed)
    if kind == "census":
        cards = [7, 8, 10, 47, 51, 91, 113, 132, 1240, min(99_800, n // 2)]
        return [zipf_column(n, c, 0.9, rng) for c in cards], cards
    cards = [2, 3, 7, 9, 11, 50, 2526, 20_000,
             min(400_000, n // 3), min(984_297, n // 2)]
    return [uniform_column(n, c, rng) for c in cards], cards


def run(n=199_523, quick=False):
    if quick:
        n = 50_000
    out = []
    for kind in ("census", "dbgen"):
        cols, cards = make_10d(n, kind=kind)
        asc = index_size_report(cols, IndexSpec(
            k=1, row_order="lex", column_order=tuple(range(10))))
        desc = index_size_report(cols, IndexSpec(
            k=1, row_order="lex", column_order=tuple(range(9, -1, -1))))
        uns = index_size_report(cols, IndexSpec(
            k=1, row_order="unsorted", column_order=tuple(range(10))))
        out.append({
            "dataset": kind, "cards": cards,
            "unsorted_words": uns["total_words"],
            "asc_words": asc["total_words"],
            "desc_words": desc["total_words"],
            "asc_per_column": asc["per_column_words"],
            "desc_per_column": desc["per_column_words"],
        })
    return out


def validate(rows):
    """Paper: sorting from the smallest column benefits 5+ columns; from the
    largest, at most ~3; both beat unsorted in total."""
    checks = []
    for r in rows:
        # how many columns shrank vs unsorted baseline per-column? compare
        # first columns of ascending sort: early columns must be tiny
        asc = r["asc_per_column"]
        ok = asc[0] < asc[-1] / 10
        checks.append(f"{r['dataset']}: asc first column {asc[0]} << last "
                      f"{asc[-1]}: {'PASS' if ok else 'FAIL'}")
        better = r["asc_words"] < r["unsorted_words"]
        checks.append(f"{r['dataset']}: sorted < unsorted: "
                      f"{'PASS' if better else 'FAIL'}")
    return checks
