"""Table 4: EWAH index sizes (words) — unsorted vs Gray-Lex vs Gray-Frequency
(+ Frequent-Component) for k = 1..4, on the four dataset profiles."""

from __future__ import annotations

from repro.core import IndexSpec
from repro.core.bitmap_index import index_size_report
from repro.data.tables import (make_census_like, make_dbgen_like,
                               make_kjv4grams_like, make_netflix_like)


def run(quick=False):
    scale = 0.2 if quick else 1.0
    datasets = {
        "census": make_census_like(int(199_523 * scale)),
        "dbgen": make_dbgen_like(int(1_000_000 * scale)),
        "netflix": make_netflix_like(int(1_500_000 * scale)),
        "kjv4grams": make_kjv4grams_like(int(3_000_000 * scale)),
    }
    methods = {
        "unsorted": dict(row_order="unsorted", code_order="gray"),
        "graylex": dict(row_order="lex", code_order="gray"),
        "grayfreq": dict(row_order="grayfreq", code_order="gray",
                         value_policy="freq"),
        "freqcomp": dict(row_order="freqcomp", code_order="gray"),
    }
    # paper: dims largest-to-smallest ("4321") except census "3214"
    out = []
    ks = (1, 2) if quick else (1, 2, 3, 4)
    for name, cols in datasets.items():
        order = [2, 1, 0, 3] if name == "census" else [3, 2, 1, 0]
        order = [i for i in order if i < len(cols)]
        for k in ks:
            row = {"dataset": name, "k": k}
            for mname, kw in methods.items():
                rep = index_size_report(cols, IndexSpec(
                    k=k, column_order=tuple(order), **kw))
                row[mname] = rep["total_words"]
            out.append(row)
    return out


def validate(rows):
    """Paper claims: sorting shrinks indexes (9x on KJV at k=1);
    Gray-Frequency <= Gray-Lex, with 10-30% extra gain for k>1."""
    checks = []
    for r in rows:
        ok = r["graylex"] <= r["unsorted"]
        checks.append(f"{r['dataset']} k={r['k']}: Gray-Lex <= unsorted "
                      f"({r['graylex']:.3g} vs {r['unsorted']:.3g}): "
                      f"{'PASS' if ok else 'FAIL'}")
        # 3% slack: our synthetic KJV-like pool has near-uniform within-pool
        # column histograms, where frequency clustering adds ~nothing (the
        # paper's 10-30% k>1 gains show on the skewed census/netflix tables)
        ok = r["grayfreq"] <= r["graylex"] * 1.03
        checks.append(f"{r['dataset']} k={r['k']}: Gray-Freq <= Gray-Lex "
                      f"({r['grayfreq']:.3g} vs {r['graylex']:.3g}): "
                      f"{'PASS' if ok else 'FAIL'}")
    kjv1 = [r for r in rows if r["dataset"] == "kjv4grams" and r["k"] == 1]
    if kjv1:
        ratio = kjv1[0]["unsorted"] / kjv1[0]["graylex"]
        checks.append(f"KJV-like k=1 sort gain {ratio:.1f}x (paper ~9x): "
                      f"{'PASS' if ratio > 3 else 'FAIL'}")
    return checks
