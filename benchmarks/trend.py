"""Benchmark trend gate: fail when ``us_per_query`` regresses against the
committed baseline.

CI runs this right after ``benchmarks.run --quick``::

  PYTHONPATH=src python -m benchmarks.trend \
      --baseline benchmarks/BENCH_baseline.json \
      --current results/benchmarks.json

A row regresses when its ``us_per_query`` exceeds the baseline's by more
than ``--tolerance`` (default 0.25, override with the
``BENCH_TREND_TOLERANCE`` env var) *and* by more than ``--abs-floor``
microseconds (absolute damping so ~10us timings don't flap on scheduler
jitter).  Three layers keep wall-clock noise from failing unrelated
commits while a genuine regression still trips every layer:

1. **machine-factor normalization** — the committed baseline is seeded on
   one machine and CI runners are another, so timings first normalize by
   the median current/baseline ratio across all matched rows: a uniformly
   slower runner cancels out (``--no-normalize`` compares raw);
2. **windowed min-of-N timing** at the producer (`bench_fig6._best_of`
   grows each timed window to >= 50ms);
3. **confirmation re-runs** — suspected regressions re-run *only their
   suites* (``--confirm``, default 2) and a row fails only when it
   regresses in every pass.  Scheduler phantoms (this container shows
   per-row swings up to 2x) don't reproduce twice; a real slowdown does.

Rows are matched by suite + their non-volatile fields (k, sort, column,
backend, scenario, ...); measurements (``us_per_query``,
``words_scanned``, ``cache_hit_rate``) and validation flags never
participate in identity.  Rows new to the current run are informational;
rows missing from it warn but do not fail.

``--update`` rewrites the baseline (how it advances after an accepted
perf change): it re-runs the timed suites until it holds ``--update-reps``
samples per row (the current results count as one) and writes the
*per-row median* — a single run's rows carry up to +-30% sampling bias
that would then "regress" forever, so one-shot copying is deliberately
not offered.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

VOLATILE = {"us_per_query", "words_scanned", "cache_hit_rate",
            "agrees_with_numpy", "agrees_with_dense",
            "agrees_with_equality", "agrees_with_per_stage",
            "agrees_with_dense_oracle", "agrees_with_local"}


def row_identity(suite: str, row: dict):
    return (suite, tuple(sorted(
        (k, v) for k, v in row.items()
        if k not in VOLATILE and isinstance(v, (str, int, bool)))))


def collect(results: dict) -> dict:
    """results json -> {identity: mean us_per_query} (rows without a
    us_per_query measurement don't participate in the gate)."""
    acc: dict = {}
    for suite, payload in results.items():
        for row in payload.get("rows", []):
            if not isinstance(row, dict) or "us_per_query" not in row:
                continue
            acc.setdefault(row_identity(suite, row), []).append(
                float(row["us_per_query"]))
    return {k: sum(v) / len(v) for k, v in acc.items()}


def fmt(ident) -> str:
    suite, fields = ident
    return f"{suite}[" + ",".join(f"{k}={v}" for k, v in fields) + "]"


def find_regressions(base: dict, cur: dict, tolerance: float,
                     abs_floor: float, normalize: bool,
                     factor: float | None = None):
    """-> (regressions [(ident, adj_baseline, current)], factor,
    improvements).  Pass an explicit ``factor`` to skip re-deriving the
    machine factor — the confirmation pass must reuse the main pass's
    fleet-wide factor, because re-deriving it from only the suspect
    suites' rows would normalize a genuine uniform regression away (the
    median ratio of a uniformly-2x-slower suite IS the regression)."""
    matched = sorted(set(base) & set(cur))
    if factor is None:
        factor = 1.0
        if matched and normalize:
            ratios = sorted(cur[i] / base[i] for i in matched if base[i] > 0)
            factor = ratios[len(ratios) // 2]
    regressions = []
    improvements = 0
    for ident in matched:
        b_adj = base[ident] * factor  # baseline at this machine's speed
        c = cur[ident]
        if c > b_adj * (1 + tolerance) and c - b_adj > abs_floor:
            regressions.append((ident, b_adj, c))
        elif c < b_adj:
            improvements += 1
    return regressions, factor, improvements


def roofline_lines(results: dict) -> list[str]:
    """Informational wall-clock-vs-roofline column: one line per current
    row that carries roofline data (the bench_fig6 fusion scenario).
    The hard within-2x gate lives in the producer's ``validate``; this
    surfaces the margin in the trend report so drift toward the bound is
    visible before it fails."""
    lines = []
    for suite, payload in results.items():
        for row in payload.get("rows", []):
            if not isinstance(row, dict) or "roofline_us" not in row:
                continue
            cell = "/".join(str(row[k]) for k in ("scenario", "bucket",
                                                  "stages") if k in row)
            lines.append(
                f"# roofline {suite}[{cell}]: fused eval "
                f"{row['fused_eval_us']:.2f}us vs bound "
                f"{row['roofline_us']:.2f}us = {row['roofline_ratio']:.2f}x "
                f"(pallas launch {row['fused_kernel_us']:.2f}us, "
                f"end-to-end {row['us_per_query']:.0f}us)")
    return lines


def rerun_suites(suites) -> dict:
    """Re-run only the named benchmark suites; return their fresh
    row measurements (the confirmation pass)."""
    import subprocess
    import tempfile

    out = os.path.join(tempfile.mkdtemp(prefix="bench_confirm"),
                       "benchmarks.json")
    cmd = [sys.executable, "-m", "benchmarks.run", "--quick",
           "--only", ",".join(sorted(suites)), "--out", out]
    print(f"# confirming {len(suites)} suite(s): {' '.join(cmd)}")
    proc = subprocess.run(cmd, capture_output=True, text=True)
    if proc.returncode != 0 and not os.path.exists(out):
        print(proc.stdout[-2000:])
        print(proc.stderr[-2000:])
        raise SystemExit(f"confirmation re-run failed: {cmd}")
    with open(out) as f:
        return collect(json.load(f))


def update_baseline(current: str, baseline: str, reps: int) -> None:
    """Write ``baseline`` with every timed row at its per-row median over
    ``reps`` samples (the current results file plus fresh suite re-runs)."""
    with open(current) as f:
        data = json.load(f)
    timed = [(suite, row) for suite, payload in data.items()
             for row in payload.get("rows", [])
             if isinstance(row, dict) and "us_per_query" in row]
    samples: dict = {}
    for suite, row in timed:
        samples.setdefault(row_identity(suite, row), []).append(
            float(row["us_per_query"]))
    for _ in range(max(0, reps - 1)):
        for ident, v in rerun_suites({s for s, _ in timed}).items():
            samples.setdefault(ident, []).append(v)
    for suite, row in timed:
        vals = sorted(samples[row_identity(suite, row)])
        row["us_per_query"] = vals[len(vals) // 2]
    with open(baseline, "w") as f:
        json.dump(data, f, indent=1, default=str)
    print(f"baseline {baseline} reseeded: {len(timed)} timed rows at "
          f"per-row median of {reps} samples")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="benchmarks/BENCH_baseline.json")
    ap.add_argument("--current", default="results/benchmarks.json")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("BENCH_TREND_TOLERANCE",
                                                 0.25)))
    ap.add_argument("--abs-floor", type=float, default=5.0,
                    help="ignore regressions smaller than this many us")
    ap.add_argument("--no-normalize", action="store_true",
                    help="skip the median machine-factor normalization")
    ap.add_argument("--confirm", type=int, default=2,
                    help="re-run suspect suites this many times; a row "
                         "fails only if it regresses in every pass (0 = "
                         "gate on the single sample)")
    ap.add_argument("--update", action="store_true",
                    help="reseed the baseline: per-row median of "
                         "--update-reps samples (current results + fresh "
                         "re-runs of the timed suites)")
    ap.add_argument("--update-reps", type=int, default=3)
    args = ap.parse_args()

    if args.update:
        update_baseline(args.current, args.baseline, args.update_reps)
        return

    with open(args.baseline) as f:
        base = collect(json.load(f))
    with open(args.current) as f:
        cur_raw = json.load(f)
    cur = collect(cur_raw)

    for line in roofline_lines(cur_raw):
        print(line)
    normalize = not args.no_normalize
    for ident in sorted(set(base) - set(cur)):
        print(f"# WARN row gone from current run: {fmt(ident)}")
    for ident in sorted(set(cur) - set(base)):
        print(f"# new row (no baseline yet): {fmt(ident)}")

    regressions, factor, improvements = find_regressions(
        base, cur, args.tolerance, args.abs_floor, normalize)

    confirms = 0
    while regressions and confirms < args.confirm:
        confirms += 1
        suspects = {ident for ident, _, _ in regressions}
        fresh = rerun_suites({ident[0] for ident in suspects})
        confirmed, cfactor, _ = find_regressions(
            {i: b for i, b in base.items() if i in fresh},
            fresh, args.tolerance, args.abs_floor, normalize, factor=factor)
        still = {ident for ident, _, _ in confirmed} & suspects
        for ident, b, c in regressions:
            if ident not in still:
                print(f"# not reproduced on confirm pass {confirms} "
                      f"(factor {cfactor:.2f}x): {fmt(ident)}")
        regressions = [r for r in regressions if r[0] in still]

    for ident, b, c in regressions:
        print(f"REGRESSION {fmt(ident)}: {b:.1f}us -> {c:.1f}us "
              f"(+{(c / b - 1):.0%}, tolerance {args.tolerance:.0%}, "
              f"reproduced on {confirms} confirm pass(es))")
    print(f"# trend: {len(base)} baseline rows, {len(regressions)} "
          f"regressions, {improvements} improvements "
          f"(machine factor {factor:.2f}x, tolerance {args.tolerance:.0%}, "
          f"floor {args.abs_floor}us, confirm {args.confirm})")
    if regressions:
        sys.exit(1)


if __name__ == "__main__":
    main()
